"""Paper Figure 2: runtime / throughput / energy-per-token vs OUTPUT tokens
(8..4096, input fixed at 32, batch 32, KV cache disabled — §5.1.2)."""

from __future__ import annotations

from benchmarks.common import emit, pow2_range, timed
from repro.configs import PAPER_ZOO
from repro.energy import AnalyticLLMSimulator

FIXED_IN = 32


def run(models=None) -> dict:
    models = models or sorted(PAPER_ZOO)
    curves: dict = {}
    for name in models:
        sim = AnalyticLLMSimulator(PAPER_ZOO[name], kv_cache=False, seed=2)
        pts = []
        for tout in pow2_range(8, 4096):
            us, (e, r) = timed(lambda s=sim, t=tout: s.measure(FIXED_IN, t),
                               repeats=1)
            tokens = (FIXED_IN + tout) * sim.batch
            pts.append({
                "tau_out": tout, "runtime_s": r, "energy_j": e,
                "throughput_tok_s": tokens / r,
                "energy_per_token_j": e / tokens,
                "us_per_call": us,
            })
        curves[name] = pts
        first, last = pts[0], pts[-1]
        emit(f"fig2.{name}", sum(p["us_per_call"] for p in pts) / len(pts),
             f"runtime {first['runtime_s']:.2f}->{last['runtime_s']:.1f}s "
             f"J/tok {first['energy_per_token_j']:.3f}->{last['energy_per_token_j']:.3f}")
    return curves


def main() -> None:
    curves = run()
    for name, pts in curves.items():
        # steep runtime increase with tau_out; throughput decreases;
        # energy/token increases (no KV cache -> superlinear recompute)
        assert pts[-1]["runtime_s"] > pts[0]["runtime_s"] * 10, name
        assert pts[-1]["throughput_tok_s"] < pts[0]["throughput_tok_s"], name
        assert pts[-1]["energy_per_token_j"] > pts[0]["energy_per_token_j"], name
    mix = curves["mixtral-8x7b"][-1]["energy_per_token_j"]
    l70 = curves["llama2-70b"][-1]["energy_per_token_j"]
    emit("fig2.smoe_efficiency", 0.0,
         f"mixtral {mix:.3f} < llama2-70b {l70:.3f} J/tok at 4096 out: {mix < l70}")


if __name__ == "__main__":
    main()
