"""Paper Table 1: the hosted-LLM fleet — params, vRAM, minimum accelerator
count (A100-40GB as in the paper, plus the v5e target), leaderboard A_K."""

from __future__ import annotations

from benchmarks.common import emit, timed
from repro.configs import PAPER_ZOO, TABLE1
from repro.energy import A100_40GB, TPU_V5E, min_accelerators
from repro.models import get_api


def run() -> list[dict]:
    rows = []
    for name, cfg in sorted(PAPER_ZOO.items()):
        api = get_api(cfg)
        us, n_params = timed(lambda c=cfg, a=api: a.count_params(c))
        pbytes = n_params * 2
        row = {
            "model": name,
            "params_b": n_params / 1e9,
            "vram_gb": pbytes / 1e9,
            "n_a100": min_accelerators(pbytes, A100_40GB),
            "n_v5e": min_accelerators(pbytes, TPU_V5E),
            "paper_n_a100": TABLE1[name]["n_a100"],
            "a_k": TABLE1[name]["a_k"],
        }
        rows.append(row)
        emit(f"table1.{name}", us,
             f"params={row['params_b']:.2f}B vram={row['vram_gb']:.1f}GB "
             f"a100={row['n_a100']}(paper {row['paper_n_a100']}) "
             f"v5e={row['n_v5e']} A_K={row['a_k']}")
    return rows


def main() -> None:
    rows = run()
    match = sum(r["n_a100"] == r["paper_n_a100"] for r in rows)
    emit("table1.match_rate", 0.0, f"{match}/{len(rows)} chip counts match paper")


if __name__ == "__main__":
    main()
