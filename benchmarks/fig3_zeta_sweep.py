"""Paper Figure 3 / §6.3 case study: offline energy-optimal routing of 500
Alpaca-like queries across the Llama-2 {7B, 13B, 70B} fleet with data-center
partition gamma = (0.05, 0.2, 0.75), swept over zeta, vs the baselines
(single-model, round-robin, random).

Claims reproduced: energy and runtime decrease monotonically as zeta -> 1;
accuracy trades off; the zeta-scheduler dominates round-robin/random on the
combined objective at every zeta."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.configs import CASE_STUDY_GAMMA, CASE_STUDY_MODELS, PAPER_ZOO, TABLE1
from repro.core import scheduler
from repro.core.characterize import (
    CampaignSettings,
    fit_profile_from_trials,
    run_campaign,
)
from repro.data import alpaca_like_workload
from repro.energy import AnalyticLLMSimulator

SETTINGS = CampaignSettings(grid_range=(8, 2048), max_trials=2, min_trials=2,
                            vary_input_range=(8, 8), vary_output_range=(8, 8),
                            seed=9)

ZETAS = np.round(np.linspace(0.0, 1.0, 11), 2)


def fit_fleet():
    profiles = []
    for name in CASE_STUDY_MODELS:
        sim = AnalyticLLMSimulator(PAPER_ZOO[name], kv_cache=False, seed=13)
        # per-query costs: batch-normalized measurements (the scheduler
        # assigns individual queries)
        trials = run_campaign(name, sim.measure_per_query, SETTINGS)
        profiles.append(fit_profile_from_trials(name, TABLE1[name]["a_k"], trials))
    return profiles


def run():
    profiles = fit_fleet()
    queries = alpaca_like_workload()
    # the paper's Eq. 2-5 objective (coverage + non-empty shares only):
    sweep = scheduler.zeta_sweep(profiles, queries, ZETAS)
    # deployment variant: gamma-capacitated partition (exactly binding when
    # sum(gamma) = 1 — counts are then fixed by gamma and only the query
    # MIX per model moves with zeta)
    capped = scheduler.zeta_sweep(profiles, queries, [0.0, 0.5, 1.0],
                                  gamma=CASE_STUDY_GAMMA)
    baselines = {
        "round_robin": scheduler.schedule_round_robin(profiles, queries),
        "random": scheduler.schedule_random(profiles, queries, seed=4),
        **{f"only_{p.name}": scheduler.schedule_single_model(profiles, queries, i)
           for i, p in enumerate(profiles)},
    }
    return profiles, queries, sweep, capped, baselines


def main() -> None:
    us, (profiles, queries, sweep, capped, baselines) = timed(run, repeats=1)
    m = len(queries)
    for z, asg in zip(ZETAS, sweep):
        emit(f"fig3.zeta_{z:.1f}", us / len(ZETAS),
             f"E={asg.total_energy_j:.0f}J runtime/query={asg.total_runtime_s/m:.3f}s "
             f"mean_A_K={asg.mean_accuracy_ak:.2f} counts={asg.counts().tolist()}")
    for z, asg in zip([0.0, 0.5, 1.0], capped):
        emit(f"fig3.gamma_capped_zeta_{z:.1f}", 0.0,
             f"E={asg.total_energy_j:.0f}J counts={asg.counts().tolist()} "
             f"(gamma={list(CASE_STUDY_GAMMA)})")
    for name, asg in baselines.items():
        emit(f"fig3.baseline_{name}", 0.0,
             f"E={asg.total_energy_j:.0f}J runtime/query={asg.total_runtime_s/m:.3f}s "
             f"mean_A_K={asg.mean_accuracy_ak:.2f}")

    energies = [a.total_energy_j for a in sweep]
    runtimes = [a.total_runtime_s for a in sweep]
    mono_e = all(b <= a + 1e-6 for a, b in zip(energies, energies[1:]))
    mono_r = all(b <= a + 1e-6 for a, b in zip(runtimes, runtimes[1:]))
    acc_tradeoff = sweep[0].mean_accuracy_ak >= sweep[-1].mean_accuracy_ak
    # savings of the zeta=1 point vs the accuracy-first baselines
    rr = baselines["round_robin"].total_energy_j
    save_rr = 1.0 - energies[-1] / rr
    big = baselines["only_llama2-70b"].total_energy_j
    save_big = 1.0 - energies[-1] / big
    emit("fig3.claims", 0.0,
         f"energy_monotone={mono_e} runtime_monotone={mono_r} "
         f"accuracy_tradeoff={acc_tradeoff} "
         f"energy_saving_vs_round_robin={save_rr:.1%} vs_70B-only={save_big:.1%}")


if __name__ == "__main__":
    main()
