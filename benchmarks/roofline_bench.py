"""Roofline table from the dry-run campaign (deliverable g) + a real
CPU-executed micro-benchmark of one reduced serve_step per arch family."""

from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import emit, timed

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun"


def load_records(mesh: str = "pod") -> list[dict]:
    recs = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        if r.get("status") == "ok":
            recs.append(r)
    return recs


def main() -> None:
    recs = load_records("pod")
    if not recs:
        emit("roofline.missing", 0.0,
             "no dry-run results — run python -m repro.launch.dryrun first")
        return
    for r in recs:
        t = r["roofline"]
        mem = r["memory_analysis"]
        emit(f"roofline.{r['arch']}.{r['shape']}", r.get("t_compile_s", 0) * 1e6,
             f"dom={t['dominant']} compute={t['compute_s']*1e3:.2f}ms "
             f"memory={t['memory_s']*1e3:.2f}ms collective={t['collective_s']*1e3:.2f}ms "
             f"useful_flops={t['useful_flops_ratio']:.2f} "
             f"mem/dev={mem['peak_bytes_per_device_tpu']/1e9:.2f}GB")
    doms = {}
    for r in recs:
        doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
    emit("roofline.summary", 0.0,
         f"{len(recs)} baselines, dominant terms: {doms}")

    # real-execution micro-bench: one reduced decode step per family
    from repro.configs import get_config
    from repro.models import get_api
    for arch in ("qwen3-1.7b", "mamba2-130m", "granite-moe-3b-a800m",
                 "recurrentgemma-9b"):
        cfg = get_config(arch + "-reduced")
        api = get_api(cfg)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        cache = api.init_cache(cfg, 2, 64)
        tok = jax.numpy.zeros((2,), jax.numpy.int32)
        step = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, {"token": t}))
        step(params, cache, tok)  # compile
        us, _ = timed(lambda: jax.block_until_ready(step(params, cache, tok)),
                      repeats=10)
        emit(f"roofline.cpu_decode_step.{arch}", us, "reduced config, CPU")


if __name__ == "__main__":
    main()
