"""Beyond-paper "Figure 4": the offline→online optimality gap.

The paper's scheduler is offline — it partitions a fully-known workload.
This benchmark streams the same Alpaca-like workload into the cluster
simulator at several arrival rates and compares every online routing
policy against the offline oracle (core.scheduler.schedule replayed over
the full trace) on the Eq. 2 objective, total/predicted energy, latency,
and SLO attainment.

Guarantee checked here: the oracle is never worse than any online policy
on the Eq. 2 objective (at ζ=1 the objective *is* normalized predicted
energy, so the energy bound holds there too).  What the oracle does NOT
bound is congestion — the latency columns show online load-aware policies
beating it at high arrival rates, which is exactly the gap this subsystem
exists to measure.

    PYTHONPATH=src:. python benchmarks/fig4_online_gap.py
"""

from __future__ import annotations

from benchmarks.common import emit, timed
from repro.cluster import (
    ClusterNode,
    GreedyEnergyPolicy,
    LeastLoadedPolicy,
    OfflineOraclePolicy,
    RandomPolicy,
    RoundRobinPolicy,
    ZetaOnlinePolicy,
    compare_policies,
    replay_trace,
)
from repro.configs import CASE_STUDY_MODELS, PAPER_ZOO, TABLE1
from repro.core.energy_model import LLMProfile, fit_profile
from repro.data import WorkloadSpec, alpaca_like_workload
from repro.energy import AnalyticLLMSimulator, SWING_NODE

N_REQUESTS = 200
RATES_QPS = (0.5, 2.0, 8.0)
ZETAS = (0.5, 1.0)
MAX_BATCH = 8

# (τin, τout) probe grid for fitting Eq. 6/7 profiles off the simulator
FIT_POINTS = [(8, 8), (64, 64), (256, 128), (1024, 256), (32, 512),
              (512, 512), (128, 32), (2048, 64), (2048, 1024)]


def fit_fleet() -> list[LLMProfile]:
    """Bilinear e_K/r_K profiles for the case-study fleet, fit against the
    same analytic simulator the cluster nodes integrate with."""
    profiles = []
    for name in CASE_STUDY_MODELS:
        sim = AnalyticLLMSimulator(PAPER_ZOO[name], SWING_NODE, batch=1,
                                   kv_cache=True, noise_sigma=0.0)
        tin = [p[0] for p in FIT_POINTS]
        tout = [p[1] for p in FIT_POINTS]
        pbs = [sim.simulate(a, b) for a, b in FIT_POINTS]
        profiles.append(fit_profile(
            name, TABLE1[name]["a_k"], tin, tout,
            [pb.energy_j for pb in pbs], [pb.runtime_s for pb in pbs]))
    return profiles


def node_builders(profiles):
    return [
        (lambda i=i, name=name, prof=prof: ClusterNode(
            i, PAPER_ZOO[name], prof, SWING_NODE, max_batch=MAX_BATCH))
        for i, (name, prof) in enumerate(zip(CASE_STUDY_MODELS, profiles))
    ]


def make_policies():
    return [RoundRobinPolicy(), RandomPolicy(seed=0), LeastLoadedPolicy(),
            GreedyEnergyPolicy(), ZetaOnlinePolicy(), OfflineOraclePolicy()]


def run():
    profiles = fit_fleet()
    builders = node_builders(profiles)
    queries = alpaca_like_workload(WorkloadSpec(n_queries=N_REQUESTS, seed=7))
    results = {}
    for rate in RATES_QPS:
        trace = replay_trace(queries, rate, seed=11,
                             name=f"alpaca@{rate:g}qps")
        for zeta in ZETAS:
            results[(rate, zeta)] = compare_policies(
                trace, builders, make_policies(), zeta=zeta)
    return results


def main() -> None:
    us, results = timed(run, repeats=1)
    n_cells = len(results)
    for (rate, zeta), reports in sorted(results.items()):
        oracle = reports["offline_oracle"]
        print(f"\n=== rate={rate:g} qps, zeta={zeta:g} "
              f"(n={N_REQUESTS}, fleet={list(CASE_STUDY_MODELS)}) ===")
        for name, rep in reports.items():
            print(rep.summary())
        for name, rep in reports.items():
            assert oracle.objective <= rep.objective + 1e-9, \
                f"oracle beaten on objective by {name} at rate={rate} zeta={zeta}"
            if zeta == 1.0:
                assert oracle.predicted_energy_j <= rep.predicted_energy_j + 1e-6, \
                    f"oracle beaten on energy by {name} at zeta=1"
        worst = max(r.objective for n, r in reports.items()
                    if n != "offline_oracle")
        best_online = min(r.objective for n, r in reports.items()
                          if n != "offline_oracle")
        emit(f"fig4.rate_{rate:g}_zeta_{zeta:g}", us / n_cells,
             f"oracle_obj={oracle.objective:+.3f} "
             f"best_online_obj={best_online:+.3f} "
             f"worst_online_obj={worst:+.3f} "
             f"gap_best={best_online - oracle.objective:.4f} "
             f"oracle_E={oracle.total_energy_j:.0f}J "
             f"oracle_p95={oracle.latency_p95:.2f}s")
    emit("fig4.claims", 0.0,
         "oracle_never_worse_on_objective=True "
         "energy_bound_at_zeta1=True")


if __name__ == "__main__":
    main()
