"""Beyond-paper "Figure 4": the offline→online optimality gap, split.

The paper's scheduler is offline — it partitions a fully-known workload.
This benchmark streams the same Alpaca-like workload into the cluster
simulator at several arrival rates and measures, on top of the PR 1
policy table, the three levers PR 4 added:

  * the **commitment gap** (oracle-τout online router vs the offline
    oracle replay) separated from the **information gap** (τout-predictor
    router vs the same router with oracle τout) — previously conflated;
  * **node power-gating** under a reactive autoscaler: idle-energy
    reduction at low arrival rates, with SLO attainment reported next to
    it (the joules are bought with wake latency, and both sides of that
    trade are printed);
  * **per-phase DVFS**: decode segments underclock, prefills mostly
    don't; governed total energy must be ≤ the fixed-frequency run on
    every (rate, ζ) cell — asserted, since scale 1.0 is always in the
    governor's candidate set;
  * **availability under faults** (cell g, `--availability-only`): a
    replicated fleet under seeded crashes and stragglers across an MTTF
    sweep — FailoverPolicy rescue (cross-node KV migration, retry,
    straggler draining) vs the failure-aware oracle replay on the same
    realized fault trace, with a live InvariantAuditor holding the
    six-bucket energy partition to 1e-9.  Asserted: the oracle bound,
    the exact partition, and ≥90% goodput recovery at MTTF = 10× mean
    service time.
  * **correlated blast radius** (cell h, `--blast-radius`): a 2-rack
    fleet under alternating whole-rack outages, swept over blast radius
    × prefill-checkpoint interval.  A survivability-blind stack piles
    its awake replicas into one rack and reruns lost prefills from
    scratch; the hardened stack (DomainSpreadPolicy anti-affinity +
    SurvivabilityAutoscalePolicy availability floor + checkpointed
    prefills) keeps warm capacity outside every blast radius.
    Asserted: naive loses >50% goodput at full radius, hardened keeps
    ≥90% at every checkpoint interval, the domain-masked failure-aware
    oracle bound, and the seven-bucket partition to 1e-9.
  * **multi-turn sessions + KV prefix cache** (cell i, `--sessions`):
    conversational traffic (session depth × cache capacity sweep) under
    session-sticky routing.  A warm turn re-prefills only its uncached
    suffix (the exact telescoping difference) plus a closed-form
    cache-read DMA term — the eighth `cache_read` energy bucket.
    Asserted: the eight-bucket partition to 1e-9 under a live
    InvariantAuditor, the cache-aware oracle bound on every realized
    hit sequence, and ≥25% prefill-energy reduction at session depth 8
    with ample capacity.

Guarantee checked here (unchanged from PR 1, same oracle replay): the
oracle is never worse than any online policy on the Eq. 2 objective (at
ζ=1 the objective *is* normalized predicted energy, so the energy bound
holds there too).  What the oracle does NOT bound is congestion — the
latency columns show online load-aware policies beating it at high
arrival rates, which is exactly the gap this subsystem exists to measure.

    PYTHONPATH=src:. python benchmarks/fig4_online_gap.py
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.common import emit, timed
from repro.cluster import (
    CacheAwareOraclePolicy,
    CheckpointConfig,
    ClusterNode,
    DomainSpreadPolicy,
    FailoverPolicy,
    FailureAwareOraclePolicy,
    FaultEvent,
    FaultInjector,
    FaultTrace,
    GreedyEnergyPolicy,
    LeastLoadedPolicy,
    OfflineOraclePolicy,
    PowerConfig,
    PrefixCacheConfig,
    RandomPolicy,
    ReactiveIdlePolicy,
    ReplicaEnergyPolicy,
    ReplicaOraclePolicy,
    ReplicaRatePolicy,
    RoundRobinPolicy,
    SLOPreemptionPolicy,
    SessionAffinityPolicy,
    SurvivabilityAutoscalePolicy,
    TauOutPredictor,
    ZetaOnlinePolicy,
    compare_policies,
    fresh_nodes,
    objective_of_assignment,
    rack_pdu_topology,
    realized_cache_hits,
    replay_trace,
    session_trace,
    simulate_cluster,
)
from repro.cluster.faults import CRASH, RECOVER
from repro.configs import CASE_STUDY_MODELS, PAPER_ZOO, TABLE1
from repro.core.energy_model import LLMProfile, fit_profile
from repro.data import WorkloadSpec, alpaca_like_workload
from repro.energy import (
    AnalyticLLMSimulator,
    SWING_NODE,
    TPU_NODE,
    kv_bytes_per_token,
)
from repro.obs import EventTracer, InvariantAuditor, Telemetry

REPO_ROOT = Path(__file__).resolve().parents[1]

N_REQUESTS = 200
RATES_QPS = (0.5, 2.0, 8.0)
POWER_RATES_QPS = (0.5, 2.0)      # where the gating/DVFS/predictor cells run
ZETAS = (0.5, 1.0)
MAX_BATCH = 8
IDLE_TIMEOUT_S = 30.0

# (τin, τout) probe grid for fitting Eq. 6/7 profiles off the simulator
FIT_POINTS = [(8, 8), (64, 64), (256, 128), (1024, 256), (32, 512),
              (512, 512), (128, 32), (2048, 64), (2048, 1024)]


def fit_fleet() -> list[LLMProfile]:
    """Bilinear e_K/r_K profiles for the case-study fleet, fit against the
    same analytic simulator the cluster nodes integrate with."""
    profiles = []
    for name in CASE_STUDY_MODELS:
        sim = AnalyticLLMSimulator(PAPER_ZOO[name], SWING_NODE, batch=1,
                                   kv_cache=True, noise_sigma=0.0)
        tin = [p[0] for p in FIT_POINTS]
        tout = [p[1] for p in FIT_POINTS]
        pbs = [sim.simulate(a, b) for a, b in FIT_POINTS]
        profiles.append(fit_profile(
            name, TABLE1[name]["a_k"], tin, tout,
            [pb.energy_j for pb in pbs], [pb.runtime_s for pb in pbs]))
    return profiles


def node_builders(profiles, *, dvfs: str = "off"):
    return [
        (lambda i=i, name=name, prof=prof: ClusterNode(
            i, PAPER_ZOO[name], prof, SWING_NODE, max_batch=MAX_BATCH,
            dvfs=dvfs))
        for i, (name, prof) in enumerate(zip(CASE_STUDY_MODELS, profiles))
    ]


def make_policies():
    return [RoundRobinPolicy(), RandomPolicy(seed=0), LeastLoadedPolicy(),
            GreedyEnergyPolicy(), ZetaOnlinePolicy(), OfflineOraclePolicy()]


def make_trace(rate):
    queries = alpaca_like_workload(WorkloadSpec(n_queries=N_REQUESTS, seed=7))
    return replay_trace(queries, rate, seed=11, name=f"alpaca@{rate:g}qps")


def run(profiles=None):
    if profiles is None:
        profiles = fit_fleet()
    builders = node_builders(profiles)
    results = {}
    for rate in RATES_QPS:
        trace = make_trace(rate)
        for zeta in ZETAS:
            results[(rate, zeta)] = compare_policies(
                trace, builders, make_policies(), zeta=zeta)
    return results


def power_cells(profiles):
    """(a) power-gating and (b) per-phase DVFS, per arrival rate."""
    fixed = node_builders(profiles)
    governed = node_builders(profiles, dvfs="per_phase")
    out = {}
    for rate in POWER_RATES_QPS:
        trace = make_trace(rate)
        base = simulate_cluster(trace, fresh_nodes(fixed),
                                ZetaOnlinePolicy(), zeta=0.5)
        gated = simulate_cluster(
            trace, fresh_nodes(fixed), ZetaOnlinePolicy(), zeta=0.5,
            autoscaler=ReactiveIdlePolicy(idle_timeout_s=IDLE_TIMEOUT_S))
        dvfs = simulate_cluster(trace, fresh_nodes(governed),
                                ZetaOnlinePolicy(), zeta=0.5)
        both = simulate_cluster(
            trace, fresh_nodes(governed), ZetaOnlinePolicy(), zeta=0.5,
            autoscaler=ReactiveIdlePolicy(idle_timeout_s=IDLE_TIMEOUT_S))
        out[rate] = {"base": base, "gated": gated, "dvfs": dvfs,
                     "both": both}
    return out


def replica_node_builders(profiles, *, replicas=2, max_batch=MAX_BATCH):
    """`replicas` nodes per case-study model (the multi-replica fleet)."""
    return [
        (lambda nid=len(CASE_STUDY_MODELS) * r + i, name=name, prof=prof:
         ClusterNode(nid, PAPER_ZOO[name], prof, SWING_NODE,
                     max_batch=max_batch))
        for r in range(replicas)
        for i, (name, prof) in enumerate(zip(CASE_STUDY_MODELS, profiles))
    ]


def replica_cells(profiles):
    """(d) multi-replica serving with decode-boundary preemption: the
    replica-set router and the replica-aware oracle replay, preemption
    enabled for every policy (identical preempter per run)."""
    builders = replica_node_builders(profiles, replicas=2, max_batch=4)
    out = {}
    for rate in (2.0, 8.0):
        trace = make_trace(rate)
        out[rate] = compare_policies(
            trace, builders,
            [LeastLoadedPolicy(), ZetaOnlinePolicy(), ReplicaEnergyPolicy(),
             ReplicaOraclePolicy()],
            zeta=0.5,
            preempter_builder=lambda: SLOPreemptionPolicy(slowdown_slo=2.0),
        )
    return out


def replica_power_cells(profiles):
    """(e) per-model replica autoscaling: the wake-cost-aware replica
    router over a gated 2-replica fleet vs power-blind zeta_online."""
    builders = replica_node_builders(profiles, replicas=2, max_batch=4)
    out = {}
    for rate in POWER_RATES_QPS:
        trace = make_trace(rate)
        cell = {}
        for tag, pol in (("zeta_online", ZetaOnlinePolicy()),
                         ("replica_energy", ReplicaEnergyPolicy())):
            cell[tag] = simulate_cluster(
                trace, fresh_nodes(builders), pol, zeta=0.5,
                autoscaler=ReplicaRatePolicy(idle_timeout_s=IDLE_TIMEOUT_S))
        out[rate] = cell
    return out


def predictor_cells(profiles):
    """(c) the information gap, separated from the commitment gap."""
    builders = node_builders(profiles)
    out = {}
    for rate in POWER_RATES_QPS:
        trace = make_trace(rate)
        cell = compare_policies(
            trace, builders,
            [ZetaOnlinePolicy(),
             ZetaOnlinePolicy(tau_out_predictor=TauOutPredictor()),
             OfflineOraclePolicy()],
            zeta=0.5)
        out[rate] = cell
    return out


def telemetry_cell(profiles):
    """Full telemetry on one seeded fig4 cell (the governed fleet with a
    predictor router, autoscaler and preempter at 2 qps): asserts the
    instrumented report is byte-identical to the uninstrumented one,
    audits every settlement live at 1e-9, and dumps the Prometheus text
    and Chrome trace artifacts next to BENCH_core.json."""
    builders = node_builders(profiles, dvfs="per_phase")
    trace = make_trace(2.0)

    def cell(telemetry=None):
        return simulate_cluster(
            trace, fresh_nodes(builders),
            ZetaOnlinePolicy(tau_out_predictor=TauOutPredictor()), zeta=0.5,
            autoscaler=ReactiveIdlePolicy(idle_timeout_s=IDLE_TIMEOUT_S),
            preempter=SLOPreemptionPolicy(slowdown_slo=2.0),
            telemetry=telemetry)

    bare = cell()
    tel = Telemetry(tracer=EventTracer(), auditor=InvariantAuditor(),
                    sample_every_s=5.0)
    instrumented = cell(tel)   # InvariantViolation here fails the benchmark
    assert (bare.to_json(include_records=True)
            == instrumented.to_json(include_records=True)), \
        "telemetry-on fig4 cell diverged from telemetry-off"
    rebuilt = type(instrumented).from_registry(tel.registry)
    assert abs(rebuilt.total_energy_j - instrumented.total_energy_j) < 1e-6
    prom_path = REPO_ROOT / "BENCH_fig4_telemetry.prom"
    prom_path.write_text(tel.prometheus_text())
    trace_path = tel.tracer.write(REPO_ROOT / "BENCH_fig4_trace.json")
    return tel, instrumented, prom_path, trace_path


AVAIL_FLEET = ("llama2-7b", "llama2-7b", "llama2-13b")
AVAIL_N = 120
AVAIL_RATE_QPS = 2.0
AVAIL_MTTF_MULTS = (5.0, 10.0, 50.0)   # × mean isolated service time


def availability_cells(profiles):
    """(g) the availability axis: a 3-node fleet (two llama2-7b replicas
    + one llama2-13b) under seeded crashes and stragglers, swept over
    node MTTF expressed as a multiple of the fleet's mean isolated
    service time.  Per MTTF point: FailoverPolicy rescue (with a live
    InvariantAuditor — every settlement, waste booking and KV shipment
    checked at 1e-9) vs the no-fault baseline vs the failure-aware
    oracle replay on the *same realized fault trace*.  Asserted here:
    the six-bucket energy partition is exact, the failure-aware oracle
    is never worse than any online policy on the Eq. 2 objective, and
    at MTTF = 10× mean service time the failover stack recovers ≥90%
    of the no-fault goodput."""
    by_name = {p.name: p for p in profiles}
    builders = [
        (lambda i=i, name=name: ClusterNode(
            i, PAPER_ZOO[name], by_name[name], SWING_NODE, max_batch=4))
        for i, name in enumerate(AVAIL_FLEET)
    ]
    queries = alpaca_like_workload(WorkloadSpec(n_queries=AVAIL_N, seed=7))
    trace = replay_trace(queries, AVAIL_RATE_QPS, seed=11,
                         name=f"alpaca@{AVAIL_RATE_QPS:g}qps")

    base = simulate_cluster(trace, fresh_nodes(builders),
                            FailoverPolicy(ZetaOnlinePolicy()), zeta=0.5)
    assert not base.abandoned
    mean_service_s = (sum(r.isolated_runtime_s for r in base.records)
                      / len(base.records))

    out = {"base": base, "mean_service_s": mean_service_s, "cells": {}}
    for mult in AVAIL_MTTF_MULTS:
        mttf = mult * mean_service_s
        faults = FaultInjector(
            mttf_s=mttf, mttr_s=2.0 * mean_service_s,
            straggle_mttf_s=mttf, straggle_mttr_s=2.0 * mean_service_s,
            slowdown_range=(1.5, 2.5), seed=13,
        ).generate(range(len(AVAIL_FLEET)), trace.duration_s)

        tel = Telemetry(auditor=InvariantAuditor())
        failover = simulate_cluster(
            trace, fresh_nodes(builders), FailoverPolicy(ZetaOnlinePolicy()),
            zeta=0.5, faults=faults, telemetry=tel)
        naive = simulate_cluster(
            trace, fresh_nodes(builders),
            FailoverPolicy(LeastLoadedPolicy()), zeta=0.5, faults=faults)
        oracle = simulate_cluster(
            trace, fresh_nodes(builders), FailureAwareOraclePolicy(faults),
            zeta=0.5, faults=faults)

        for tag, rep in (("failover", failover), ("least_loaded", naive),
                         ("oracle", oracle)):
            buckets = rep.energy_breakdown()
            residual = abs(sum(buckets.values()) - rep.total_energy_j)
            assert residual <= 1e-9 * max(1.0, rep.total_energy_j), \
                f"six-bucket partition leaked {residual} J ({tag}, {mult}x)"
        for tag, rep in (("failover", failover), ("least_loaded", naive)):
            if len(rep.records) == len(oracle.records):
                assert oracle.objective <= rep.objective + 1e-9, \
                    f"failure-aware oracle beaten by {tag} at MTTF {mult}x"
        out["cells"][mult] = {
            "mttf_s": mttf, "n_faults": len(faults),
            "failover": failover, "least_loaded": naive, "oracle": oracle,
            "auditor_checks": tel.auditor.n_checks,
        }
    recovery = (out["cells"][10.0]["failover"].goodput()
                / max(base.goodput(), 1e-12))
    assert recovery >= 0.9, \
        f"failover recovered only {recovery:.1%} of no-fault goodput"
    out["recovery_at_10x"] = recovery
    return out


def run_availability(profiles, cell_dumps):
    print("\n=== availability under faults (2x llama2-7b + llama2-13b, "
          f"{AVAIL_RATE_QPS:g} qps) ===")
    avail = availability_cells(profiles)
    base = avail["base"]
    cell_dumps["availability.base"] = base.to_dict()
    print(f"  no-fault baseline: goodput={base.goodput():5.1%} "
          f"E={base.total_energy_j:9.0f}J "
          f"(mean service {avail['mean_service_s']:.2f}s)")
    for mult, cell in sorted(avail["cells"].items()):
        for tag in ("failover", "least_loaded", "oracle"):
            rep = cell[tag]
            cell_dumps[f"availability.mttf_{mult:g}x.{tag}"] = rep.to_dict()
            print(f"  mttf={mult:4g}x {tag:>12s}: "
                  f"goodput={rep.goodput():5.1%} "
                  f"obj={rep.objective:+.4f} "
                  f"E={rep.total_energy_j:9.0f}J "
                  f"(wasted={rep.total_wasted_energy_j:6.0f} "
                  f"ship={rep.total_shipping_energy_j:4.1f}) "
                  f"crash={rep.total_crashes} "
                  f"migr={rep.total_migrations} "
                  f"aband={len(rep.abandoned)}")
        fo = cell["failover"]
        emit(f"fig4.availability_mttf_{mult:g}x", 0.0,
             f"n_faults={cell['n_faults']} "
             f"goodput_failover={fo.goodput():.4f} "
             f"goodput_oracle={cell['oracle'].goodput():.4f} "
             f"crashes={fo.total_crashes} "
             f"migrations={fo.total_migrations} "
             f"wasted_j={fo.total_wasted_energy_j:.1f} "
             f"auditor_checks={cell['auditor_checks']} "
             f"partition_exact=True oracle_bound_holds=True")
    print(f"  goodput recovery at mttf=10x: {avail['recovery_at_10x']:.1%}")
    emit("fig4.availability", 0.0,
         f"recovery_at_10x={avail['recovery_at_10x']:.4f} "
         f"recovery_geq_0.9=True "
         f"baseline_goodput={base.goodput():.4f}")
    avail_path = REPO_ROOT / "BENCH_fig4_availability.json"
    avail_path.write_text(json.dumps(
        {k: v for k, v in cell_dumps.items()
         if k.startswith("availability.")},
        sort_keys=True, indent=1))
    print(f"  wrote availability cells -> {avail_path.name}")


# Heterogeneous 2-rack fleet, all hosting llama2-7b: rack 0 holds the
# energy-efficient A100 (SWING) replicas, rack 1 the pricier TPU-v5e
# standbys.  Every energy-aware router therefore *structurally* packs
# work — and the demand autoscaler its awake set — into rack 0: the
# efficient rack IS a correlated failure domain, which is exactly the
# blast-radius hazard this cell measures.
BLAST_HARDWARE = (SWING_NODE, SWING_NODE, TPU_NODE, TPU_NODE)
BLAST_N = 300
BLAST_RATE_QPS = 2.0
BLAST_SLO_SLOWDOWN = 3.0
BLAST_CKPT_INTERVALS = (128, 512)      # tokens between durable KV cuts
BLAST_MTTF_S = 30.0                    # what the autoscaler is told
BLAST_MTTR_S = 25.0
# prefill-heavy alpaca variant: exp(5.8) ~ 330-token prompts, so a rack
# crash actually lands mid-prefill and the checkpoint interval matters
BLAST_SPEC = WorkloadSpec(n_queries=BLAST_N, in_log_mean=5.8,
                          in_log_sigma=0.8, seed=7)
# a deliberately cold wake (weights re-resident from disk): the window a
# survivability-blind awake set goes dark for after every blow
BLAST_POWER = PowerConfig(wake_s=30.0)
# repeated outages of the efficient rack as (rack, start, end) fractions
# of the nominal span — each blow lands after the idle timer has
# re-gated the previously woken standbys, so a survivability-blind
# awake set is cold every single time
BLAST_WINDOWS = ((0, 0.06, 0.26), (0, 0.43, 0.63), (0, 0.80, 0.99))

_BLAST_PROFILES: dict = {}


def blast_profile(hw):
    """llama2-7b fit against `hw` — one Eq. 6/7 cost model per rack
    flavor, so routing predictions see the real heterogeneity."""
    key = "swing" if hw is SWING_NODE else "tpu"
    if key not in _BLAST_PROFILES:
        sim = AnalyticLLMSimulator(PAPER_ZOO["llama2-7b"], hw, batch=1,
                                   kv_cache=True, noise_sigma=0.0)
        pbs = [sim.simulate(a, b) for a, b in FIT_POINTS]
        _BLAST_PROFILES[key] = fit_profile(
            "llama2-7b", TABLE1["llama2-7b"]["a_k"],
            [p[0] for p in FIT_POINTS], [p[1] for p in FIT_POINTS],
            [pb.energy_j for pb in pbs], [pb.runtime_s for pb in pbs])
    return _BLAST_PROFILES[key]


def blast_storm(duration_s: float, rack_size: int) -> FaultTrace:
    """Correlated storm for the blast-radius cell: at each window the
    first `rack_size` nodes of the efficient rack crash simultaneously
    and recover together — rack_size=1 degenerates to independent
    single-node faults on the same schedule (the blast-radius control)."""
    racks = rack_pdu_topology(range(len(BLAST_HARDWARE)),
                              rack_size=2).groups()
    events = []
    for rack, f0, f1 in BLAST_WINDOWS:
        for nid in racks[rack][:rack_size]:
            events.append(FaultEvent(f0 * duration_s, nid, CRASH))
            events.append(FaultEvent(f1 * duration_s, nid, RECOVER))
    events.sort(key=lambda ev: (ev.time_s, ev.node_id))
    domains = tuple(r[:rack_size] for r in racks) + tuple(
        (n,) for r in racks for n in r[rack_size:])
    return FaultTrace(f"blast@rack_size={rack_size}", tuple(events),
                      domains=domains)


def blast_builders(*, interval=None):
    ck = (None if interval is None
          else CheckpointConfig(interval_tokens=interval))
    return [
        (lambda i=i, hw=hw, ck=ck: ClusterNode(
            i, PAPER_ZOO["llama2-7b"], blast_profile(hw), hw, max_batch=4,
            power=BLAST_POWER, checkpoint=ck))
        for i, hw in enumerate(BLAST_HARDWARE)
    ]


def seven_bucket_residual(rep) -> float:
    buckets = rep.energy_breakdown()
    return abs(sum(buckets.values()) - rep.total_energy_j) \
        / max(1.0, rep.total_energy_j)


def blast_radius_cells():
    """(h) the blast-radius axis: a heterogeneous 2-rack fleet (two
    efficient A100 replicas, two TPU-v5e standbys, one model) under
    repeated efficient-rack outages, swept over blast radius
    (rack_size 1 vs 2) x checkpoint interval.

    The *naive* stack (wake-cost-aware energy router + idle-timeout
    gating over a two-node fleet floor, no checkpointing) packs both
    awake replicas into the efficient rack — N+1 redundancy inside one
    failure domain — so every correlated blow leaves zero warm
    capacity: a cold `wake_s` restart plus a from-scratch prefill
    rerun.  The *hardened*
    stack (DomainSpreadPolicy anti-affinity routing +
    SurvivabilityAutoscalePolicy holding one awake replica per fault
    domain + prefill checkpointing) pays the pricier rack's joules to
    keep warm capacity outside every blast radius, and restarts lost
    prefills from their last durable boundary.  Asserted at full blast
    radius (rack_size=2): the naive stack loses >50% of the no-fault
    goodput, the hardened stack keeps >=90% at every checkpoint
    interval, the failure-aware oracle replay (domain-masked capacity)
    is never beaten on the Eq. 2 objective, and the seven-bucket energy
    partition closes to 1e-9 on every run under a live
    InvariantAuditor."""
    queries = alpaca_like_workload(BLAST_SPEC)
    trace = replay_trace(queries, BLAST_RATE_QPS, seed=11,
                         name=f"alpaca-long@{BLAST_RATE_QPS:g}qps")
    span = BLAST_N / BLAST_RATE_QPS

    def goodput(rep):
        return rep.goodput(slowdown=BLAST_SLO_SLOWDOWN)

    def naive_stack():
        return dict(
            policy=FailoverPolicy(ReplicaEnergyPolicy()),
            autoscaler=ReactiveIdlePolicy(idle_timeout_s=4.0,
                                          min_awake=2))

    base = simulate_cluster(trace, fresh_nodes(blast_builders()),
                            zeta=0.5, **naive_stack())
    assert not base.abandoned
    out = {"base": base, "cells": {}}
    for rack_size in (1, 2):
        storm = blast_storm(span, rack_size)
        cell = {"naive": None, "hardened": {}, "oracle": None,
                "n_faults": len(storm)}
        tel = Telemetry(auditor=InvariantAuditor())
        cell["naive"] = simulate_cluster(
            trace, fresh_nodes(blast_builders()), zeta=0.5,
            faults=storm, telemetry=tel, **naive_stack())
        cell["auditor_checks"] = tel.auditor.n_checks
        for interval in BLAST_CKPT_INTERVALS:
            htel = Telemetry(auditor=InvariantAuditor())
            cell["hardened"][interval] = simulate_cluster(
                trace,
                fresh_nodes(blast_builders(interval=interval)),
                FailoverPolicy(DomainSpreadPolicy(storm.domains)),
                zeta=0.5,
                autoscaler=SurvivabilityAutoscalePolicy(
                    BLAST_MTTF_S, BLAST_MTTR_S, domains=storm.domains,
                    target_util=1.0, min_awake_per_model=2,
                    idle_timeout_s=4.0),
                faults=storm, telemetry=htel)
            cell["auditor_checks"] += htel.auditor.n_checks
        cell["oracle"] = simulate_cluster(
            trace, fresh_nodes(blast_builders()),
            FailureAwareOraclePolicy(storm, domains=storm.domains),
            zeta=0.5, faults=storm)
        reps = [("naive", cell["naive"]), ("oracle", cell["oracle"])] + [
            (f"hardened_ckpt{iv}", r) for iv, r in cell["hardened"].items()]
        for tag, rep in reps:
            assert seven_bucket_residual(rep) <= 1e-9, \
                f"seven-bucket partition leaked ({tag}, rack_size={rack_size})"
            if tag != "oracle" \
                    and len(rep.records) == len(cell["oracle"].records):
                assert cell["oracle"].objective <= rep.objective + 1e-9, \
                    f"failure-aware oracle beaten by {tag} " \
                    f"(rack_size={rack_size})"
        out["cells"][rack_size] = cell

    full = out["cells"][2]
    base_g = max(goodput(base), 1e-12)
    naive_loss = 1.0 - goodput(full["naive"]) / base_g
    assert naive_loss > 0.5, \
        f"naive stack lost only {naive_loss:.1%} at full blast radius"
    recoveries = {iv: goodput(rep) / base_g
                  for iv, rep in full["hardened"].items()}
    for iv, rec in recoveries.items():
        assert rec >= 0.9, \
            f"hardened stack recovered only {rec:.1%} (ckpt interval {iv})"
        assert full["hardened"][iv].total_checkpoints > 0
    out["naive_loss_at_full_radius"] = naive_loss
    out["recoveries"] = recoveries
    return out


def run_blast_radius(cell_dumps):
    print(f"\n=== blast radius (efficient A100 rack + TPU standby rack, "
          f"{BLAST_RATE_QPS:g} qps, SLO {BLAST_SLO_SLOWDOWN:g}x) ===")
    blast = blast_radius_cells()
    base = blast["base"]

    def goodput(rep):
        return rep.goodput(slowdown=BLAST_SLO_SLOWDOWN)

    cell_dumps["blast_radius.base"] = base.to_dict()
    print(f"  no-fault baseline: goodput={goodput(base):5.1%} "
          f"E={base.total_energy_j:9.0f}J")
    for rack_size, cell in sorted(blast["cells"].items()):
        reps = [("naive", cell["naive"]), ("oracle", cell["oracle"])] + [
            (f"hardened_ckpt{iv}", r) for iv, r in cell["hardened"].items()]
        for tag, rep in reps:
            cell_dumps[f"blast_radius.rack_{rack_size}.{tag}"] = rep.to_dict()
            print(f"  rack_size={rack_size} {tag:>16s}: "
                  f"goodput={goodput(rep):5.1%} "
                  f"E={rep.total_energy_j:9.0f}J "
                  f"(wasted={rep.total_wasted_energy_j:6.1f} "
                  f"ckpt={rep.total_checkpoint_energy_j:6.3f}) "
                  f"crash={rep.total_crashes} "
                  f"ckpts={rep.total_checkpoints} "
                  f"restores={rep.total_restores} "
                  f"aband={len(rep.abandoned)}")
        emit(f"fig4.blast_radius_rack_{rack_size}", 0.0,
             f"n_faults={cell['n_faults']} "
             f"goodput_naive={goodput(cell['naive']):.4f} "
             f"goodput_oracle={goodput(cell['oracle']):.4f} "
             f"auditor_checks={cell['auditor_checks']} "
             f"partition_exact=True oracle_bound_holds=True")
    print(f"  naive goodput loss at full radius: "
          f"{blast['naive_loss_at_full_radius']:.1%}")
    for iv, rec in sorted(blast["recoveries"].items()):
        print(f"  hardened recovery (ckpt interval {iv}): {rec:.1%}")
    emit("fig4.blast_radius", 0.0,
         f"naive_loss={blast['naive_loss_at_full_radius']:.4f} "
         f"naive_loss_gt_0.5=True "
         + " ".join(f"recovery_ckpt{iv}={rec:.4f}"
                    for iv, rec in sorted(blast["recoveries"].items()))
         + " recovery_geq_0.9=True")
    blast_path = REPO_ROOT / "BENCH_fig4_blast_radius.json"
    blast_path.write_text(json.dumps(
        {k: v for k, v in cell_dumps.items()
         if k.startswith("blast_radius.")},
        sort_keys=True, indent=1))
    print(f"  wrote blast-radius cells -> {blast_path.name}")


# --- (i): multi-turn sessions + the KV prefix cache -----------------------
SESSION_N = 40                 # concurrent conversations
SESSION_RATE_QPS = 0.4         # session *starts* per second
SESSION_THINK_S = 10.0
SESSION_DEPTHS = (2, 8)        # turns per session
SESSION_MIN_PREFILL_CUT = 0.25   # acceptance floor at depth 8, ample cache
# "small" holds ~1.5k tokens of KV per node: a couple of warm sessions,
# so 40 concurrent ones churn the LRU hard
SESSION_SMALL_TOKENS = 1500


def session_builders(profiles, cache):
    return [
        (lambda i=i, name=name, prof=prof: ClusterNode(
            i, PAPER_ZOO[name], prof, SWING_NODE, max_batch=MAX_BATCH,
            prefix_cache=cache))
        for i, (name, prof) in enumerate(zip(CASE_STUDY_MODELS, profiles))
    ]


def session_cache_points():
    small = SESSION_SMALL_TOKENS * kv_bytes_per_token(PAPER_ZOO["llama2-13b"])
    return (("disabled", None),
            ("small", PrefixCacheConfig(capacity_bytes=small)),
            ("ample", PrefixCacheConfig()))


def prefill_energy_cut(rep):
    """Realized prefill-energy reduction, closed form: every request's
    prompt prices at the canonical batch-1 prefill_cost on the node that
    served it; a warm request skipped exactly prefill_cost(cached) of
    that (the telescoping identity the node charges by).  Returns
    (cold_j, saved_j, saved_j / cold_j)."""
    sims = {name: AnalyticLLMSimulator(PAPER_ZOO[name], SWING_NODE, batch=1,
                                       kv_cache=True, noise_sigma=0.0)
            for name in CASE_STUDY_MODELS}
    cold = saved = 0.0
    for r in rep.records:
        sim = sims[r.model]
        cold += sim.prefill_cost(r.tau_in, batch=1, freq_scale=1.0)[1]
        if r.cached_tokens:
            saved += sim.prefill_cost(r.cached_tokens, batch=1,
                                      freq_scale=1.0)[1]
    return cold, saved, saved / max(cold, 1e-12)


def assert_session_oracle_bound(profiles, trace, rep, cache, tag):
    """The cache-aware oracle replay, conditioned on the hit sequence
    `rep` actually realized, is never worse than `rep`'s own assignment
    when both are scored under the same discounted cost matrix."""
    cached = realized_cache_hits(rep.records)
    cvec = [cached.get(r.request_id, 0) for r in trace.requests]
    model_of = {r.request_id: r.model for r in rep.records}
    online_obj = objective_of_assignment(
        profiles, trace.queries(),
        [model_of[r.request_id] for r in trace.requests], 0.5, cached=cvec)
    orep = simulate_cluster(
        trace, fresh_nodes(session_builders(profiles, cache)),
        CacheAwareOraclePolicy(cached), zeta=0.5)
    omodel = {r.request_id: r.model for r in orep.records}
    oracle_obj = objective_of_assignment(
        profiles, trace.queries(),
        [omodel[r.request_id] for r in trace.requests], 0.5, cached=cvec)
    assert oracle_obj <= online_obj + 1e-9, \
        f"cache-aware oracle beaten at {tag}"
    return oracle_obj, online_obj, orep


def session_cells(profiles):
    """(i) the conversational axis: session depth x cache capacity.
    Asserted on every run: the eight-bucket partition to 1e-9 under a
    live InvariantAuditor (which re-derives each warm charge from the
    telescoping identity and the cache-read closed form), the
    cache-aware oracle bound on the realized hit sequence, and — at
    depth 8 with ample capacity — >=25% prefill-energy reduction over
    the cache-disabled run."""
    out = {}
    for depth in SESSION_DEPTHS:
        trace = session_trace(SESSION_N, turns=depth,
                              think_s=SESSION_THINK_S,
                              rate_qps=SESSION_RATE_QPS, seed=17,
                              name=f"sessions@depth{depth}")
        cell = {}
        for tag, cache in session_cache_points():
            tel = Telemetry(auditor=InvariantAuditor())
            rep = simulate_cluster(
                trace, fresh_nodes(session_builders(profiles, cache)),
                SessionAffinityPolicy(), zeta=0.5, telemetry=tel)
            assert len(rep.records) == len(trace)
            assert seven_bucket_residual(rep) <= 1e-9, \
                f"energy partition leaked (depth={depth}, {tag})"
            cold, saved, cut = prefill_energy_cut(rep)
            entry = {"report": rep, "auditor_checks": tel.auditor.n_checks,
                     "prefill_cold_j": cold, "prefill_saved_j": saved,
                     "prefill_cut": cut}
            if cache is not None:
                oracle_obj, online_obj, orep = assert_session_oracle_bound(
                    profiles, trace, rep, cache, f"depth={depth}, {tag}")
                entry.update(oracle_obj=oracle_obj, online_obj=online_obj,
                             oracle_report=orep)
            else:
                assert rep.total_cache_hits == 0 and cut == 0.0
            cell[tag] = entry
        assert cell["small"]["report"].total_cache_evictions > 0, \
            f"small capacity never evicted at depth {depth}"
        out[depth] = cell
    deep = out[SESSION_DEPTHS[-1]]["ample"]
    assert deep["prefill_cut"] >= SESSION_MIN_PREFILL_CUT, \
        f"ample cache cut only {deep['prefill_cut']:.1%} of prefill " \
        f"energy at depth {SESSION_DEPTHS[-1]}"
    return out


def run_sessions(profiles, cell_dumps):
    print(f"\n=== multi-turn sessions + KV prefix cache "
          f"({SESSION_N} sessions, {SESSION_RATE_QPS:g} starts/s, "
          f"think {SESSION_THINK_S:g}s) ===")
    cells = session_cells(profiles)
    for depth, cell in sorted(cells.items()):
        for tag, entry in cell.items():
            rep = entry["report"]
            cell_dumps[f"sessions.depth_{depth}.{tag}"] = rep.to_dict()
            if "oracle_report" in entry:
                cell_dumps[f"sessions.depth_{depth}.{tag}.cache_oracle"] = \
                    entry["oracle_report"].to_dict()
            print(f"  depth={depth} {tag:>9s}: "
                  f"hit_rate={rep.cache_hit_rate:5.1%} "
                  f"reuse={rep.total_cache_hit_tokens:6d}tok "
                  f"evict={rep.total_cache_evictions:4d} "
                  f"E={rep.total_energy_j:9.0f}J "
                  f"(read={rep.total_cache_read_energy_j:6.2f}) "
                  f"prefill_cut={entry['prefill_cut']:5.1%} "
                  f"p95={rep.latency_p95:6.2f}s")
        disabled = cell["disabled"]["report"]
        for tag in ("small", "ample"):
            entry = cell[tag]
            rep = entry["report"]
            total_cut = 1.0 - rep.total_energy_j / disabled.total_energy_j
            emit(f"fig4.sessions_depth_{depth}_{tag}", 0.0,
                 f"hit_rate={rep.cache_hit_rate:.4f} "
                 f"hit_tokens={rep.total_cache_hit_tokens} "
                 f"evictions={rep.total_cache_evictions} "
                 f"prefill_cut={entry['prefill_cut']:.4f} "
                 f"total_energy_cut={total_cut:.4f} "
                 f"cache_read_j={rep.total_cache_read_energy_j:.3f} "
                 f"oracle_obj={entry['oracle_obj']:+.4f} "
                 f"online_obj={entry['online_obj']:+.4f} "
                 f"auditor_checks={entry['auditor_checks']} "
                 f"partition_exact=True oracle_bound_holds=True")
    deep = cells[SESSION_DEPTHS[-1]]["ample"]
    emit("fig4.sessions", 0.0,
         f"prefill_cut_depth{SESSION_DEPTHS[-1]}_ample="
         f"{deep['prefill_cut']:.4f} "
         f"prefill_cut_geq_{SESSION_MIN_PREFILL_CUT:g}=True "
         f"eight_bucket_partition_exact=True "
         f"cache_oracle_bound_holds=True")
    sess_path = REPO_ROOT / "BENCH_fig4_sessions.json"
    sess_path.write_text(json.dumps(
        {k: v for k, v in cell_dumps.items() if k.startswith("sessions.")},
        sort_keys=True, indent=1))
    print(f"  wrote session cells -> {sess_path.name}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--availability-only", action="store_true",
                    help="run just the fault/availability cell (g)")
    ap.add_argument("--blast-radius", action="store_true",
                    help="run just the correlated-failure/checkpoint "
                         "blast-radius cell (h)")
    ap.add_argument("--sessions", action="store_true",
                    help="run just the multi-turn-session / KV-prefix-"
                         "cache cell (i)")
    opts = ap.parse_args()
    profiles = fit_fleet()
    if opts.availability_only:
        cell_dumps: dict[str, dict] = {}
        run_availability(profiles, cell_dumps)
        return
    if opts.blast_radius:
        cell_dumps = {}
        run_blast_radius(cell_dumps)
        return
    if opts.sessions:
        cell_dumps = {}
        run_sessions(profiles, cell_dumps)
        return
    us, results = timed(lambda: run(profiles), repeats=1)
    n_cells = len(results)
    cell_dumps: dict[str, dict] = {}
    for (rate, zeta), reports in sorted(results.items()):
        oracle = reports["offline_oracle"]
        print(f"\n=== rate={rate:g} qps, zeta={zeta:g} "
              f"(n={N_REQUESTS}, fleet={list(CASE_STUDY_MODELS)}) ===")
        for name, rep in reports.items():
            print(rep.summary())
            cell_dumps[f"rate_{rate:g}_zeta_{zeta:g}.{name}"] = rep.to_dict()
        for name, rep in reports.items():
            assert oracle.objective <= rep.objective + 1e-9, \
                f"oracle beaten on objective by {name} at rate={rate} zeta={zeta}"
            if zeta == 1.0:
                assert oracle.predicted_energy_j <= rep.predicted_energy_j + 1e-6, \
                    f"oracle beaten on energy by {name} at zeta=1"
        worst = max(r.objective for n, r in reports.items()
                    if n != "offline_oracle")
        best_online = min(r.objective for n, r in reports.items()
                          if n != "offline_oracle")
        emit(f"fig4.rate_{rate:g}_zeta_{zeta:g}", us / n_cells,
             f"oracle_obj={oracle.objective:+.3f} "
             f"best_online_obj={best_online:+.3f} "
             f"worst_online_obj={worst:+.3f} "
             f"gap_best={best_online - oracle.objective:.4f} "
             f"oracle_E={oracle.total_energy_j:.0f}J "
             f"oracle_p95={oracle.latency_p95:.2f}s")

    # --- (a)+(b): power-gating and per-phase DVFS ----------------------
    print("\n=== power management (zeta_online, zeta=0.5) ===")
    for rate, cell in power_cells(profiles).items():
        base, gated, dvfs, both = (cell["base"], cell["gated"],
                                   cell["dvfs"], cell["both"])
        # (b) asserted on every run.  The guarantee is per-phase (scale
        # 1.0 is always a governor candidate); globally, slower phases
        # can reshape batch composition and extend the makespan (idle on
        # OTHER nodes), which the per-phase argmin does not see — this
        # deterministic benchmark holds with an 8-20% margin, so a trip
        # here means the governor or accounting regressed, not fp noise.
        assert dvfs.total_busy_energy_j <= base.total_busy_energy_j + 1e-6, \
            f"DVFS busy energy above fixed at rate={rate}"
        assert dvfs.total_energy_j <= base.total_energy_j + 1e-6, \
            f"DVFS total energy above fixed at rate={rate}"
        assert len(gated.records) == len(base.records)
        idle_cut = 1.0 - (gated.total_idle_energy_j
                          / max(base.total_idle_energy_j, 1e-12))
        total_cut_gate = 1.0 - gated.total_energy_j / base.total_energy_j
        total_cut_dvfs = 1.0 - dvfs.total_energy_j / base.total_energy_j
        total_cut_both = 1.0 - both.total_energy_j / base.total_energy_j
        for tag, rep in (("always-on", base), ("gated", gated),
                         ("dvfs", dvfs), ("gated+dvfs", both)):
            cell_dumps[f"power_rate_{rate:g}.{tag}"] = rep.to_dict()
            print(f"  rate={rate:g} {tag:>10s}: "
                  f"E={rep.total_energy_j:9.0f}J "
                  f"(busy={rep.total_busy_energy_j:7.0f} "
                  f"idle={rep.total_idle_energy_j:7.0f} "
                  f"gated={rep.total_gated_energy_j:6.0f} "
                  f"trans={rep.total_transition_energy_j:6.0f}) "
                  f"slo={rep.slo_attainment():5.1%} "
                  f"p95={rep.latency_p95:6.2f}s wakes={rep.total_wakes}")
        emit(f"fig4.power_rate_{rate:g}", 0.0,
             f"idle_energy_cut={idle_cut:.1%} "
             f"total_cut_gating={total_cut_gate:.1%} "
             f"total_cut_dvfs={total_cut_dvfs:.1%} "
             f"total_cut_both={total_cut_both:.1%} "
             f"slo_base={base.slo_attainment():.3f} "
             f"slo_gated={gated.slo_attainment():.3f} "
             f"slo_both={both.slo_attainment():.3f} "
             f"dvfs_leq_fixed=True")

    # --- (c): information gap vs commitment gap ------------------------
    print("\n=== tau_out information gap vs commitment gap (zeta=0.5) ===")
    for rate, cell in predictor_cells(profiles).items():
        oracle_tau = cell["zeta_online"]
        pred_tau = cell["zeta_online+tau_pred"]
        offline = cell["offline_oracle"]
        commitment = oracle_tau.objective - offline.objective
        information = pred_tau.objective - oracle_tau.objective
        assert offline.objective <= oracle_tau.objective + 1e-9
        for tag, rep in (("offline_oracle", offline),
                         ("oracle_tau", oracle_tau),
                         ("predicted_tau", pred_tau)):
            cell_dumps[f"gaps_rate_{rate:g}.{tag}"] = rep.to_dict()
            print(f"  rate={rate:g} {tag:>14s}: obj={rep.objective:+.4f} "
                  f"E={rep.total_energy_j:9.0f}J "
                  f"p95={rep.latency_p95:6.2f}s")
        print(f"  rate={rate:g}   commitment gap={commitment:+.4f}  "
              f"information gap={information:+.4f}")
        emit(f"fig4.gaps_rate_{rate:g}", 0.0,
             f"commitment_gap={commitment:.4f} "
             f"information_gap={information:.4f} "
             f"offline_obj={offline.objective:+.4f} "
             f"oracle_tau_obj={oracle_tau.objective:+.4f} "
             f"pred_tau_obj={pred_tau.objective:+.4f}")

    # --- (d): multi-replica fleets with decode-boundary preemption -----
    print("\n=== multi-replica serving + preemption (2 replicas/model, "
          "zeta=0.5) ===")
    for rate, cell in replica_cells(profiles).items():
        oracle = cell["replica_oracle"]
        for name, rep in cell.items():
            print(f"  rate={rate:g} {name:>15s}: obj={rep.objective:+.4f} "
                  f"E={rep.total_energy_j:9.0f}J "
                  f"p95={rep.latency_p95:6.2f}s "
                  f"slo={rep.slo_attainment():5.1%} "
                  f"preempt={rep.total_preemptions} "
                  f"resume={rep.total_resumes}")
            # the acceptance bound: the replica-aware oracle replay is
            # never worse than any online policy on the Eq. 2 objective
            assert oracle.objective <= rep.objective + 1e-9, \
                f"replica oracle beaten on objective by {name} at rate={rate}"
            assert rep.total_preemptions == rep.total_resumes, \
                f"unmatched preemptions for {name} at rate={rate}"
        best_online = min(r.objective for n, r in cell.items()
                          if n != "replica_oracle")
        emit(f"fig4.replica_rate_{rate:g}",
             0.0,
             f"replica_oracle_obj={oracle.objective:+.4f} "
             f"best_online_obj={best_online:+.4f} "
             f"gap_best={best_online - oracle.objective:.4f} "
             f"preemptions={cell['replica_energy'].total_preemptions} "
             f"oracle_bound_holds=True")

    # --- (e): per-model replica autoscaling + wake-aware routing -------
    print("\n=== replica autoscaling (replica_rate, 2 replicas/model) ===")
    for rate, cell in replica_power_cells(profiles).items():
        blind, aware = cell["zeta_online"], cell["replica_energy"]
        for tag, rep in (("zeta_online", blind),
                         ("replica_energy", aware)):
            print(f"  rate={rate:g} {tag:>15s}: "
                  f"E={rep.total_energy_j:9.0f}J "
                  f"(idle={rep.total_idle_energy_j:7.0f} "
                  f"gated={rep.total_gated_energy_j:6.0f}) "
                  f"slo={rep.slo_attainment():5.1%} "
                  f"wakes={rep.total_wakes} gates={rep.total_gates}")
        emit(f"fig4.replica_power_rate_{rate:g}", 0.0,
             f"E_blind={blind.total_energy_j:.0f} "
             f"E_aware={aware.total_energy_j:.0f} "
             f"wakes_blind={blind.total_wakes} "
             f"wakes_aware={aware.total_wakes}")

    # --- (f): full telemetry on one seeded cell ------------------------
    print("\n=== telemetry (tracer + live auditor, governed fleet, "
          "2 qps) ===")
    tel, instrumented, prom_path, trace_path = telemetry_cell(profiles)
    cell_dumps["telemetry_rate_2.instrumented"] = instrumented.to_dict()
    print(f"  auditor checks={tel.auditor.n_checks} "
          f"trace events={len(tel.tracer.events)} "
          f"prom -> {prom_path.name}, trace -> {trace_path.name}")
    emit("fig4.telemetry", 0.0,
         f"report_byte_identical=True "
         f"auditor_checks={tel.auditor.n_checks} "
         f"trace_events={len(tel.tracer.events)} "
         f"registry_rebuild_matches=True")

    # --- (g): availability under injected faults -----------------------
    run_availability(profiles, cell_dumps)

    # --- (h): correlated failure domains + prefill checkpointing -------
    run_blast_radius(cell_dumps)

    # --- (i): multi-turn sessions + the KV prefix cache ----------------
    run_sessions(profiles, cell_dumps)

    # every cell's full ClusterReport as structured JSON — downstream
    # tooling reads this instead of parsing the printed tables
    cells_path = REPO_ROOT / "BENCH_fig4_cells.json"
    cells_path.write_text(json.dumps(cell_dumps, sort_keys=True, indent=1))
    print(f"\nwrote {len(cell_dumps)} cell reports -> {cells_path.name}")

    emit("fig4.claims", 0.0,
         "oracle_never_worse_on_objective=True "
         "energy_bound_at_zeta1=True "
         "dvfs_energy_leq_fixed_every_run=True "
         "gap_split=commitment_vs_information "
         "replica_oracle_bound_holds=True "
         "preemption_energy_conserving=True "
         "telemetry_report_byte_identical=True "
         "failure_aware_oracle_bound_holds=True "
         "six_bucket_partition_exact=True "
         "failover_recovery_geq_0.9_at_10x_mttf=True "
         "seven_bucket_partition_exact=True "
         "naive_loss_gt_0.5_at_full_blast_radius=True "
         "hardened_recovery_geq_0.9_every_ckpt_interval=True "
         "eight_bucket_partition_exact=True "
         "cache_oracle_bound_holds=True "
         "session_prefill_cut_geq_0.25_at_depth8=True")


if __name__ == "__main__":
    main()
