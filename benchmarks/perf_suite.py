"""Perf trajectory suite for the analytic hot paths — feeds BENCH_core.json.

Usage (from the repo root):

    PYTHONPATH=src:. python benchmarks/perf_suite.py             # full run:
        times every hot path and writes BENCH_core.json
    PYTHONPATH=src:. python benchmarks/perf_suite.py --quick     # CI gate:
        correctness checks only (closed-form vs chunked reference, chains
        solver vs _MinCostFlow, batch vs scalar equivalence, warm-start
        reschedule vs cold solve, jit cost kernel vs the numpy closed
        form, DVFS governor vs a brute-force frequency grid, gated-sim
        busy/idle/gated/transition energy conservation, and decode-
        boundary preemption: split additivity of the decode integral plus
        end-to-end conservation + the replica-oracle bound on a
        preempting multi-replica run); no timing assertions, no JSON.
        This is what `scripts/test.sh perf` runs.

    --out PATH            where to write the JSON (default <repo>/BENCH_core.json)
    --sizes A,B,C         workload sizes to sweep (default 1000,10000,100000)
    --headline-m M        the capacitated-scheduler headline size (default 50000)
    --ref-direct-max M    largest m at which the _MinCostFlow oracle is run
                          directly (default 10000; it is O(m²k) so the
                          headline reference time is extrapolated from a
                          power-law fit of the directly measured points,
                          with bit-identical objective checks at every
                          direct point and an exact LP-optimality
                          certificate at the headline size)

What is measured:

  * `AnalyticLLMSimulator.decode_cost` (exact closed form) vs the legacy
    chunked loop at τout = 4096 — against chunk=1 (the exact per-step
    reference it must match to ≤1e-9 rel) and chunk=256 (the old
    midpoint approximation, whose error is also recorded);
  * `pass_costs_batch` vs a scalar `pass_costs` loop;
  * `measure_batch` vs sequential `measure` over characterization grids;
  * `core.scheduler.schedule` (vectorized argmin) throughput;
  * `core.scheduler.schedule_capacitated`: chains vs flow oracle;
  * `core.sweep.IncrementalScheduler.reschedule`: warm-start small-delta
    repair vs a cold chains re-solve at the headline size;
  * `core.sweep.pareto_frontier`: the warm ζ grid vs cold zeta_sweep, and
    the exact-breakpoint frontier;
  * `kernels.cost_batch.simulate_batch`: the jitted batch cost kernel
    (throughput + ≤1e-9 agreement with the numpy closed form);
  * the cluster discrete-event sim with memoized phase costs.

Exit status is nonzero iff any correctness gate fails; timing numbers are
recorded, never asserted (no flaky wall-clock assertions in CI).

BENCH_core.json keeps the latest full snapshot, plus a `history` list with
one compact entry per *commit* (hash, wall_s, headline numbers) so the
perf trajectory across PRs stays on record; re-running on the same commit
replaces that commit's entry in place, keeping the best wall_s, instead
of appending duplicates.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

import numpy as np

if __package__ in (None, ""):  # `python benchmarks/perf_suite.py`
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.common import synthetic_fleet, timed  # noqa: E402

from repro.configs import PAPER_ZOO, get_config  # noqa: E402
from repro.core import scheduler  # noqa: E402
from repro.core import characterize as characterize_lib  # noqa: E402
from repro.core.energy_model import (  # noqa: E402
    normalized_costs,
    objective_matrix,
)
from repro.core.sweep import IncrementalScheduler, pareto_frontier  # noqa: E402
from repro.data.workloads import WorkloadSpec, alpaca_like_workload  # noqa: E402
from repro.energy import costs as costs_lib  # noqa: E402
from repro.energy.simulator import AnalyticLLMSimulator  # noqa: E402

REPO_ROOT = Path(__file__).resolve().parents[1]

GATE_CONFIGS = {
    "llama2-7b": lambda: PAPER_ZOO["llama2-7b"],
    "mixtral-8x7b": lambda: PAPER_ZOO["mixtral-8x7b"],
    "mistral-7b": lambda: get_config("mistral-7b"),
    "mamba2-130m": lambda: get_config("mamba2-130m"),
    "recurrentgemma-9b": lambda: get_config("recurrentgemma-9b"),
    "deepseek-v3-671b": lambda: get_config("deepseek-v3-671b"),
}


def workload(m: int, seed: int = 0) -> list[tuple[int, int]]:
    return alpaca_like_workload(WorkloadSpec(n_queries=m, seed=seed))


def random_gamma(k: int, rng) -> tuple[float, ...]:
    g = rng.dirichlet(np.ones(k) * rng.uniform(0.5, 3.0))
    return tuple((g / g.sum()).tolist())


# ---------------------------------------------------------------------------
# Correctness gates (shared by --quick and the full run)
# ---------------------------------------------------------------------------


def gate_decode_closed_form(failures: list[str]) -> dict:
    """Closed form must match the chunk=1 per-step reference ≤ 1e-9 rel
    across every family and both KV modes, including window/MoE-breakpoint
    crossings and tiny phases."""
    worst = 0.0
    ranges = [(1, 1), (1, 3), (8, 100), (32, 512), (3000, 2000), (100, 4096)]
    for name, mk in GATE_CONFIGS.items():
        cfg = mk()
        for kv in (True, False):
            sim = AnalyticLLMSimulator(cfg, batch=4, kv_cache=kv,
                                       noise_sigma=0.0)
            for ctx0, n in ranges:
                t1, e1 = sim.decode_cost(ctx0, n)
                t2, e2 = sim.decode_cost_chunked(ctx0, n, chunk=1)
                rel = max(abs(t1 - t2) / max(abs(t2), 1e-300),
                          abs(e1 - e2) / max(abs(e2), 1e-300))
                worst = max(worst, rel)
                if rel > 1e-9:
                    failures.append(
                        f"decode closed-form mismatch: {name} kv={kv} "
                        f"ctx0={ctx0} n={n} rel={rel:.3e}")
    return {"worst_rel_err": worst, "tolerance": 1e-9}


def gate_pass_costs_batch(failures: list[str]) -> dict:
    """pass_costs_batch must agree with scalar pass_costs elementwise."""
    rng = np.random.default_rng(7)
    worst = 0.0
    for name, mk in GATE_CONFIGS.items():
        cfg = mk()
        nt = rng.integers(1, 4096, 64).astype(float)
        ctx = nt + rng.integers(0, 4096, 64)
        bt = rng.integers(1, 64, 64).astype(float)
        for decode in (False, True):
            pcb = costs_lib.pass_costs_batch(cfg, nt, ctx, bt, decode=decode)
            for i in range(len(nt)):
                pc = costs_lib.pass_costs(cfg, nt[i], ctx[i], bt[i],
                                          decode=decode)
                rel = max(abs(pc.flops - pcb.flops[i]) / max(pc.flops, 1e-300),
                          abs(pc.hbm_bytes - pcb.hbm_bytes[i])
                          / max(pc.hbm_bytes, 1e-300))
                worst = max(worst, rel)
                if rel > 1e-12:
                    failures.append(
                        f"pass_costs_batch mismatch: {name} decode={decode} "
                        f"i={i} rel={rel:.3e}")
    return {"worst_rel_err": worst, "tolerance": 1e-12}


def gate_measure_batch(failures: list[str]) -> dict:
    """measure_batch must be noise-stream-identical to sequential measure."""
    cfg = PAPER_ZOO["llama2-7b"]
    pts = [(8, 8), (64, 32), (8, 8), (128, 16), (512, 256), (64, 32)]
    s1 = AnalyticLLMSimulator(cfg, seed=9)
    s2 = AnalyticLLMSimulator(cfg, seed=9)
    seq = [s1.measure(a, b) for a, b in pts]
    e, r = s2.measure_batch([p[0] for p in pts], [p[1] for p in pts])
    ok = all(sv[0] == e[i] and sv[1] == r[i] for i, sv in enumerate(seq))
    if not ok:
        failures.append("measure_batch diverges from sequential measure")
    return {"stream_identical": ok}


def gate_capacitated_solver(failures: list[str], *, n_instances: int = 8,
                            m_max: int = 400) -> dict:
    """chains solver vs _MinCostFlow: objectives must be bit-identical."""
    n_exact = 0
    for t in range(n_instances):
        rng = np.random.default_rng(5000 + t)
        m = int(rng.integers(10, m_max))
        k = int(rng.integers(2, 7))
        qs = [(int(a), int(b)) for a, b in
              zip(rng.integers(1, 4096, m), rng.integers(1, 4096, m))]
        profs = synthetic_fleet(k, seed=t)
        gamma = random_gamma(k, rng)
        zeta = float(rng.uniform(0, 1))
        a = scheduler.schedule_capacitated(profs, qs, zeta, gamma,
                                           method="chains")
        b = scheduler.schedule_capacitated(profs, qs, zeta, gamma,
                                           method="flow")
        if a.objective == b.objective:
            n_exact += 1
        elif abs(a.objective - b.objective) > 1e-12 * max(1.0,
                                                          abs(b.objective)):
            # 1e-12 rel, not == : permuted exact optima over duplicate
            # queries can differ in the last ulp of the pairwise sum
            failures.append(
                f"capacitated solver mismatch: instance {t} m={m} k={k} "
                f"chains={a.objective!r} flow={b.objective!r}")
        costs = normalized_costs(profs, qs)
        C = objective_matrix(costs, zeta)
        caps = scheduler._capacities_from_gamma(gamma, m)
        if not scheduler.capacitated_optimality_certificate(C, a.assignee, caps):
            failures.append(f"optimality certificate failed: instance {t}")
    return {"instances": n_instances, "bit_identical": n_exact}


def gate_warm_start(failures: list[str], *, n_instances: int = 12) -> dict:
    """IncrementalScheduler.reschedule after a randomized delta (adds,
    removals, capacity shifts, ζ moves) must match a cold
    schedule_capacitated solve on the identical workload: objective within
    the chains-vs-flow 1e-12-relative equivalence class and the exact
    LP-optimality certificate (asserted via check=True)."""
    n_bit = 0
    for t in range(n_instances):
        rng = np.random.default_rng(8100 + t)
        m = int(rng.integers(10, 300))
        k = int(rng.integers(2, 7))
        qs = [(int(a), int(b)) for a, b in
              zip(rng.integers(1, 4096, m), rng.integers(1, 4096, m))]
        profs = synthetic_fleet(k, seed=t)
        gamma = random_gamma(k, rng)
        zeta = float(rng.uniform(0, 1))
        inc = IncrementalScheduler(profs, qs, zeta, gamma, check=True)
        n_add = int(rng.integers(0, 8))
        n_rem = int(rng.integers(0, min(8, m - 1)))
        added = [(int(a), int(b)) for a, b in
                 zip(rng.integers(1, 4096, n_add),
                     rng.integers(1, 4096, n_add))]
        removed = list(rng.choice(inc.active_ids, size=n_rem, replace=False))
        z2 = float(np.clip(zeta + rng.uniform(-0.2, 0.2), 0, 1))
        try:
            asg = inc.reschedule(added=added, removed=removed, zeta=z2)
        except RuntimeError as e:
            failures.append(f"warm-start reschedule failed: instance {t}: {e}")
            continue
        cold = scheduler.schedule_capacitated(profs, inc.active_queries(),
                                              z2, gamma)
        if asg.objective == cold.objective:
            n_bit += 1
        elif abs(asg.objective - cold.objective) > 1e-12 * max(
                1.0, abs(cold.objective)):
            failures.append(
                f"warm-start objective mismatch: instance {t} "
                f"warm={asg.objective!r} cold={cold.objective!r}")
    return {"instances": n_instances, "bit_identical": n_bit}


def gate_jit_cost_kernel(failures: list[str]) -> dict:
    """kernels.cost_batch.simulate_batch must match the numpy closed form
    (AnalyticLLMSimulator.simulate) ≤ 1e-9 rel, both KV modes, including
    window/MoE breakpoint crossings, τout ∈ {0, 1} edges."""
    try:
        from repro.kernels import cost_batch
    except Exception as e:  # noqa: BLE001 — missing jax must not fail CI
        return {"skipped": f"{type(e).__name__}: {e}"}
    rng = np.random.default_rng(31)
    tin = np.concatenate([rng.integers(1, 4096, 24),
                          [1, 2, 3000, 4095, 4096, 5000]])
    tout = np.concatenate([rng.integers(1, 4096, 24), [1, 2, 3, 4, 0, 512]])
    worst = 0.0
    for name in ("llama2-7b", "mixtral-8x7b", "mistral-7b"):
        cfg = GATE_CONFIGS[name]()
        for kv in (True, False):
            sim = AnalyticLLMSimulator(cfg, batch=4, kv_cache=kv,
                                       noise_sigma=0.0)
            e_j, r_j = cost_batch.simulate_batch(sim, tin, tout)
            for i in range(len(tin)):
                pb = sim.simulate(int(tin[i]), int(tout[i]))
                rel = max(abs(e_j[i] - pb.energy_j) / max(abs(pb.energy_j),
                                                          1e-300),
                          abs(r_j[i] - pb.runtime_s) / max(abs(pb.runtime_s),
                                                           1e-300))
                worst = max(worst, rel)
                if rel > 1e-9:
                    failures.append(
                        f"jit cost kernel mismatch: {name} kv={kv} "
                        f"tin={tin[i]} tout={tout[i]} rel={rel:.3e}")
    return {"worst_rel_err": worst, "tolerance": 1e-9}


def gate_dvfs_closed_form(failures: list[str]) -> dict:
    """The per-phase DVFS governor's closed-form frequency choice must
    match a brute-force sweep of the same operating-point grid evaluated
    with the chunk=1 per-step reference loop — same argmin scale, same
    energy to 1e-9 — and the scaled closed forms themselves must match the
    reference at every grid point."""
    worst = 0.0
    n_checked = 0
    for name in ("llama2-7b", "mixtral-8x7b"):
        cfg = GATE_CONFIGS[name]()
        for kv in (True, False):
            sim = AnalyticLLMSimulator(cfg, batch=1, kv_cache=kv,
                                       noise_sigma=0.0)
            host = sim.host_power_w
            for ctx0, n in ((32, 200), (1024, 64)):
                grid = {}
                for s in sim.node.accel.dvfs_scales:
                    t_c, e_c = sim.decode_cost(ctx0, n, 4, freq_scale=s)
                    t_r, e_r = sim.decode_cost_chunked(ctx0, n, 4, chunk=1,
                                                       freq_scale=s)
                    rel = max(abs(t_c - t_r) / max(abs(t_r), 1e-300),
                              abs(e_c - e_r) / max(abs(e_r), 1e-300))
                    worst = max(worst, rel)
                    if rel > 1e-9:
                        failures.append(
                            f"scaled decode closed-form mismatch: {name} "
                            f"kv={kv} s={s} rel={rel:.3e}")
                    grid[s] = (t_r, e_r)
                s_gov, t_gov, e_gov = sim.best_decode_frequency(
                    ctx0, n, 4, extra_w=host)
                # brute force applies the governor's own tie rule (1e-12
                # relative band, higher clock wins ties) to the reference
                # values, so a near-tie between operating points cannot
                # flip the gate on an fp hair
                s_bf, bf_tot = None, None
                for s, (t_r, e_r) in grid.items():
                    tot = e_r + host * t_r
                    if bf_tot is None or tot < bf_tot - 1e-12 * max(
                            1.0, abs(bf_tot)):
                        s_bf, bf_tot = s, tot
                    elif abs(tot - bf_tot) <= 1e-12 * max(
                            1.0, abs(bf_tot)) and s > s_bf:
                        s_bf, bf_tot = s, tot
                gov_tot = e_gov + host * t_gov
                n_checked += 1
                choice_ok = (s_gov == s_bf
                             or abs(gov_tot - bf_tot) <= 1e-9 * max(
                                 1.0, abs(bf_tot)))
                if not choice_ok or gov_tot > bf_tot * (1 + 1e-9) + 1e-9:
                    failures.append(
                        f"DVFS governor vs brute force: {name} kv={kv} "
                        f"ctx0={ctx0} n={n}: chose {s_gov} ({gov_tot!r} J) "
                        f"vs grid {s_bf} ({bf_tot!r} J)")
    return {"worst_rel_err": worst, "tolerance": 1e-9,
            "choices_checked": n_checked}


def gate_preemption_split(failures: list[str]) -> dict:
    """Decode-boundary preemption must conserve energy exactly.

    (a) The closed-form decode integral is additive at any split point:
        decode_cost(c, a) + decode_cost(c+a, b) == decode_cost(c, a+b)
        to 1e-9 rel, across model families, both KV modes and a scaled
        operating point — this is the identity that makes a preempted
        segment's two halves sum to the unpreempted cost.
    (b) A preempting multi-replica cluster run conserves end to end: all
        requests served, preemptions actually fire and every preemption
        has a matching resume, the four buckets still partition each
        node's horizon, per-request attributed energies sum to the busy
        bucket, and the replica-aware oracle replay is never worse than
        the online policy on the Eq. 2 objective."""
    worst = 0.0
    splits = [(64, 300, 1), (64, 300, 150), (64, 300, 299),
              (1000, 64, 20), (8, 2048, 777)]
    for name in ("llama2-7b", "mixtral-8x7b", "mamba2-130m"):
        cfg = GATE_CONFIGS[name]()
        for kv in (True, False):
            sim = AnalyticLLMSimulator(cfg, batch=4, kv_cache=kv,
                                       noise_sigma=0.0)
            for s in (1.0, sim.node.accel.dvfs_scales[0]):
                for ctx0, n, cut in splits:
                    t, e = sim.decode_cost(ctx0, n, freq_scale=s)
                    t1, e1 = sim.decode_cost(ctx0, cut, freq_scale=s)
                    t2, e2 = sim.decode_cost(ctx0 + cut, n - cut,
                                             freq_scale=s)
                    rel = max(abs(t1 + t2 - t) / max(abs(t), 1e-300),
                              abs(e1 + e2 - e) / max(abs(e), 1e-300))
                    worst = max(worst, rel)
                    if rel > 1e-9:
                        failures.append(
                            f"preemption split not additive: {name} kv={kv} "
                            f"s={s} ctx0={ctx0} n={n} cut={cut} "
                            f"rel={rel:.3e}")

    from repro.cluster import (ClusterNode, ReplicaEnergyPolicy,
                               ReplicaOraclePolicy, SLOPreemptionPolicy,
                               poisson_trace, simulate_cluster)
    from repro.configs import TABLE1
    from repro.core.energy_model import fit_profile
    from repro.energy import SWING_NODE

    fleet = ("llama2-7b", "llama2-13b")
    profiles = {}
    for name in fleet:
        sim = AnalyticLLMSimulator(PAPER_ZOO[name], SWING_NODE, batch=1,
                                   kv_cache=True, noise_sigma=0.0)
        pts = [(8, 8), (64, 64), (256, 128), (512, 512), (128, 32)]
        pbs = [sim.simulate(a, b) for a, b in pts]
        profiles[name] = fit_profile(
            name, TABLE1[name]["a_k"],
            [p[0] for p in pts], [p[1] for p in pts],
            [pb.energy_j for pb in pbs], [pb.runtime_s for pb in pbs])

    def nodes():   # two replicas per model, tiny batches force contention
        return [ClusterNode(2 * i + j, PAPER_ZOO[name], profiles[name],
                            SWING_NODE, max_batch=2)
                for i, name in enumerate(fleet) for j in (0, 1)]

    trace = poisson_trace(60, 6.0, seed=3)
    preempter = SLOPreemptionPolicy(slowdown_slo=1.2, min_remaining=2)
    rep = simulate_cluster(trace, nodes(), ReplicaEnergyPolicy(), zeta=0.5,
                           preempter=preempter)
    oracle = simulate_cluster(
        trace, nodes(), ReplicaOraclePolicy(), zeta=0.5,
        preempter=SLOPreemptionPolicy(slowdown_slo=1.2, min_remaining=2))
    if len(rep.records) != len(trace):
        failures.append("preemption gate lost requests")
    if rep.total_preemptions == 0:
        failures.append("preemption gate saw no preemptions")
    if rep.total_preemptions != rep.total_resumes:
        failures.append(
            f"preemptions ({rep.total_preemptions}) != resumes "
            f"({rep.total_resumes})")
    worst_e = worst_t = 0.0
    for s in rep.node_stats:
        e_sum = (s.busy_energy_j + s.idle_energy_j + s.gated_energy_j
                 + s.transition_energy_j)
        worst_e = max(worst_e, abs(e_sum - s.total_energy_j)
                      / max(1.0, s.total_energy_j))
        worst_t = max(worst_t, abs(s.accounted_s - s.horizon_s)
                      / max(1.0, s.horizon_s))
    attributed = sum(r.energy_j for r in rep.records)
    busy = sum(s.busy_energy_j for s in rep.node_stats)
    worst_e = max(worst_e, abs(attributed - busy) / max(1.0, busy))
    if worst_e > 1e-9 or worst_t > 1e-9:
        failures.append(
            f"preempting run violates conservation: energy rel "
            f"{worst_e:.3e}, time rel {worst_t:.3e}")
    if oracle.objective > rep.objective + 1e-9:
        failures.append(
            f"replica oracle beaten on objective: {oracle.objective!r} > "
            f"{rep.objective!r}")
    return {"worst_split_rel": worst, "worst_energy_rel": worst_e,
            "worst_time_rel": worst_t, "tolerance": 1e-9,
            "preemptions": rep.total_preemptions,
            "resumes": rep.total_resumes}


def gate_migration_settlement(failures: list[str]) -> dict:
    """Cross-node migration rescue must settle exactly, end to end.

    (a) A scripted crash storm over a 2-replica fleet, run under a live
        InvariantAuditor (every donor truncated charge, waste move and
        KV shipment checked at 1e-9 as it happens): migrations must
        actually fire, the six energy buckets must partition each node's
        horizon exactly, and per-request attributed energy must still
        sum to the fleet busy bucket — the cross-node split contract.
    (b) The shipping bucket must follow the interconnect closed form in
        aggregate: Σ shipped KV bytes × j_per_byte_ici == the fleet
        shipping energy, and bytes / ici_bw == the shipping seconds
        (uniform hardware, so the totals close without per-event state).
    (c) A crash with no same-model survivor books the refugees'
        accrued joules as wasted and their requests as abandoned —
        conservation closes through the waste bucket, never a leak."""
    from repro.cluster import (ClusterNode, FailoverPolicy, FaultEvent,
                               FaultTrace, LeastLoadedPolicy,
                               ZetaOnlinePolicy, poisson_trace,
                               simulate_cluster)
    from repro.cluster.faults import CRASH, RECOVER
    from repro.configs import TABLE1
    from repro.core.energy_model import fit_profile
    from repro.energy import SWING_NODE
    from repro.energy.costs import kv_bytes_per_token
    from repro.obs import InvariantAuditor, InvariantViolation, Telemetry

    fleet = ("llama2-7b", "llama2-7b", "llama2-13b")
    profiles = {}
    for name in set(fleet):
        sim = AnalyticLLMSimulator(PAPER_ZOO[name], SWING_NODE, batch=1,
                                   kv_cache=True, noise_sigma=0.0)
        pts = [(8, 8), (64, 64), (256, 128), (512, 512), (128, 32)]
        pbs = [sim.simulate(a, b) for a, b in pts]
        profiles[name] = fit_profile(
            name, TABLE1[name]["a_k"],
            [p[0] for p in pts], [p[1] for p in pts],
            [pb.energy_j for pb in pbs], [pb.runtime_s for pb in pbs])

    def nodes(names=fleet):
        return [ClusterNode(i, PAPER_ZOO[name], profiles[name], SWING_NODE,
                            max_batch=2)
                for i, name in enumerate(names)]

    # (a)+(b): alternate crashing each 7b replica so refugees ship to the
    # surviving one; high rate keeps decodes in flight at crash time
    trace = poisson_trace(60, 6.0, seed=3)
    storm = FaultTrace("storm", tuple(
        FaultEvent(t, nid, kind)
        for t, nid, kind in ((1.5, 0, CRASH), (4.0, 0, RECOVER),
                             (5.0, 1, CRASH), (8.0, 1, RECOVER),
                             (9.0, 0, CRASH), (12.0, 0, RECOVER))))
    tel = Telemetry(auditor=InvariantAuditor())
    try:
        rep = simulate_cluster(trace, nodes(), FailoverPolicy(
            ZetaOnlinePolicy()), zeta=0.5, faults=storm, telemetry=tel)
    except InvariantViolation as e:
        failures.append(f"migration gate tripped the live auditor: {e}")
        return {"auditor": "violated"}
    if rep.total_migrations == 0:
        failures.append("migration gate saw no migrations")
    if rep.total_crashes == 0:
        failures.append("migration gate saw no crashes")
    worst_e = worst_t = 0.0
    for s in rep.node_stats:
        e_sum = (s.busy_energy_j + s.idle_energy_j + s.gated_energy_j
                 + s.transition_energy_j + s.shipping_energy_j
                 + s.wasted_energy_j)
        worst_e = max(worst_e, abs(e_sum - s.total_energy_j)
                      / max(1.0, s.total_energy_j))
        worst_t = max(worst_t, abs(s.accounted_s - s.horizon_s)
                      / max(1.0, s.horizon_s))
    attributed = sum(r.energy_j for r in rep.records)
    busy = sum(s.busy_energy_j for s in rep.node_stats)
    worst_e = max(worst_e, abs(attributed - busy) / max(1.0, busy))
    if worst_e > 1e-9 or worst_t > 1e-9:
        failures.append(
            f"faulted run violates six-bucket conservation: energy rel "
            f"{worst_e:.3e}, time rel {worst_t:.3e}")
    # (b): aggregate interconnect closed form (uniform SWING hardware)
    accel = SWING_NODE.accel
    shipped = sum(r.shipped_bytes for r in rep.records)
    ship_j = sum(s.shipping_energy_j for s in rep.node_stats)
    ship_s = sum(s.shipping_s for s in rep.node_stats)
    rel_j = (abs(ship_j - shipped * accel.j_per_byte_ici)
             / max(1.0, ship_j))
    rel_s = (abs(ship_s - shipped / accel.ici_bw) / max(1.0, ship_s))
    if shipped <= 0.0:
        failures.append("migration gate shipped no KV bytes")
    if rel_j > 1e-9 or rel_s > 1e-9:
        failures.append(
            f"shipping bucket off the interconnect closed form: energy "
            f"rel {rel_j:.3e}, time rel {rel_s:.3e}")
    # (c): lone node crashes mid-run and never recovers — no survivor,
    # so in-flight work is wasted and the rest abandoned, books closed
    lone_trace = poisson_trace(10, 4.0, seed=5)
    lone = simulate_cluster(
        lone_trace, nodes(("llama2-7b",)),
        FailoverPolicy(LeastLoadedPolicy(), max_retries=2), zeta=0.5,
        faults=FaultTrace("lone", (FaultEvent(0.8, 0, CRASH),)))
    if not lone.abandoned:
        failures.append("no-survivor crash abandoned nothing")
    if len(lone.records) + len(lone.abandoned) != len(lone_trace):
        failures.append("no-survivor crash lost requests")
    wasted = sum(s.wasted_energy_j for s in lone.node_stats)
    if wasted <= 0.0:
        failures.append("no-survivor crash booked no wasted energy")
    lone_rel = max(
        abs((s.busy_energy_j + s.idle_energy_j + s.gated_energy_j
             + s.transition_energy_j + s.shipping_energy_j
             + s.wasted_energy_j) - s.total_energy_j)
        / max(1.0, s.total_energy_j)
        for s in lone.node_stats)
    if lone_rel > 1e-9:
        failures.append(
            f"no-survivor waste leaks energy: rel {lone_rel:.3e}")
    return {"worst_energy_rel": worst_e, "worst_time_rel": worst_t,
            "shipping_energy_rel": rel_j, "shipping_time_rel": rel_s,
            "tolerance": 1e-9, "crashes": rep.total_crashes,
            "migrations": rep.total_migrations,
            "shipped_bytes": shipped,
            "auditor_checks": tel.auditor.n_checks,
            "no_survivor_abandoned": len(lone.abandoned),
            "no_survivor_wasted_j": wasted}


def gate_checkpoint_settlement(failures: list[str]) -> dict:
    """Prefill checkpointing must settle exactly, end to end.

    (a) Telescoping: with no faults a checkpointed run must match the
        unchunked run per request to 1e-9 in finish time and energy —
        chunk costs are exact prefix differences of `prefill_cost` at
        one pinned operating point, so Σ chunks == one prefill.
    (b) Aggregate storage closed form: every interior boundary persists
        exactly `interval_tokens` of new KV, so over the whole fleet
        Σ checkpoint energy == n_checkpoints × interval × kv_bytes ×
        j_per_byte_ckpt and Σ checkpoint seconds == bytes / ckpt_bw
        (uniform config, so the totals close without per-event state).
    (c) A scripted mid-prefill crash under a live InvariantAuditor
        restores from the last durable boundary on the survivor: one
        restore, only the durable prefix ships, the in-flight chunk is
        the only waste, and the seven buckets partition each node's
        horizon exactly."""
    from repro.cluster import (CheckpointConfig, ClusterNode,
                               FailoverPolicy, FaultEvent, FaultTrace,
                               LeastLoadedPolicy, simulate_cluster,
                               timestamped_trace)
    from repro.cluster.faults import CRASH
    from repro.configs import TABLE1
    from repro.core.energy_model import fit_profile
    from repro.energy import SWING_NODE
    from repro.energy.costs import kv_bytes_per_token
    from repro.obs import InvariantAuditor, InvariantViolation, Telemetry

    name = "llama2-7b"
    sim = AnalyticLLMSimulator(PAPER_ZOO[name], SWING_NODE, batch=1,
                               kv_cache=True, noise_sigma=0.0)
    pts = [(8, 8), (64, 64), (256, 128), (512, 512), (2048, 64)]
    pbs = [sim.simulate(a, b) for a, b in pts]
    profile = fit_profile(name, TABLE1[name]["a_k"],
                          [p[0] for p in pts], [p[1] for p in pts],
                          [pb.energy_j for pb in pbs],
                          [pb.runtime_s for pb in pbs])
    interval = 256
    kvb = kv_bytes_per_token(PAPER_ZOO[name])
    ck = CheckpointConfig(interval_tokens=interval)

    def nodes(checkpoint):
        return [ClusterNode(i, PAPER_ZOO[name], profile, SWING_NODE,
                            max_batch=2, checkpoint=checkpoint)
                for i in range(2)]

    # (a)+(b): prefill-heavy trace with interior boundaries at several
    # depths; identical runs modulo the checkpoint layer
    shapes = [(0.0, (2048, 16)), (0.5, (1024, 32)), (1.0, (300, 64)),
              (4.0, (512, 16)), (6.0, (768, 8)), (9.0, (1536, 24))]
    trace = timestamped_trace(shapes, name="ckpt-settle")
    plain = simulate_cluster(trace, nodes(None),
                             FailoverPolicy(LeastLoadedPolicy()), zeta=0.5)
    ckpt = simulate_cluster(trace, nodes(ck),
                            FailoverPolicy(LeastLoadedPolicy()), zeta=0.5)
    worst_tel = 0.0
    for a, b in zip(plain.records, ckpt.records):
        worst_tel = max(worst_tel,
                        abs(a.finish_s - b.finish_s) / max(1.0, a.finish_s),
                        abs(a.energy_j - b.energy_j) / max(1.0, a.energy_j))
    if worst_tel > 1e-9:
        failures.append(
            f"checkpoint telescoping drifted off the unchunked run: rel "
            f"{worst_tel:.3e}")
    n_ckpts = ckpt.total_checkpoints
    if n_ckpts == 0:
        failures.append("checkpoint gate persisted no boundaries")
    bytes_ckpt = n_ckpts * interval * kvb
    rel_j = (abs(ckpt.total_checkpoint_energy_j
                 - bytes_ckpt * ck.j_per_byte_ckpt)
             / max(1.0, ckpt.total_checkpoint_energy_j))
    ckpt_s = sum(s.checkpoint_s for s in ckpt.node_stats)
    rel_s = abs(ckpt_s - bytes_ckpt / ck.ckpt_bw) / max(1.0, ckpt_s)
    if rel_j > 1e-9 or rel_s > 1e-9:
        failures.append(
            f"checkpoint bucket off the storage closed form: energy rel "
            f"{rel_j:.3e}, time rel {rel_s:.3e}")
    # (c): crash strictly inside the 5th chunk — 1024 tokens durable
    cn = nodes(ck)
    t1, e1 = cn[0].sim.prefill_cost(1024, batch=1, freq_scale=1.0)
    t2, e2 = cn[0].sim.prefill_cost(1280, batch=1, freq_scale=1.0)
    tel = Telemetry(auditor=InvariantAuditor())
    try:
        rescue = simulate_cluster(
            timestamped_trace([(0.0, (2048, 8))]), cn,
            FailoverPolicy(LeastLoadedPolicy()), zeta=0.5,
            faults=FaultTrace("mid", (FaultEvent((t1 + t2) / 2.0, 0,
                                                 CRASH),)),
            telemetry=tel)
    except InvariantViolation as e:
        failures.append(f"checkpoint gate tripped the live auditor: {e}")
        return {"auditor": "violated"}
    if rescue.total_restores != 1 or rescue.abandoned:
        failures.append(
            f"mid-prefill crash did not restore once cleanly: "
            f"{rescue.total_restores} restores, "
            f"{len(rescue.abandoned)} abandoned")
    shipped = sum(r.shipped_bytes for r in rescue.records)
    rel_ship = abs(shipped - 1024 * kvb) / max(1.0, shipped)
    chunk_j = (e2 - e1) + cn[0].sim.host_power_w * (t2 - t1)
    rel_waste = (abs(rescue.total_wasted_energy_j - chunk_j)
                 / max(1.0, chunk_j))
    if rel_ship > 1e-9 or rel_waste > 1e-9:
        failures.append(
            f"restore settlement off closed form: shipped rel "
            f"{rel_ship:.3e}, wasted rel {rel_waste:.3e}")
    worst_e = worst_t = 0.0
    for rep in (ckpt, rescue):
        for s in rep.node_stats:
            e_sum = (s.busy_energy_j + s.idle_energy_j + s.gated_energy_j
                     + s.transition_energy_j + s.shipping_energy_j
                     + s.checkpoint_energy_j + s.wasted_energy_j)
            worst_e = max(worst_e, abs(e_sum - s.total_energy_j)
                          / max(1.0, s.total_energy_j))
            worst_t = max(worst_t, abs(s.accounted_s - s.horizon_s)
                          / max(1.0, s.horizon_s))
    if worst_e > 1e-9 or worst_t > 1e-9:
        failures.append(
            f"checkpointed run violates seven-bucket conservation: energy "
            f"rel {worst_e:.3e}, time rel {worst_t:.3e}")
    return {"telescoping_rel": worst_tel, "checkpoint_energy_rel": rel_j,
            "checkpoint_time_rel": rel_s, "worst_energy_rel": worst_e,
            "worst_time_rel": worst_t, "tolerance": 1e-9,
            "checkpoints": n_ckpts, "checkpoint_bytes": bytes_ckpt,
            "restores": rescue.total_restores,
            "auditor_checks": tel.auditor.n_checks}


def gate_prefix_cache_settlement(failures: list[str]) -> dict:
    """The KV prefix cache must settle exactly, end to end.

    (a) Warm-suffix telescoping: a scripted two-turn session's warm
        record is charged exactly prefill_cost(τin) − prefill_cost(cached)
        plus its decode — the same prefix-difference contract restores
        use — to 1e-9.
    (b) Cache-read closed form: fleet Σ cache-read joules ==
        Σ hits cached × kv_bytes × j_per_byte_read (and seconds ==
        bytes / read_bw), the eighth bucket.
    (c) Default-off identity: a cache-equipped fleet serving sessionless
        traffic is byte-identical to a cache-free fleet.
    (d) A session storm with tight capacity (LRU churn) and a crash
        (cache invalidation) under a live InvariantAuditor keeps the
        eight-bucket partition exact."""
    from repro.cluster import (ArrivalTrace, ClusterNode, FaultInjector,
                               LeastLoadedPolicy, PrefixCacheConfig,
                               SessionAffinityPolicy, TracedRequest,
                               poisson_trace, session_trace,
                               simulate_cluster)
    from repro.configs import TABLE1
    from repro.core.energy_model import fit_profile
    from repro.energy import SWING_NODE
    from repro.energy.costs import kv_bytes_per_token
    from repro.obs import InvariantAuditor, InvariantViolation, Telemetry

    name = "llama2-7b"
    sim = AnalyticLLMSimulator(PAPER_ZOO[name], SWING_NODE, batch=1,
                               kv_cache=True, noise_sigma=0.0)
    pts = [(8, 8), (64, 64), (256, 128), (512, 512), (2048, 64)]
    pbs = [sim.simulate(a, b) for a, b in pts]
    profile = fit_profile(name, TABLE1[name]["a_k"],
                          [p[0] for p in pts], [p[1] for p in pts],
                          [pb.energy_j for pb in pbs],
                          [pb.runtime_s for pb in pbs])
    kvb = kv_bytes_per_token(PAPER_ZOO[name])

    def nodes(cache, n=1):
        return [ClusterNode(i, PAPER_ZOO[name], profile, SWING_NODE,
                            max_batch=2, prefix_cache=cache)
                for i in range(n)]

    # (a)+(b): one session, two far-apart turns on one node
    pc = PrefixCacheConfig()
    trace = ArrivalTrace(name="warm", requests=(
        TracedRequest(0, 0.0, 512, 32, session_id=0, turn=0,
                      prefix_tokens=0),
        TracedRequest(1, 60.0, 800, 32, session_id=0, turn=1,
                      prefix_tokens=544),
    ))
    rep = simulate_cluster(trace, nodes(pc), LeastLoadedPolicy(), zeta=0.5)
    warm = rep.records[-1]
    t2, e2 = sim.prefill_cost(800, batch=1, freq_scale=1.0)
    t1, e1 = sim.prefill_cost(544, batch=1, freq_scale=1.0)
    td, ed = sim.decode_cost(800, 32, batch=1, freq_scale=1.0)
    want = (e2 - e1) + ed + sim.host_power_w * ((t2 - t1) + td)
    rel_warm = abs(warm.energy_j - want) / max(1.0, want)
    if warm.cached_tokens != 544 or rel_warm > 1e-9:
        failures.append(
            f"warm suffix charge off the telescoped closed form: cached "
            f"{warm.cached_tokens}, energy rel {rel_warm:.3e}")
    read_bytes = 544 * kvb
    rel_read_j = (abs(rep.total_cache_read_energy_j
                      - read_bytes * pc.j_per_byte_read)
                  / max(1e-12, rep.total_cache_read_energy_j))
    read_s = sum(s.cache_read_s for s in rep.node_stats)
    rel_read_s = abs(read_s - read_bytes / pc.read_bw) / max(1e-12, read_s)
    if rel_read_j > 1e-9 or rel_read_s > 1e-9:
        failures.append(
            f"cache-read bucket off closed form: energy rel "
            f"{rel_read_j:.3e}, time rel {rel_read_s:.3e}")

    # (c): sessionless traffic must not see the cache at all
    plain_trace = poisson_trace(30, 4.0, seed=3)
    with_cache = simulate_cluster(plain_trace, nodes(pc, n=2),
                                  LeastLoadedPolicy(), zeta=0.5)
    without = simulate_cluster(plain_trace, nodes(None, n=2),
                               LeastLoadedPolicy(), zeta=0.5)
    identical = (with_cache.to_json(include_records=True)
                 == without.to_json(include_records=True))
    if not identical:
        failures.append(
            "cache-equipped fleet diverged from cache-free on "
            "sessionless traffic")

    # (d): storm with LRU churn + crash invalidation, live-audited
    tight = PrefixCacheConfig(capacity_bytes=600 * kvb)
    storm_trace = session_trace(8, turns=5, think_s=4.0, rate_qps=1.0,
                                seed=5)
    faults = FaultInjector(mttf_s=25.0, mttr_s=5.0, seed=7).generate(
        [0, 1, 2], storm_trace.duration_s)
    tel = Telemetry(auditor=InvariantAuditor())
    try:
        storm = simulate_cluster(
            storm_trace, nodes(tight, n=3), SessionAffinityPolicy(),
            zeta=0.5, faults=faults, telemetry=tel)
    except InvariantViolation as e:
        failures.append(f"prefix-cache gate tripped the live auditor: {e}")
        return {"auditor": "violated"}
    worst_e = worst_t = 0.0
    for s in storm.node_stats:
        e_sum = (s.busy_energy_j + s.idle_energy_j + s.gated_energy_j
                 + s.transition_energy_j + s.shipping_energy_j
                 + s.checkpoint_energy_j + s.wasted_energy_j
                 + s.cache_read_energy_j)
        worst_e = max(worst_e, abs(e_sum - s.total_energy_j)
                      / max(1.0, s.total_energy_j))
        worst_t = max(worst_t, abs(s.accounted_s - s.horizon_s)
                      / max(1.0, s.horizon_s))
    if worst_e > 1e-9 or worst_t > 1e-9:
        failures.append(
            f"cached run violates eight-bucket conservation: energy rel "
            f"{worst_e:.3e}, time rel {worst_t:.3e}")
    if storm.total_cache_hits + storm.total_cache_misses == 0:
        failures.append("prefix-cache storm never consulted the cache")
    return {"warm_charge_rel": rel_warm, "cache_read_energy_rel": rel_read_j,
            "cache_read_time_rel": rel_read_s,
            "sessionless_identical": identical,
            "worst_energy_rel": worst_e, "worst_time_rel": worst_t,
            "tolerance": 1e-9, "storm_hits": storm.total_cache_hits,
            "storm_evictions": storm.total_cache_evictions,
            "auditor_checks": tel.auditor.n_checks}


def gate_power_conservation(failures: list[str]) -> dict:
    """Gated-sim energy accounting: the busy/idle/gated/transition buckets
    must sum to the total to 1e-9 and partition every node's horizon —
    gated seconds are never double-charged as idle."""
    from repro.cluster import (ClusterNode, PowerConfig, ReactiveIdlePolicy,
                               ZetaOnlinePolicy, onoff_trace,
                               simulate_cluster)
    from repro.configs import TABLE1
    from repro.core.energy_model import fit_profile
    from repro.energy import SWING_NODE

    fleet = ("llama2-7b", "llama2-13b")
    profiles = {}
    for name in fleet:
        sim = AnalyticLLMSimulator(PAPER_ZOO[name], SWING_NODE, batch=1,
                                   kv_cache=True, noise_sigma=0.0)
        pts = [(8, 8), (64, 64), (256, 128), (512, 512), (128, 32)]
        pbs = [sim.simulate(a, b) for a, b in pts]
        profiles[name] = fit_profile(
            name, TABLE1[name]["a_k"],
            [p[0] for p in pts], [p[1] for p in pts],
            [pb.energy_j for pb in pbs], [pb.runtime_s for pb in pbs])

    trace = onoff_trace(60, 0.5, on_s=5.0, off_s=45.0, seed=3)
    power = PowerConfig(gated_w=8.0, wake_s=10.0, gate_s=4.0,
                        wake_j=500.0, gate_j=100.0)
    nodes = [ClusterNode(i, PAPER_ZOO[name], profiles[name], SWING_NODE,
                         max_batch=8, power=power)
             for i, name in enumerate(fleet)]
    rep = simulate_cluster(
        trace, nodes, ZetaOnlinePolicy(), zeta=0.5,
        autoscaler=ReactiveIdlePolicy(idle_timeout_s=5.0, min_awake=0))
    worst_e = worst_t = 0.0
    if len(rep.records) != len(trace):
        failures.append("power-conservation gate lost requests")
    if rep.total_gates == 0 or rep.total_wakes == 0:
        failures.append("power-conservation gate saw no gate/wake churn")
    for s in rep.node_stats:
        e_sum = (s.busy_energy_j + s.idle_energy_j + s.gated_energy_j
                 + s.transition_energy_j)
        rel_e = abs(e_sum - s.total_energy_j) / max(1.0, s.total_energy_j)
        rel_t = abs(s.accounted_s - s.horizon_s) / max(1.0, s.horizon_s)
        worst_e = max(worst_e, rel_e)
        worst_t = max(worst_t, rel_t)
        if rel_e > 1e-9 or rel_t > 1e-9:
            failures.append(
                f"power conservation violated on node {s.node_id}: "
                f"energy rel {rel_e:.3e}, time rel {rel_t:.3e}")
    total = sum(s.busy_energy_j + s.idle_energy_j + s.gated_energy_j
                + s.transition_energy_j for s in rep.node_stats)
    rel = abs(total - rep.total_energy_j) / max(1.0, rep.total_energy_j)
    if rel > 1e-9:
        failures.append(f"fleet energy buckets off by rel {rel:.3e}")
    return {"worst_energy_rel": max(worst_e, rel), "worst_time_rel": worst_t,
            "tolerance": 1e-9, "gates": rep.total_gates,
            "wakes": rep.total_wakes}


def gate_metrics_overhead(failures: list[str]) -> dict:
    """Full telemetry (metrics + tracer + auditor + periodic sampling) on
    the seeded fig4-style fleet: the ClusterReport must be byte-identical
    to the uninstrumented run, the Prometheus dump must parse, the Chrome
    trace must be valid JSON, every settlement must pass the live auditor
    at 1e-9, and instrumentation CPU overhead must stay ≤ 20%.  (The
    budget was 5% when the uninstrumented loop still re-integrated
    phase physics per fresh fleet; the process-wide memo store removed
    that cost from the denominator, so the same ~25 µs/request of hook
    work now reads as ~10% relative, and the ±5% window-to-window swing
    the null comparison shows on shared runners rides on top — 20% of
    the faster baseline bounds the same absolute cost the old 5% did,
    and a real hook regression still fails every retry window.)"""
    from repro.cluster import (ClusterNode, ReactiveIdlePolicy,
                               SLOPreemptionPolicy, TauOutPredictor,
                               ZetaOnlinePolicy, replay_trace,
                               simulate_cluster)
    from repro.configs import CASE_STUDY_MODELS, TABLE1
    from repro.core.energy_model import fit_profile
    from repro.energy import SWING_NODE
    from repro.obs import (EventTracer, InvariantAuditor, InvariantViolation,
                           Telemetry)

    profiles = {}
    for name in CASE_STUDY_MODELS:
        sim = AnalyticLLMSimulator(PAPER_ZOO[name], SWING_NODE, batch=1,
                                   kv_cache=True, noise_sigma=0.0)
        pts = [(8, 8), (64, 64), (256, 128), (512, 512), (128, 32)]
        pbs = [sim.simulate(a, b) for a, b in pts]
        profiles[name] = fit_profile(
            name, TABLE1[name]["a_k"],
            [p[0] for p in pts], [p[1] for p in pts],
            [pb.energy_j for pb in pbs], [pb.runtime_s for pb in pbs])

    # the fig4 high-rate cell: 8 qps drives real batching and ~20
    # preemption splits, so the auditor's split-energy path is exercised
    # while the baseline per-event work (queue scans, batch scoring) is
    # representative of a loaded fleet
    queries = alpaca_like_workload(WorkloadSpec(n_queries=150, seed=7))
    trace = replay_trace(queries, 8.0, seed=11, name="alpaca@8qps")

    def run(telemetry=None):
        nodes = [ClusterNode(i, PAPER_ZOO[name], profiles[name], SWING_NODE,
                             max_batch=8, dvfs="per_phase")
                 for i, name in enumerate(CASE_STUDY_MODELS)]
        return simulate_cluster(
            trace, nodes,
            ZetaOnlinePolicy(tau_out_predictor=TauOutPredictor()), zeta=0.5,
            autoscaler=ReactiveIdlePolicy(idle_timeout_s=30.0),
            preempter=SLOPreemptionPolicy(slowdown_slo=2.0),
            telemetry=telemetry)

    def full_telemetry():
        return Telemetry(tracer=EventTracer(), auditor=InvariantAuditor(),
                         sample_every_s=5.0)

    # overhead first, on a clean heap (the export checks below allocate
    # MB-scale JSON strings whose allocator churn would pollute the
    # timing).  Interleaved best-of-N on *process* CPU time — a shared
    # runner's wall clock measures the co-tenant, CPU time measures us —
    # with GC paused so collection spikes don't land on one side.  On a
    # steal-prone host even CPU time carries cache-refill noise of a few
    # percent (an off-vs-off null comparison swings ±5%), so a miss is
    # retried with backoff until a quiet window is found: a real
    # regression fails every window, noise doesn't.
    import gc
    budget, rel = 0.20, float("inf")
    us_per_req = float("inf")   # reported for absolute-cost trend reading
    n_requests = len(trace.requests)
    run(); run(full_telemetry())   # warm both paths
    for attempt in range(5):
        if attempt:   # let a transient co-tenant burst pass before retrying
            time.sleep(2 ** attempt)
        reps = 5 + 3 * attempt
        t_off = t_on = float("inf")
        gc.collect()
        gc.disable()
        try:
            for _ in range(reps):
                start = time.process_time()
                run()
                t_off = min(t_off, time.process_time() - start)
                start = time.process_time()
                run(full_telemetry())
                t_on = min(t_on, time.process_time() - start)
        finally:
            gc.enable()
        rel = min(rel, (t_on - t_off) / t_off)
        us_per_req = min(us_per_req, (t_on - t_off) / n_requests * 1e6)
        if rel <= budget:
            break
    if rel > budget:
        failures.append(
            f"telemetry overhead {rel:.1%} ({us_per_req:.1f} µs/request) "
            f"exceeds the {budget:.0%} budget")

    base = run()
    tel = full_telemetry()
    try:
        instr = run(tel)
    except InvariantViolation as exc:
        failures.append(f"live auditor tripped on a clean run: {exc}")
        return {"auditor": "violated"}
    byte_identical = (base.to_json(include_records=True)
                      == instr.to_json(include_records=True))
    if not byte_identical:
        failures.append("telemetry-on report differs from telemetry-off")

    prom = tel.prometheus_text()
    (REPO_ROOT / "BENCH_telemetry.prom").write_text(prom)
    try:
        from prometheus_client.parser import text_string_to_metric_families
        n_fams = len(list(text_string_to_metric_families(prom)))
    except ImportError:   # minimal grammar check without the parser
        n_fams = sum(1 for ln in prom.splitlines()
                     if ln.startswith("# TYPE "))
    if n_fams < 10:
        failures.append(f"prometheus dump looks empty: {n_fams} families")
    try:
        chrome = json.loads(tel.tracer.to_json())
        if not chrome["traceEvents"]:
            failures.append("chrome trace has no events")
    except (json.JSONDecodeError, KeyError) as exc:
        failures.append(f"chrome trace export invalid: {exc}")
    return {"overhead_rel": rel, "budget": budget,
            "overhead_us_per_request": us_per_req,
            "auditor_checks": tel.auditor.n_checks,
            "trace_events": len(tel.tracer.events),
            "prom_families": n_fams,
            "report_byte_identical": byte_identical}


def gate_sharded_replay(failures: list[str]) -> dict:
    """The sharded event engine's two contracts on the fig4 fleet:

    *equivalence* — replaying a seeded fault+autoscale+preemption trace
    over {1, 2, 4, 8} node-group shards is byte-identical to the
    sequential loop (ClusterReport JSON, Prometheus exposition, Chrome
    trace — the merge mode's by-construction guarantee, pinned here
    against drift);

    *throughput* — the engine sustains ≥ 1e6 simulated requests/min,
    measured warm best-of-N over fresh fleets in each execution mode
    (sequential merge, windowed barriers, and the process-pool runner at
    auto worker count); the headline is the best mode, recorded per-mode
    so a single-core runner degrading the pool to inline is visible."""
    from repro.cluster import (ClusterNode, FailoverPolicy, FaultInjector,
                               PowerConfig, ReactiveIdlePolicy,
                               RoundRobinPolicy, Runner, SLOPreemptionPolicy,
                               ZetaOnlinePolicy, replay_trace)
    from repro.configs import CASE_STUDY_MODELS, TABLE1
    from repro.core.energy_model import fit_profile
    from repro.energy import SWING_NODE
    from repro.obs import EventTracer, InvariantAuditor, Telemetry

    profiles = {}
    for name in CASE_STUDY_MODELS:
        sim = AnalyticLLMSimulator(PAPER_ZOO[name], SWING_NODE, batch=1,
                                   kv_cache=True, noise_sigma=0.0)
        pts = [(8, 8), (64, 64), (256, 128), (512, 512), (128, 32)]
        pbs = [sim.simulate(a, b) for a, b in pts]
        profiles[name] = fit_profile(
            name, TABLE1[name]["a_k"],
            [p[0] for p in pts], [p[1] for p in pts],
            [pb.energy_j for pb in pbs], [pb.runtime_s for pb in pbs])

    # --- equivalence: every cross-shard channel live at once ----------
    def governed_nodes():
        return [ClusterNode(i, PAPER_ZOO[name], profiles[name], SWING_NODE,
                            max_batch=2,
                            power=PowerConfig(wake_s=3.0, gate_s=1.0))
                for i, name in enumerate(CASE_STUDY_MODELS * 2)]

    eq_trace = replay_trace(
        alpaca_like_workload(WorkloadSpec(n_queries=100, seed=7)),
        6.0, seed=11, name="alpaca@6qps")
    faults = FaultInjector(mttf_s=15.0, mttr_s=4.0, seed=5).generate(
        [n.node_id for n in governed_nodes()], eq_trace.duration_s + 20)

    def replay(shards):
        tel = Telemetry(tracer=EventTracer(), auditor=InvariantAuditor(),
                        sample_every_s=2.0)
        rep = Runner(eq_trace, governed_nodes(),
                     FailoverPolicy(ZetaOnlinePolicy()), zeta=0.5,
                     autoscaler=ReactiveIdlePolicy(idle_timeout_s=2.0),
                     preempter=SLOPreemptionPolicy(slowdown_slo=1.2,
                                                   min_remaining=2),
                     faults=faults, telemetry=tel, shard_count=shards).run()
        return (rep.to_json(include_records=True), tel.prometheus_text(),
                tel.tracer.to_json())

    base = replay(1)
    equivalent_at = []
    for k in (2, 4, 8):
        if replay(k) == base:
            equivalent_at.append(k)
        else:
            failures.append(
                f"sharded replay diverged from sequential at shards={k}")

    # --- throughput: the fig4 fleet, warm best-of-N per mode ----------
    def fleet():
        return [ClusterNode(i, PAPER_ZOO[name], profiles[name], SWING_NODE,
                            max_batch=8)
                for i, name in enumerate(CASE_STUDY_MODELS)]

    n_requests = 1200
    tp_trace = replay_trace(
        alpaca_like_workload(WorkloadSpec(n_queries=n_requests, seed=7)),
        8.0, seed=11, name="alpaca@8qps")

    def throughput(mode, shards, workers, reps=3):
        best = float("inf")
        for _ in range(reps):
            nodes = fleet()
            start = time.perf_counter()
            Runner(tp_trace, nodes, RoundRobinPolicy(), zeta=0.5,
                   shard_count=shards, mode=mode, workers=workers).run()
            best = min(best, time.perf_counter() - start)
        return n_requests / best * 60.0

    throughput("merge", 1, None, reps=1)   # warm the physics memos
    modes = {
        "merge_s1": throughput("merge", 1, None),
        "windowed_s4": throughput("windowed", 4, None),
        "pooled_s4_auto": throughput("windowed", 4, "auto"),
    }
    headline_mode = max(modes, key=modes.get)
    requests_per_min = modes[headline_mode]
    floor = 1e6
    if requests_per_min < floor:
        failures.append(
            f"sharded engine sustains {requests_per_min:,.0f} simulated "
            f"requests/min (best mode {headline_mode}) — below the "
            f"{floor:,.0f} floor")
    return {"equivalent_at_shards": equivalent_at,
            "requests_per_min": requests_per_min,
            "headline_mode": headline_mode,
            "requests_per_min_by_mode": modes,
            "floor": floor,
            "auto_workers": min(4, os.cpu_count() or 1)}


def run_gates(quick: bool) -> tuple[dict, list[str]]:
    failures: list[str] = []
    out = {
        "decode_closed_form": gate_decode_closed_form(failures),
        "pass_costs_batch": gate_pass_costs_batch(failures),
        "measure_batch": gate_measure_batch(failures),
        "capacitated_solver": gate_capacitated_solver(
            failures, n_instances=8 if quick else 12),
        "warm_start": gate_warm_start(
            failures, n_instances=12 if quick else 25),
        "jit_cost_kernel": gate_jit_cost_kernel(failures),
        "dvfs_closed_form": gate_dvfs_closed_form(failures),
        "power_conservation": gate_power_conservation(failures),
        "preemption_split": gate_preemption_split(failures),
        "migration_settlement": gate_migration_settlement(failures),
        "checkpoint_settlement": gate_checkpoint_settlement(failures),
        "prefix_cache_settlement": gate_prefix_cache_settlement(failures),
        "metrics_overhead": gate_metrics_overhead(failures),
        "sharded_replay": gate_sharded_replay(failures),
    }
    return out, failures


# ---------------------------------------------------------------------------
# Timings (full run only)
# ---------------------------------------------------------------------------


def bench_decode() -> dict:
    """Headline (a): decode_cost closed form at τout = 4096 vs the loop."""
    cfg = PAPER_ZOO["llama2-7b"]
    out = {}
    for kv in (False, True):
        sim = AnalyticLLMSimulator(cfg, batch=32, kv_cache=kv, noise_sigma=0.0)
        us_closed, res_c = timed(
            lambda: sim._decode_closed_form(32, 4096, 32), repeats=20)
        us_exact, res_e = timed(
            lambda: sim.decode_cost_chunked(32, 4096, chunk=1), repeats=2)
        us_256, res_256 = timed(
            lambda: sim.decode_cost_chunked(32, 4096, chunk=256), repeats=10)
        rel_exact = max(abs(res_c[0] - res_e[0]) / res_e[0],
                        abs(res_c[1] - res_e[1]) / res_e[1])
        rel_256 = max(abs(res_256[0] - res_e[0]) / res_e[0],
                      abs(res_256[1] - res_e[1]) / res_e[1])
        out[f"kv_{'on' if kv else 'off'}"] = {
            "closed_form_us": us_closed,
            "exact_loop_us": us_exact,
            "chunk256_loop_us": us_256,
            "speedup_vs_exact_loop": us_exact / us_closed,
            "speedup_vs_chunk256": us_256 / us_closed,
            "rel_err_vs_exact_loop": rel_exact,
            "chunk256_rel_err_vs_exact": rel_256,
        }
    return out


def bench_pass_costs_batch(sizes: list[int]) -> dict:
    cfg = PAPER_ZOO["llama2-7b"]
    out = {}
    for m in sizes:
        rng = np.random.default_rng(m)
        nt = rng.integers(1, 2048, m).astype(float)
        ctx = nt.copy()
        us_batch, pcb = timed(
            lambda: costs_lib.pass_costs_batch(cfg, nt, ctx, 32.0,
                                               decode=False), repeats=5)
        n_scalar = min(m, 2000)  # scalar loop timed on a slice, scaled up
        us_scalar_slice, _ = timed(
            lambda: [costs_lib.pass_costs(cfg, nt[i], ctx[i], 32.0,
                                          decode=False)
                     for i in range(n_scalar)], repeats=2)
        us_scalar = us_scalar_slice * (m / n_scalar)
        out[str(m)] = {
            "batch_us": us_batch,
            "scalar_loop_us": us_scalar,
            "speedup": us_scalar / us_batch,
        }
    return out


def bench_measure_batch(sizes: list[int]) -> dict:
    cfg = PAPER_ZOO["llama2-7b"]
    out = {}
    for m in sizes:
        qs = workload(m, seed=m)
        tin = np.array([q[0] for q in qs])
        tout = np.array([q[1] for q in qs])
        sim_b = AnalyticLLMSimulator(cfg, kv_cache=True, seed=0)
        t0 = time.perf_counter()
        sim_b.measure_batch(tin, tout)
        t_batch = time.perf_counter() - t0
        n_seq = min(m, 1000)
        sim_s = AnalyticLLMSimulator(cfg, kv_cache=True, seed=0)
        t0 = time.perf_counter()
        for i in range(n_seq):
            sim_s.measure(int(tin[i]), int(tout[i]))
        t_seq = (time.perf_counter() - t0) * (m / n_seq)
        out[str(m)] = {
            "batch_s": t_batch,
            "sequential_s_scaled": t_seq,
            "speedup": t_seq / t_batch,
            "unique_pairs": int(len(np.unique(np.stack([tin, tout], 1),
                                              axis=0))),
        }
    return out


def bench_campaign() -> dict:
    """Whole-grid batched characterization campaign vs the scalar driver."""
    cfg = PAPER_ZOO["llama2-7b"]
    settings = characterize_lib.CampaignSettings(max_trials=5)
    sim_b = AnalyticLLMSimulator(cfg, kv_cache=False, seed=0)
    t0 = time.perf_counter()
    trials_b = characterize_lib.run_campaign(
        "llama2-7b", None, settings, measure_batch=sim_b.measure_batch)
    t_batch = time.perf_counter() - t0
    sim_s = AnalyticLLMSimulator(cfg, kv_cache=False, seed=0)
    t0 = time.perf_counter()
    trials_s = characterize_lib.run_campaign("llama2-7b", sim_s.measure,
                                             settings)
    t_seq = time.perf_counter() - t0
    return {
        "batched_s": t_batch,
        "sequential_s": t_seq,
        "speedup": t_seq / t_batch,
        "trials_batched": len(trials_b),
        "trials_sequential": len(trials_s),
    }


def bench_schedule(sizes: list[int]) -> dict:
    out = {}
    profs = synthetic_fleet(5, seed=1)
    for m in sizes:
        qs = workload(m, seed=m)
        us, asg = timed(lambda: scheduler.schedule(profs, qs, 0.5), repeats=3)
        out[str(m)] = {"schedule_us": us,
                       "queries_per_s": m / (us * 1e-6),
                       "objective": asg.objective}
    return out


def bench_schedule_capacitated(sizes: list[int], headline_m: int,
                               ref_direct_max: int,
                               failures: list[str]) -> dict:
    """Headline (b): chains solver vs the _MinCostFlow oracle.

    The oracle is O(m²k), so it is run directly up to `ref_direct_max`
    (objectives checked bit-identical at every direct point) and its
    headline-size runtime is extrapolated from a power-law fit; the chains
    result at the headline size carries the exact optimality certificate
    instead of an oracle re-solve."""
    k = 5
    profs = synthetic_fleet(k, seed=1)
    rng = np.random.default_rng(42)
    gamma = random_gamma(k, rng)
    zeta = 0.5

    direct_ms = sorted({m for m in (500, 1000, 2000, 5000, ref_direct_max)
                        if m <= ref_direct_max})
    if len(direct_ms) < 2:  # the power-law fit needs >= 2 direct points
        direct_ms = sorted({max(2, ref_direct_max // 4), ref_direct_max})
    if len(direct_ms) < 2:
        raise SystemExit("--ref-direct-max too small to fit the oracle "
                         "runtime (need >= 2 distinct direct sizes)")
    points = {}
    for m in direct_ms:
        qs = workload(m, seed=m)
        t0 = time.perf_counter()
        a = scheduler.schedule_capacitated(profs, qs, zeta, gamma,
                                           method="chains")
        t_chain = time.perf_counter() - t0
        t0 = time.perf_counter()
        b = scheduler.schedule_capacitated(profs, qs, zeta, gamma,
                                           method="flow")
        t_flow = time.perf_counter() - t0
        identical = a.objective == b.objective
        if not identical and abs(a.objective - b.objective) > 1e-12 * max(
                1.0, abs(b.objective)):
            failures.append(
                f"capacitated objective mismatch at m={m}: "
                f"chains={a.objective!r} flow={b.objective!r}")
        points[str(m)] = {
            "chains_s": t_chain,
            "flow_s": t_flow,
            "speedup": t_flow / t_chain,
            "objective_bit_identical": identical,
        }

    # power-law fit of the oracle runtime (known ~quadratic in m)
    ms = np.array([int(m) for m in points], dtype=float)
    ts = np.array([points[m]["flow_s"] for m in points])
    slope, intercept = np.polyfit(np.log(ms), np.log(ts), 1)
    flow_headline_s = float(np.exp(intercept + slope * np.log(headline_m)))

    qs = workload(headline_m, seed=headline_m)
    t0 = time.perf_counter()
    a = scheduler.schedule_capacitated(profs, qs, zeta, gamma,
                                       method="chains")
    t_chain_headline = time.perf_counter() - t0
    costs = normalized_costs(profs, qs)
    C = objective_matrix(costs, zeta)
    caps = scheduler._capacities_from_gamma(gamma, len(qs))
    cert = scheduler.capacitated_optimality_certificate(C, a.assignee, caps)
    if not cert:
        failures.append(f"optimality certificate failed at m={headline_m}")

    extra_sizes = {}
    for m in sizes:
        if str(m) in points or m == headline_m:
            continue
        qs_m = workload(m, seed=m)
        t0 = time.perf_counter()
        scheduler.schedule_capacitated(profs, qs_m, zeta, gamma,
                                       method="chains")
        extra_sizes[str(m)] = {"chains_s": time.perf_counter() - t0}

    return {
        "k": k,
        "direct_comparison": points,
        "flow_runtime_fit": {"log_slope": float(slope),
                             "log_intercept": float(intercept)},
        "headline": {
            "m": headline_m,
            "chains_s": t_chain_headline,
            "flow_s_extrapolated": flow_headline_s,
            "speedup_vs_flow_extrapolated": flow_headline_s / t_chain_headline,
            "optimality_certificate": cert,
            "objective": a.objective,
        },
        "chains_scaling": extra_sizes,
    }


def bench_warm_start(headline_m: int, failures: list[str],
                     *, delta: int = 64) -> dict:
    """Headline (c): warm-start small-delta reschedule vs cold chains
    re-solve at the headline size.  The delta draws from the same workload
    distribution, so the normalization maxima stay put and the repair does
    O(delta) chain moves — the small-delta regime the ≥10× target names.
    A ζ-step re-plan (the sweep's inner move) is timed too."""
    k = 5
    profs = synthetic_fleet(k, seed=1)
    gamma = tuple((np.ones(k) / k).tolist())
    qs = workload(headline_m, seed=headline_m)
    inc = IncrementalScheduler(profs, qs, 0.5, gamma)
    added = workload(delta, seed=headline_m + 1)
    rng = np.random.default_rng(3)
    removed = list(rng.choice(inc.active_ids, size=delta, replace=False))

    t0 = time.perf_counter()
    warm = inc.reschedule(added=added, removed=removed)
    t_warm = time.perf_counter() - t0
    t0 = time.perf_counter()
    cold = scheduler.schedule_capacitated(profs, inc.active_queries(),
                                          0.5, gamma)
    t_cold = time.perf_counter() - t0
    delta_match = abs(warm.objective - cold.objective) <= 1e-12 * max(
        1.0, abs(cold.objective))
    if not delta_match:
        failures.append(
            f"warm-start headline objective mismatch at m={headline_m}: "
            f"warm={warm.objective!r} cold={cold.objective!r}")

    t0 = time.perf_counter()
    zstep = inc.reschedule(zeta=0.55)
    t_zeta = time.perf_counter() - t0
    cold_z = scheduler.schedule_capacitated(profs, inc.active_queries(),
                                            0.55, gamma)
    zeta_match = abs(zstep.objective - cold_z.objective) <= 1e-12 * max(
        1.0, abs(cold_z.objective))
    if not zeta_match:
        failures.append(f"warm-start ζ-step mismatch at m={headline_m}")
    return {
        "m": headline_m,
        "delta": delta,
        "warm_reschedule_s": t_warm,
        "cold_chains_s": t_cold,
        "speedup": t_cold / t_warm,
        "zeta_step_warm_s": t_zeta,
        "objective_matches_cold": delta_match and zeta_match,
    }


def bench_pareto(sizes: list[int], failures: list[str]) -> dict:
    """Streaming ζ sweep: warm grid vs cold zeta_sweep, and the exact
    breakpoint frontier's cost."""
    k = 5
    profs = synthetic_fleet(k, seed=1)
    gamma = tuple((np.ones(k) / k).tolist())
    zetas = np.linspace(0.0, 1.0, 21)
    out = {}
    for m in sizes:
        if m > 20000:   # cold sweep at 21 ζ would dominate the suite
            continue
        qs = workload(m, seed=m)
        t0 = time.perf_counter()
        warm = pareto_frontier(profs, qs, zetas, gamma=gamma)
        t_warm = time.perf_counter() - t0
        t0 = time.perf_counter()
        cold = scheduler.zeta_sweep(profs, qs, zetas, gamma=gamma)
        t_cold = time.perf_counter() - t0
        match = all(abs(a.objective - b.objective)
                    <= 1e-12 * max(1.0, abs(b.objective))
                    for a, b in zip(warm.assignments, cold))
        if not match:
            failures.append(f"pareto grid objective mismatch at m={m}")
        t0 = time.perf_counter()
        fr = pareto_frontier(profs, qs, breakpoints=True)
        t_bp = time.perf_counter() - t0
        out[str(m)] = {
            "grid21_warm_s": t_warm,
            "grid21_cold_s": t_cold,
            "grid21_speedup": t_cold / t_warm,
            "grid21_objectives_match": match,
            "breakpoints": len(fr.breakpoints),
            "breakpoint_frontier_s": t_bp,
        }
    return out


def bench_jit_cost_kernel(sizes: list[int]) -> dict:
    """Jitted batch cost kernel throughput: m-query (and m×k) energy/
    runtime surfaces in one on-device call vs the numpy closed-form loop."""
    try:
        from repro.kernels import cost_batch
    except Exception as e:  # noqa: BLE001
        return {"skipped": f"{type(e).__name__}: {e}"}
    cfg = PAPER_ZOO["llama2-7b"]
    sim = AnalyticLLMSimulator(cfg, batch=4, kv_cache=True, noise_sigma=0.0)
    out = {}
    for m in sizes:
        rng = np.random.default_rng(m)
        tin = rng.integers(1, 4096, m)
        tout = rng.integers(1, 4096, m)
        us_jit, (e_j, r_j) = timed(
            lambda: cost_batch.simulate_batch(sim, tin, tout), repeats=3)
        n_ref = min(m, 2000)      # python loop timed on a slice, scaled up;
        sim._prefill_memo.clear()  # memo-cold, so the loop pays full price
        sim._decode_memo.clear()
        t0 = time.perf_counter()
        for i in range(n_ref):
            sim.simulate(int(tin[i]), int(tout[i]))
        us_ref = (time.perf_counter() - t0) * 1e6 * (m / n_ref)
        out[str(m)] = {
            "jit_us": us_jit,
            "numpy_loop_us_scaled": us_ref,
            "speedup": us_ref / us_jit,
            "queries_per_s": m / (us_jit * 1e-6),
        }
    return out


def bench_cluster(sizes: list[int]) -> dict:
    from repro.cluster import (ClusterNode, ZetaOnlinePolicy, poisson_trace,
                               simulate_cluster)
    from repro.configs import TABLE1
    from repro.core.energy_model import fit_profile
    from repro.energy import SWING_NODE

    fleet = ("llama2-7b", "llama2-13b", "llama2-70b")
    profiles = {}
    for name in fleet:
        sim = AnalyticLLMSimulator(PAPER_ZOO[name], SWING_NODE, batch=1,
                                   kv_cache=True, noise_sigma=0.0)
        pts = [(8, 8), (64, 64), (256, 128), (1024, 256), (32, 512),
               (512, 512), (128, 32), (2048, 64)]
        pbs = [sim.simulate(a, b) for a, b in pts]
        profiles[name] = fit_profile(
            name, TABLE1[name]["a_k"],
            [p[0] for p in pts], [p[1] for p in pts],
            [pb.energy_j for pb in pbs], [pb.runtime_s for pb in pbs])

    out = {}
    for n in sizes:
        if n > 20000:   # event loop is O(n log n); keep the suite bounded
            continue
        trace = poisson_trace(n, 8.0, seed=3)
        nodes = [ClusterNode(i, PAPER_ZOO[name], profiles[name], SWING_NODE,
                             max_batch=8) for i, name in enumerate(fleet)]
        t0 = time.perf_counter()
        rep = simulate_cluster(trace, nodes, ZetaOnlinePolicy(), zeta=0.5)
        dt = time.perf_counter() - t0
        out[str(n)] = {"wall_s": dt, "requests_per_s": n / dt,
                       "slo": rep.slo_attainment()}
    return out


# ---------------------------------------------------------------------------


def _git_commit() -> str:
    import subprocess
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=REPO_ROOT, capture_output=True, text=True,
                             timeout=10)
        return out.stdout.strip() or "unknown"
    except Exception:  # noqa: BLE001
        return "unknown"


def _load_history(path: Path) -> list:
    """Prior runs' compact entries — the perf trajectory across PRs."""
    if not path.exists():
        return []
    try:
        prev = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError):
        return []
    history = list(prev.get("history", []))
    if not history and "headline" in prev:
        # first run after the history feature landed: preserve the last
        # pre-history snapshot as the opening entry
        history.append({"commit": "pre-history",
                        "created_unix": prev.get("created_unix"),
                        "wall_s": prev.get("wall_s"),
                        "headline": prev["headline"]})
    return history


def _merge_history(history: list, entry: dict) -> list:
    """One history entry per commit: a re-run on the same commit replaces
    its entry in place (keeping whichever run had the best wall_s), so
    repeated local runs don't inflate the trajectory; prior commits'
    entries are never touched."""
    out = list(history)
    for i, prev in enumerate(out):
        if prev.get("commit") == entry.get("commit"):
            prev_wall = prev.get("wall_s") or float("inf")
            new_wall = entry.get("wall_s") or float("inf")
            out[i] = entry if new_wall <= prev_wall else prev
            return out
    out.append(entry)
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="correctness gates only (the scripts/test.sh perf tier)")
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_core.json"))
    ap.add_argument("--sizes", default="1000,10000,100000")
    ap.add_argument("--headline-m", type=int, default=50_000)
    ap.add_argument("--ref-direct-max", type=int, default=10_000)
    args = ap.parse_args(argv)
    sizes = [int(s) for s in args.sizes.split(",") if s]

    t_start = time.time()
    gates, failures = run_gates(args.quick)
    for name, res in gates.items():
        print(f"gate.{name},0,{res}")

    if not args.quick:
        bench = {
            "decode_cost_tau4096": bench_decode(),
            "pass_costs_batch": bench_pass_costs_batch(sizes),
            "measure_batch": bench_measure_batch(sizes),
            "campaign_grid": bench_campaign(),
            "schedule": bench_schedule(sizes),
            "schedule_capacitated": bench_schedule_capacitated(
                sizes, args.headline_m, args.ref_direct_max, failures),
            "warm_start_reschedule": bench_warm_start(
                args.headline_m, failures),
            "pareto_sweep": bench_pareto(sizes, failures),
            "jit_cost_kernel": bench_jit_cost_kernel(sizes),
            "cluster_sim": bench_cluster(sizes),
        }
        dec = bench["decode_cost_tau4096"]["kv_off"]
        cap = bench["schedule_capacitated"]["headline"]
        ws = bench["warm_start_reschedule"]
        jit = bench["jit_cost_kernel"]
        jit_top = (None if "skipped" in jit
                   else jit[max(jit, key=lambda s: int(s))])
        doc = {
            "suite": "core",
            "created_unix": time.time(),
            "wall_s": time.time() - t_start,
            "headline": {
                "decode_cost_tau4096_speedup_vs_exact_loop":
                    dec["speedup_vs_exact_loop"],
                "decode_cost_tau4096_rel_err": dec["rel_err_vs_exact_loop"],
                f"schedule_capacitated_m{args.headline_m}_k5_speedup":
                    cap["speedup_vs_flow_extrapolated"],
                f"schedule_capacitated_m{args.headline_m}_chains_s":
                    cap["chains_s"],
                f"schedule_capacitated_m{args.headline_m}_flow_s_extrapolated":
                    cap["flow_s_extrapolated"],
                "objectives_bit_identical_at_direct_points": all(
                    p["objective_bit_identical"] for p in
                    bench["schedule_capacitated"]["direct_comparison"].values()),
                "optimality_certificate_at_headline":
                    cap["optimality_certificate"],
                f"warm_start_reschedule_m{args.headline_m}_delta{ws['delta']}"
                "_speedup": ws["speedup"],
                f"warm_start_reschedule_m{args.headline_m}_warm_s":
                    ws["warm_reschedule_s"],
                "warm_start_objective_matches_cold":
                    ws["objective_matches_cold"],
                "jit_cost_kernel_worst_rel_err":
                    gates["jit_cost_kernel"].get("worst_rel_err"),
                "jit_cost_kernel_queries_per_s":
                    None if jit_top is None else jit_top["queries_per_s"],
                "sharded_replay_requests_per_min":
                    gates["sharded_replay"]["requests_per_min"],
                "sharded_replay_equivalent_at_shards":
                    gates["sharded_replay"]["equivalent_at_shards"],
            },
            "gates": gates,
            "bench": bench,
            "env": {"python": sys.version.split()[0],
                    "numpy": np.__version__},
        }
        out_path = Path(args.out)
        doc["history"] = _merge_history(_load_history(out_path), {
            "commit": _git_commit(),
            "created_unix": doc["created_unix"],
            "wall_s": doc["wall_s"],
            "headline": doc["headline"],
        })
        Path(args.out).write_text(json.dumps(doc, indent=2) + "\n")
        print(f"perf_suite.wrote,{(time.time() - t_start) * 1e6:.0f},{args.out}")
        for key, val in doc["headline"].items():
            print(f"headline.{key},0,{val}")

    if failures:
        for f in failures:
            print(f"FAIL,0,{f}", file=sys.stderr)
        return 1
    print(f"perf_suite.ok,{(time.time() - t_start) * 1e6:.0f},"
          f"{'quick' if args.quick else 'full'}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
