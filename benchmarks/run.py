"""Benchmark harness — one module per paper table/figure.  Prints
``name,us_per_call,derived`` CSV lines (the repo contract)."""

from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (
    fig1_input_tokens,
    fig2_output_tokens,
    fig3_zeta_sweep,
    fig_pareto,
    roofline_bench,
    table1_models,
    table2_anova,
    table3_ols,
)

SUITES = [
    ("table1", table1_models),
    ("fig1", fig1_input_tokens),
    ("fig2", fig2_output_tokens),
    ("table2", table2_anova),
    ("table3", table3_ols),
    ("fig3", fig3_zeta_sweep),
    ("fig_pareto", fig_pareto),
    ("roofline", roofline_bench),
]


def main() -> int:
    failures = 0
    print("name,us_per_call,derived")
    for name, mod in SUITES:
        t0 = time.time()
        try:
            mod.main()
            print(f"{name}.wall_s,{(time.time() - t0) * 1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001
            failures += 1
            traceback.print_exc(limit=4, file=sys.stderr)
            print(f"{name}.wall_s,{(time.time() - t0) * 1e6:.0f},FAILED {type(e).__name__}: {e}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
