"""Pareto-frontier figure (paper §6 trade-off study, streaming edition).

Traces the energy–runtime–accuracy frontier of the Llama-2 case-study
fleet three ways and prints the `name,us_per_call,derived` CSV contract:

  * exact mode — `core.sweep.pareto_frontier(breakpoints=True)`: the ζ
    values where the unconstrained argmin assignment actually changes
    (lower-envelope crossings), one assignment per constant segment —
    the whole frontier, not a grid sample of it;
  * warm grid — the γ-capacitated frontier on a 21-point grid, each ζ
    warm-started from its neighbour through IncrementalScheduler, timed
    against the cold per-ζ `zeta_sweep` and checked to match it exactly;
  * re-plan delta — a 20k-query synthetic workload edited by ±64 queries,
    `reschedule` vs a cold `schedule_capacitated` re-solve.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, synthetic_fleet, timed
from benchmarks.fig3_zeta_sweep import fit_fleet
from repro.configs import CASE_STUDY_GAMMA
from repro.core import scheduler
from repro.core.energy_model import normalized_costs
from repro.core.sweep import IncrementalScheduler, pareto_frontier
from repro.data import alpaca_like_workload
from repro.data.workloads import WorkloadSpec

GRID = np.round(np.linspace(0.0, 1.0, 21), 3)


def main() -> None:
    profiles = fit_fleet()
    queries = alpaca_like_workload()
    m = len(queries)
    costs = normalized_costs(profiles, queries)

    # --- exact frontier: breakpoints instead of a grid -------------------
    us_exact, fr = timed(
        lambda: pareto_frontier(profiles, queries, costs=costs,
                                breakpoints=True), repeats=1)
    emit("fig_pareto.exact_frontier", us_exact,
         f"breakpoints={len(fr.breakpoints)} segments={len(fr.assignments)} "
         f"E_range=[{fr.energies().min():.0f},{fr.energies().max():.0f}]J")
    e = fr.energies()
    r = fr.runtimes()
    mono = (all(b <= a + 1e-6 for a, b in zip(e, e[1:]))
            and all(b <= a + 1e-6 for a, b in zip(r, r[1:])))
    emit("fig_pareto.exact_claims", 0.0,
         f"energy_runtime_monotone_along_frontier={mono} "
         f"accuracy_tradeoff={fr.accuracies()[0] >= fr.accuracies()[-1]}")

    # --- capacitated warm grid vs cold sweep -----------------------------
    t0 = time.perf_counter()
    warm = pareto_frontier(profiles, queries, GRID,
                           gamma=CASE_STUDY_GAMMA, costs=costs)
    t_warm = time.perf_counter() - t0
    t0 = time.perf_counter()
    cold = scheduler.zeta_sweep(profiles, queries, GRID,
                                gamma=CASE_STUDY_GAMMA)
    t_cold = time.perf_counter() - t0
    match = all(abs(a.objective - b.objective)
                <= 1e-12 * max(1.0, abs(b.objective))
                for a, b in zip(warm.assignments, cold))
    emit("fig_pareto.gamma_grid21", t_warm * 1e6 / len(GRID),
         f"m={m} warm_s={t_warm:.3f} cold_s={t_cold:.3f} "
         f"speedup={t_cold / t_warm:.1f}x objectives_match={match}")
    for z, asg in zip(warm.zetas[::5], warm.assignments[::5]):
        emit(f"fig_pareto.gamma_zeta_{z:.2f}", 0.0,
             f"E={asg.total_energy_j:.0f}J counts={asg.counts().tolist()}")

    # --- incremental re-plan on a 20k workload ---------------------------
    k = 5
    profs = synthetic_fleet(k, seed=1)
    rng = np.random.default_rng(2)
    big = alpaca_like_workload(WorkloadSpec(n_queries=20_000, seed=7))
    gamma = tuple((np.ones(k) / k).tolist())
    inc = IncrementalScheduler(profs, big, 0.5, gamma)
    # same-distribution delta (the honest small-delta case: normalization
    # maxima stay put, so the repair is O(delta) chain moves)
    added = alpaca_like_workload(WorkloadSpec(n_queries=64, seed=11))
    removed = list(rng.choice(inc.active_ids, size=64, replace=False))
    t0 = time.perf_counter()
    asg = inc.reschedule(added=added, removed=removed)
    t_delta = time.perf_counter() - t0
    t0 = time.perf_counter()
    cold_asg = scheduler.schedule_capacitated(profs, inc.active_queries(),
                                              0.5, gamma)
    t_cold = time.perf_counter() - t0
    emit("fig_pareto.replan_delta64_m20000", t_delta * 1e6,
         f"warm_s={t_delta:.4f} cold_s={t_cold:.3f} "
         f"speedup={t_cold / t_delta:.0f}x "
         f"objective_match={abs(asg.objective - cold_asg.objective) <= 1e-12 * max(1.0, abs(cold_asg.objective))}")


if __name__ == "__main__":
    main()
