"""Paper Table 3: per-model OLS fits of Eq. 6 (energy) and Eq. 7 (runtime).

Headline claim: R^2 > 0.96 for every model, both metrics.  Also runs the
beyond-paper EXTENDED model (adds tau_out^2 — the KV-less decode's true
quadratic term) and reports the R^2 gain."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.configs import PAPER_ZOO, TABLE1
from repro.core import stats
from repro.core.characterize import (
    CampaignSettings,
    fit_profile_from_trials,
    run_campaign,
    trials_to_arrays,
)
from repro.energy import AnalyticLLMSimulator

SETTINGS = CampaignSettings(
    vary_input_range=(8, 2048), vary_output_range=(8, 4096),
    grid_range=(8, 2048), max_trials=3, min_trials=2, seed=3)


def extended_fit(tin, tout, y):
    """Beyond-paper: e = a0*tin + a1*tout + a2*tin*tout + a3*tout^2."""
    X = np.stack([tin, tout, tin * tout, tout * tout], axis=1)
    return stats.ols(X, y)


def run(models=None):
    models = models or sorted(PAPER_ZOO)
    out = {}
    for name in models:
        sim = AnalyticLLMSimulator(PAPER_ZOO[name], kv_cache=False,
                                   noise_sigma=0.015, seed=5)
        trials = run_campaign(name, sim.measure, SETTINGS)
        prof = fit_profile_from_trials(name, TABLE1[name]["a_k"], trials)
        tin, tout, e, r = trials_to_arrays(trials, conditions=("grid",))
        ext_e = extended_fit(tin, tout, e)
        ext_r = extended_fit(tin, tout, r)
        out[name] = {"profile": prof, "ext_e": ext_e, "ext_r": ext_r,
                     "trials": trials}
    return out


def main() -> None:
    us, fits = timed(run, repeats=1)
    all_pass = True
    for name, d in fits.items():
        p = d["profile"]
        ok = p.energy.r_squared > 0.96 and p.runtime.r_squared > 0.96
        all_pass &= ok
        emit(f"table3.{name}", us / len(fits),
             f"energy R2={p.energy.r_squared:.4f} F={p.energy.f_statistic:.0f} "
             f"runtime R2={p.runtime.r_squared:.4f} F={p.runtime.f_statistic:.0f} "
             f"paper_claim_R2>0.96={ok}")
        emit(f"table3.{name}.extended", 0.0,
             f"energy R2 {p.energy.r_squared:.4f}->{d['ext_e'].r_squared:.4f} "
             f"runtime R2 {p.runtime.r_squared:.4f}->{d['ext_r'].r_squared:.4f} "
             f"(+tau_out^2 term, beyond-paper)")
    emit("table3.all_models_above_0.96", 0.0, str(bool(all_pass)))


if __name__ == "__main__":
    main()
