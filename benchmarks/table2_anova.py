"""Paper Table 2: two-way ANOVA (input x output tokens, with interaction)
on the grid campaign, aggregated across models.

Claims reproduced: all three effects significant; OUTPUT tokens dominate
(largest F); the interaction term is significant (motivates Eq. 6/7's
tau_in*tau_out term)."""

from __future__ import annotations

from benchmarks.common import emit, timed
from repro.configs import PAPER_ZOO
from repro.core.characterize import (
    CampaignSettings,
    anova_from_trials,
    run_campaign,
)
from repro.energy import AnalyticLLMSimulator

# grid-only campaign, 5 repeats per cell (the paper used the CI rule with
# up to 25 trials; 5 at 1% noise gives the same significance resolution)
SETTINGS = CampaignSettings(
    vary_input_range=(8, 8), vary_output_range=(8, 8),   # suppress 1-D sweeps
    grid_range=(8, 2048), max_trials=5, min_trials=5, seed=7)

MODELS = ("llama2-7b", "llama2-70b", "mixtral-8x7b")


def run(models=MODELS):
    trials = []
    for name in models:
        sim = AnalyticLLMSimulator(PAPER_ZOO[name], kv_cache=False,
                                   noise_sigma=0.005, seed=11)
        trials += run_campaign(name, sim.measure, SETTINGS)
    return anova_from_trials(trials), trials


def main() -> None:
    us, (results, trials) = timed(run, repeats=1)
    for metric, res in results.items():
        for row in res.rows():
            emit(f"table2.{metric}.{row.source.replace(' ', '_')}", us / 6,
                 f"SS={row.sum_sq:.3e} F={row.f_statistic:.1f} p={row.p_value:.2e}")
        out_f = res.factor_b.f_statistic
        in_f = res.factor_a.f_statistic
        inter_p = res.interaction.p_value
        emit(f"table2.{metric}.claims", 0.0,
             f"output_dominates={out_f > in_f} interaction_significant={inter_p < 1e-3}")


if __name__ == "__main__":
    main()
