"""Shared benchmark utilities: timing + the CSV contract
(`name,us_per_call,derived`)."""

from __future__ import annotations

import time
from typing import Callable


def timed(fn: Callable, *, repeats: int = 3) -> tuple[float, object]:
    """Returns (us_per_call, last_result)."""
    out = fn()  # warmup
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn()
    us = (time.perf_counter() - t0) / repeats * 1e6
    return us, out


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def pow2_range(lo: int, hi: int) -> list[int]:
    out, v = [], lo
    while v <= hi:
        out.append(v)
        v *= 2
    return out
