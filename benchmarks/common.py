"""Shared benchmark utilities: timing, the CSV contract
(`name,us_per_call,derived`), and the synthetic scheduler fleet."""

from __future__ import annotations

import time
from typing import Callable


def timed(fn: Callable, *, repeats: int = 3) -> tuple[float, object]:
    """Returns (us_per_call, last_result)."""
    out = fn()  # warmup
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn()
    us = (time.perf_counter() - t0) / repeats * 1e6
    return us, out


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def pow2_range(lo: int, hi: int) -> list[int]:
    out, v = [], lo
    while v <= hi:
        out.append(v)
        v *= 2
    return out


def synthetic_fleet(k: int, seed: int):
    """k random LLMProfiles — the one fleet every scheduler benchmark and
    perf gate shares, so their numbers stay comparable."""
    import numpy as np

    from repro.core.energy_model import (AccuracyModel, BilinearModel,
                                         LLMProfile)

    rng = np.random.default_rng(seed)
    out = []
    for i in range(k):
        e = BilinearModel(tuple(rng.uniform(0.05, 1.0, 3)))
        r = BilinearModel(tuple(rng.uniform(1e-4, 1e-2, 3)))
        out.append(LLMProfile(f"m{i}", e, r,
                              AccuracyModel(float(rng.uniform(30.0, 80.0)))))
    return out
