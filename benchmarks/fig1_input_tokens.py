"""Paper Figure 1: runtime / throughput / energy-per-token vs INPUT tokens
(8..2048, output fixed at 32, batch 32, KV cache disabled — §5.1.1), per
model, on the paper's A100+EPYC node model."""

from __future__ import annotations

from benchmarks.common import emit, pow2_range, timed
from repro.configs import PAPER_ZOO
from repro.energy import AnalyticLLMSimulator

FIXED_OUT = 32


def run(models=None) -> dict:
    models = models or sorted(PAPER_ZOO)
    curves: dict = {}
    for name in models:
        sim = AnalyticLLMSimulator(PAPER_ZOO[name], kv_cache=False, seed=1)
        pts = []
        for tin in pow2_range(8, 2048):
            us, (e, r) = timed(lambda s=sim, t=tin: s.measure(t, FIXED_OUT),
                               repeats=1)
            tokens = (tin + FIXED_OUT) * sim.batch
            pts.append({
                "tau_in": tin, "runtime_s": r, "energy_j": e,
                "throughput_tok_s": tokens / r,
                "energy_per_token_j": e / tokens,
                "us_per_call": us,
            })
        curves[name] = pts
        first, last = pts[0], pts[-1]
        emit(f"fig1.{name}", sum(p["us_per_call"] for p in pts) / len(pts),
             f"runtime {first['runtime_s']:.2f}->{last['runtime_s']:.2f}s "
             f"J/tok {first['energy_per_token_j']:.3f}->{last['energy_per_token_j']:.3f}")
    return curves


def main() -> None:
    curves = run()
    # paper claims: runtime increases with tau_in; Mixtral (SMoE) is more
    # energy-efficient than the dense large models at large inputs
    for name, pts in curves.items():
        assert pts[-1]["runtime_s"] > pts[0]["runtime_s"], name
    mix = curves["mixtral-8x7b"][-1]["energy_per_token_j"]
    l70 = curves["llama2-70b"][-1]["energy_per_token_j"]
    f40 = curves["falcon-40b"][-1]["energy_per_token_j"]
    emit("fig1.smoe_efficiency", 0.0,
         f"mixtral {mix:.3f} < llama2-70b {l70:.3f} and falcon-40b {f40:.3f} J/tok: "
         f"{mix < l70 and mix < f40}")


if __name__ == "__main__":
    main()
