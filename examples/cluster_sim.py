"""Serve streaming Alpaca-like traffic on a heterogeneous cluster and
print the offline→online gap — a narrated single run of repro.cluster —
then rerun the same trace on a 2-replica-per-model fleet with
decode-boundary preemption enabled.

    PYTHONPATH=src:. python examples/cluster_sim.py
"""

from benchmarks.fig4_online_gap import (
    fit_fleet,
    make_policies,
    node_builders,
    replica_node_builders,
)
from repro.cluster import (
    ReplicaEnergyPolicy,
    ReplicaOraclePolicy,
    SLOPreemptionPolicy,
    ZetaOnlinePolicy,
    bursty_trace,
    compare_policies,
)

N, RATE, ZETA = 80, 4.0, 0.5


def main():
    profiles = fit_fleet()
    builders = node_builders(profiles)
    trace = bursty_trace(N, RATE, burstiness=6.0, seed=5)
    print(f"trace: {len(trace)} requests, mean rate "
          f"{trace.mean_rate_qps:.2f} qps (bursty), "
          f"fleet: {[p.name for p in profiles]}\n")
    reports = compare_policies(trace, builders, make_policies(), zeta=ZETA)
    oracle = reports["offline_oracle"]
    for rep in reports.values():
        print(rep.summary())
    print(f"\noffline oracle objective bound: {oracle.objective:+.3f}")
    for name, rep in reports.items():
        if name == "offline_oracle":
            continue
        gap = rep.objective - oracle.objective
        print(f"  {name:>15s}: online gap = {gap:8.4f} "
              f"({'matches the bound' if gap < 1e-6 else 'suboptimal'})"
              f"  p95 {rep.latency_p95:5.2f}s vs oracle {oracle.latency_p95:5.2f}s")

    # --- the same trace on a replicated fleet, preemption enabled -------
    print("\n=== 2 replicas per model, SLO preemption enabled ===")
    rep_reports = compare_policies(
        trace, replica_node_builders(profiles, replicas=2, max_batch=4),
        [ZetaOnlinePolicy(), ReplicaEnergyPolicy(), ReplicaOraclePolicy()],
        zeta=ZETA,
        preempter_builder=lambda: SLOPreemptionPolicy(slowdown_slo=2.0))
    for rep in rep_reports.values():
        print(rep.summary())
    r_oracle = rep_reports["replica_oracle"]
    print(f"replica-aware oracle bound: {r_oracle.objective:+.3f} "
          f"(never worse than any online policy — asserted in fig4); "
          f"preemptions: "
          f"{ {n: r.total_preemptions for n, r in rep_reports.items()} }")


if __name__ == "__main__":
    main()
