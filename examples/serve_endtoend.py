"""End-to-end serving driver (deliverable b): characterize a two-model
fleet by REAL execution on this host, fit the paper's workload models,
route a batched workload with the energy-aware router, and serve it through
the batched inference engines with wall-clock energy metering.

    PYTHONPATH=src python examples/serve_endtoend.py
"""

from repro.launch.serve import main

if __name__ == "__main__":
    raise SystemExit(main(["--fleet", "llama2-7b-reduced,llama2-70b-reduced",
                           "--queries", "16", "--zeta", "0.5"]))
