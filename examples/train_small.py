"""End-to-end training driver (deliverable b).  The paper is a SERVING
paper, so the required driver is examples/serve_endtoend.py; this trains a
small llama3-style model for a few hundred steps as the training-side
counterpart.  Default size (~30M params) is chosen so a few hundred steps
finish on this CPU container; pass --d-model 768 --layers 12 for the ~100M
variant on real hardware.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""

import argparse

from repro.configs import get_config
from repro.launch.train import train


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--d-model", type=int, default=384)
    p.add_argument("--layers", type=int, default=6)
    p.add_argument("--vocab", type=int, default=8192)
    args = p.parse_args()

    cfg = get_config("llama3.2-3b").replace(
        name=f"llama3-small-{args.d_model}d{args.layers}L",
        n_layers=args.layers, d_model=args.d_model, n_heads=6, n_kv_heads=2,
        head_dim=64, d_ff=3 * args.d_model, vocab_size=args.vocab,
        param_dtype="float32", microbatch=0, remat=False)

    losses = train(cfg, steps=args.steps, batch=4, seq=64)
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
