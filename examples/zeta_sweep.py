"""Reproduce the paper's Figure 3 (the zeta trade-off curves) as an ASCII
table: energy / runtime / accuracy vs zeta, with the constant baselines.

    PYTHONPATH=src python examples/zeta_sweep.py
"""

import numpy as np

from benchmarks.fig3_zeta_sweep import ZETAS, run


def main():
    profiles, queries, sweep, capped, baselines = run()
    m = len(queries)
    w = 46

    def bar(v, vmax):
        n = int(v / vmax * w)
        return "#" * n

    emax = max(a.total_energy_j for a in sweep)
    print(f"{'zeta':>5} {'energy (J)':>12} {'s/query':>8} {'mean A_K':>8}")
    for z, a in zip(ZETAS, sweep):
        print(f"{z:5.2f} {a.total_energy_j:12.0f} "
              f"{a.total_runtime_s / m:8.3f} {a.mean_accuracy_ak:8.2f}  "
              f"|{bar(a.total_energy_j, emax)}")
    print("\nbaselines (constant in zeta):")
    for name, a in baselines.items():
        print(f"  {name:22s} E={a.total_energy_j:12.0f} J  "
              f"{a.total_runtime_s / m:6.3f} s/query  A_K={a.mean_accuracy_ak:.2f}")


if __name__ == "__main__":
    main()
