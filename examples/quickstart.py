"""Quickstart: the paper's pipeline in one page.

1. Characterize two LLMs with the analytic energy simulator (A100 node).
2. Fit the workload-based energy/runtime models (Eq. 6/7) — check R^2.
3. Route a workload with the offline energy-optimal scheduler (Eq. 2).

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.configs import PAPER_ZOO, TABLE1
from repro.core import scheduler
from repro.core.characterize import (
    CampaignSettings,
    fit_profile_from_trials,
    run_campaign,
)
from repro.data import alpaca_like_workload
from repro.energy import AnalyticLLMSimulator


def main():
    # 1+2: characterize + fit
    settings = CampaignSettings(grid_range=(8, 1024), max_trials=2,
                                min_trials=2,
                                vary_input_range=(8, 8),
                                vary_output_range=(8, 8))
    profiles = []
    for name in ("llama2-7b", "llama2-70b"):
        sim = AnalyticLLMSimulator(PAPER_ZOO[name], kv_cache=False)
        trials = run_campaign(name, sim.measure_per_query, settings)
        prof = fit_profile_from_trials(name, TABLE1[name]["a_k"], trials)
        print(f"{name}: e_K coeffs={['%.3g' % c for c in prof.energy.coeffs]} "
              f"R2={prof.energy.r_squared:.3f} (paper claims > 0.96)")
        profiles.append(prof)

    # 3: schedule 500 Alpaca-like queries at three operating points
    queries = alpaca_like_workload()
    for zeta in (0.0, 0.5, 1.0):
        asg = scheduler.schedule(profiles, queries, zeta)
        print(f"zeta={zeta:.1f}: energy={asg.total_energy_j:9.0f} J  "
              f"mean A_K={asg.mean_accuracy_ak:.2f}  "
              f"counts={dict(zip([p.name for p in profiles], asg.counts()))}")

    rr = scheduler.schedule_round_robin(profiles, queries)
    opt = scheduler.schedule(profiles, queries, 1.0)
    print(f"energy saving vs round-robin at zeta=1: "
          f"{1 - opt.total_energy_j / rr.total_energy_j:.1%}")


if __name__ == "__main__":
    main()
