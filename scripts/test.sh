#!/usr/bin/env sh
# Test tiers (run from anywhere; cd's to the repo root).
#
#   scripts/test.sh          tier-1 verify: the full suite, fail-fast
#                            (the ROADMAP command, run before every PR)
#   scripts/test.sh fast     fast tier: skips @pytest.mark.slow
#                            (compile dry-runs, end-to-end pipelines);
#                            includes the fault/migration suite
#                            (tests/test_faults.py — fault replay
#                            determinism, cross-node settlement, rescue
#                            policies); finishes in well under a minute
#   scripts/test.sh perf     perf tier: benchmarks/perf_suite.py --quick —
#                            correctness gates for the vectorized hot paths
#                            (closed-form decode vs chunked reference, fast
#                            capacitated solver vs min-cost-flow oracle,
#                            warm-start reschedule vs cold solve, jitted
#                            batch cost kernel vs the numpy closed form,
#                            DVFS closed-form frequency choice vs a brute-
#                            force frequency grid, gated-sim energy
#                            conservation: busy+idle+gated+transition ==
#                            total to 1e-9, and decode-boundary preemption:
#                            split additivity of the decode integral plus
#                            end-to-end conservation and the replica-oracle
#                            bound on a preempting multi-replica run, the
#                            migration_settlement gate: a scripted crash
#                            storm under the live auditor — six-bucket
#                            busy+idle+gated+transition+shipping+wasted ==
#                            total to 1e-9, the shipping bucket on the
#                            interconnect closed form, and no-survivor
#                            crashes booking waste instead of leaking —
#                            the checkpoint_settlement gate: checkpointed
#                            prefills telescope exactly onto the unchunked
#                            run, the checkpoint bucket follows the
#                            storage closed form in aggregate, and a
#                            scripted mid-prefill crash restores from the
#                            last durable boundary with seven-bucket
#                            conservation at 1e-9 — the
#                            prefix_cache_settlement gate: warm session
#                            turns charged exactly the telescoped prefix
#                            difference, the cache_read bucket on the
#                            byte closed form, cache-equipped fleets
#                            byte-identical on sessionless traffic, and a
#                            tight-capacity session storm with crash
#                            invalidation holding eight-bucket
#                            conservation under the live auditor —
#                            and the telemetry metrics_overhead gate: with full
#                            telemetry on a governed fleet the ClusterReport
#                            is byte-identical, the Prometheus dump parses,
#                            the live auditor passes every settlement, and
#                            instrumentation costs ≤20% CPU time — the one
#                            timing-sensitive gate, measured min-of-N with
#                            GC paused and retried with backoff so only a
#                            real regression fails every window);
#                            fails on disagreement, not on slow runners
set -e
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

tier="${1:-tier1}"
[ $# -gt 0 ] && shift

case "$tier" in
  fast)  exec python -m pytest -x -q -m "not slow" "$@" ;;
  tier1) exec python -m pytest -x -q "$@" ;;
  perf)  export PYTHONPATH=".:$PYTHONPATH"
         # expose N host-platform XLA devices so jitted kernels and the
         # sharded-engine gates see a multi-device topology even on CPU
         # (REPRO_XLA_DEVICES=N to override; matches the shard counts the
         # sharded_replay gate replays)
         export XLA_FLAGS="--xla_force_host_platform_device_count=${REPRO_XLA_DEVICES:-8}${XLA_FLAGS:+ $XLA_FLAGS}"
         exec python benchmarks/perf_suite.py --quick "$@" ;;
  *)     echo "usage: scripts/test.sh [tier1|fast] [pytest args...]" >&2
         echo "       scripts/test.sh perf [perf_suite args...]" >&2
         exit 2 ;;
esac
