"""Live invariant auditing: conservation contracts checked as they accrue.

The repo's two core accounting contracts — the four-bucket
busy/idle/gated/transition energy partition and the split-energy
preemption settlement — were previously gated only at *report* time (perf
suite, tests).  The auditor checks them **incrementally at every
settlement event**, so a violation surfaces at the first settle that
breaks the books, with the recent event context attached, instead of as
an end-of-run aggregate mismatch thousands of events later.

What is re-derived independently (never read back from the quantity it
checks):

  * busy bucket    — the auditor accumulates its own Σ(t, e) over the
    settlement stream and compares against the node's busy_s /
    busy_energy_j after every settle;
  * time partition — after a settle of phase [start, start+t], every
    node second through start+t is accounted: busy_s + idle_s + gated_s
    + transition_s == start + t (prefill charges at phase start, decode
    at settle — both close the books exactly at the segment end);
  * idle / gated / transition buckets — recomputed from first
    principles: idle_s·idle_power_w, gated_s·gated_w, and
    transition_s·transition_w + wakes·wake_j + gates·gate_j (the only
    closed forms those buckets may follow);
  * split-energy contract — at a preemption (or crash-quantization)
    settlement, the truncated charge must equal decode_cost(base,
    n_done) under the phase's straggler stretch transform, and the two
    raw halves must sum to the unpreempted decode_cost(base, n_total),
    both to `tol` (the closed-form additivity identity the perf suite
    gates — linear in t, so it survives stretching);
  * wasted bucket  — `book_waste` is a *move* (busy → wasted), never a
    new charge: the auditor mirrors every booking, checks the node's
    wasted bucket against its own Σ, and keeps the busy drift check
    exact by moving its accumulator in lockstep (gross settlements ==
    busy + wasted at all times);
  * shipping bucket — every KV migration must follow the interconnect
    closed form (bytes == context · kv_bytes_per_token; seconds ==
    bytes / ici_bw; joules == bytes · j_per_byte_ici, all on the
    *recipient's* spec and meter);
  * checkpoint bucket — every durable prefill-KV persist must follow
    its closed form (bytes == new tokens · kv_bytes_per_token; seconds
    == bytes / ckpt_bw; joules == bytes · j_per_byte_ckpt, all on the
    node's CheckpointConfig and meter), and every restore phase's
    charge must equal the telescoping suffix prefill_cost(τin) −
    prefill_cost(ckpt) under the phase's stretch transform;
  * cache_read bucket — every warm-prefix hit must charge the
    telescoping suffix prefill_cost(τin) − prefill_cost(cached) at
    batch 1 (the same identity restores use) and its cache-read term
    must follow the closed form (bytes == cached · kv_bytes_per_token;
    seconds == bytes / read_bw; joules == bytes · j_per_byte_read, all
    on the node's PrefixCacheConfig and meter).

`on_finalize` re-checks the fleet-level books (per-request attributed
energy == Σ busy buckets; horizon == accounted seconds including FAILED
time; wasted and shipping buckets == the audited migration/waste
streams; gross settlements == busy + wasted) once the report exists.
All checks raise :class:`InvariantViolation` with the last few audited
events formatted into the message."""

from __future__ import annotations

from collections import deque


class InvariantViolation(AssertionError):
    """An accounting contract broke; the message carries event context."""


class InvariantAuditor:
    """Incremental checker for the cluster accounting contracts.

    One auditor per simulation run (it accumulates per-node settlement
    totals).  `tol` is the relative tolerance of every check (default the
    repo-wide 1e-9 conservation class)."""

    def __init__(self, tol: float = 1e-9, *, context_events: int = 16):
        if tol <= 0:
            raise ValueError("tol must be > 0")
        self.tol = tol
        self.n_checks = 0
        self._busy_t: dict[int, float] = {}
        self._busy_e: dict[int, float] = {}
        # gross settled joules: never decremented by waste moves, so at
        # any instant gross == busy + wasted per node (leak detector)
        self._gross_e: dict[int, float] = {}
        self._waste_e: dict[int, float] = {}
        self._ship_t: dict[int, float] = {}
        self._ship_e: dict[int, float] = {}
        self._ckpt_t: dict[int, float] = {}
        self._ckpt_e: dict[int, float] = {}
        self._cache_t: dict[int, float] = {}
        self._cache_e: dict[int, float] = {}
        self._last_settle: dict[int, tuple[str, float, float, float]] = {}
        self._context: deque = deque(maxlen=context_events)
        # per-node power constants (idle_w, gated_w, transition_w, wake_j,
        # gate_j), cached on first settle — they are fixed for a node's
        # lifetime and the closed-form re-derivation reads them every event
        self._const: dict[int, tuple[float, float, float, float, float]] = {}
        # last-verified off-phase book signature per node: consecutive busy
        # settles leave the idle/gated/transition buckets untouched, so the
        # closed-form re-check can skip until the books actually move
        self._off_sig: dict[int, tuple] = {}

    # --- helpers ------------------------------------------------------
    def _close(self, a: float, b: float) -> bool:
        return abs(a - b) <= self.tol * max(1.0, abs(a), abs(b))

    def _fail(self, what: str) -> None:
        ctx = "\n  ".join(
            c if isinstance(c, str) else " ".join(map(str, c))
            for c in self._context) or "(no prior events)"
        raise InvariantViolation(
            f"{what}\nrecent audited events:\n  {ctx}")

    def note(self, desc) -> None:
        """Fold an event description into the context ring buffer: a
        string, or a flat tuple of fields formatted lazily — the hot
        settlement path stores tuples so no string work happens unless a
        check actually fails."""
        self._context.append(desc)

    # --- settlement-time checks ---------------------------------------
    def on_settle(self, node, kind: str, start_s: float, t: float,
                  e_total: float) -> None:
        """Audit one phase settlement (prefill charge at phase start,
        decode charge at segment end or preemption boundary)."""
        nid = node.node_id
        self._context.append(("settle", nid, kind, "start", start_s,
                              "t", t, "e", e_total))
        self._busy_t[nid] = bt = self._busy_t.get(nid, 0.0) + t
        self._busy_e[nid] = be = self._busy_e.get(nid, 0.0) + e_total
        self._gross_e[nid] = self._gross_e.get(nid, 0.0) + e_total
        self._last_settle[nid] = (kind, start_s, t, e_total)
        self.n_checks += 1
        # inlined `_close` — this path runs at every settlement
        tol, nb, ne = self.tol, node.busy_s, node.busy_energy_j
        if abs(bt - nb) > tol * max(1.0, abs(bt), abs(nb)):
            self._fail(f"busy-time drift on node {nid}: settlements sum to "
                       f"{bt!r} s but node.busy_s == {nb!r}")
        if abs(be - ne) > tol * max(1.0, abs(be), abs(ne)):
            self._fail(f"busy-energy drift on node {nid}: settlements sum "
                       f"to {be!r} J but node.busy_energy_j == {ne!r}")
        # the time partition: every second through this settle's segment
        # end lands in exactly one bucket
        end_s = start_s + t
        acc = node.accounted_s
        if abs(acc - end_s) > tol * max(1.0, abs(acc), abs(end_s)):
            self._fail(f"time-partition violation on node {nid} at "
                       f"{kind} settle: accounted_s == {acc!r} but the "
                       f"settled segment ends at {end_s!r}")
        # off-phase books only move on power transitions; skip the
        # closed-form re-derivation while the signature is unchanged
        sig = (node.idle_s, node.idle_energy_j, node.gated_s,
               node.gated_energy_j, node.transition_s,
               node.transition_energy_j, node.n_wakes, node.n_gates)
        if self._off_sig.get(nid) != sig:
            self._check_offphase_buckets(node)
            self._off_sig[nid] = sig

    def _check_offphase_buckets(self, node) -> None:
        """idle/gated/transition energies must follow their closed forms —
        catches double-charging (e.g. gated seconds billed as idle)."""
        nid = node.node_id
        cst = self._const.get(nid)
        if cst is None:
            cst = self._const[nid] = (
                node.idle_power_w, node.power.gated_w,
                node.transition_power_w, node.power.wake_j,
                node.power.gate_j)
        idle_w, gated_w, trans_w, wake_j, gate_j = cst
        if not self._close(node.idle_energy_j, node.idle_s * idle_w):
            self._fail(f"idle bucket off closed form on node {nid}: "
                       f"{node.idle_energy_j!r} J over {node.idle_s!r} s "
                       f"at {idle_w!r} W")
        if not self._close(node.gated_energy_j, node.gated_s * gated_w):
            self._fail(f"gated bucket off closed form on node {nid}: "
                       f"{node.gated_energy_j!r} J over {node.gated_s!r} s "
                       f"at {gated_w!r} W")
        expect_trans = (node.transition_s * trans_w
                        + node.n_wakes * wake_j + node.n_gates * gate_j)
        if not self._close(node.transition_energy_j, expect_trans):
            self._fail(f"transition bucket off closed form on node {nid}: "
                       f"{node.transition_energy_j!r} J vs expected "
                       f"{expect_trans!r}")

    def on_preempt_split(self, node, base: int, n_done: int, n_total: int,
                         batch: int, scale: float) -> None:
        """Audit the split-energy contract right after a truncated decode
        settled (a preemption boundary or a crash quantization — both
        charge through the same path): the charge must equal the
        closed-form integral over [0, n_done) under the phase's straggler
        stretch, and the two raw halves of the split must sum to the
        unpreempted decode_cost."""
        nid = node.node_id
        self.note(("preempt-split", nid, "base", base, "n_done", n_done,
                   "n_total", n_total, "batch", batch, "scale", scale))
        self.n_checks += 1
        last = self._last_settle.get(nid)
        if last is None:
            self._fail(f"preemption settled on node {nid} with no prior "
                       f"settlement event")
        _, _, t_charged, e_charged = last
        t1, e1 = node.sim.decode_cost(base, n_done, batch=batch,
                                      freq_scale=scale)
        # the stretch transform (t, e) → (σ·t, e + (σ−1)·t·static) the
        # node applied to the truncated charge, re-derived independently
        sigma = node.phase_stretch
        t1s = sigma * t1
        e1s = e1 + (sigma - 1.0) * t1 * node.accel_static_w
        e1_total = e1s + node.sim.host_power_w * t1s
        if not (self._close(t_charged, t1s)
                and self._close(e_charged, e1_total)):
            self._fail(
                f"preemption charge mismatch on node {nid}: settled "
                f"(t={t_charged!r}, e={e_charged!r}) but decode_cost"
                f"({base}, {n_done}) at stretch {sigma!r} gives "
                f"(t={t1s!r}, e={e1_total!r})")
        t2, e2 = node.sim.decode_cost(base + n_done, n_total - n_done,
                                      batch=batch, freq_scale=scale)
        tf, ef = node.sim.decode_cost(base, n_total, batch=batch,
                                      freq_scale=scale)
        if not (self._close(t1 + t2, tf) and self._close(e1 + e2, ef)):
            self._fail(
                f"split-energy contract violated on node {nid}: "
                f"decode_cost({base},{n_done}) + decode_cost"
                f"({base + n_done},{n_total - n_done}) != decode_cost"
                f"({base},{n_total}): t {t1 + t2!r} vs {tf!r}, "
                f"e {e1 + e2!r} vs {ef!r}")

    # --- fault-path checks --------------------------------------------
    def on_waste(self, node, e_j: float) -> None:
        """Audit a `book_waste` move (busy → wasted, booked on the node
        that actually spent the joules): mirror it into the auditor's
        accumulators — the busy drift check stays exact because the move
        is applied to both sides — and re-check the node's wasted bucket
        against the audited stream."""
        nid = node.node_id
        self.note(("waste", nid, "e", e_j))
        self.n_checks += 1
        if e_j < 0.0:
            self._fail(f"negative waste booking on node {nid}: {e_j!r} J")
        self._busy_e[nid] = self._busy_e.get(nid, 0.0) - e_j
        self._waste_e[nid] = we = self._waste_e.get(nid, 0.0) + e_j
        nw = node.wasted_energy_j
        if not self._close(we, nw):
            self._fail(f"wasted-energy drift on node {nid}: bookings sum "
                       f"to {we!r} J but node.wasted_energy_j == {nw!r}")
        nb, be = node.busy_energy_j, self._busy_e[nid]
        if not self._close(be, nb):
            self._fail(f"waste booking on node {nid} broke the busy "
                       f"bucket: settlements − wastes == {be!r} J but "
                       f"node.busy_energy_j == {nb!r}")

    def on_migration(self, home, recipient, context: int, n_bytes: float,
                     ship_s: float, ship_j: float) -> None:
        """Audit one cross-node KV shipment against the interconnect
        closed form — bytes from the *donor's* KV layout at the decode
        boundary, seconds and joules from the *recipient's* spec — and
        the recipient's running shipping meter."""
        from repro.energy.costs import kv_bytes_per_token

        rid = recipient.node_id
        self.note(("migrate", home.node_id, "->", rid, "ctx", context,
                   "bytes", n_bytes, "s", ship_s, "j", ship_j))
        self.n_checks += 1
        expect_bytes = context * kv_bytes_per_token(home.sim.cfg)
        if not self._close(n_bytes, expect_bytes):
            self._fail(f"KV shipment size off closed form: {n_bytes!r} B "
                       f"for {context} tokens but kv_bytes_per_token "
                       f"gives {expect_bytes!r} B")
        accel = recipient.hardware.accel
        if not self._close(ship_s, n_bytes / accel.ici_bw):
            self._fail(f"KV shipping time off closed form on node {rid}: "
                       f"{ship_s!r} s for {n_bytes!r} B over "
                       f"{accel.ici_bw!r} B/s")
        if not self._close(ship_j, n_bytes * accel.j_per_byte_ici):
            self._fail(f"KV shipping energy off closed form on node "
                       f"{rid}: {ship_j!r} J for {n_bytes!r} B at "
                       f"{accel.j_per_byte_ici!r} J/B")
        self._ship_t[rid] = st = self._ship_t.get(rid, 0.0) + ship_s
        self._ship_e[rid] = se = self._ship_e.get(rid, 0.0) + ship_j
        if not (self._close(st, recipient.shipping_s)
                and self._close(se, recipient.shipping_energy_j)):
            self._fail(f"shipping-meter drift on node {rid}: audited "
                       f"(t={st!r}, e={se!r}) but node books "
                       f"(t={recipient.shipping_s!r}, "
                       f"e={recipient.shipping_energy_j!r})")

    def on_checkpoint(self, node, new_tokens: int, n_bytes: float,
                      ckpt_s: float, ckpt_j: float, n_members: int) -> None:
        """Audit one durable prefill-KV persist against the checkpoint
        closed form (bytes from the model's KV layout, seconds and joules
        from the node's CheckpointConfig) and the node's running
        checkpoint meters."""
        from repro.energy.costs import kv_bytes_per_token

        nid = node.node_id
        self.note(("checkpoint", nid, "tokens", new_tokens, "bytes",
                   n_bytes, "s", ckpt_s, "j", ckpt_j,
                   "members", n_members))
        self.n_checks += 1
        if new_tokens <= 0 or n_members <= 0:
            self._fail(f"empty checkpoint persisted on node {nid}: "
                       f"{new_tokens} tokens over {n_members} members")
        expect_bytes = new_tokens * kv_bytes_per_token(node.sim.cfg)
        if not self._close(n_bytes, expect_bytes):
            self._fail(f"checkpoint size off closed form on node {nid}: "
                       f"{n_bytes!r} B for {new_tokens} tokens but "
                       f"kv_bytes_per_token gives {expect_bytes!r} B")
        ck = node.checkpoint
        if not self._close(ckpt_s, n_bytes / ck.ckpt_bw):
            self._fail(f"checkpoint time off closed form on node {nid}: "
                       f"{ckpt_s!r} s for {n_bytes!r} B over "
                       f"{ck.ckpt_bw!r} B/s")
        if not self._close(ckpt_j, n_bytes * ck.j_per_byte_ckpt):
            self._fail(f"checkpoint energy off closed form on node {nid}: "
                       f"{ckpt_j!r} J for {n_bytes!r} B at "
                       f"{ck.j_per_byte_ckpt!r} J/B")
        self._ckpt_t[nid] = ct = self._ckpt_t.get(nid, 0.0) + ckpt_s
        self._ckpt_e[nid] = ce = self._ckpt_e.get(nid, 0.0) + ckpt_j
        if not (self._close(ct, node.checkpoint_s)
                and self._close(ce, node.checkpoint_energy_j)):
            self._fail(f"checkpoint-meter drift on node {nid}: audited "
                       f"(t={ct!r}, e={ce!r}) but node books "
                       f"(t={node.checkpoint_s!r}, "
                       f"e={node.checkpoint_energy_j!r})")

    def on_restore(self, node, tau_in: int, base: int,
                   scale: float) -> None:
        """Audit a restore phase's charge (fired at phase start, right
        after the charge settled): it must equal the telescoping suffix
        prefill_cost(τin) − prefill_cost(base) at batch 1 under the
        phase's straggler stretch — the same identity that makes the
        chunk sum exact, applied to the unfinished remainder."""
        nid = node.node_id
        self.note(("restore", nid, "tau", tau_in, "base", base,
                   "scale", scale))
        self.n_checks += 1
        last = self._last_settle.get(nid)
        if last is None or last[0] != "restore":
            self._fail(f"restore began on node {nid} without a settled "
                       f"restore charge (last settle: {last!r})")
        _, _, t_charged, e_charged = last
        if not 0 < base < tau_in:
            self._fail(f"restore on node {nid} for a non-partial prefill: "
                       f"ckpt {base} of τin {tau_in}")
        t1, e1 = node.sim.prefill_cost(base, batch=1, freq_scale=scale)
        t2, e2 = node.sim.prefill_cost(tau_in, batch=1, freq_scale=scale)
        sigma = node.phase_stretch
        ts = sigma * (t2 - t1)
        es = (e2 - e1) + (sigma - 1.0) * (t2 - t1) * node.accel_static_w
        e_total = es + node.sim.host_power_w * ts
        if not (self._close(t_charged, ts)
                and self._close(e_charged, e_total)):
            self._fail(
                f"restore charge off the telescoping suffix on node "
                f"{nid}: settled (t={t_charged!r}, e={e_charged!r}) but "
                f"prefill_cost({tau_in}) − prefill_cost({base}) at "
                f"stretch {sigma!r} gives (t={ts!r}, e={e_total!r})")

    def on_cache_hit(self, node, tau_in: int, cached: int, n_bytes: float,
                     read_s: float, read_j: float, scale: float) -> None:
        """Audit a warm-prefix batch-1 prefill (fired at phase start,
        right after the charge settled): the suffix charge must equal the
        telescoping difference prefill_cost(τin) − prefill_cost(cached)
        under the phase's stretch — the restore identity, applied to a
        cache hit — and the cache-read term must follow its closed form
        on the node's PrefixCacheConfig and meters."""
        from repro.energy.costs import kv_bytes_per_token

        nid = node.node_id
        self.note(("cache-hit", nid, "tau", tau_in, "cached", cached,
                   "bytes", n_bytes, "s", read_s, "j", read_j,
                   "scale", scale))
        self.n_checks += 1
        last = self._last_settle.get(nid)
        if last is None or last[0] != "prefill":
            self._fail(f"cache-hit prefill began on node {nid} without a "
                       f"settled prefill charge (last settle: {last!r})")
        _, _, t_charged, e_charged = last
        if not 0 < cached < tau_in:
            self._fail(f"cache hit on node {nid} outside (0, τin): "
                       f"{cached} of {tau_in}")
        t1, e1 = node.sim.prefill_cost(cached, batch=1, freq_scale=scale)
        t2, e2 = node.sim.prefill_cost(tau_in, batch=1, freq_scale=scale)
        sigma = node.phase_stretch
        ts = sigma * (t2 - t1)
        es = (e2 - e1) + (sigma - 1.0) * (t2 - t1) * node.accel_static_w
        e_total = es + node.sim.host_power_w * ts
        if not (self._close(t_charged, ts)
                and self._close(e_charged, e_total)):
            self._fail(
                f"cache-hit charge off the telescoping suffix on node "
                f"{nid}: settled (t={t_charged!r}, e={e_charged!r}) but "
                f"prefill_cost({tau_in}) − prefill_cost({cached}) at "
                f"stretch {sigma!r} gives (t={ts!r}, e={e_total!r})")
        expect_bytes = cached * kv_bytes_per_token(node.sim.cfg)
        if not self._close(n_bytes, expect_bytes):
            self._fail(f"cache-read size off closed form on node {nid}: "
                       f"{n_bytes!r} B for {cached} tokens but "
                       f"kv_bytes_per_token gives {expect_bytes!r} B")
        pc = node.prefix_cache
        if not self._close(read_s, n_bytes / pc.read_bw):
            self._fail(f"cache-read time off closed form on node {nid}: "
                       f"{read_s!r} s for {n_bytes!r} B over "
                       f"{pc.read_bw!r} B/s")
        if not self._close(read_j, n_bytes * pc.j_per_byte_read):
            self._fail(f"cache-read energy off closed form on node {nid}: "
                       f"{read_j!r} J for {n_bytes!r} B at "
                       f"{pc.j_per_byte_read!r} J/B")
        self._cache_t[nid] = ct = self._cache_t.get(nid, 0.0) + read_s
        self._cache_e[nid] = ce = self._cache_e.get(nid, 0.0) + read_j
        if not (self._close(ct, node.cache_read_s)
                and self._close(ce, node.cache_read_energy_j)):
            self._fail(f"cache-read-meter drift on node {nid}: audited "
                       f"(t={ct!r}, e={ce!r}) but node books "
                       f"(t={node.cache_read_s!r}, "
                       f"e={node.cache_read_energy_j!r})")

    # --- end-of-run checks --------------------------------------------
    def on_finalize(self, nodes, report) -> None:
        """Close the audit: fleet-level conservation against the report."""
        self.n_checks += 1
        for n in nodes:
            if not self._close(n.accounted_s, n.horizon_s):
                self._fail(f"node {n.node_id} horizon not partitioned: "
                           f"accounted {n.accounted_s!r} s of "
                           f"{n.horizon_s!r} s")
            self._check_offphase_buckets(n)
            nid = n.node_id
            # waste is a move, never a leak: the gross settlement stream
            # must reappear exactly as busy + wasted
            gross = self._gross_e.get(nid, 0.0)
            split = n.busy_energy_j + n.wasted_energy_j
            if not self._close(gross, split):
                self._fail(f"energy leak on node {nid}: settlements sum "
                           f"to {gross!r} J but busy + wasted == "
                           f"{split!r} J")
        attributed = sum(r.energy_j for r in report.records)
        busy = sum(s.busy_energy_j for s in report.node_stats)
        if report.records and not self._close(attributed, busy):
            self._fail(f"attributed per-request energy {attributed!r} J "
                       f"does not sum to the fleet busy bucket {busy!r} J")
        wasted = sum(s.wasted_energy_j for s in report.node_stats)
        if not self._close(wasted, sum(self._waste_e.values())):
            self._fail(f"fleet wasted bucket {wasted!r} J does not match "
                       f"the audited waste stream "
                       f"{sum(self._waste_e.values())!r} J")
        shipping = sum(s.shipping_energy_j for s in report.node_stats)
        if not self._close(shipping, sum(self._ship_e.values())):
            self._fail(f"fleet shipping bucket {shipping!r} J does not "
                       f"match the audited migration stream "
                       f"{sum(self._ship_e.values())!r} J")
        ckpt = sum(s.checkpoint_energy_j for s in report.node_stats)
        if not self._close(ckpt, sum(self._ckpt_e.values())):
            self._fail(f"fleet checkpoint bucket {ckpt!r} J does not "
                       f"match the audited persistence stream "
                       f"{sum(self._ckpt_e.values())!r} J")
        ckpt_s = sum(s.checkpoint_s for s in report.node_stats)
        if not self._close(ckpt_s, sum(self._ckpt_t.values())):
            self._fail(f"fleet checkpoint seconds {ckpt_s!r} do not "
                       f"match the audited persistence stream "
                       f"{sum(self._ckpt_t.values())!r} s")
        cache = sum(s.cache_read_energy_j for s in report.node_stats)
        if not self._close(cache, sum(self._cache_e.values())):
            self._fail(f"fleet cache_read bucket {cache!r} J does not "
                       f"match the audited hit stream "
                       f"{sum(self._cache_e.values())!r} J")
        cache_s = sum(s.cache_read_s for s in report.node_stats)
        if not self._close(cache_s, sum(self._cache_t.values())):
            self._fail(f"fleet cache_read seconds {cache_s!r} do not "
                       f"match the audited hit stream "
                       f"{sum(self._cache_t.values())!r} s")
