"""Observability for the cluster simulator: metrics, traces, live audits.

Architecture note
-----------------
The subsystem is three independent components behind one facade:

    Telemetry  (telemetry.py)  — the hook surface `simulate_cluster`,
      │                          ClusterNode and the policies call into;
      │                          owns which events become which metrics.
      ├─ MetricsRegistry (metrics.py) — named Counter / Gauge / Histogram
      │    families labeled by (node, model, phase, ...).  Histograms are
      │    log-bucketed, bounded-memory and mergeable; the registry
      │    exports Prometheus text exposition (`prometheus_text()`) and a
      │    JSON-able snapshot (`to_dict()`).
      ├─ EventTracer (tracing.py) — append-only event log exporting
      │    Chrome trace_event JSON for chrome://tracing / Perfetto: one
      │    track per node, phase spans, power transitions, sampled
      │    queue/energy counter series.
      └─ InvariantAuditor (audit.py) — re-derives the four-bucket energy
           partition and the split-energy preemption contract at *every*
           settlement event and raises InvariantViolation with recent
           event context on the first broken check.

Design rules that everything here obeys:

  * hooks are read-only observers — telemetry on vs. off yields
    byte-identical ClusterReports (gated in benchmarks/perf_suite.py);
  * no wall-clock — timestamps are simulation seconds, so seeded runs
    export byte-identical traces and metric dumps;
  * everything merges — counters add, gauges add (or max), histograms
    add per-bucket, registries merge family-wise.  This is the substrate
    the planned actor-sharded simulator partitions per node and reduces
    with `MetricsRegistry.merged`, so mergeability is by construction,
    not retrofit.

Typical use::

    from repro.obs import Telemetry, EventTracer, InvariantAuditor
    tel = Telemetry(tracer=EventTracer(), auditor=InvariantAuditor(),
                    sample_every_s=5.0)
    report = simulate_cluster(trace, nodes, policy, telemetry=tel)
    print(tel.prometheus_text())
    tel.tracer.write("trace.json")          # open in ui.perfetto.dev
"""

from repro.obs.audit import InvariantAuditor, InvariantViolation
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricFamily,
                               MetricsRegistry)
from repro.obs.telemetry import Telemetry
from repro.obs.tracing import EventTracer

__all__ = [
    "Counter",
    "EventTracer",
    "Gauge",
    "Histogram",
    "InvariantAuditor",
    "InvariantViolation",
    "MetricFamily",
    "MetricsRegistry",
    "Telemetry",
]
