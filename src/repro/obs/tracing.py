"""Structured event tracing with Chrome trace_event JSON export.

The tracer records simulation events — arrivals, phase spans (prefill /
decode / preempted decode), power transitions (gate/wake spans), DVFS
shifts, preemption settlements, routing decisions — as compact tuples and
exports the Chrome ``trace_event`` JSON format, loadable in
chrome://tracing and Perfetto (https://ui.perfetto.dev): one track (tid)
per cluster node, phase spans as complete ("X") events, instants ("i"),
and sampled time series (queue depth, batch occupancy, bucket power) as
counter ("C") tracks.

Event arguments are passed as *flat* ``(k1, v1, k2, v2, ...)`` tuples —
one tuple allocation per event, no dict on the hot path (the recording
hooks sit inside the simulator event loop and are budgeted by the
perf-suite metrics_overhead gate).  Key order is call-site order, which is
deterministic for a given code path; ``to_json`` sorts keys at export.

Timestamps are *simulation* seconds converted to trace microseconds —
wall-clock never enters, so a seeded run traces byte-identically
(tests/test_obs.py pins this).  Memory is bounded by ``max_events``:
beyond the cap events are counted in ``dropped`` instead of stored (the
cap is generous — a 10⁴-request fig4 run emits ~10⁵ events)."""

from __future__ import annotations

import json
from pathlib import Path

# record layout: (ph, name, cat, ts_us, dur_us, tid, flat_args)
_PH, _NAME, _CAT, _TS, _DUR, _TID, _ARGS = range(7)


class EventTracer:
    """Append-only trace of simulation events in (record-time) order."""

    def __init__(self, max_events: int = 1_000_000):
        if max_events < 1:
            raise ValueError("max_events must be >= 1")
        self.max_events = max_events
        self.events: list[tuple] = []
        self.dropped = 0
        self._thread_names: dict[int, str] = {}
        # sharded runs: a fleet-wide monotone counter (shared by every
        # shard's tracer) stamping each record with its global append
        # order, so `absorb` can interleave per-shard traces back into
        # the exact order a fused tracer would have recorded
        self.stamp_source = None
        self._stamps: list[int] = []

    def __len__(self) -> int:
        return len(self.events)

    # --- recording ----------------------------------------------------
    def thread_name(self, tid: int, name: str) -> None:
        """Name a track (one per cluster node, plus tid 0 for the sim)."""
        self._thread_names[int(tid)] = name

    def instant(self, name: str, ts_s: float, tid: int = 0,
                cat: str = "sim", args: tuple = ()) -> None:
        events = self.events
        if len(events) >= self.max_events:
            self.dropped += 1
            return
        events.append(("i", name, cat, ts_s * 1e6, None, tid, args))
        if self.stamp_source is not None:
            self._stamps.append(self.stamp_source())

    def complete(self, name: str, start_s: float, dur_s: float,
                 tid: int = 0, cat: str = "sim", args: tuple = ()) -> None:
        """A span [start_s, start_s + dur_s] — a phase, a wake ramp."""
        events = self.events
        if len(events) >= self.max_events:
            self.dropped += 1
            return
        events.append(("X", name, cat, start_s * 1e6, dur_s * 1e6, tid,
                       args))
        if self.stamp_source is not None:
            self._stamps.append(self.stamp_source())

    def counter(self, name: str, ts_s: float, values: tuple,
                tid: int = 0) -> None:
        """A sampled time-series point (queue depth, bucket power, ...);
        `values` is the same flat (k1, v1, ...) layout."""
        events = self.events
        if len(events) >= self.max_events:
            self.dropped += 1
            return
        events.append(("C", name, "sample", ts_s * 1e6, None, tid, values))
        if self.stamp_source is not None:
            self._stamps.append(self.stamp_source())

    # --- sharded fold -------------------------------------------------
    def absorb(self, tracers) -> "EventTracer":
        """Fold stamp-ordered per-shard tracers into this one: records
        interleave by their global append-order stamps (so the merged
        trace is byte-identical to a fused single-tracer run), thread
        names union (duplicates agree by construction), drop counts add.
        The shard tracers must all share one ``stamp_source``."""
        stamped: list[tuple[int, tuple]] = []
        for t in tracers:
            if len(t._stamps) != len(t.events):
                raise ValueError("absorb needs stamp-ordered tracers "
                                 "(set stamp_source before recording)")
            self._thread_names.update(t._thread_names)
            self.dropped += t.dropped
            stamped.extend(zip(t._stamps, t.events))
        stamped.sort(key=lambda p: p[0])
        self.events.extend(rec for _, rec in stamped)
        return self

    # --- export -------------------------------------------------------
    def to_chrome(self) -> dict:
        """The Chrome trace_event JSON object (dict form)."""
        out = []
        for tid in sorted(self._thread_names):
            out.append({"ph": "M", "name": "thread_name", "pid": 0,
                        "tid": tid,
                        "args": {"name": self._thread_names[tid]}})
        for rec in self.events:
            ev = {"ph": rec[_PH], "name": rec[_NAME], "cat": rec[_CAT],
                  "ts": rec[_TS], "pid": 0, "tid": rec[_TID]}
            if rec[_DUR] is not None:
                ev["dur"] = rec[_DUR]
            flat = rec[_ARGS]
            if flat:
                ev["args"] = dict(zip(flat[0::2], flat[1::2]))
            out.append(ev)
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def to_json(self) -> str:
        return json.dumps(self.to_chrome(), sort_keys=True)

    def write(self, path: str | Path) -> Path:
        p = Path(path)
        p.write_text(self.to_json() + "\n")
        return p
