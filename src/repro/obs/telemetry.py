"""The telemetry facade: one object the cluster stack reports into.

A :class:`Telemetry` bundles the three observability components —

  * a :class:`~repro.obs.metrics.MetricsRegistry` (always present),
  * an optional :class:`~repro.obs.tracing.EventTracer`,
  * an optional :class:`~repro.obs.audit.InvariantAuditor`,

— behind the narrow hook surface the instrumented code calls
(`on_arrival`, `on_phase_settle`, `on_power_span`, `on_completion`, ...).
Hooks are **read-only observers**: they never mutate node, policy or
event-loop state, touch no RNG, and do no float arithmetic that feeds
back into the simulation, which is what makes the telemetry-on vs
telemetry-off ClusterReport byte-identity a structural guarantee rather
than a tested accident (it is also tested — tests/test_obs.py and the
perf-suite `metrics_overhead` gate).

Lifecycle: one Telemetry per `simulate_cluster` call (like autoscalers
and preempters, it holds per-run state); `attach` raises on reuse.
`sample_every_s` enables periodic time-series sampling of queue depth,
batch occupancy and per-bucket energy inside the event loop (None — the
default — disables sampling; hooks alone are cheap enough for the
metrics_overhead gate's budget, sampling cost scales with the chosen period)."""

from __future__ import annotations

from typing import Sequence

from repro.obs.audit import InvariantAuditor
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracing import EventTracer


class Telemetry:
    """Streaming metrics + tracing + auditing for one simulation run."""

    def __init__(self, *, registry: MetricsRegistry | None = None,
                 tracer: EventTracer | None = None,
                 auditor: InvariantAuditor | None = None,
                 sample_every_s: float | None = None):
        if sample_every_s is not None and sample_every_s <= 0:
            raise ValueError("sample_every_s must be > 0 (or None)")
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer
        self.auditor = auditor
        self.sample_every_s = sample_every_s
        self._attached = False

    # ------------------------------------------------------------------
    def attach(self, nodes: Sequence, policy, trace, zeta: float) -> None:
        """Declare the metric families and name the trace tracks.  Called
        once by `simulate_cluster`; a Telemetry accumulates per-run state,
        so reuse across runs is an error (fresh one per run, like
        autoscalers)."""
        if self._attached:
            raise ValueError(
                "Telemetry objects are single-run (their registries and "
                "auditors accumulate); build a fresh one per simulate_cluster")
        self._attached = True
        r = self.registry
        node_model = ("node", "model")
        # counters — the live event stream
        self._arrivals = r.counter(
            "sim_arrivals_total", "requests routed, by destination node",
            node_model)
        self._completions = r.counter(
            "sim_completions_total", "requests completed", node_model)
        self._phases = r.counter(
            "sim_phases_total", "phase settlements",
            ("node", "model", "phase"))
        self._routes = r.counter(
            "sim_routing_decisions_total", "router picks, by policy",
            ("policy", "node"))
        self._preempt_considered = r.counter(
            "sim_preempt_considered_total",
            "preemption checks at arrivals", ("policy",))
        self._preempt_fired = r.counter(
            "sim_preempt_fired_total", "preemptions requested", ("policy",))
        self._wakes = r.counter("sim_wakes_total", "node wake transitions",
                                ("node",))
        self._gates = r.counter("sim_gates_total", "node gate transitions",
                                ("node",))
        self._prewakes = r.counter(
            "sim_autoscaler_prewakes_total",
            "proactive wakes requested by the autoscaler", ("policy",))
        self._gate_decisions = r.counter(
            "sim_autoscaler_gate_decisions_total",
            "idle-timer gate verdicts", ("policy", "verdict"))
        self._dvfs = r.counter(
            "sim_dvfs_choice_total", "operating-point picks per phase",
            ("node", "phase", "scale"))
        self._faults = r.counter(
            "sim_faults_total", "injected fault events applied",
            ("node", "kind"))
        self._migrations = r.counter(
            "sim_migrations_total", "cross-node KV shipments",
            ("src", "dst"))
        self._retries = r.counter(
            "sim_retries_total", "re-routes of displaced/backed-off "
            "requests, by destination node", ("node",))
        self._abandons = r.counter(
            "sim_abandons_total", "requests the fleet gave up on",
            ("reason",))
        self._drains = r.counter(
            "sim_drain_transitions_total", "straggler-governance drain "
            "starts/stops", ("node", "action"))
        self._checkpoints = r.counter(
            "sim_checkpoints_total", "prefill-KV checkpoint persists",
            ("node",))
        self._restores = r.counter(
            "sim_restores_total", "checkpoint-restore phases begun",
            ("node",))
        self._domain_outages = r.counter(
            "sim_domain_outages_total",
            "correlated fault batches (simultaneous crash groups)", ())
        self._cache_hits = r.counter(
            "sim_cache_hits_total",
            "KV prefix-cache hits at request admission", ("node",))
        self._cache_misses = r.counter(
            "sim_cache_misses_total",
            "KV prefix-cache misses at request admission "
            "(session requests only)", ("node",))
        self._cache_evictions = r.counter(
            "sim_cache_evictions_total",
            "LRU prefix-cache entry evictions", ("node",))
        self._cache_hit_tokens = r.counter(
            "sim_cache_hit_tokens_total",
            "warm prefix tokens reused (reuse depth)", ("node",))
        self._cache_invalidations = r.counter(
            "sim_cache_invalidations_total",
            "crash wipes of a node's resident prefix cache", ("node",))
        # gauges — live fleet state + end-of-run snapshot
        self._queue_depth = r.gauge(
            "sim_queue_depth", "waiting requests per node", ("node",))
        self._batch_occupancy = r.gauge(
            "sim_batch_occupancy", "active batch members per node", ("node",))
        self._bucket_energy = r.gauge(
            "sim_node_energy_joules",
            "per-node energy by accounting bucket", ("node", "bucket"))
        self._bucket_seconds = r.gauge(
            "sim_node_seconds", "per-node horizon split by bucket",
            ("node", "bucket"))
        self._pred_err = r.gauge(
            "sim_tau_out_prediction_abs_error",
            "last |τ̂out − τout| per model (predictor policies)",
            ("policy", "model"))
        # histograms — the quantile surface
        self._h_latency = r.histogram(
            "sim_request_latency_seconds", "arrival → finish", ("model",))
        self._h_queue = r.histogram(
            "sim_request_queue_seconds", "arrival → first service",
            ("model",))
        self._h_slowdown = r.histogram(
            "sim_request_slowdown", "latency / isolated runtime", ("model",))
        self._h_energy = r.histogram(
            "sim_request_energy_joules", "attributed busy energy per request",
            ("model",))
        self._h_phase_s = r.histogram(
            "sim_phase_seconds", "settled phase durations",
            ("node", "model", "phase"))
        self._h_outage_size = r.histogram(
            "sim_domain_outage_size",
            "nodes killed per correlated fault batch")
        # Pre-resolve the hot-path children once per node: hooks fire per
        # event, and `labels()` stringifies its key on every call — caching
        # the child objects here keeps the instrumented run inside the
        # perf-suite metrics_overhead budget.  (Side effect: per-node series
        # exist from t=0 with value 0, which is standard Prometheus
        # practice anyway.)
        self._node_ch: dict[int, dict] = {}
        self._lazy_ch: dict[tuple, object] = {}
        pol = policy.name
        for n in nodes:
            nid, model = n.node_id, n.model_name
            self._node_ch[nid] = {
                "arrival": self._arrivals.labels(nid, model),
                "route": self._routes.labels(pol, nid),
                "completion": self._completions.labels(nid, model),
                "phase_c": {k: self._phases.labels(nid, model, k)
                            for k in ("prefill", "decode", "restore")},
                "phase_h": {k: self._h_phase_s.labels(nid, model, k)
                            for k in ("prefill", "decode", "restore")},
                "h_latency": self._h_latency.labels(model),
                "h_queue": self._h_queue.labels(model),
                "h_slowdown": self._h_slowdown.labels(model),
                "h_energy": self._h_energy.labels(model),
                "wake": self._wakes.labels(nid),
                "gate": self._gates.labels(nid),
                "queue_depth": self._queue_depth.labels(nid),
                "batch_occ": self._batch_occupancy.labels(nid),
                "track": f"node{nid}",
            }
        if self.tracer is not None:
            self.tracer.thread_name(0, "cluster")
            for n in nodes:
                self.tracer.thread_name(
                    n.node_id + 1, f"node{n.node_id}:{n.model_name}")

    def _lazy(self, fam, *key):
        """Cached child lookup for the cooler paths whose label values are
        not known at attach time (DVFS scales, autoscaler verdicts, ...)."""
        k = (fam.name,) + key
        child = self._lazy_ch.get(k)
        if child is None:
            child = self._lazy_ch[k] = fam.labels(*key)
        return child

    # --- event-loop hooks (called by repro.cluster.sim) ----------------
    def on_arrival(self, req, policy_name: str, nid: int, model: str,
                   now: float) -> None:
        ch = self._node_ch[nid]
        ch["arrival"].inc()
        ch["route"].inc()
        if self.tracer is not None:
            self.tracer.instant("arrival", now, nid + 1, "arrival",
                                ("request", req.request_id,
                                 "tau_in", req.tau_in))

    def on_preempt_decision(self, policy_name: str, fired: bool) -> None:
        self._lazy(self._preempt_considered, policy_name).inc()
        if fired:
            self._lazy(self._preempt_fired, policy_name).inc()

    def on_prewake(self, policy_name: str, n: int) -> None:
        if n:
            self._lazy(self._prewakes, policy_name).inc(n)

    def on_gate_decision(self, policy_name: str, gated: bool) -> None:
        self._lazy(self._gate_decisions, policy_name,
                   "gate" if gated else "decline").inc()

    def on_completion(self, rec, now: float) -> None:
        ch = self._node_ch[rec.node_id]
        ch["completion"].inc()
        ch["h_latency"].observe(rec.latency_s)
        ch["h_queue"].observe(rec.queue_s)
        ch["h_slowdown"].observe(rec.slowdown)
        ch["h_energy"].observe(rec.energy_j)
        if self.tracer is not None:
            self.tracer.instant("completion", now, rec.node_id + 1,
                                "completion",
                                ("request", rec.request_id,
                                 "tau_out", rec.tau_out,
                                 "preemptions", rec.preemptions))

    def sample(self, nodes: Sequence, now: float) -> None:
        """Periodic time series: queue depth, batch occupancy, per-bucket
        energy so far — gauges for scraping, counter tracks for the trace."""
        for n in nodes:
            ch = self._node_ch[n.node_id]
            ch["queue_depth"].set(len(n.waiting))
            ch["batch_occ"].set(len(n.active))
            if self.tracer is not None:
                track = ch["track"]
                self.tracer.counter(
                    track, now,
                    ("queue", len(n.waiting), "batch", len(n.active)),
                    n.node_id + 1)
                self.tracer.counter(
                    track + "_energy_j", now,
                    ("busy", n.busy_energy_j, "idle", n.idle_energy_j,
                     "gated", n.gated_energy_j,
                     "transition", n.transition_energy_j),
                    n.node_id + 1)

    # --- node hooks (called by repro.cluster.node) ----------------------
    def on_phase_settle(self, node, kind: str, start_s: float, t: float,
                        e_total: float, batch: int, scale: float) -> None:
        ch = self._node_ch[node.node_id]
        ch["phase_c"][kind].inc()
        ch["phase_h"][kind].observe(t)
        self._lazy(self._dvfs, node.node_id, kind, scale).inc()
        if self.tracer is not None:
            self.tracer.complete(kind, start_s, t, node.node_id + 1,
                                 "phase", ("batch", batch,
                                           "energy_j", e_total,
                                           "scale", scale))
        if self.auditor is not None:
            self.auditor.on_settle(node, kind, start_s, t, e_total)

    def on_preempt_split(self, node, base: int, n_done: int, n_total: int,
                         batch: int, scale: float) -> None:
        if self.tracer is not None:
            self.tracer.instant("preempt", node.phase_end_s or 0.0,
                                node.node_id + 1, "preempt",
                                ("n_done", n_done, "n_total", n_total))
        if self.auditor is not None:
            self.auditor.on_preempt_split(node, base, n_done, n_total,
                                          batch, scale)

    def on_power_begin(self, node, kind: str, now: float) -> None:
        self._node_ch[node.node_id][kind].inc()

    def on_checkpoint(self, node, new_tokens: int, n_bytes: float,
                      ckpt_s: float, ckpt_j: float, n_members: int) -> None:
        """A chunk boundary durably persisted `new_tokens` of fresh KV
        prefix across `n_members` batch members (the chunk itself settles
        through on_phase_settle; this hook carries the persistence cost)."""
        self._lazy(self._checkpoints, node.node_id).inc(n_members)
        if self.tracer is not None:
            self.tracer.instant("checkpoint", node.phase_end_s or 0.0,
                                node.node_id + 1, "checkpoint",
                                ("tokens", new_tokens, "bytes", n_bytes,
                                 "energy_j", ckpt_j, "members", n_members))
        if self.auditor is not None:
            self.auditor.on_checkpoint(node, new_tokens, n_bytes,
                                       ckpt_s, ckpt_j, n_members)

    def on_restore(self, node, tau_in: int, base: int,
                   scale: float) -> None:
        """A prefill refugee began its batch-1 restore phase (fired at
        phase start, right after the charge lands, so the auditor can
        cross-check the suffix cost against the just-settled charge)."""
        self._lazy(self._restores, node.node_id).inc()
        if self.auditor is not None:
            self.auditor.on_restore(node, tau_in, base, scale)

    # --- prefix-cache hooks (called by repro.cluster.node) --------------
    def on_cache_lookup(self, node, req, hit_tokens: int) -> None:
        """A session request hit the admission boundary: `hit_tokens` of
        its shared prefix were warm (0 ⇒ miss)."""
        if hit_tokens > 0:
            self._lazy(self._cache_hits, node.node_id).inc()
            self._lazy(self._cache_hit_tokens,
                       node.node_id).inc(hit_tokens)
        else:
            self._lazy(self._cache_misses, node.node_id).inc()

    def on_cache_hit(self, node, tau_in: int, cached: int, n_bytes: float,
                     read_s: float, read_j: float, scale: float) -> None:
        """A warm-prefix batch-1 prefill began (fired at phase start,
        right after the charge lands, like on_restore): the suffix charge
        and the closed-form cache-read term are both auditable here."""
        if self.tracer is not None:
            self.tracer.instant("cache_hit", node.phase_end_s or 0.0,
                                node.node_id + 1, "cache",
                                ("tau_in", tau_in, "cached", cached,
                                 "bytes", n_bytes, "energy_j", read_j))
        if self.auditor is not None:
            self.auditor.on_cache_hit(node, tau_in, cached, n_bytes,
                                      read_s, read_j, scale)

    def on_cache_evict(self, node, session_id: int,
                       reserved_tokens: int) -> None:
        self._lazy(self._cache_evictions, node.node_id).inc()
        if self.tracer is not None:
            self.tracer.instant("cache_evict", node.phase_end_s or 0.0,
                                node.node_id + 1, "cache",
                                ("session", session_id,
                                 "tokens", reserved_tokens))

    def on_cache_invalidate(self, node, n_entries: int, now: float) -> None:
        self._lazy(self._cache_invalidations, node.node_id).inc()
        if self.tracer is not None:
            self.tracer.instant("cache_invalidate", now, node.node_id + 1,
                                "cache", ("entries", n_entries))

    # --- fault/rescue hooks (called by repro.cluster.sim) ---------------
    def on_fault(self, event, node, now: float) -> None:
        self._lazy(self._faults, event.node_id, event.kind).inc()
        if self.tracer is not None:
            self.tracer.instant(event.kind, now, event.node_id + 1, "fault",
                                ("value", event.value))

    def on_migration(self, home, recipient, context: int, n_bytes: float,
                     ship_s: float, ship_j: float, now: float) -> None:
        self._lazy(self._migrations, home.node_id, recipient.node_id).inc()
        if self.tracer is not None:
            self.tracer.complete("kv_ship", now, ship_s,
                                 recipient.node_id + 1, "migration",
                                 ("from", home.node_id, "context", context,
                                  "bytes", n_bytes, "energy_j", ship_j))
        if self.auditor is not None:
            self.auditor.on_migration(home, recipient, context, n_bytes,
                                      ship_s, ship_j)

    def on_domain_outage(self, now: float, size: int) -> None:
        """A batch of simultaneous crash events finished applying: one
        correlated outage of `size` nodes (size 1 for independent faults
        — the degenerate one-node-per-domain topology)."""
        self._domain_outages.get().inc()
        self._h_outage_size.get().observe(size)
        if self.tracer is not None:
            self.tracer.instant("domain_outage", now, 0, "fault",
                                ("size", size))

    def on_retry(self, req, nid: int, attempts: int, now: float) -> None:
        self._lazy(self._retries, nid).inc()
        if self.tracer is not None:
            self.tracer.instant("retry", now, nid + 1, "retry",
                                ("request", req.request_id,
                                 "attempts", attempts))

    def on_abandon(self, rec, now: float) -> None:
        self._lazy(self._abandons, rec.reason).inc()
        if self.tracer is not None:
            self.tracer.instant("abandon", now, 0, "abandon",
                                ("request", rec.request_id,
                                 "reason", rec.reason,
                                 "wasted_j", rec.wasted_j))

    def on_drain(self, node, draining: bool, now: float) -> None:
        self._lazy(self._drains, node.node_id,
                   "drain" if draining else "undrain").inc()
        if self.tracer is not None:
            self.tracer.instant("drain" if draining else "undrain", now,
                                node.node_id + 1, "drain")

    def on_waste(self, node, e_j: float) -> None:
        if self.auditor is not None:
            self.auditor.on_waste(node, e_j)

    def on_power_span(self, node, kind: str, start_s: float,
                      end_s: float) -> None:
        if self.tracer is not None:
            self.tracer.complete(kind, start_s, end_s - start_s,
                                 node.node_id + 1, "power")

    # --- policy hooks (called by repro.cluster.policies) ----------------
    def on_prediction_error(self, policy_name: str, model: str,
                            predicted: float, actual: int) -> None:
        self._lazy(self._pred_err, policy_name, model).set(
            abs(predicted - float(actual)))

    # --- end of run -----------------------------------------------------
    def finalize(self, nodes: Sequence, report) -> None:
        """Write the end-of-run snapshot gauges (the aggregate view
        ClusterReport.from_registry rebuilds) and close the audit."""
        for n in report.node_stats:
            for bucket, e_j, secs in (
                    ("busy", n.busy_energy_j, n.busy_s),
                    ("idle", n.idle_energy_j, n.idle_s),
                    ("gated", n.gated_energy_j, n.gated_s),
                    ("transition", n.transition_energy_j, n.transition_s),
                    ("shipping", n.shipping_energy_j, n.shipping_s),
                    ("checkpoint", n.checkpoint_energy_j, n.checkpoint_s),
                    ("cache_read", n.cache_read_energy_j, n.cache_read_s),
                    ("wasted", n.wasted_energy_j, None),
                    ("failed", None, n.failed_s)):
                if e_j is not None:
                    self._bucket_energy.labels(n.node_id, bucket).set(e_j)
                if secs is not None:
                    self._bucket_seconds.labels(n.node_id, bucket).set(secs)
        r = self.registry
        # run-level gauges merge by max: every per-node partition of a
        # sharded run writes the same values, so the fold is idempotent
        info = r.gauge("sim_run_info", "run identity (always 1)",
                       ("policy",), merge="max")
        info.labels(report.policy).set(1)
        r.gauge("sim_zeta", "Eq. 2 tradeoff weight",
                merge="max").get().set(report.zeta)
        r.gauge("sim_makespan_seconds", "trace horizon",
                merge="max").get().set(report.makespan_s)
        r.gauge("sim_objective", "realized Eq. 2 objective",
                merge="max").get().set(report.objective)
        r.gauge("sim_predicted_energy_joules",
                "Σ e_K(q) under the fitted profiles",
                merge="max").get().set(report.predicted_energy_j)
        served = r.gauge("sim_node_served", "requests served per node",
                         ("node", "model"))
        util = r.gauge("sim_node_utilization", "busy_s / makespan",
                       ("node", "model"), merge="max")
        horizon = r.gauge("sim_node_horizon_seconds",
                          "accounted node horizon", ("node",), merge="max")
        pre = r.gauge("sim_node_preemptions", "preemptions per node",
                      ("node",))
        res = r.gauge("sim_node_resumes", "resumes per node", ("node",))
        wk = r.gauge("sim_node_wakes", "wake transitions per node",
                     ("node",))
        gt = r.gauge("sim_node_gates", "gate transitions per node",
                     ("node",))
        cr = r.gauge("sim_node_crashes", "crashes per node", ("node",))
        rc = r.gauge("sim_node_recoveries", "recoveries per node",
                     ("node",))
        mi = r.gauge("sim_node_migrations_in",
                     "refugee decodes received per node", ("node",))
        mo = r.gauge("sim_node_migrations_out",
                     "refugee decodes shipped away per node", ("node",))
        ck = r.gauge("sim_node_checkpoints",
                     "prefill-KV checkpoint persists per node", ("node",))
        rs = r.gauge("sim_node_restores",
                     "restore phases begun per node", ("node",))
        chh = r.gauge("sim_node_cache_hits",
                      "prefix-cache hits per node", ("node",))
        chm = r.gauge("sim_node_cache_misses",
                      "prefix-cache misses per node", ("node",))
        che = r.gauge("sim_node_cache_evictions",
                      "prefix-cache evictions per node", ("node",))
        cht = r.gauge("sim_node_cache_hit_tokens",
                      "reused warm prefix tokens per node", ("node",))
        for s in report.node_stats:
            served.labels(s.node_id, s.model).set(s.n_served)
            util.labels(s.node_id, s.model).set(s.utilization)
            horizon.labels(s.node_id).set(s.horizon_s)
            pre.labels(s.node_id).set(s.n_preemptions)
            res.labels(s.node_id).set(s.n_resumes)
            wk.labels(s.node_id).set(s.n_wakes)
            gt.labels(s.node_id).set(s.n_gates)
            cr.labels(s.node_id).set(s.n_crashes)
            rc.labels(s.node_id).set(s.n_recoveries)
            mi.labels(s.node_id).set(s.n_migrations_in)
            mo.labels(s.node_id).set(s.n_migrations_out)
            ck.labels(s.node_id).set(s.n_checkpoints)
            rs.labels(s.node_id).set(s.n_restores)
            chh.labels(s.node_id).set(s.n_cache_hits)
            chm.labels(s.node_id).set(s.n_cache_misses)
            che.labels(s.node_id).set(s.n_cache_evictions)
            cht.labels(s.node_id).set(s.cache_hit_tokens)
        if self.auditor is not None:
            self.auditor.on_finalize(nodes, report)

    # --- convenience ----------------------------------------------------
    def prometheus_text(self) -> str:
        return self.registry.prometheus_text()
