"""Streaming metrics: counters, gauges, bounded-memory quantile histograms.

The registry is the observable substrate the actor refactor will shard
over, so every primitive is *mergeable by construction*:

  * Counter    — merge by sum (monotone, associative, commutative);
  * Gauge      — merge by sum by default (per-node partitions carry
                 disjoint label sets, so fleet gauges like queue depth
                 simply add up); `merge="max"` opts a family into
                 max-merge (e.g. high-water marks).  Both rules are
                 associative and commutative, so a sharded fleet's
                 registries fold in any order to the same bytes.
  * Histogram  — log-bucketed streaming histogram: a value v > 0 lands
                 in bucket floor(log_b(v)) for a fixed base b, so memory
                 is O(log(range)/log(b)) regardless of sample count, and
                 merging is per-bucket count addition.  Quantile queries
                 return the upper edge of the first bucket whose
                 cumulative count reaches the rank, so the estimate is
                 within one bucket (a factor of b) of the exact sample
                 percentile — the error bound tests/test_obs.py pins
                 against numpy on adversarial distributions.

Families are labeled (the cluster layer uses (node, model, phase) label
sets); children are created lazily on first `.labels(...)` touch and
exported in sorted label order, so `prometheus_text()` output is
deterministic for a deterministic run and invariant to merge order.

`prometheus_text()` emits the standard text exposition format (HELP/TYPE
comments, `name{label="value"} value` samples, histograms as cumulative
`_bucket{le=...}` + `_sum` + `_count`) — parseable by prometheus_client's
`text_string_to_metric_families` (asserted in tests) and scrapeable by an
actual Prometheus once a serving endpoint fronts it.

No wall-clock anywhere: values are driven purely by simulation state, so
two runs of the same seeded trace produce byte-identical exports (the
determinism contract the tracer tests rely on).
"""

from __future__ import annotations

import math
import re
from typing import Iterable, Sequence

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# default histogram bucket growth factor: 2**(1/8) ≈ 1.09, i.e. ~9%
# relative quantile resolution at ~8 buckets per octave (≈ 320 buckets
# spanning 1e-6 s .. 1e6 s — bounded memory at any sample count)
DEFAULT_BASE = 2.0 ** 0.125


def _fmt(v: float) -> str:
    """Prometheus sample value: integers without a trailing .0, floats via
    repr (shortest round-trip form; exposition format accepts exponents)."""
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _escape(value: str) -> str:
    return (value.replace("\\", r"\\").replace('"', r"\"")
            .replace("\n", r"\n"))


class Counter:
    """Monotone counter.  Merge rule: sum."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        self.value += amount

    def merge_from(self, other: "Counter") -> None:
        self.value += other.value


class Gauge:
    """Point-in-time value.  Merge rule: sum (default) or max — both
    associative, so sharded registries fold in any order."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount

    def merge_max_from(self, other: "Gauge") -> None:
        self.value = max(self.value, other.value)

    def merge_from(self, other: "Gauge") -> None:
        self.value += other.value


class Histogram:
    """Log-bucketed streaming histogram with bounded memory.

    Bucket i holds values in (base**i, base**(i+1)]; non-positive values
    land in a dedicated zero bucket (durations/energies are never
    negative, but the zero case is real: e.g. queue_s of an immediately
    served request).  Tracks count/sum/min/max exactly; quantiles are
    bucket-resolution estimates (within a factor of `base` of the exact
    sample percentile)."""

    __slots__ = ("base", "_log_base", "counts", "zero_count", "count",
                 "sum", "min", "max")

    def __init__(self, base: float = DEFAULT_BASE):
        if base <= 1.0:
            raise ValueError("histogram base must be > 1")
        self.base = base
        self._log_base = math.log(base)
        self.counts: dict[int, int] = {}
        self.zero_count = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v <= 0.0:
            self.zero_count += 1
            return
        # ceil(log_b(v)) - 1 == floor when not on an edge; the -1e-12 guard
        # keeps exact bucket edges (v == base**i) in the lower bucket
        i = math.floor(math.log(v) / self._log_base - 1e-12)
        self.counts[i] = self.counts.get(i, 0) + 1

    def quantile(self, q: float) -> float:
        """Upper edge of the first bucket whose cumulative count reaches
        rank q·count — within one bucket (factor `base`) of the exact
        sample percentile.  q in [0, 1]; empty histograms answer 0.0."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return 0.0
        rank = q * self.count
        cum = self.zero_count
        if cum >= rank:
            return 0.0
        for i in sorted(self.counts):
            cum += self.counts[i]
            if cum >= rank:
                # clamp to the exactly-tracked extremes so p0/p100-ish
                # queries never leave the observed range
                return min(max(self.base ** (i + 1), self.min), self.max)
        return self.max

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def merge_from(self, other: "Histogram") -> None:
        if other.base != self.base:
            raise ValueError("cannot merge histograms with different bases")
        for i, c in other.counts.items():
            self.counts[i] = self.counts.get(i, 0) + c
        self.zero_count += other.zero_count
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)


class MetricFamily:
    """One named metric with a fixed label schema and lazily-created
    children per label-value tuple."""

    def __init__(self, name: str, kind: str, help: str,
                 labelnames: Sequence[str], make_child, merge: str = "sum"):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r}")
        if merge not in ("sum", "max"):
            raise ValueError(f"merge must be 'sum' or 'max', got {merge!r}")
        self.name = name
        self.kind = kind
        self.help = help
        self.labelnames = tuple(labelnames)
        self.merge = merge
        self._make_child = make_child
        self.children: dict[tuple[str, ...], object] = {}

    def labels(self, *values) -> object:
        if len(values) != len(self.labelnames):
            raise ValueError(
                f"{self.name}: expected {len(self.labelnames)} label values "
                f"({self.labelnames}), got {len(values)}")
        key = tuple(str(v) for v in values)
        child = self.children.get(key)
        if child is None:
            child = self.children[key] = self._make_child()
        return child

    def get(self) -> object:
        """The unlabeled child (only valid for label-free families)."""
        return self.labels()

    def _label_str(self, key: tuple[str, ...],
                   extra: str = "") -> str:
        parts = [f'{n}="{_escape(v)}"' for n, v in zip(self.labelnames, key)]
        if extra:
            parts.append(extra)
        return "{" + ",".join(parts) + "}" if parts else ""

    def sorted_children(self) -> Iterable[tuple[tuple[str, ...], object]]:
        return sorted(self.children.items())

    def merge_from(self, other: "MetricFamily") -> None:
        if (other.kind != self.kind or other.labelnames != self.labelnames
                or other.merge != self.merge):
            raise ValueError(
                f"family {self.name!r} schema mismatch on merge")
        for key, child in other.children.items():
            mine = self.children.get(key)
            if mine is None:
                mine = self.children[key] = self._make_child()
                if self.kind == "gauge" and self.merge == "max":
                    # A fresh Gauge starts at 0.0; max-merging against
                    # that floor would clobber negative values (the
                    # realized objective can be < 0), so a child absent
                    # on this side adopts the incoming value verbatim.
                    mine.value = child.value
                    continue
            if self.kind == "gauge" and self.merge == "max":
                mine.merge_max_from(child)
            else:
                mine.merge_from(child)


class MetricsRegistry:
    """Factory and container for metric families; the unit of sharding.

    `counter`/`gauge`/`histogram` are idempotent (same name → same family,
    with a schema check), so instrumented code can declare its metrics at
    the point of use.  `merge` folds another registry in (per-family,
    per-child, using each primitive's associative merge rule), which is
    how per-node partitions of a sharded fleet will aggregate."""

    def __init__(self):
        self._families: dict[str, MetricFamily] = {}

    # --- factories ----------------------------------------------------
    def _family(self, name: str, kind: str, help: str,
                labelnames: Sequence[str], make_child,
                merge: str = "sum") -> MetricFamily:
        fam = self._families.get(name)
        if fam is not None:
            if fam.kind != kind or fam.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} re-declared with a different schema: "
                    f"{fam.kind}{fam.labelnames} vs {kind}{tuple(labelnames)}")
            return fam
        fam = MetricFamily(name, kind, help, labelnames, make_child, merge)
        self._families[name] = fam
        return fam

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> MetricFamily:
        return self._family(name, "counter", help, labelnames, Counter)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = (),
              merge: str = "sum") -> MetricFamily:
        return self._family(name, "gauge", help, labelnames, Gauge,
                            merge=merge)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  base: float = DEFAULT_BASE) -> MetricFamily:
        return self._family(name, "histogram", help, labelnames,
                            lambda: Histogram(base))

    # --- access -------------------------------------------------------
    def families(self) -> dict[str, MetricFamily]:
        return dict(self._families)

    def __contains__(self, name: str) -> bool:
        return name in self._families

    def __getitem__(self, name: str) -> MetricFamily:
        return self._families[name]

    def value(self, name: str, *labelvalues) -> float:
        """Convenience scalar read (counter/gauge value); 0.0 when the
        child was never touched."""
        fam = self._families[name]
        child = fam.children.get(tuple(str(v) for v in labelvalues))
        return 0.0 if child is None else child.value

    # --- merge --------------------------------------------------------
    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold `other` into self (in place; returns self).  Families
        missing on either side are unioned in; shared families merge
        child-wise under their associative rules."""
        for name, fam in other._families.items():
            mine = self._families.get(name)
            if mine is None:
                mine = self._families[name] = MetricFamily(
                    fam.name, fam.kind, fam.help, fam.labelnames,
                    fam._make_child, fam.merge)
            mine.merge_from(fam)
        return self

    @classmethod
    def merged(cls, registries: Sequence["MetricsRegistry"]
               ) -> "MetricsRegistry":
        out = cls()
        for r in registries:
            out.merge(r)
        return out

    # --- export -------------------------------------------------------
    def prometheus_text(self) -> str:
        """Standard Prometheus text exposition format (version 0.0.4)."""
        lines: list[str] = []
        for name in sorted(self._families):
            fam = self._families[name]
            if not fam.children:
                continue
            if fam.help:
                lines.append(f"# HELP {name} {_escape(fam.help)}")
            lines.append(f"# TYPE {name} {fam.kind}")
            for key, child in fam.sorted_children():
                if fam.kind == "histogram":
                    cum = child.zero_count
                    if child.zero_count:
                        lab = fam._label_str(key, 'le="0"')
                        lines.append(f"{name}_bucket{lab} {cum}")
                    for i in sorted(child.counts):
                        cum += child.counts[i]
                        le = _fmt(child.base ** (i + 1))
                        lab = fam._label_str(key, f'le="{le}"')
                        lines.append(f"{name}_bucket{lab} {cum}")
                    lab = fam._label_str(key, 'le="+Inf"')
                    lines.append(f"{name}_bucket{lab} {child.count}")
                    lines.append(
                        f"{name}_sum{fam._label_str(key)} {_fmt(child.sum)}")
                    lines.append(
                        f"{name}_count{fam._label_str(key)} {child.count}")
                else:
                    lines.append(
                        f"{name}{fam._label_str(key)} {_fmt(child.value)}")
        return "\n".join(lines) + "\n" if lines else ""

    def to_dict(self) -> dict:
        """JSON-able snapshot (counters/gauges as scalars, histograms as
        count/sum/quantile summaries) — the benchmark dump format."""
        out: dict = {}
        for name in sorted(self._families):
            fam = self._families[name]
            fam_out: dict = {"kind": fam.kind, "labels": list(fam.labelnames),
                             "children": {}}
            for key, child in fam.sorted_children():
                tag = ",".join(key) if key else ""
                if fam.kind == "histogram":
                    fam_out["children"][tag] = {
                        "count": child.count, "sum": child.sum,
                        "min": None if child.count == 0 else child.min,
                        "max": None if child.count == 0 else child.max,
                        "p50": child.quantile(0.50),
                        "p95": child.quantile(0.95),
                        "p99": child.quantile(0.99),
                    }
                else:
                    fam_out["children"][tag] = child.value
            out[name] = fam_out
        return out
