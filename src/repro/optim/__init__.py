"""In-house optimizers (no optax in this environment).

API:  opt = get_optimizer(name)
      state = opt.init(params)
      params, state = opt.update(grads, state, params, lr)

AdamW keeps f32 moments; Adafactor keeps factored second moments only
(no first moment) — required to fit deepseek-v3-671b training state into
256 x 16 GB (see DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (params, state)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def _adamw(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * gf * gf
            mh = m / c1
            vh = v / c2
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return params, {"m": m, "v": v, "step": step}

    return Optimizer("adamw", init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments, no momentum)
# ---------------------------------------------------------------------------


def _adafactor(decay=0.8, eps=1e-30, clip=1.0) -> Optimizer:
    def init(params):
        def leaf(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(leaf, params), "step": jnp.zeros((), jnp.int32)}

    # flattened implementation: per-leaf state dicts have heterogeneous
    # structure (factored vs unfactored), so zip over grads' treedef.
    def update(grads, state, params, lr):
        step = state["step"] + 1
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)

        g_leaves, treedef = jax.tree.flatten(grads)
        p_leaves = treedef.flatten_up_to(params)
        s_leaves = treedef.flatten_up_to(state["f"])
        new_p, new_s = [], []
        for g, s, p in zip(g_leaves, s_leaves, p_leaves):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                r = vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                u = gf / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :] + 1e-12)
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = gf / (jnp.sqrt(v) + 1e-12)
                ns = {"v": v}
            norm = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, norm / clip)
            new_p.append((p.astype(jnp.float32) - lr * u).astype(p.dtype))
            new_s.append(ns)
        return (jax.tree.unflatten(treedef, new_p),
                {"f": jax.tree.unflatten(treedef, new_s), "step": step})

    return Optimizer("adafactor", init, update)


# ---------------------------------------------------------------------------
# SGD + momentum
# ---------------------------------------------------------------------------


def _sgd(momentum=0.9) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, lr):
        def upd(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m
        g_leaves, treedef = jax.tree.flatten(grads)
        m_leaves = treedef.flatten_up_to(state["m"])
        p_leaves = treedef.flatten_up_to(params)
        out = [upd(g, m, p) for g, m, p in zip(g_leaves, m_leaves, p_leaves)]
        return (jax.tree.unflatten(treedef, [o[0] for o in out]),
                {"m": jax.tree.unflatten(treedef, [o[1] for o in out])})

    return Optimizer("sgd", init, update)


_REGISTRY = {
    "adamw": _adamw,
    "adafactor": _adafactor,
    "sgd": _sgd,
}


def get_optimizer(name: str, **kw) -> Optimizer:
    if name not in _REGISTRY:
        raise KeyError(f"unknown optimizer {name!r}")
    return _REGISTRY[name](**kw)
