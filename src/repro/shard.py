"""Logical-axis sharding: the one leaf module models and launch both import.

Models annotate activations/params with *logical* axis names ("batch",
"heads", "mlp", "expert", ...).  A rules table maps logical names to mesh
axes.  Outside any rules context (CPU unit tests) every constraint is a
no-op, so the model code runs unchanged on one device.

The rules table is ALSO the main performance-iteration lever: §Perf
experiments swap rules (e.g. move "kv_seq" from None to "model") without
touching model code.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Mapping, Sequence

import jax
from jax.sharding import PartitionSpec as P

# logical axis -> mesh axis (str), tuple of mesh axes, or None (replicated)
Rules = Mapping[str, object]

# Baseline rules for the production mesh ("data", "model") [+ "pod"].
# "pod" is folded into the batch axis by make_rules(multi_pod=True).
DEFAULT_RULES: dict[str, object] = {
    "batch": "data",
    "seq": None,          # activation sequence dim ("model" = Megatron-SP, set for train)
    "kv_seq": "model",    # KV-cache sequence dim: flash-decode layout by default
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "embed": None,        # activation d_model dim
    "embed_w": None,      # weight d_model (contraction) dim
    "mlp": "model",       # d_ff
    "vocab": "model",
    "expert": "model",
    "capacity": "data",   # MoE expert-capacity dim
    "moe_embed": "model",  # d dim of token-major MoE intermediates (gathers
                           # run locally per d-shard; rows stay replicated)
    "ssm_heads": "model",
    "state": None,
    "lru": "model",
    "frames": None,
    "layers": None,
}

_rules_var: contextvars.ContextVar[Rules | None] = contextvars.ContextVar(
    "shard_rules", default=None
)
_axis_sizes_var: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "shard_axis_sizes", default=None
)


def make_rules(*, multi_pod: bool = False, overrides: Rules | None = None) -> dict:
    rules = dict(DEFAULT_RULES)
    if multi_pod:
        rules["batch"] = ("pod", "data")
    if overrides:
        rules.update(overrides)
    return rules


@contextlib.contextmanager
def use_rules(rules: Rules | None, axis_sizes: dict | None = None):
    """Activate logical-axis rules.  Pass the mesh's {axis: size} so
    constraints are legalized consistently with input shardings (see
    legalize_spec)."""
    token = _rules_var.set(rules)
    token2 = _axis_sizes_var.set(axis_sizes)
    try:
        yield
    finally:
        _rules_var.reset(token)
        _axis_sizes_var.reset(token2)


def current_rules() -> Rules | None:
    return _rules_var.get()


def current_axis_sizes() -> dict | None:
    return _axis_sizes_var.get()


def legalize_spec(shape: tuple, spec: P, axis_sizes: dict) -> P:
    """Make `spec` divisibility-valid for `shape` by RELOCATING any mesh
    axis on a non-dividing dim to the largest free dim it divides.

    This is the layout policy, not just a fallback:
      * GQA kv=8 weights against a model=16 axis -> row-parallel (d_model)
      * KV caches with few kv heads -> sequence-sharded (flash-decode)
      * odd vocab (92553) -> shard d_model instead

    Deterministic, so model-internal constraints and jit input shardings
    resolve to the SAME layout (no hidden reshards)."""
    entries: list = list(spec) + [None] * (len(shape) - len(spec))

    def factor(entry) -> int:
        if entry is None:
            return 1
        axes = entry if isinstance(entry, tuple) else (entry,)
        f = 1
        for a in axes:
            f *= axis_sizes[a]
        return f

    for i in range(len(entries)):
        e = entries[i]
        if e is None:
            continue
        f = factor(e)
        if f <= 1 or shape[i] % f == 0:
            continue
        entries[i] = None
        candidates = sorted(
            (j for j in range(len(entries))
             if entries[j] is None and shape[j] % f == 0 and shape[j] >= f),
            key=lambda j: -shape[j])
        if candidates:
            entries[candidates[0]] = e
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def resolve(axes: Sequence[str | None], rules: Rules | None = None) -> P:
    """Logical axes -> PartitionSpec under the active rules.  A mesh axis
    may appear only once per spec: first logical occurrence wins (e.g. an
    MoE expert weight [E, d, ff] with expert->model keeps ff replicated)."""
    if rules is None:
        rules = current_rules()
    if rules is None:
        return P()
    entries = []
    used: set = set()
    for ax in axes:
        entry = None if ax is None else rules.get(ax, None)
        if entry is not None:
            mesh_axes = entry if isinstance(entry, tuple) else (entry,)
            if any(a in used for a in mesh_axes):
                entry = None
            else:
                used.update(mesh_axes)
        entries.append(entry)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def constrain(x: jax.Array, *axes: str | None) -> jax.Array:
    """with_sharding_constraint via logical axes; no-op without rules.
    Legalized against the ambient mesh axis sizes so it always agrees with
    the jit input layout."""
    rules = current_rules()
    if rules is None:
        return x
    spec = resolve(axes, rules)
    sizes = current_axis_sizes()
    if sizes:
        spec = legalize_spec(x.shape, spec, sizes)
    if not spec:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
