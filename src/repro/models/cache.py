"""Decode-time state containers (KV caches and recurrent states).

All are registered pytrees so they flow through jit/scan/pjit.  `pos` is a
scalar int32: the absolute position of the *next* token to be written.
Sliding-window caches are ring buffers of size `window`; keys are stored
already-roped at absolute positions so the ring overwrite is safe.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


def _register(cls):
    fields = [f.name for f in dataclasses.fields(cls)]
    data = [f for f in fields if f != "meta"]
    jax.tree_util.register_dataclass(cls, data_fields=data, meta_fields=["meta"] if "meta" in fields else [])
    return cls


@_register
@dataclasses.dataclass
class KVCache:
    """Full attention cache: k, v [L, B, S, Hkv, Dh]."""
    k: jax.Array
    v: jax.Array
    pos: jax.Array   # scalar int32

    @staticmethod
    def init(n_layers, batch, cache_len, n_kv, head_dim, dtype) -> "KVCache":
        shape = (n_layers, batch, cache_len, n_kv, head_dim)
        return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                       jnp.zeros((), jnp.int32))

    @property
    def cache_len(self) -> int:
        return self.k.shape[2]


@_register
@dataclasses.dataclass
class WindowKVCache:
    """Ring-buffer sliding-window cache: k, v [L, B, W, Hkv, Dh]."""
    k: jax.Array
    v: jax.Array
    pos: jax.Array

    @staticmethod
    def init(n_layers, batch, window, n_kv, head_dim, dtype) -> "WindowKVCache":
        shape = (n_layers, batch, window, n_kv, head_dim)
        return WindowKVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                             jnp.zeros((), jnp.int32))

    @property
    def window(self) -> int:
        return self.k.shape[2]


@_register
@dataclasses.dataclass
class MLACache:
    """DeepSeek-V3 latent cache: c_kv [L, B, S, kv_lora], k_rope [L, B, S, rope_dim]."""
    c_kv: jax.Array
    k_rope: jax.Array
    pos: jax.Array

    @staticmethod
    def init(n_layers, batch, cache_len, kv_lora, rope_dim, dtype) -> "MLACache":
        return MLACache(
            jnp.zeros((n_layers, batch, cache_len, kv_lora), dtype),
            jnp.zeros((n_layers, batch, cache_len, rope_dim), dtype),
            jnp.zeros((), jnp.int32),
        )

    @property
    def cache_len(self) -> int:
        return self.c_kv.shape[2]


@_register
@dataclasses.dataclass
class SSMCache:
    """Mamba-2 state: conv_state [L, B, K-1, conv_ch], ssd_state [L, B, H, P, N]."""
    conv: jax.Array
    state: jax.Array
    pos: jax.Array

    @staticmethod
    def init(n_layers, batch, conv_kernel, conv_ch, nheads, headdim, state, dtype) -> "SSMCache":
        return SSMCache(
            jnp.zeros((n_layers, batch, conv_kernel - 1, conv_ch), dtype),
            jnp.zeros((n_layers, batch, nheads, headdim, state), jnp.float32),
            jnp.zeros((), jnp.int32),
        )


@_register
@dataclasses.dataclass
class HybridCache:
    """RecurrentGemma: RG-LRU states + conv states for recurrent layers,
    sliding-window KV for attention layers."""
    lru: jax.Array      # [Lr, B, width] f32
    conv: jax.Array     # [Lr, B, K-1, width]
    k: jax.Array        # [La, B, W, Hkv, Dh]
    v: jax.Array
    pos: jax.Array

    @staticmethod
    def init(n_rec, n_attn, batch, width, conv_kernel, window, n_kv, head_dim, dtype) -> "HybridCache":
        kv = (n_attn, batch, window, n_kv, head_dim)
        return HybridCache(
            jnp.zeros((n_rec, batch, width), jnp.float32),
            jnp.zeros((n_rec, batch, conv_kernel - 1, width), dtype),
            jnp.zeros(kv, dtype), jnp.zeros(kv, dtype),
            jnp.zeros((), jnp.int32),
        )

    @property
    def window(self) -> int:
        return self.k.shape[2]


@_register
@dataclasses.dataclass
class EncDecCache:
    """Seamless decoder cache: self-attn KV + precomputed cross-attn KV."""
    self_k: jax.Array    # [L, B, S, H, Dh]
    self_v: jax.Array
    cross_k: jax.Array   # [L, B, T_frames, H, Dh]
    cross_v: jax.Array
    pos: jax.Array

    @staticmethod
    def init(n_layers, batch, cache_len, n_frames, n_kv, head_dim, dtype) -> "EncDecCache":
        s = (n_layers, batch, cache_len, n_kv, head_dim)
        c = (n_layers, batch, n_frames, n_kv, head_dim)
        return EncDecCache(jnp.zeros(s, dtype), jnp.zeros(s, dtype),
                           jnp.zeros(c, dtype), jnp.zeros(c, dtype),
                           jnp.zeros((), jnp.int32))

    @property
    def cache_len(self) -> int:
        return self.self_k.shape[2]


def onehot_write(cache_l: jax.Array, new: jax.Array, slot) -> jax.Array:
    """Write one token into a per-layer cache slice at `slot` along axis 1.

    cache_l [B, S, ...rest]; new [B, ...rest].  Implemented as an
    elementwise one-hot blend instead of dynamic_update_slice: DUS at a
    dynamic index on a SHARDED sequence dim makes GSPMD replicate the whole
    buffer ("involuntary full rematerialization"); the one-hot blend stays
    elementwise on the sharded layout."""
    S = cache_l.shape[1]
    blend_dt = new.dtype            # fp8 caches blend in the compute dtype
    oh = (jnp.arange(S) == slot).astype(blend_dt)
    oh = oh.reshape((1, S) + (1,) * (cache_l.ndim - 2))
    out = cache_l.astype(blend_dt) * (1 - oh) + new[:, None].astype(blend_dt) * oh
    return out.astype(cache_l.dtype)


def ring_pack(ks: jax.Array, vs: jax.Array, window: int, pos_end: int):
    """Pack full-sequence K/V [L,B,S,H,D] into ring buffers [L,B,W,H,D]
    holding the last min(S, W) positions at slot = pos % W."""
    S = ks.shape[2]
    take = min(S, window)
    pos = jnp.arange(pos_end - take, pos_end)
    slots = pos % window
    shape = ks.shape[:2] + (window,) + ks.shape[3:]
    k = jnp.zeros(shape, ks.dtype).at[:, :, slots].set(ks[:, :, -take:])
    v = jnp.zeros(shape, vs.dtype).at[:, :, slots].set(vs[:, :, -take:])
    return k, v


def write_kv(k_cache: jax.Array, v_cache: jax.Array, layer: jax.Array | int,
             k_new: jax.Array, v_new: jax.Array, slot: jax.Array):
    """Write one token's K/V at `slot` for `layer`.
    k_cache [L,B,S,H,D]; k_new [B,H,D]."""
    k_new = k_new[None, :, None]  # [1,B,1,H,D]
    v_new = v_new[None, :, None]
    idx = (layer, 0, slot, 0, 0)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new.astype(k_cache.dtype), idx)
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new.astype(v_cache.dtype), idx)
    return k_cache, v_cache
