"""InternVL2 language backbone (VLM family).

The InternViT vision tower is the allowed stub: `input_specs()` supplies
precomputed patch embeddings [B, n_patches, vision_dim].  This module owns
the MLP projector (vision_dim -> d_model) and the InternLM2-style decoder
(llama-arch GQA), with patch embeddings interleaved BEFORE the text tokens
in the causal stream — the standard VLM prefill layout.

Everything after embedding reuses repro.models.dense; the KV cache covers
patch positions + text positions, so decode is identical to dense decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import dense
from repro.models.common import (
    ModelConfig,
    ParamDef,
    cross_entropy,
    embed_tokens,
    lm_logits,
    rmsnorm,
)

VISION_DIM = 1024  # InternViT-300M output width (frontend stub contract)


def param_defs(cfg: ModelConfig) -> dict:
    defs = dense.param_defs(cfg)
    defs["projector"] = {
        "w1": ParamDef((VISION_DIM, cfg.d_model), (None, "embed_w")),
        "b1": ParamDef((cfg.d_model,), (None,), init="zeros"),
        "w2": ParamDef((cfg.d_model, cfg.d_model), ("embed_w", None)),
        "b2": ParamDef((cfg.d_model,), (None,), init="zeros"),
    }
    return defs


def project_patches(params: dict, patches: jax.Array, dtype) -> jax.Array:
    p = params["projector"]
    h = jnp.einsum("bpv,vd->bpd", patches.astype(dtype), p["w1"]) + p["b1"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(dtype)
    return jnp.einsum("bpd,de->bpe", h, p["w2"]) + p["b2"]


def _embed_multimodal(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    """[patches ; tokens] -> [B, P + S_text, d]."""
    x_txt = embed_tokens(params["embed"], batch["tokens"])
    x_img = project_patches(params, batch["patches"], x_txt.dtype)
    return jnp.concatenate([x_img, x_txt], axis=1)


def train_loss(cfg: ModelConfig, params: dict, batch: dict):
    """batch: {"patches": [B,P,VISION_DIM], "tokens": [B,S], "labels": [B,S]}.
    Labels cover only the text positions; patch positions are ignored."""
    x = _embed_multimodal(cfg, params, batch)
    h, _ = dense.forward_full(cfg, params["blocks"], x, window=cfg.window)
    h = rmsnorm(h, params["final_norm"]["w"], cfg.rmsnorm_eps)
    P = batch["patches"].shape[1]
    logits = lm_logits(h[:, P:], dense.head_matrix(cfg, params), cfg.vocab_size)
    loss, _ = cross_entropy(logits, batch["labels"])
    return loss, {}


def prefill(cfg: ModelConfig, params: dict, batch: dict, *,
            cache_len: int, long_context: bool = False):
    window = cfg.long_context_window if long_context else cfg.window
    x = _embed_multimodal(cfg, params, batch)
    S = x.shape[1]
    h, (ks, vs) = dense.forward_full(cfg, params["blocks"], x, window=window,
                                     collect_kv=True)
    h = rmsnorm(h[:, -1], params["final_norm"]["w"], cfg.rmsnorm_eps)
    logits = lm_logits(h, dense.head_matrix(cfg, params), cfg.vocab_size)
    cache = dense._finish_cache(cfg, ks, vs, cache_len, window, S)
    return logits, cache


init_cache = dense.init_cache
decode_step = dense.decode_step
