"""Decoder-only dense transformer (Llama/Qwen/Falcon/Mistral family).

Covers the assigned dense archs (qwen2.5-14b, deepseek-67b, llama3.2-3b,
qwen3-1.7b) and the paper's own zoo (Falcon 7/40B, Llama-2 7/13/70B,
Mistral 7B).  GQA with optional QKV bias (Qwen2.5), qk-norm (Qwen3) and
sliding-window attention (long-context decode mode for dense archs).

Layers are stacked and scanned; decode threads the KV cache through the
layer scan as carry.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import shard
from repro.models import attention as attn
from repro.models import cache as cachelib
from repro.models.common import (
    ModelConfig,
    padded_vocab,
    ParamDef,
    cross_entropy,
    embed_tokens,
    lm_logits,
    maybe_remat,
    mlp_defs,
    rmsnorm,
    rope,
    swiglu,
)


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


def attn_defs(cfg: ModelConfig, n_layers: int) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    L = (n_layers,)
    A = ("layers",)
    defs = {
        "wq": ParamDef(L + (d, hq, hd), A + ("embed_w", "heads", None)),
        "wk": ParamDef(L + (d, hkv, hd), A + ("embed_w", "kv_heads", None)),
        "wv": ParamDef(L + (d, hkv, hd), A + ("embed_w", "kv_heads", None)),
        "wo": ParamDef(L + (hq, hd, d), A + ("heads", None, "embed_w"),
                       scale=0.02 / max(1, (2 * cfg.n_layers) ** 0.5)),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef(L + (hq, hd), A + ("heads", None), init="zeros")
        defs["bk"] = ParamDef(L + (hkv, hd), A + ("kv_heads", None), init="zeros")
        defs["bv"] = ParamDef(L + (hkv, hd), A + ("kv_heads", None), init="zeros")
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef(L + (hd,), A + (None,), init="zeros")
        defs["k_norm"] = ParamDef(L + (hd,), A + (None,), init="zeros")
    return defs


def layer_defs(cfg: ModelConfig) -> dict:
    L = (cfg.n_layers,)
    A = ("layers",)
    return {
        "attn": attn_defs(cfg, cfg.n_layers),
        "mlp": mlp_defs(cfg.d_model, cfg.d_ff, cfg.n_layers),
        "ln_attn": {"w": ParamDef(L + (cfg.d_model,), A + (None,), init="zeros")},
        "ln_mlp": {"w": ParamDef(L + (cfg.d_model,), A + (None,), init="zeros")},
    }


def param_defs(cfg: ModelConfig) -> dict:
    defs = {
        "embed": ParamDef((padded_vocab(cfg.vocab_size), cfg.d_model), ("vocab", "embed_w")),
        "blocks": layer_defs(cfg),
        "final_norm": {"w": ParamDef((cfg.d_model,), (None,), init="zeros")},
    }
    if not cfg.tie_embeddings:
        defs["head"] = ParamDef((cfg.d_model, padded_vocab(cfg.vocab_size)),
                                ("embed_w", "vocab"))
    return defs


def head_matrix(cfg: ModelConfig, params: dict) -> jax.Array:
    return params["embed"].T if cfg.tie_embeddings else params["head"]


# ---------------------------------------------------------------------------
# Attention sublayer
# ---------------------------------------------------------------------------


def _project_qkv(cfg: ModelConfig, pl: dict, x: jax.Array):
    """x [..., d] -> q [..., Hq, Dh], k/v [..., Hkv, Dh] (roped by caller)."""
    q = jnp.einsum("...d,dhe->...he", x, pl["wq"])
    k = jnp.einsum("...d,dhe->...he", x, pl["wk"])
    v = jnp.einsum("...d,dhe->...he", x, pl["wv"])
    if cfg.qkv_bias:
        q, k, v = q + pl["bq"], k + pl["bk"], v + pl["bv"]
    if cfg.qk_norm:
        q = rmsnorm(q, pl["q_norm"], cfg.rmsnorm_eps)
        k = rmsnorm(k, pl["k_norm"], cfg.rmsnorm_eps)
    return q, k, v


def attention_full(cfg: ModelConfig, pl: dict, x: jax.Array, *,
                   q_offset: int = 0, window: int = 0, causal: bool = True):
    """Full-sequence attention sublayer.  Returns (y, k, v) — roped k and raw
    v for the cache."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, pl, x)
    positions = q_offset + jnp.arange(S)
    q = rope(q, jnp.broadcast_to(positions, (B, S)), cfg.rope_theta)
    k = rope(k, jnp.broadcast_to(positions, (B, S)), cfg.rope_theta)
    o = attn.full_attention(q, k, v, causal=causal, window=window,
                            q_offset=q_offset, softcap=cfg.attn_logit_softcap)
    y = jnp.einsum("...he,hed->...d", o, pl["wo"])
    return y, k, v


def attention_decode(cfg: ModelConfig, pl: dict, x: jax.Array,
                     k_cache_l: jax.Array, v_cache_l: jax.Array,
                     pos: jax.Array, *, ring: bool):
    """One-token attention.  x [B, d]; k_cache_l [B, S, Hkv, Dh] — already
    containing this token's K/V (written by the caller).  Returns y [B, d]."""
    q, _, _ = _project_qkv(cfg, pl, x)
    q = rope(q[:, None], jnp.full((x.shape[0], 1), pos), cfg.rope_theta)[:, 0]
    o = attn.decode_attention(q, k_cache_l, v_cache_l, pos, ring=ring,
                              softcap=cfg.attn_logit_softcap)
    return jnp.einsum("bhe,hed->bd", o, pl["wo"])


def project_kv_token(cfg: ModelConfig, pl: dict, x: jax.Array, pos: jax.Array):
    """K/V for one token [B, d] -> roped k, v [B, Hkv, Dh]."""
    _, k, v = _project_qkv(cfg, pl, x)
    k = rope(k[:, None], jnp.full((x.shape[0], 1), pos), cfg.rope_theta)[:, 0]
    return k, v


# ---------------------------------------------------------------------------
# Transformer stack
# ---------------------------------------------------------------------------


def forward_full(cfg: ModelConfig, blocks: dict, x: jax.Array, *,
                 q_offset: int = 0, window: int = 0, collect_kv: bool = False):
    """Run the scanned layer stack over embeddings x [B, S, d].
    Returns (hidden, (ks, vs) | None); ks [L, B, S, Hkv, Dh]."""

    def body(h, pl):
        h = shard.constrain(h, "batch", "seq", None)
        a, k, v = attention_full(cfg, pl["attn"], rmsnorm(h, pl["ln_attn"]["w"], cfg.rmsnorm_eps),
                                 q_offset=q_offset, window=window)
        h = h + a
        m = swiglu(rmsnorm(h, pl["ln_mlp"]["w"], cfg.rmsnorm_eps),
                   pl["mlp"]["w_gate"], pl["mlp"]["w_up"], pl["mlp"]["w_down"])
        h = h + m
        out = (k, v) if collect_kv else None
        return h, out

    body = maybe_remat(body, cfg.remat)
    h, kv = jax.lax.scan(body, x, blocks)
    return h, kv


def decode_pass(cfg: ModelConfig, blocks: dict, x: jax.Array,
                k_cache: jax.Array, v_cache: jax.Array, pos: jax.Array, *,
                ring: bool):
    """One-token pass.  x [B, d]; k_cache [L, B, S, Hkv, Dh].

    Per-layer cache slices flow through the scan as xs and the updated
    layers come back as ys — NOT as carry, which would double-buffer the
    multi-GB cache inside the loop (measured 4x cache bytes of temp).
    Returns (hidden, k_cache, v_cache)."""
    S = k_cache.shape[2]
    slot = jnp.where(jnp.asarray(ring), pos % S, jnp.minimum(pos, S - 1))

    def body(h, inp):
        pl, k_l, v_l = inp          # k_l [B, S, Hkv, Dh] — this layer's cache
        xin = rmsnorm(h, pl["ln_attn"]["w"], cfg.rmsnorm_eps)
        k_new, v_new = project_kv_token(cfg, pl["attn"], xin, pos)
        k_l = cachelib.onehot_write(k_l, k_new, slot)
        v_l = cachelib.onehot_write(v_l, v_new, slot)
        a = attention_decode(cfg, pl["attn"], xin, k_l, v_l, pos, ring=ring)
        h = h + a
        m = swiglu(rmsnorm(h, pl["ln_mlp"]["w"], cfg.rmsnorm_eps),
                   pl["mlp"]["w_gate"], pl["mlp"]["w_up"], pl["mlp"]["w_down"])
        h = h + m
        return h, (k_l, v_l)

    h, (k_cache, v_cache) = jax.lax.scan(body, x, (blocks, k_cache, v_cache))
    return h, k_cache, v_cache


# ---------------------------------------------------------------------------
# Registry API
# ---------------------------------------------------------------------------


def train_loss(cfg: ModelConfig, params: dict, batch: dict):
    tokens, labels = batch["tokens"], batch["labels"]
    x = embed_tokens(params["embed"], tokens)
    h, _ = forward_full(cfg, params["blocks"], x, window=cfg.window)
    h = rmsnorm(h, params["final_norm"]["w"], cfg.rmsnorm_eps)
    logits = lm_logits(h, head_matrix(cfg, params), cfg.vocab_size)
    loss, _ = cross_entropy(logits, labels)
    return loss, {}


def _finish_cache(cfg, ks, vs, cache_len, window, pos_end):
    """Stacked per-layer K/V [L,B,S,...] -> cache object sized cache_len or
    ring-packed into `window` slots."""
    ks = ks.astype(cfg.kv_dtype)
    vs = vs.astype(cfg.kv_dtype)
    if window:
        k, v = cachelib.ring_pack(ks, vs, window, pos_end)
        return cachelib.WindowKVCache(k, v, jnp.asarray(pos_end, jnp.int32))
    S = ks.shape[2]
    pad = [(0, 0), (0, 0), (0, cache_len - S), (0, 0), (0, 0)]
    return cachelib.KVCache(jnp.pad(ks, pad), jnp.pad(vs, pad),
                            jnp.asarray(pos_end, jnp.int32))


def prefill(cfg: ModelConfig, params: dict, batch: dict, *,
            cache_len: int, long_context: bool = False):
    tokens = batch["tokens"]
    S = tokens.shape[1]
    window = cfg.long_context_window if long_context else cfg.window
    x = embed_tokens(params["embed"], tokens)
    h, (ks, vs) = forward_full(cfg, params["blocks"], x, window=window,
                               collect_kv=True)
    h = rmsnorm(h[:, -1], params["final_norm"]["w"], cfg.rmsnorm_eps)
    logits = lm_logits(h, head_matrix(cfg, params), cfg.vocab_size)
    cache = _finish_cache(cfg, ks, vs, cache_len, window, S)
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, *,
               long_context: bool = False, dtype=None):
    dtype = dtype or cfg.kv_dtype
    window = cfg.long_context_window if long_context else cfg.window
    if window:
        return cachelib.WindowKVCache.init(
            cfg.n_layers, batch, min(window, cache_len), cfg.n_kv_heads,
            cfg.head_dim_, dtype)
    return cachelib.KVCache.init(cfg.n_layers, batch, cache_len,
                                 cfg.n_kv_heads, cfg.head_dim_, dtype)


def decode_step(cfg: ModelConfig, params: dict, cache, batch: dict):
    """batch: {"token": [B] int32}.  Uses cache.pos as the write position."""
    token = batch["token"]
    pos = cache.pos
    ring = isinstance(cache, cachelib.WindowKVCache)
    x = jnp.take(params["embed"], token, axis=0)
    h, kc, vc = decode_pass(cfg, params["blocks"], x, cache.k, cache.v, pos,
                            ring=ring)
    h = rmsnorm(h, params["final_norm"]["w"], cfg.rmsnorm_eps)
    logits = lm_logits(h, head_matrix(cfg, params), cfg.vocab_size)
    new_cache = type(cache)(kc, vc, pos + 1)
    return logits, new_cache
