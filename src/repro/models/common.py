"""Shared model substrate: config, parameter definitions, norms, RoPE,
embeddings, losses.

Every architecture is a pure-functional JAX model: params are nested dicts
of arrays, layer stacks are stacked along a leading `layers` axis and run
under `jax.lax.scan` (keeps HLO size and compile time flat in depth, which
matters for the 95-layer dry-runs).
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro import shard


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config object drives every family; family-specific fields default
    to 'off'.  Instances live in repro.configs.<arch>."""

    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    window: int = 0                # 0 = full causal attention
    long_context_window: int = 8192  # sliding window used in long_500k mode
    attn_logit_softcap: float = 0.0

    # norm / misc
    rmsnorm_eps: float = 1e-5
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert: int = 0              # expert FFN width (d_ff used if 0)
    n_dense_layers: int = 0        # leading dense layers (DeepSeek-V3)
    dense_d_ff: int = 0            # FFN width of those dense layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_token_chunk: int = 32768   # dispatch in token chunks: bounds the
                                   # [T*K, d] pair intermediates at 1M-token
                                   # prefill scale
    expert_shard_axes: tuple[str, ...] = ("model",)  # mesh axes for "expert"

    # MLA (DeepSeek-V3)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mla_absorb: bool = False       # absorbed-matmul decode (beyond-paper opt)
    mtp: bool = False              # multi-token-prediction aux head (train)

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    ssm_chunk: int = 256
    conv_kernel: int = 4

    # hybrid (RecurrentGemma / Griffin)
    block_pattern: tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    local_window: int = 0

    # encoder-decoder (Seamless)
    enc_layers: int = 0
    dec_layers: int = 0
    n_frames: int = 4096           # stubbed audio frontend output length

    # VLM (InternVL2)
    n_patches: int = 0             # stubbed vision frontend output length

    # numerics
    param_dtype: str = "float32"
    cache_dtype: str = ""          # "" = param dtype; "float8_e4m3fn" halves
                                   # KV-cache bytes (beyond-paper decode opt)
    # training
    microbatch: int = 0            # 0 = single step, else gradient accumulation
    grad_accum_dtype: str = "float32"  # bfloat16 for the 671B config (memory)
    optimizer: str = "adamw"
    remat: bool = True
    # metadata
    n_params_note: str = ""
    source: str = ""
    accuracy_ak: float = 0.0       # A_K for the paper's accuracy model

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:       # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def kv_dtype(self):
        return jnp.dtype(self.cache_dtype) if self.cache_dtype else self.dtype

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Parameter definitions — one code path builds shapes, specs and values
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]   # logical axes, same rank as shape
    init: str = "normal"           # normal | zeros | ones
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


ParamTree = Mapping[str, object]   # nested dict: str -> ParamDef | ParamTree


def _flatten_defs(defs: ParamTree, prefix: str = "") -> list[tuple[str, ParamDef]]:
    out = []
    for k in sorted(defs):
        v = defs[k]
        path = f"{prefix}/{k}" if prefix else k
        if isinstance(v, ParamDef):
            out.append((path, v))
        else:
            out.extend(_flatten_defs(v, path))
    return out


def _set_path(tree: dict, path: str, value) -> None:
    keys = path.split("/")
    for k in keys[:-1]:
        tree = tree.setdefault(k, {})
    tree[keys[-1]] = value


def init_params(defs: ParamTree, key: jax.Array, dtype) -> dict:
    """Materialize parameters from defs (deterministic per path)."""
    params: dict = {}
    for path, d in _flatten_defs(defs):
        sub = jax.random.fold_in(key, zlib.crc32(path.encode()))
        if d.init == "zeros":
            val = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            val = jnp.ones(d.shape, dtype)
        else:
            val = (jax.random.normal(sub, d.shape, jnp.float32) * d.scale).astype(dtype)
        _set_path(params, path, val)
    return params


def param_specs(defs: ParamTree, rules=None) -> dict:
    """PartitionSpec pytree matching init_params' structure."""
    specs: dict = {}
    for path, d in _flatten_defs(defs):
        _set_path(specs, path, shard.resolve(d.axes, rules))
    return specs


def param_shapes(defs: ParamTree, dtype) -> dict:
    out: dict = {}
    for path, d in _flatten_defs(defs):
        _set_path(out, path, jax.ShapeDtypeStruct(d.shape, dtype))
    return out


def count_params(defs: ParamTree) -> int:
    return int(sum(np.prod(d.shape) for _, d in _flatten_defs(defs)))


# ---------------------------------------------------------------------------
# Numerics building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: [..., S, H, D] (D even), positions broadcastable
    to [..., S]."""
    d = x.shape[-1]
    assert d % 2 == 0, "rope head dim must be even"
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # [..., S, 1, D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(max_len: int, d: int) -> jax.Array:
    """Classic transformer sinusoidal position table [max_len, d]."""
    pos = np.arange(max_len)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    inv = 1.0 / (10000.0 ** (dim / d))
    tab = np.zeros((max_len, d), dtype=np.float32)
    tab[:, 0::2] = np.sin(pos * inv)
    tab[:, 1::2] = np.cos(pos * inv)
    return jnp.asarray(tab)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = shard.constrain(h, "batch", None, "mlp") if h.ndim == 3 else h
    return jnp.einsum("...f,fd->...d", h, w_down)


def gelu_mlp(x: jax.Array, w_up: jax.Array, b_up, w_down: jax.Array, b_down) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, w_up) + b_up
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = shard.constrain(h, "batch", None, "mlp") if h.ndim == 3 else h
    return jnp.einsum("...f,fd->...d", h, w_down) + b_down


def mlp_defs(d_model: int, d_ff: int, n_layers: int | None = None, *, scale: float = 0.02) -> dict:
    """SwiGLU MLP ParamDefs, optionally stacked over layers."""
    lead = () if n_layers is None else (n_layers,)
    lax_ = () if n_layers is None else ("layers",)
    return {
        "w_gate": ParamDef(lead + (d_model, d_ff), lax_ + ("embed_w", "mlp"), scale=scale),
        "w_up": ParamDef(lead + (d_model, d_ff), lax_ + ("embed_w", "mlp"), scale=scale),
        "w_down": ParamDef(lead + (d_ff, d_model), lax_ + ("mlp", "embed_w"), scale=scale),
    }


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------


def padded_vocab(v: int, multiple: int = 128) -> int:
    """Vocabulary rows padded so the vocab dim shards evenly on any mesh
    axis (the standard production fix for odd vocab sizes like 92553).
    Padded logit columns are masked to -inf in lm_logits."""
    return ((v + multiple - 1) // multiple) * multiple


def embed_tokens(emb: jax.Array, tokens: jax.Array) -> jax.Array:
    x = jnp.take(emb, tokens, axis=0)
    return shard.constrain(x, "batch", "seq", None)


def lm_logits(x: jax.Array, head: jax.Array, n_valid: int | None = None) -> jax.Array:
    """x [..., d] @ head [d, Vp] -> f32 logits (vocab sharded); columns
    >= n_valid (padding) are masked to -inf."""
    logits = jnp.einsum("...d,dv->...v", x, head).astype(jnp.float32)
    if n_valid is not None and n_valid < head.shape[-1]:
        col = jnp.arange(head.shape[-1])
        logits = jnp.where(col < n_valid, logits, -1e30)
    if logits.ndim == 3:
        logits = shard.constrain(logits, "batch", "seq", "vocab")
    else:
        logits = shard.constrain(logits, "batch", "vocab")
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Masked mean CE.  labels: int32, -1 = ignore.  Returns (loss, n_valid)."""
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    n = jnp.maximum(mask.sum(), 1.0)
    return nll.sum() / n, n


def maybe_remat(fn: Callable, enabled: bool) -> Callable:
    if not enabled:
        return fn
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
