"""Attention primitives: chunked full-sequence attention (never materializes
the [S, S] score matrix for long sequences) and single-token decode
attention over a cache.

The decode path is the serving hot spot the paper measures; the Pallas
flash-decode kernel in repro.kernels targets it on TPU, while this module
provides the portable jnp implementation (also the kernel's oracle).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import shard

NEG_INF = -1e30


def _softcap(s: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0:
        return jnp.tanh(s / cap) * cap
    return s


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q [B,Sq,Hkv,G,D], k [B,Sk,Hkv,D] -> [B,Hkv,G,Sq,Sk] (f32)."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)


def _gqa_out(w: jax.Array, v: jax.Array) -> jax.Array:
    """w [B,Hkv,G,Sq,Sk], v [B,Sk,Hkv,D] -> [B,Sq,Hkv,G,D]."""
    return jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v)


def _attend_block(q, k, v, mask, scale, softcap):
    """One (q-chunk × full-K) attention block.
    q [B,Cq,Hkv,G,D]; k,v [B,Sk,Hkv,D]; mask [Cq,Sk] or broadcastable."""
    s = _gqa_scores(q, k) * scale
    s = _softcap(s, softcap)
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return _attend_out_cast(w, v, q.dtype)


def _attend_out_cast(w, v, dtype):
    return _gqa_out(w, v).astype(dtype)


def full_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    chunk_q: int = 512,
    softcap: float = 0.0,
) -> jax.Array:
    """Full-sequence attention.

    q: [B, Sq, Hq, D]; k, v: [B, Sk, Hkv, D] (Hq % Hkv == 0).
    Returns [B, Sq, Hq, D].  When Sq > chunk_q and divisible, scans over
    query chunks so peak score memory is [B, Hq, chunk_q, Sk].
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, Sq, Hkv, G, D)
    qg = shard.constrain(qg, "batch", "seq", "kv_heads", None, None)
    k = shard.constrain(k, "batch", "seq", "kv_heads", None)
    v = shard.constrain(v, "batch", "seq", "kv_heads", None)

    k_pos = jnp.arange(Sk)

    def mask_for(q_pos):
        m = jnp.ones((len(q_pos), Sk), bool)
        if causal:
            m &= k_pos[None, :] <= q_pos[:, None]
        if window and window > 0:
            m &= k_pos[None, :] > q_pos[:, None] - window
        return m

    if Sq <= chunk_q or Sq % chunk_q != 0:
        q_pos = q_offset + jnp.arange(Sq)
        out = _attend_block(qg, k, v, mask_for(q_pos), scale, softcap)
        return out.reshape(B, Sq, Hq, D)

    n_chunks = Sq // chunk_q
    qc = qg.reshape(B, n_chunks, chunk_q, Hkv, G, D).transpose(1, 0, 2, 3, 4, 5)

    def body(carry, inp):
        i, q_chunk = inp
        q_pos = q_offset + i * chunk_q + jnp.arange(chunk_q)
        o = _attend_block(q_chunk, k, v, mask_for(q_pos), scale, softcap)
        return carry, o

    _, outs = jax.lax.scan(body, None, (jnp.arange(n_chunks), qc))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hq, D)
    return out


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    ring: bool = False,
    softcap: float = 0.0,
) -> jax.Array:
    """One-token attention over a cache.

    q: [B, Hq, D]; k_cache, v_cache: [B, S, Hkv, D]; pos: scalar int32 —
    absolute position of the current token (already written into the cache).

    ring=False: entries with index > pos are masked (cache longer than
    generated prefix).  ring=True: sliding-window ring buffer — every slot
    is valid once pos+1 >= S, else slots > pos are masked.
    """
    B, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, Hkv, G, D)

    k_cache = shard.constrain(k_cache, "batch", "kv_seq", "kv_heads", None)
    v_cache = shard.constrain(v_cache, "batch", "kv_seq", "kv_heads", None)
    k_cache = k_cache.astype(q.dtype)   # fp8 caches compute in model dtype
    v_cache = v_cache.astype(q.dtype)

    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = _softcap(s, softcap)
    idx = jnp.arange(S)
    valid = idx <= pos  # same rule for ring: until full, slots [0..pos] valid;
    if ring:            # once full (pos >= S-1), everything is valid.
        valid = valid | (pos >= S - 1)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", w.astype(v_cache.dtype), v_cache)
    return o.reshape(B, Hq, D)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V3)
# ---------------------------------------------------------------------------


def mla_full_attention(
    q_nope: jax.Array,   # [B,S,H,Dn]
    q_rope: jax.Array,   # [B,S,H,Dr]
    k_nope: jax.Array,   # [B,S,H,Dn]
    k_rope: jax.Array,   # [B,S,Dr] (shared across heads)
    value: jax.Array,    # [B,S,H,Dv]
    *,
    causal: bool = True,
    window: int = 0,
    chunk_q: int = 512,
) -> jax.Array:
    """Full-sequence MLA attention (decoupled rope scores)."""
    B, Sq, H, Dn = q_nope.shape
    Dr = q_rope.shape[-1]
    Sk = k_nope.shape[1]
    scale = 1.0 / ((Dn + Dr) ** 0.5)
    k_pos = jnp.arange(Sk)

    def block(q_n, q_r, q_pos):
        s = jnp.einsum("bqhd,bkhd->bhqk", q_n, k_nope,
                       preferred_element_type=jnp.float32)
        s += jnp.einsum("bqhr,bkr->bhqk", q_r, k_rope,
                        preferred_element_type=jnp.float32)
        s *= scale
        m = jnp.ones((q_n.shape[1], Sk), bool)
        if causal:
            m &= k_pos[None, :] <= q_pos[:, None]
        if window:
            m &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(m[None, None], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", w.astype(value.dtype), value)

    if Sq <= chunk_q or Sq % chunk_q != 0:
        return block(q_nope, q_rope, jnp.arange(Sq))

    n = Sq // chunk_q
    qn = q_nope.reshape(B, n, chunk_q, H, Dn).transpose(1, 0, 2, 3, 4)
    qr = q_rope.reshape(B, n, chunk_q, H, Dr).transpose(1, 0, 2, 3, 4)

    def body(carry, inp):
        i, a, b = inp
        o = block(a, b, i * chunk_q + jnp.arange(chunk_q))
        return carry, o

    _, outs = jax.lax.scan(body, None, (jnp.arange(n), qn, qr))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, -1)


def mla_decode_absorbed(
    q_latent: jax.Array,  # [B,H,Ckv]  (q_nope absorbed through W_uk)
    q_rope: jax.Array,    # [B,H,Dr]
    c_kv: jax.Array,      # [B,S,Ckv]  latent cache (already rms-normed)
    k_rope: jax.Array,    # [B,S,Dr]
    w_uv: jax.Array,      # [H, Ckv, Dv] (up-projection for V)
    pos: jax.Array,
    scale: float,
) -> jax.Array:
    """Absorbed-matmul MLA decode: scores and values computed in latent
    space — O(S·Ckv) cache traffic instead of O(S·H·Dn) expansion.
    Returns [B, H, Dv]."""
    c_kv = c_kv.astype(q_latent.dtype)
    k_rope = k_rope.astype(q_rope.dtype)
    s = jnp.einsum("bhc,bkc->bhk", q_latent, c_kv,
                   preferred_element_type=jnp.float32)
    s += jnp.einsum("bhr,bkr->bhk", q_rope, k_rope,
                    preferred_element_type=jnp.float32)
    s *= scale
    S = c_kv.shape[1]
    valid = jnp.arange(S) <= pos
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o_latent = jnp.einsum("bhk,bkc->bhc", w.astype(c_kv.dtype), c_kv)
    return jnp.einsum("bhc,hcd->bhd", o_latent, w_uv)
