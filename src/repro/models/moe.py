"""Mixture-of-Experts transformers.

Covers granite-moe-3b-a800m (GQA attention, 40 experts top-8),
Mixtral 8x7B (paper zoo; GQA, 8 experts top-2) and deepseek-v3-671b
(MLA attention, 1 shared + 256 routed top-8, leading dense layers, MTP).

Expert-parallel dispatch is capacity-based: tokens are sorted by expert,
scattered into an [E, C, d] buffer (sharded over the expert axis — this is
what turns into the all-to-all on the production mesh), run through stacked
expert GEMMs, and combined back with router gates.  This is the SMoE path
whose energy efficiency the paper highlights in §5.2/§5.3.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro import shard
from repro.models import attention as attnlib
from repro.models import cache as cachelib
from repro.models import dense
from repro.models.common import (
    ModelConfig,
    padded_vocab,
    ParamDef,
    cross_entropy,
    embed_tokens,
    lm_logits,
    maybe_remat,
    mlp_defs,
    rmsnorm,
    rope,
    swiglu,
)


# ---------------------------------------------------------------------------
# Router + capacity dispatch
# ---------------------------------------------------------------------------


def expert_capacity(n_tokens: int, cfg: ModelConfig, *,
                    dropless: bool = False) -> int:
    """Per-expert slot count.

    Training uses the usual capacity-factor formula (tokens beyond it are
    dropped).  Inference must be *dropless*: capacity depends on the token
    count T, so a dropped pair in one batch shape but not another makes
    prefill/decode disagree with the teacher-forced pass (the granite-moe
    consistency bug).  top_k returns K distinct experts per token, so each
    expert receives at most T pairs — capacity T guarantees no drops at an
    E/(K·capacity_factor)× buffer cost, bounded by cfg.moe_token_chunk.
    """
    if dropless:
        return max(8, int(math.ceil(n_tokens / 8)) * 8)
    c = math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, int(math.ceil(c / 8)) * 8)


def moe_defs(cfg: ModelConfig, n_layers: int) -> dict:
    d = cfg.d_model
    de = cfg.d_expert or cfg.d_ff
    E = cfg.n_experts
    L = (n_layers,)
    A = ("layers",)
    defs = {
        "router": ParamDef(L + (d, E), A + ("embed_w", None), scale=0.02),
        "w_gate": ParamDef(L + (E, d, de), A + ("expert", "embed_w", "mlp")),
        "w_up": ParamDef(L + (E, d, de), A + ("expert", "embed_w", "mlp")),
        "w_down": ParamDef(L + (E, de, d), A + ("expert", "mlp", "embed_w"),
                           scale=0.02 / max(1, (2 * cfg.n_layers) ** 0.5)),
    }
    if cfg.n_shared_experts:
        defs["shared"] = mlp_defs(d, de * cfg.n_shared_experts, n_layers)
    return defs


def _masked_take(operand: jax.Array, idx: jax.Array, oob: int) -> jax.Array:
    """operand [N, d] gathered at idx [...] with idx == oob -> zeros."""
    safe = jnp.minimum(idx, operand.shape[0] - 1)
    out = jnp.take(operand, safe, axis=0)
    return out * (idx < oob)[..., None].astype(out.dtype)


@jax.custom_vjp
def _dispatch(xt, idx_ec, tok2slot):
    """xt [T, d] -> expert buffer [E, C, d] via slot-source indices
    idx_ec [E, C] (value T = empty slot)."""
    buf = _masked_take(xt, idx_ec, xt.shape[0])
    return shard.constrain(buf, None, None, "moe_embed")


def _dispatch_fwd(xt, idx_ec, tok2slot):
    return _dispatch(xt, idx_ec, tok2slot), (tok2slot, xt.shape)


def _dispatch_bwd(res, g):
    # Transpose of a capacity-dropped permutation-gather is ANOTHER gather
    # (via the token->slot table) — never a scatter, which GSPMD would
    # replicate at 30 GB/device scale (measured).
    tok2slot, (T, d) = res
    E, C, _ = g.shape
    g = shard.constrain(g, None, None, "moe_embed")
    gt = _masked_take(g.reshape(E * C, d), tok2slot, E * C)   # [T, K, d]
    gt = shard.constrain(gt, None, None, "moe_embed")
    import numpy as _np
    zi = _np.zeros(tok2slot.shape, jax.dtypes.float0)
    return gt.sum(1), zi, zi


_dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)


@jax.custom_vjp
def _combine(y, gates, tok2slot, slot2pair):
    """y [E, C, d], gates [T, K] -> out [T, d]; tok2slot [T, K] maps each
    (token, k) pair to its flat slot (E*C = dropped)."""
    E, C, d = y.shape
    y = shard.constrain(y, None, None, "moe_embed")
    pairs = _masked_take(y.reshape(E * C, d), tok2slot, E * C)  # [T, K, d]
    pairs = shard.constrain(pairs, None, None, "moe_embed")
    return (pairs * gates[..., None].astype(pairs.dtype)).sum(1)


def _combine_fwd(y, gates, tok2slot, slot2pair):
    return _combine(y, gates, tok2slot, slot2pair), (y, gates, tok2slot, slot2pair)


def _combine_bwd(res, g):
    y, gates, tok2slot, slot2pair = res
    E, C, d = y.shape
    T, K = gates.shape
    g = shard.constrain(g, None, "moe_embed")
    grad_pairs = g[:, None, :] * gates[..., None].astype(g.dtype)  # [T, K, d]
    grad_pairs = shard.constrain(grad_pairs, None, None, "moe_embed")
    grad_y = _masked_take(grad_pairs.reshape(T * K, d), slot2pair, T * K)
    grad_y = grad_y.reshape(E, C, d).astype(y.dtype)
    grad_y = shard.constrain(grad_y, None, None, "moe_embed")
    pairs = _masked_take(y.reshape(E * C, d), tok2slot, E * C)
    pairs = shard.constrain(pairs, None, None, "moe_embed")
    grad_gates = (pairs.astype(g.dtype) * g[:, None, :]).sum(-1)
    import numpy as _np
    zi = _np.zeros(tok2slot.shape, jax.dtypes.float0)
    zs = _np.zeros(slot2pair.shape, jax.dtypes.float0)
    return grad_y, grad_gates.astype(gates.dtype), zi, zs


_combine.defvjp(_combine_fwd, _combine_bwd)


def moe_ffn(cfg: ModelConfig, pl: dict, x: jax.Array, *,
            dropless: bool = False):
    """x [B, S, d] -> (y [B, S, d], aux_loss scalar).

    Sort-based capacity dispatch where BOTH directions (and both VJPs) are
    gathers over d-sharded operands; the [E, C, d] buffer resharding to the
    expert layout is the explicit expert-parallel all-to-all.  Token counts
    beyond cfg.moe_token_chunk are processed in chunks (lax.scan) so the
    [T*K, d] pair intermediates stay bounded at 32k-prefill scale.

    dropless=True (the inference paths) sizes the buffer so no pair is ever
    dropped — required for prefill/decode == teacher-forced consistency."""
    B, S, d = x.shape
    T = B * S
    chunk = cfg.moe_token_chunk
    if chunk and T > chunk and T % chunk == 0:
        n = T // chunk
        xc = x.reshape(n, chunk, 1, d)

        def body(carry, xg):
            out_g, aux_g = _moe_ffn_inner(cfg, pl, xg, dropless=dropless)
            return carry + aux_g, out_g

        aux, outs = jax.lax.scan(body, jnp.zeros((), jnp.float32), xc)
        return outs.reshape(B, S, d), aux / n
    return _moe_ffn_inner(cfg, pl, x, dropless=dropless)


def _moe_ffn_inner(cfg: ModelConfig, pl: dict, x: jax.Array, *,
                   dropless: bool = False):
    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt, pl["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, K)                      # [T, K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    C = expert_capacity(T, cfg, dropless=dropless)
    pair_e = eidx.reshape(T * K)
    order = jnp.argsort(pair_e, stable=True)
    inv_order = jnp.argsort(order, stable=True)
    pair_e_s = pair_e[order]
    counts = jnp.bincount(pair_e, length=E)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * K) - starts[pair_e_s]
    keep = rank < C

    # index tables (all int32, all O(T*K + E*C)):
    #   src [E, C]      — sorted-pair position feeding each slot (OOB = T*K)
    #   slot2tok [E, C] — token id feeding each slot (OOB = T)
    #   tok2slot [T, K] — flat slot of each pair (OOB = E*C)
    arangeC = jnp.arange(C)[None, :]
    src = starts[:, None] + arangeC
    valid = arangeC < counts[:, None]
    order_tok = order // K                                     # sorted pos -> token
    slot2tok = jnp.where(valid, order_tok[jnp.minimum(src, T * K - 1)], T)
    slot2pair = jnp.where(valid, order[jnp.minimum(src, T * K - 1)], T * K)
    slot_sorted = jnp.where(keep, pair_e_s * C + rank, E * C)  # per sorted pair
    tok2slot = slot_sorted[inv_order].reshape(T, K)

    xt_sh = shard.constrain(xt, None, "moe_embed")
    buf = _dispatch(xt_sh, slot2tok, tok2slot)                 # [E, C, d]
    buf = shard.constrain(buf, "expert", "capacity", None)     # all-to-all

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, pl["w_gate"]).astype(jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", buf, pl["w_up"])
    h = (g.astype(x.dtype) * u)
    h = shard.constrain(h, "expert", "capacity", "mlp")
    y = jnp.einsum("ecf,efd->ecd", h, pl["w_down"])
    y = shard.constrain(y, "expert", "capacity", None)         # local GEMM out
    y = shard.constrain(y, None, None, "moe_embed")            # all-to-all back

    out = _combine(y, gates.astype(y.dtype), tok2slot, slot2pair)  # [T, d]
    out = shard.constrain(out, None, "moe_embed")
    out = out.reshape(B, S, d)

    if cfg.n_shared_experts:
        sh = pl["shared"]
        out = out + swiglu(x, sh["w_gate"], sh["w_up"], sh["w_down"])

    # Switch-style load-balance loss: E * Σ_e f_e · p_e
    f = jnp.bincount(pair_e, weights=keep.astype(jnp.float32)[jnp.argsort(order)],
                     length=E) / jnp.maximum(T * K, 1)
    p_mean = probs.mean(0)
    aux = cfg.router_aux_coef * E * jnp.sum(f * p_mean)
    return out, aux


def moe_ffn_token(cfg: ModelConfig, pl: dict, x: jax.Array):
    """Decode-path MoE for [B, d] single tokens (wraps the batched path)."""
    y, aux = moe_ffn(cfg, pl, x[:, None, :], dropless=True)
    return y[:, 0, :], aux


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V3)
# ---------------------------------------------------------------------------


def mla_defs(cfg: ModelConfig, n_layers: int) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    Dn, Dr, Dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    L = (n_layers,)
    A = ("layers",)
    return {
        "w_q_a": ParamDef(L + (d, qr), A + ("embed_w", None)),
        "q_norm": ParamDef(L + (qr,), A + (None,), init="zeros"),
        "w_q_b": ParamDef(L + (qr, H, Dn + Dr), A + (None, "heads", None)),
        "w_kv_a": ParamDef(L + (d, kr + Dr), A + ("embed_w", None)),
        "kv_norm": ParamDef(L + (kr,), A + (None,), init="zeros"),
        "w_kv_b": ParamDef(L + (kr, H, Dn + Dv), A + (None, "heads", None)),
        "wo": ParamDef(L + (H, Dv, d), A + ("heads", None, "embed_w"),
                       scale=0.02 / max(1, (2 * cfg.n_layers) ** 0.5)),
    }


def _mla_q(cfg, pl, x, positions):
    """x [..., d] -> q_nope [..., H, Dn], q_rope [..., H, Dr] (roped)."""
    Dn, Dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = rmsnorm(jnp.einsum("...d,dr->...r", x, pl["w_q_a"]), pl["q_norm"], cfg.rmsnorm_eps)
    q = jnp.einsum("...r,rhe->...he", cq, pl["w_q_b"])
    q_nope, q_rope = q[..., :Dn], q[..., Dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latents(cfg, pl, x, positions):
    """x [..., d] -> c_kv (normed) [..., kr], k_rope (roped) [..., Dr]."""
    kr, Dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    kv = jnp.einsum("...d,dr->...r", x, pl["w_kv_a"])
    c_kv = rmsnorm(kv[..., :kr], pl["kv_norm"], cfg.rmsnorm_eps)
    k_rope = kv[..., kr:]
    # shared-across-heads rope: add a head axis of 1 for the helper
    k_rope = rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope


def mla_attention_full(cfg: ModelConfig, pl: dict, x: jax.Array, *,
                       q_offset: int = 0, window: int = 0):
    """Full-sequence MLA.  Returns (y, c_kv, k_rope) for the latent cache."""
    B, S, _ = x.shape
    Dn, Dv = cfg.qk_nope_dim, cfg.v_head_dim
    positions = jnp.broadcast_to(q_offset + jnp.arange(S), (B, S))
    q_nope, q_rope = _mla_q(cfg, pl, x, positions)
    c_kv, k_rope = _mla_latents(cfg, pl, x, positions)
    kv = jnp.einsum("bsr,rhe->bshe", c_kv, pl["w_kv_b"])
    k_nope, value = kv[..., :Dn], kv[..., Dn:]
    o = attnlib.mla_full_attention(q_nope, q_rope, k_nope, k_rope, value,
                                   causal=True, window=window)
    y = jnp.einsum("bshe,hed->bsd", o, pl["wo"])
    return y, c_kv, k_rope


def mla_attention_decode(cfg: ModelConfig, pl: dict, x: jax.Array,
                         c_kv_l: jax.Array, k_rope_l: jax.Array,
                         pos: jax.Array):
    """One-token MLA over the latent cache (already containing this token).

    Baseline path expands K/V from latents each step; cfg.mla_absorb=True
    uses the absorbed-matmul decode (beyond-paper optimization)."""
    B = x.shape[0]
    Dn, Dr, Dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    positions = jnp.full((B, 1), pos)
    q_nope, q_rope = _mla_q(cfg, pl, x[:, None], positions)
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]     # [B,H,*]
    scale = 1.0 / ((Dn + Dr) ** 0.5)

    c_kv_l = c_kv_l.astype(x.dtype)
    k_rope_l = k_rope_l.astype(x.dtype)
    if cfg.mla_absorb:
        w_uk = pl["w_kv_b"][..., :Dn]               # [kr, H, Dn]
        w_uv = pl["w_kv_b"][..., Dn:]               # [kr, H, Dv]
        q_lat = jnp.einsum("bhn,rhn->bhr", q_nope, w_uk)
        o = attnlib.mla_decode_absorbed(
            q_lat, q_rope, c_kv_l, k_rope_l,
            jnp.transpose(w_uv, (1, 0, 2)), pos, scale)
    else:
        kv = jnp.einsum("bsr,rhe->bshe", c_kv_l, pl["w_kv_b"])
        k_nope, value = kv[..., :Dn], kv[..., Dn:]
        s = jnp.einsum("bhn,bshn->bhs", q_nope, k_nope,
                       preferred_element_type=jnp.float32)
        s += jnp.einsum("bhr,bsr->bhs", q_rope, k_rope_l,
                        preferred_element_type=jnp.float32)
        s *= scale
        valid = jnp.arange(c_kv_l.shape[1]) <= pos
        s = jnp.where(valid[None, None], s, attnlib.NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhs,bshv->bhv", w.astype(value.dtype), value)
    return jnp.einsum("bhv,hvd->bd", o, pl["wo"])


# ---------------------------------------------------------------------------
# Blocks / stacks
# ---------------------------------------------------------------------------


def _uses_mla(cfg: ModelConfig) -> bool:
    return cfg.use_mla


def layer_defs(cfg: ModelConfig) -> dict:
    """Two stacks: leading dense-FFN layers (DeepSeek-V3) + MoE layers."""
    nd = cfg.n_dense_layers
    nm = cfg.n_layers - nd
    att = mla_defs if _uses_mla(cfg) else dense.attn_defs
    out: dict = {
        "moe_blocks": {
            "attn": att(cfg, nm),
            "moe": moe_defs(cfg, nm),
            "ln_attn": {"w": ParamDef((nm, cfg.d_model), ("layers", None), init="zeros")},
            "ln_mlp": {"w": ParamDef((nm, cfg.d_model), ("layers", None), init="zeros")},
        }
    }
    if nd:
        out["dense_blocks"] = {
            "attn": att(cfg, nd),
            "mlp": mlp_defs(cfg.d_model, cfg.dense_d_ff or cfg.d_ff, nd),
            "ln_attn": {"w": ParamDef((nd, cfg.d_model), ("layers", None), init="zeros")},
            "ln_mlp": {"w": ParamDef((nd, cfg.d_model), ("layers", None), init="zeros")},
        }
    return out


def param_defs(cfg: ModelConfig) -> dict:
    defs = {
        "embed": ParamDef((padded_vocab(cfg.vocab_size), cfg.d_model), ("vocab", "embed_w")),
        "blocks": layer_defs(cfg),
        "final_norm": {"w": ParamDef((cfg.d_model,), (None,), init="zeros")},
        "head": ParamDef((cfg.d_model, padded_vocab(cfg.vocab_size)), ("embed_w", "vocab")),
    }
    if cfg.mtp:
        defs["mtp"] = {
            "proj": ParamDef((2 * cfg.d_model, cfg.d_model), (None, "embed_w")),
            "ln": {"w": ParamDef((cfg.d_model,), (None,), init="zeros")},
            "mlp": mlp_defs(cfg.d_model, cfg.dense_d_ff or cfg.d_ff),
        }
    return defs


def _attn_full(cfg, pl, xin, window):
    if _uses_mla(cfg):
        y, c_kv, k_rope = mla_attention_full(cfg, pl["attn"], xin, window=window)
        return y, (c_kv, k_rope)
    y, k, v = dense.attention_full(cfg, pl["attn"], xin, window=window)
    return y, (k, v)


def _stack_forward(cfg, blocks, x, *, moe: bool, window: int, collect: bool,
                   dropless: bool = False):
    def body(carry, pl):
        h, aux = carry
        h = shard.constrain(h, "batch", "seq", None)
        xin = rmsnorm(h, pl["ln_attn"]["w"], cfg.rmsnorm_eps)
        a, kv = _attn_full(cfg, pl, xin, window)
        h = h + a
        xmid = rmsnorm(h, pl["ln_mlp"]["w"], cfg.rmsnorm_eps)
        if moe:
            m, a_loss = moe_ffn(cfg, pl["moe"], xmid, dropless=dropless)
            aux = aux + a_loss
        else:
            mp = pl["mlp"]
            m = swiglu(xmid, mp["w_gate"], mp["w_up"], mp["w_down"])
        h = h + m
        return (h, aux), (kv if collect else None)

    body = maybe_remat(body, cfg.remat)
    (h, aux), kvs = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), blocks)
    return h, aux, kvs


def forward_full(cfg: ModelConfig, params: dict, x: jax.Array, *,
                 window: int = 0, collect: bool = False,
                 dropless: bool = False):
    """Returns (hidden, aux_loss, caches) where caches stacks dense+moe
    layers in order."""
    blocks = params["blocks"]
    kvs = []
    aux = jnp.zeros((), jnp.float32)
    if cfg.n_dense_layers:
        x, a0, kv0 = _stack_forward(cfg, blocks["dense_blocks"], x,
                                    moe=False, window=window, collect=collect)
        aux += a0
        if collect:
            kvs.append(kv0)
    x, a1, kv1 = _stack_forward(cfg, blocks["moe_blocks"], x, moe=True,
                                window=window, collect=collect,
                                dropless=dropless)
    aux += a1
    if collect:
        kvs.append(kv1)
        merged = tuple(jnp.concatenate([k[i] for k in kvs], axis=0)
                       for i in range(2))
        return x, aux, merged
    return x, aux, None


# ---------------------------------------------------------------------------
# Registry API
# ---------------------------------------------------------------------------


def train_loss(cfg: ModelConfig, params: dict, batch: dict):
    tokens, labels = batch["tokens"], batch["labels"]
    x = embed_tokens(params["embed"], tokens)
    h, aux, _ = forward_full(cfg, params, x, window=cfg.window)
    h = rmsnorm(h, params["final_norm"]["w"], cfg.rmsnorm_eps)
    logits = lm_logits(h, params["head"], cfg.vocab_size)
    loss, _ = cross_entropy(logits, labels)
    metrics = {"aux_loss": aux}
    if cfg.mtp:
        # Multi-token prediction: predict t+2 from h_t and emb(t+1).
        hm = rmsnorm(h[:, :-1], params["mtp"]["ln"]["w"], cfg.rmsnorm_eps)
        e_next = embed_tokens(params["embed"], tokens[:, 1:])
        z = jnp.concatenate([hm, e_next], axis=-1)
        z = jnp.einsum("bsd,dk->bsk", z, params["mtp"]["proj"])
        mp = params["mtp"]["mlp"]
        z = z + swiglu(z, mp["w_gate"], mp["w_up"], mp["w_down"])
        mtp_logits = lm_logits(z, params["head"], cfg.vocab_size)
        mtp_labels = jnp.where(labels[:, 1:] >= 0, labels[:, 1:], -1)
        mtp_loss, _ = cross_entropy(mtp_logits, mtp_labels)
        metrics["mtp_loss"] = mtp_loss
        loss = loss + 0.3 * mtp_loss
    return loss + aux, metrics


def prefill(cfg: ModelConfig, params: dict, batch: dict, *,
            cache_len: int, long_context: bool = False):
    tokens = batch["tokens"]
    S = tokens.shape[1]
    window = cfg.long_context_window if long_context else cfg.window
    x = embed_tokens(params["embed"], tokens)
    h, _, kv = forward_full(cfg, params, x, window=window, collect=True,
                            dropless=True)
    h = rmsnorm(h[:, -1], params["final_norm"]["w"], cfg.rmsnorm_eps)
    logits = lm_logits(h, params["head"], cfg.vocab_size)
    if _uses_mla(cfg):
        c_kv, k_rope = kv
        c_kv = c_kv.astype(cfg.kv_dtype)
        k_rope = k_rope.astype(cfg.kv_dtype)
        pad = cache_len - S
        cache = cachelib.MLACache(
            jnp.pad(c_kv, [(0, 0), (0, 0), (0, pad), (0, 0)]),
            jnp.pad(k_rope, [(0, 0), (0, 0), (0, pad), (0, 0)]),
            jnp.asarray(S, jnp.int32))
    else:
        ks, vs = kv
        cache = dense._finish_cache(cfg, ks, vs, cache_len, window, S)
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, *,
               long_context: bool = False, dtype=None):
    dtype = dtype or cfg.kv_dtype
    if _uses_mla(cfg):
        return cachelib.MLACache.init(cfg.n_layers, batch, cache_len,
                                      cfg.kv_lora_rank, cfg.qk_rope_dim, dtype)
    window = cfg.long_context_window if long_context else cfg.window
    if window:
        return cachelib.WindowKVCache.init(cfg.n_layers, batch,
                                           min(window, cache_len),
                                           cfg.n_kv_heads, cfg.head_dim_, dtype)
    return cachelib.KVCache.init(cfg.n_layers, batch, cache_len,
                                 cfg.n_kv_heads, cfg.head_dim_, dtype)


def _decode_stack(cfg, blocks, x, caches, pos, *, moe: bool, ring: bool):
    """One-token pass over one stack.  Per-layer cache slices flow as scan
    xs -> ys (carrying the full cache would double-buffer it)."""
    if _uses_mla(cfg):
        ckv, krope = caches
        S = ckv.shape[2]
        slot = jnp.minimum(pos, S - 1)

        def body(h, inp):
            pl, c_l, r_l = inp       # c_l [B, S, kr]; r_l [B, S, Dr]
            xin = rmsnorm(h, pl["ln_attn"]["w"], cfg.rmsnorm_eps)
            c_new, r_new = _mla_latents(cfg, pl["attn"], xin,
                                        jnp.full((h.shape[0],), pos))
            c_l = cachelib.onehot_write(c_l, c_new, slot)
            r_l = cachelib.onehot_write(r_l, r_new, slot)
            a = mla_attention_decode(cfg, pl["attn"], xin, c_l, r_l, pos)
            h = h + a
            xmid = rmsnorm(h, pl["ln_mlp"]["w"], cfg.rmsnorm_eps)
            if moe:
                m, _ = moe_ffn_token(cfg, pl["moe"], xmid)
            else:
                mp = pl["mlp"]
                m = swiglu(xmid, mp["w_gate"], mp["w_up"], mp["w_down"])
            h = h + m
            return h, (c_l, r_l)

        h, (ckv, krope) = jax.lax.scan(body, x, (blocks, ckv, krope))
        return h, (ckv, krope)

    kc, vc = caches
    S = kc.shape[2]
    slot = jnp.where(jnp.asarray(ring), pos % S, jnp.minimum(pos, S - 1))

    def body(h, inp):
        pl, k_l, v_l = inp
        xin = rmsnorm(h, pl["ln_attn"]["w"], cfg.rmsnorm_eps)
        k_new, v_new = dense.project_kv_token(cfg, pl["attn"], xin, pos)
        k_l = cachelib.onehot_write(k_l, k_new, slot)
        v_l = cachelib.onehot_write(v_l, v_new, slot)
        a = dense.attention_decode(cfg, pl["attn"], xin, k_l, v_l, pos, ring=ring)
        h = h + a
        xmid = rmsnorm(h, pl["ln_mlp"]["w"], cfg.rmsnorm_eps)
        if moe:
            m, _ = moe_ffn_token(cfg, pl["moe"], xmid)
        else:
            mp = pl["mlp"]
            m = swiglu(xmid, mp["w_gate"], mp["w_up"], mp["w_down"])
        h = h + m
        return h, (k_l, v_l)

    h, (kc, vc) = jax.lax.scan(body, x, (blocks, kc, vc))
    return h, (kc, vc)


def decode_step(cfg: ModelConfig, params: dict, cache, batch: dict):
    token = batch["token"]
    pos = cache.pos
    ring = isinstance(cache, cachelib.WindowKVCache)
    x = jnp.take(params["embed"], token, axis=0)
    if _uses_mla(cfg):
        arrays = (cache.c_kv, cache.k_rope)
    else:
        arrays = (cache.k, cache.v)
    blocks = params["blocks"]
    if cfg.n_dense_layers:
        nd = cfg.n_dense_layers
        head_arrays = tuple(a[:nd] for a in arrays)
        tail_arrays = tuple(a[nd:] for a in arrays)
        x, head_arrays = _decode_stack(cfg, blocks["dense_blocks"], x,
                                       head_arrays, pos, moe=False, ring=ring)
        x, tail_arrays = _decode_stack(cfg, blocks["moe_blocks"], x,
                                       tail_arrays, pos, moe=True, ring=ring)
        arrays = tuple(jnp.concatenate([h, t], axis=0)
                       for h, t in zip(head_arrays, tail_arrays))
    else:
        x, arrays = _decode_stack(cfg, blocks["moe_blocks"], x, arrays, pos,
                                  moe=True, ring=ring)
    h = rmsnorm(x, params["final_norm"]["w"], cfg.rmsnorm_eps)
    logits = lm_logits(h, params["head"], cfg.vocab_size)
    new_cache = type(cache)(*arrays, pos + 1)
    return logits, new_cache
