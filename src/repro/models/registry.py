"""Family dispatch: one uniform API over all six architecture families.

    api = get_api(cfg)
    params = api.init_params(cfg, key)
    loss, metrics = api.train_loss(cfg, params, batch)
    logits, cache = api.prefill(cfg, params, batch, cache_len=...)
    cache = api.init_cache(cfg, batch_size, cache_len, long_context=...)
    logits, cache = api.decode_step(cfg, params, cache, {"token": ...})
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax

from repro.models import dense, encdec, hybrid, moe, ssm, vlm
from repro.models.common import (
    ModelConfig,
    count_params,
    init_params as _init,
    param_shapes as _shapes,
    param_specs as _specs,
)

_FAMILIES = {
    "dense": dense,
    "moe": moe,
    "ssm": ssm,
    "hybrid": hybrid,
    "encdec": encdec,
    "vlm": vlm,
}


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    family: str
    param_defs: Callable[[ModelConfig], dict]
    train_loss: Callable
    prefill: Callable
    init_cache: Callable
    decode_step: Callable

    def init_params(self, cfg: ModelConfig, key: jax.Array) -> dict:
        return _init(self.param_defs(cfg), key, cfg.dtype)

    def param_shapes(self, cfg: ModelConfig) -> dict:
        return _shapes(self.param_defs(cfg), cfg.dtype)

    def param_specs(self, cfg: ModelConfig, rules=None) -> dict:
        return _specs(self.param_defs(cfg), rules)

    def count_params(self, cfg: ModelConfig) -> int:
        return _count_params_cached(cfg)


@functools.lru_cache(maxsize=64)
def get_api(cfg_or_family: ModelConfig | str) -> ModelAPI:
    family = (cfg_or_family if isinstance(cfg_or_family, str)
              else cfg_or_family.family)
    if family not in _FAMILIES:
        raise KeyError(f"unknown family {family!r}; have {sorted(_FAMILIES)}")
    mod = _FAMILIES[family]
    return ModelAPI(
        family=family,
        param_defs=mod.param_defs,
        train_loss=mod.train_loss,
        prefill=mod.prefill,
        init_cache=mod.init_cache,
        decode_step=mod.decode_step,
    )


@functools.lru_cache(maxsize=256)
def _count_params_cached(cfg: ModelConfig) -> int:
    return count_params(_FAMILIES[cfg.family].param_defs(cfg))


@functools.lru_cache(maxsize=256)
def active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: shared + top-k routed experts +
    attention/embedding), for MODEL_FLOPS = 2·N_active·D."""
    api = get_api(cfg)
    total = api.count_params(cfg)
    if cfg.family != "moe" or not cfg.n_experts:
        return total
    de = cfg.d_expert or cfg.d_ff
    per_expert = 3 * cfg.d_model * de
    nm = cfg.n_layers - cfg.n_dense_layers
    routed_total = nm * cfg.n_experts * per_expert
    routed_active = nm * cfg.top_k * per_expert
    return total - routed_total + routed_active
