"""Model zoo: six architecture families behind one functional API."""

from repro.models.common import ModelConfig  # noqa: F401
from repro.models.registry import ModelAPI, active_params, get_api  # noqa: F401
