"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060).

Pure-jnp chunked SSD for train/prefill (quadratic intra-chunk + linear
inter-chunk recurrence) and a constant-state decode step.  The Pallas
kernel in repro.kernels.ssd_scan targets the intra-chunk block; this module
is its oracle and the portable path.

No attention, no KV cache: decode cost is position-independent, which is
exactly the workload-model contrast this arch contributes to the paper's
e_K(τin, τout) study (no τin·τout interaction from cache reads).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import shard
from repro.models import cache as cachelib
from repro.models.common import (
    ModelConfig,
    padded_vocab,
    ParamDef,
    cross_entropy,
    embed_tokens,
    lm_logits,
    maybe_remat,
    rmsnorm,
)


def conv_channels(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state


def layer_defs(cfg: ModelConfig) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    G, N, H = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    cc = conv_channels(cfg)
    L = (cfg.n_layers,)
    A = ("layers",)
    proj_out = 2 * di + 2 * G * N + H
    return {
        "in_proj": ParamDef(L + (d, proj_out), A + ("embed_w", "mlp")),
        "conv_w": ParamDef(L + (cfg.conv_kernel, cc), A + (None, "mlp"), scale=0.1),
        "conv_b": ParamDef(L + (cc,), A + ("mlp",), init="zeros"),
        "A_log": ParamDef(L + (H,), A + (None,), init="zeros"),   # A = -exp(A_log) ~ -1
        "D": ParamDef(L + (H,), A + (None,), init="ones"),
        "dt_bias": ParamDef(L + (H,), A + (None,), init="zeros"),
        "norm_w": ParamDef(L + (di,), A + ("mlp",), init="zeros"),
        "out_proj": ParamDef(L + (di, d), A + ("mlp", "embed_w"),
                             scale=0.02 / max(1, (2 * cfg.n_layers) ** 0.5)),
        "ln": {"w": ParamDef(L + (d,), A + (None,), init="zeros")},
    }


def param_defs(cfg: ModelConfig) -> dict:
    return {
        "embed": ParamDef((padded_vocab(cfg.vocab_size), cfg.d_model), ("vocab", "embed_w")),
        "blocks": layer_defs(cfg),
        "final_norm": {"w": ParamDef((cfg.d_model,), (None,), init="zeros")},
        "head": ParamDef((cfg.d_model, padded_vocab(cfg.vocab_size)), ("embed_w", "vocab")),
    }


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def _segsum(x: jax.Array) -> jax.Array:
    """x [..., T] -> lower-triangular segment sums [..., T, T]:
    out[..., i, j] = sum(x[..., j+1 : i+1]) for i >= j, -inf above."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, ss, -jnp.inf)


def ssd_chunked(xdt: jax.Array, dA: jax.Array, B: jax.Array, C: jax.Array,
                chunk: int, h0: jax.Array | None = None):
    """Chunked SSD.

    xdt [b,s,h,p] (x pre-multiplied by dt), dA [b,s,h] (dt * A, negative),
    B, C [b,s,h,n] (groups already broadcast to heads).
    Returns (y [b,s,h,p], final_state [b,h,p,n]).
    """
    b, s, h, p = xdt.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc, cl = s // chunk, chunk

    f32 = jnp.float32
    xdt_c = xdt.reshape(b, nc, cl, h, p)
    dA_c = dA.reshape(b, nc, cl, h).astype(f32)
    B_c = B.reshape(b, nc, cl, h, n)
    C_c = C.reshape(b, nc, cl, h, n)

    dA_cs = jnp.cumsum(dA_c, axis=2)                         # [b,nc,cl,h]
    # intra-chunk (quadratic) term
    Lmat = jnp.exp(_segsum(dA_c.transpose(0, 1, 3, 2)))      # [b,nc,h,cl,cl]
    scores = jnp.einsum("bclhn,bcshn->bchls", C_c, B_c,
                        preferred_element_type=f32)
    scores = scores * Lmat
    y_diag = jnp.einsum("bchls,bcshp->bclhp", scores.astype(xdt.dtype), xdt_c)

    # per-chunk input states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)      # [b,nc,cl,h]
    states = jnp.einsum("bcshn,bcsh,bcshp->bchpn", B_c,
                        decay_states.astype(B_c.dtype), xdt_c)

    # inter-chunk linear recurrence
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :]).astype(f32)    # [b,nc,h]

    def scan_body(carry, inp):
        st, dec = inp
        prev = carry
        new = prev * dec[:, :, None, None] + st.astype(f32)
        return new, prev

    init = jnp.zeros((b, h, p, n), f32) if h0 is None else h0.astype(f32)
    final, prev_states = jax.lax.scan(
        scan_body, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # [b,nc,h,p,n]

    state_decay = jnp.exp(dA_cs)                             # [b,nc,cl,h]
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", C_c,
                       prev_states.astype(C_c.dtype),
                       state_decay.astype(C_c.dtype))
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv, kernel K.  x [B,S,C], w [K,C], b [C].
    state [B,K-1,C] holds the trailing context (decode).  Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                   # [B, S+K-1, C]
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1):, :]
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), new_state


def _split_proj(cfg: ModelConfig, z: jax.Array):
    di, G, N, H = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    zg = z[..., :di]
    xbc = z[..., di : di + di + 2 * G * N]
    dt = z[..., -H:]
    return zg, xbc, dt


def _ssm_params(cfg: ModelConfig, pl: dict, dt_raw: jax.Array):
    A = -jnp.exp(pl["A_log"].astype(jnp.float32))            # [H]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + pl["dt_bias"].astype(jnp.float32))
    return A, dt


def _broadcast_groups(cfg: ModelConfig, bc: jax.Array):
    """[..., G*N] -> B, C each [..., H, N] with groups broadcast to heads."""
    G, N, H = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    B_, C_ = jnp.split(bc, 2, axis=-1)
    rep = H // G
    def expand(t):
        t = t.reshape(t.shape[:-1] + (G, N))
        return jnp.repeat(t, rep, axis=-2)
    return expand(B_), expand(C_)


def mamba_block_full(cfg: ModelConfig, pl: dict, x: jax.Array):
    """Full-sequence Mamba-2 block.  x [B,S,d] -> (y [B,S,d], final_state,
    conv_state)."""
    Bsz, S, _ = x.shape
    H, P = cfg.ssm_nheads, cfg.ssm_headdim
    z = jnp.einsum("bsd,dk->bsk", x, pl["in_proj"])
    zg, xbc, dt_raw = _split_proj(cfg, z)
    xbc, conv_state = _causal_conv(xbc, pl["conv_w"], pl["conv_b"])
    x_ssm = xbc[..., : cfg.d_inner].reshape(Bsz, S, H, P)
    x_ssm = shard.constrain(x_ssm, "batch", "seq", "ssm_heads", None)
    B_, C_ = _broadcast_groups(cfg, xbc[..., cfg.d_inner:])
    A, dt = _ssm_params(cfg, pl, dt_raw)                     # [H], [B,S,H]
    dA = dt * A
    xdt = x_ssm * dt[..., None].astype(x_ssm.dtype)
    chunk = min(cfg.ssm_chunk, S)
    y, final = ssd_chunked(xdt, dA, B_, C_, chunk)
    y = y + pl["D"].astype(y.dtype)[None, None, :, None] * x_ssm
    y = y.reshape(Bsz, S, cfg.d_inner)
    y = y * jax.nn.silu(zg.astype(jnp.float32)).astype(y.dtype)
    y = rmsnorm(y, pl["norm_w"], cfg.rmsnorm_eps)
    return jnp.einsum("bsk,kd->bsd", y, pl["out_proj"]), final, conv_state


def mamba_block_decode(cfg: ModelConfig, pl: dict, x: jax.Array,
                       state: jax.Array, conv_state: jax.Array):
    """One-token Mamba-2 step.  x [B,d]; state [B,H,P,N] f32;
    conv_state [B,K-1,cc]."""
    Bsz = x.shape[0]
    H, P, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    z = jnp.einsum("bd,dk->bk", x, pl["in_proj"])
    zg, xbc, dt_raw = _split_proj(cfg, z)
    xbc, conv_state = _causal_conv(xbc[:, None], pl["conv_w"], pl["conv_b"],
                                   state=conv_state)
    xbc = xbc[:, 0]
    x_ssm = xbc[..., : cfg.d_inner].reshape(Bsz, H, P)
    B_, C_ = _broadcast_groups(cfg, xbc[..., cfg.d_inner:])  # [B,H,N]
    A, dt = _ssm_params(cfg, pl, dt_raw)                     # [H], [B,H]
    decay = jnp.exp(dt * A)                                  # [B,H]
    upd = jnp.einsum("bhp,bhn->bhpn", (x_ssm * dt[..., None].astype(x_ssm.dtype)).astype(jnp.float32),
                     B_.astype(jnp.float32))
    state = state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, C_.astype(jnp.float32)).astype(x.dtype)
    y = y + pl["D"].astype(y.dtype)[None, :, None] * x_ssm
    y = y.reshape(Bsz, cfg.d_inner)
    y = y * jax.nn.silu(zg.astype(jnp.float32)).astype(y.dtype)
    y = rmsnorm(y, pl["norm_w"], cfg.rmsnorm_eps)
    return jnp.einsum("bk,kd->bd", y, pl["out_proj"]), state, conv_state


# ---------------------------------------------------------------------------
# Registry API
# ---------------------------------------------------------------------------


def forward_full(cfg: ModelConfig, params: dict, x: jax.Array, *,
                 collect: bool = False):
    def body(h, pl):
        h = shard.constrain(h, "batch", "seq", None)
        y, final, conv = mamba_block_full(cfg, pl, rmsnorm(h, pl["ln"]["w"], cfg.rmsnorm_eps))
        out = (final, conv) if collect else None
        return h + y, out

    body = maybe_remat(body, cfg.remat)
    h, states = jax.lax.scan(body, x, params["blocks"])
    return h, states


def train_loss(cfg: ModelConfig, params: dict, batch: dict):
    x = embed_tokens(params["embed"], batch["tokens"])
    h, _ = forward_full(cfg, params, x)
    h = rmsnorm(h, params["final_norm"]["w"], cfg.rmsnorm_eps)
    logits = lm_logits(h, params["head"], cfg.vocab_size)
    loss, _ = cross_entropy(logits, batch["labels"])
    return loss, {}


def prefill(cfg: ModelConfig, params: dict, batch: dict, *,
            cache_len: int = 0, long_context: bool = False):
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens)
    h, (finals, convs) = forward_full(cfg, params, x, collect=True)
    h = rmsnorm(h[:, -1], params["final_norm"]["w"], cfg.rmsnorm_eps)
    logits = lm_logits(h, params["head"], cfg.vocab_size)
    cache = cachelib.SSMCache(convs, finals,
                              jnp.asarray(tokens.shape[1], jnp.int32))
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, cache_len: int = 0, *,
               long_context: bool = False, dtype=None):
    dtype = dtype or cfg.dtype
    return cachelib.SSMCache.init(cfg.n_layers, batch, cfg.conv_kernel,
                                  conv_channels(cfg), cfg.ssm_nheads,
                                  cfg.ssm_headdim, cfg.ssm_state, dtype)


def decode_step(cfg: ModelConfig, params: dict, cache, batch: dict):
    token = batch["token"]
    x = jnp.take(params["embed"], token, axis=0)

    def body(carry, inp):
        h, = carry,
        pl, st, cv = inp
        y, st, cv = mamba_block_decode(cfg, pl, rmsnorm(h, pl["ln"]["w"], cfg.rmsnorm_eps), st, cv)
        return h + y, (st, cv)

    h, (states, convs) = jax.lax.scan(body, x,
                                      (params["blocks"], cache.state, cache.conv))
    h = rmsnorm(h, params["final_norm"]["w"], cfg.rmsnorm_eps)
    logits = lm_logits(h, params["head"], cfg.vocab_size)
    return logits, cachelib.SSMCache(convs, states, cache.pos + 1)
