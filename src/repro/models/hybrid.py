"""RecurrentGemma / Griffin hybrid (arXiv:2402.19427).

Repeating block pattern (recurrent, recurrent, local-attention); each
temporal-mixing block is followed by its own MLP residual.  The RG-LRU
recurrence

    r_t = sigmoid(W_a u_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_i u_t + b_i)            (input gate)
    a_t = exp(c * r_t * log(sigmoid(Lambda)))
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

is evaluated with jax.lax.associative_scan over the sequence (the TPU-native
parallelization of a linear recurrence — this replaces the paper-agnostic
CUDA linear-scan kernel).  Local attention is MQA (kv=1) with a bounded
window, so decode state is O(window) — the long_500k shape runs natively.

38 layers = 12 x (rec, rec, attn) + (rec, rec) tail.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import shard
from repro.models import attention as attnlib
from repro.models import cache as cachelib
from repro.models.common import (
    ModelConfig,
    padded_vocab,
    ParamDef,
    cross_entropy,
    embed_tokens,
    lm_logits,
    maybe_remat,
    mlp_defs,
    rmsnorm,
    rope,
    swiglu,
)

LRU_C = 8.0


def pattern_counts(cfg: ModelConfig) -> tuple[int, int, int]:
    """(n_units, n_tail_rec, n_attn).  Unit = (rec, rec, attn)."""
    per = len(cfg.block_pattern)            # 3
    n_units = cfg.n_layers // per
    rem = cfg.n_layers - n_units * per      # 38 - 36 = 2 tail rec layers
    n_attn = n_units
    return n_units, rem, n_attn


def n_rec_layers(cfg: ModelConfig) -> int:
    n_units, tail, _ = pattern_counts(cfg)
    return 2 * n_units + tail


# ---------------------------------------------------------------------------
# Param defs
# ---------------------------------------------------------------------------


def _rec_defs(cfg: ModelConfig, n: int) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or cfg.d_model
    L, A = (n,), ("layers",)
    return {
        "w_gate": ParamDef(L + (d, w), A + ("embed_w", "lru")),
        "w_x": ParamDef(L + (d, w), A + ("embed_w", "lru")),
        "conv_w": ParamDef(L + (cfg.conv_kernel, w), A + (None, "lru"), scale=0.1),
        "conv_b": ParamDef(L + (w,), A + ("lru",), init="zeros"),
        "w_a": ParamDef(L + (w, w), A + ("lru", None), scale=0.02),
        "b_a": ParamDef(L + (w,), A + ("lru",), init="zeros"),
        "w_i": ParamDef(L + (w, w), A + ("lru", None), scale=0.02),
        "b_i": ParamDef(L + (w,), A + ("lru",), init="zeros"),
        "lam": ParamDef(L + (w,), A + ("lru",), init="ones", scale=1.0),
        "w_out": ParamDef(L + (w, d), A + ("lru", "embed_w"),
                          scale=0.02 / max(1, (2 * cfg.n_layers) ** 0.5)),
        "ln_mix": {"w": ParamDef(L + (d,), A + (None,), init="zeros")},
        "mlp": mlp_defs(d, cfg.d_ff, n),
        "ln_mlp": {"w": ParamDef(L + (d,), A + (None,), init="zeros")},
    }


def _attn_block_defs(cfg: ModelConfig, n: int) -> dict:
    return {
        "attn": _dense_attn_defs(cfg, n),
        "ln_mix": {"w": ParamDef((n, cfg.d_model), ("layers", None), init="zeros")},
        "mlp": mlp_defs(cfg.d_model, cfg.d_ff, n),
        "ln_mlp": {"w": ParamDef((n, cfg.d_model), ("layers", None), init="zeros")},
    }


def _dense_attn_defs(cfg, n):
    from repro.models import dense
    return dense.attn_defs(cfg, n)


def param_defs(cfg: ModelConfig) -> dict:
    n_units, tail, _ = pattern_counts(cfg)
    defs: dict = {
        "embed": ParamDef((padded_vocab(cfg.vocab_size), cfg.d_model), ("vocab", "embed_w")),
        "units": {
            "rec_a": _rec_defs(cfg, n_units),
            "rec_b": _rec_defs(cfg, n_units),
            "attn": _attn_block_defs(cfg, n_units),
        },
        "final_norm": {"w": ParamDef((cfg.d_model,), (None,), init="zeros")},
        "head": ParamDef((cfg.d_model, padded_vocab(cfg.vocab_size)), ("embed_w", "vocab")),
    }
    if tail:
        defs["tail"] = {"rec": _rec_defs(cfg, tail)}
    return defs


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


def _lru_coeffs(pl: dict, u: jax.Array):
    """u [..., w] -> (a_t, b_t) of h_t = a_t*h + b_t, in f32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", uf, pl["w_a"].astype(jnp.float32)) + pl["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", uf, pl["w_i"].astype(jnp.float32)) + pl["b_i"].astype(jnp.float32))
    log_a0 = jax.nn.log_sigmoid(pl["lam"].astype(jnp.float32))      # [w]
    log_a = LRU_C * r * log_a0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, b


def rglru_scan(pl: dict, u: jax.Array, h0: jax.Array | None = None):
    """Parallel RG-LRU over u [B,S,w].  Returns (h [B,S,w] f32, h_last)."""
    a, b = _lru_coeffs(pl, u)
    if h0 is not None:
        # fold the initial state into the first step: h_1 = a_1 h_0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, ar * bl + br

    a_s, b_s = jax.lax.associative_scan(combine, (a, b), axis=1)
    return b_s, b_s[:, -1]


def rglru_step(pl: dict, u: jax.Array, h: jax.Array):
    """One-token RG-LRU.  u [B,w]; h [B,w] f32."""
    a, b = _lru_coeffs(pl, u)
    return a * h + b


def _rec_mix_full(cfg, pl, x, h0=None, conv0=None):
    """Recurrent temporal-mixing branch, full sequence.  x [B,S,d]."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, pl["w_gate"]).astype(jnp.float32))
    u = jnp.einsum("bsd,dw->bsw", x, pl["w_x"])
    u = shard.constrain(u, "batch", "seq", "lru")
    from repro.models.ssm import _causal_conv
    u, conv_state = _causal_conv(u, pl["conv_w"], pl["conv_b"], state=conv0)
    h, h_last = rglru_scan(pl, u)
    y = (gate * h).astype(x.dtype)
    return jnp.einsum("bsw,wd->bsd", y, pl["w_out"]), h_last, conv_state


def _rec_mix_step(cfg, pl, x, h, conv_state):
    """x [B,d]; h [B,w] f32; conv_state [B,K-1,w]."""
    gate = jax.nn.gelu(jnp.einsum("bd,dw->bw", x, pl["w_gate"]).astype(jnp.float32))
    u = jnp.einsum("bd,dw->bw", x, pl["w_x"])
    from repro.models.ssm import _causal_conv
    u, conv_state = _causal_conv(u[:, None], pl["conv_w"], pl["conv_b"], state=conv_state)
    h = rglru_step(pl, u[:, 0], h)
    y = (gate * h).astype(x.dtype)
    return jnp.einsum("bw,wd->bd", y, pl["w_out"]), h, conv_state


def _rec_block_full(cfg, pl, x, conv0=None, h0=None):
    mix, h_last, conv = _rec_mix_full(cfg, pl, rmsnorm(x, pl["ln_mix"]["w"], cfg.rmsnorm_eps))
    x = x + mix
    m = swiglu(rmsnorm(x, pl["ln_mlp"]["w"], cfg.rmsnorm_eps),
               pl["mlp"]["w_gate"], pl["mlp"]["w_up"], pl["mlp"]["w_down"])
    return x + m, h_last, conv


def _rec_block_step(cfg, pl, x, h, conv):
    mix, h, conv = _rec_mix_step(cfg, pl, rmsnorm(x, pl["ln_mix"]["w"], cfg.rmsnorm_eps), h, conv)
    x = x + mix
    m = swiglu(rmsnorm(x, pl["ln_mlp"]["w"], cfg.rmsnorm_eps),
               pl["mlp"]["w_gate"], pl["mlp"]["w_up"], pl["mlp"]["w_down"])
    return x + m, h, conv


def _attn_block_full(cfg, pl, x, window):
    from repro.models import dense
    a, k, v = dense.attention_full(cfg, pl["attn"],
                                   rmsnorm(x, pl["ln_mix"]["w"], cfg.rmsnorm_eps),
                                   window=window)
    x = x + a
    m = swiglu(rmsnorm(x, pl["ln_mlp"]["w"], cfg.rmsnorm_eps),
               pl["mlp"]["w_gate"], pl["mlp"]["w_up"], pl["mlp"]["w_down"])
    return x + m, k, v


def _attn_block_step(cfg, pl, x, k_l, v_l, pos):
    """k_l, v_l [B, W, 1, Dh] ring caches for this layer (token not yet
    written).  Returns (x', k_l, v_l)."""
    from repro.models import dense
    W = k_l.shape[1]
    slot = pos % W
    xin = rmsnorm(x, pl["ln_mix"]["w"], cfg.rmsnorm_eps)
    k_new, v_new = dense.project_kv_token(cfg, pl["attn"], xin, pos)
    k_l = cachelib.onehot_write(k_l, k_new, slot)
    v_l = cachelib.onehot_write(v_l, v_new, slot)
    a = dense.attention_decode(cfg, pl["attn"], xin, k_l, v_l, pos, ring=True)
    x = x + a
    m = swiglu(rmsnorm(x, pl["ln_mlp"]["w"], cfg.rmsnorm_eps),
               pl["mlp"]["w_gate"], pl["mlp"]["w_up"], pl["mlp"]["w_down"])
    return x + m, k_l, v_l


# ---------------------------------------------------------------------------
# Full forward / decode over the (rec, rec, attn) unit scan
# ---------------------------------------------------------------------------


def forward_full(cfg: ModelConfig, params: dict, x: jax.Array, *,
                 collect: bool = False):
    window = cfg.local_window

    def unit_body(h, pu):
        h = shard.constrain(h, "batch", "seq", None)
        h, st_a, cv_a = _rec_block_full(cfg, pu["rec_a"], h)
        h, st_b, cv_b = _rec_block_full(cfg, pu["rec_b"], h)
        h, k, v = _attn_block_full(cfg, pu["attn"], h, window)
        out = (st_a, cv_a, st_b, cv_b, k, v) if collect else None
        return h, out

    unit_body = maybe_remat(unit_body, cfg.remat)
    h, unit_states = jax.lax.scan(unit_body, x, params["units"])

    tail_states = None
    if "tail" in params:
        def tail_body(hh, pl):
            hh, st, cv = _rec_block_full(cfg, pl, hh)
            return hh, (st, cv) if collect else None
        tail_body = maybe_remat(tail_body, cfg.remat)
        h, tail_states = jax.lax.scan(tail_body, h, params["tail"]["rec"])
    return h, unit_states, tail_states


def train_loss(cfg: ModelConfig, params: dict, batch: dict):
    x = embed_tokens(params["embed"], batch["tokens"])
    h, _, _ = forward_full(cfg, params, x)
    h = rmsnorm(h, params["final_norm"]["w"], cfg.rmsnorm_eps)
    logits = lm_logits(h, params["head"], cfg.vocab_size)
    loss, _ = cross_entropy(logits, batch["labels"])
    return loss, {}


def _assemble_cache(cfg, batch, unit_states, tail_states, pos_end):
    n_units, tail, n_attn = pattern_counts(cfg)
    st_a, cv_a, st_b, cv_b, ks, vs = unit_states
    # interleave rec states in layer order: a0, b0, a1, b1, ...
    lru = jnp.stack([st_a, st_b], axis=1).reshape((2 * n_units,) + st_a.shape[1:])
    conv = jnp.stack([cv_a, cv_b], axis=1).reshape((2 * n_units,) + cv_a.shape[1:])
    if tail_states is not None:
        t_st, t_cv = tail_states
        lru = jnp.concatenate([lru, t_st], axis=0)
        conv = jnp.concatenate([conv, t_cv], axis=0)
    W = cfg.local_window
    k, v = cachelib.ring_pack(ks.astype(cfg.kv_dtype), vs.astype(cfg.kv_dtype),
                              W, pos_end)
    return cachelib.HybridCache(lru, conv, k, v, jnp.asarray(pos_end, jnp.int32))


def prefill(cfg: ModelConfig, params: dict, batch: dict, *,
            cache_len: int = 0, long_context: bool = False):
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens)
    h, unit_states, tail_states = forward_full(cfg, params, x, collect=True)
    hl = rmsnorm(h[:, -1], params["final_norm"]["w"], cfg.rmsnorm_eps)
    logits = lm_logits(hl, params["head"], cfg.vocab_size)
    cache = _assemble_cache(cfg, batch, unit_states, tail_states, tokens.shape[1])
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, cache_len: int = 0, *,
               long_context: bool = False, dtype=None):
    dtype = dtype or cfg.kv_dtype
    n_units, tail, n_attn = pattern_counts(cfg)
    return cachelib.HybridCache.init(
        2 * n_units + tail, n_attn, batch, cfg.lru_width or cfg.d_model,
        cfg.conv_kernel, cfg.local_window, cfg.n_kv_heads, cfg.head_dim_, dtype)


def decode_step(cfg: ModelConfig, params: dict, cache, batch: dict):
    token = batch["token"]
    pos = cache.pos
    n_units, tail, _ = pattern_counts(cfg)
    x = jnp.take(params["embed"], token, axis=0)

    B = x.shape[0]
    lru_main = cache.lru[: 2 * n_units].reshape((n_units, 2) + cache.lru.shape[1:])
    conv_main = cache.conv[: 2 * n_units].reshape((n_units, 2) + cache.conv.shape[1:])

    def unit_body(h, inp):
        pu, lru2, conv2, k_l, v_l = inp
        h, ha, cva = _rec_block_step(cfg, pu["rec_a"], h, lru2[0], conv2[0])
        h, hb, cvb = _rec_block_step(cfg, pu["rec_b"], h, lru2[1], conv2[1])
        h, k_l, v_l = _attn_block_step(cfg, pu["attn"], h, k_l, v_l, pos)
        return h, (jnp.stack([ha, hb]), jnp.stack([cva, cvb]), k_l, v_l)

    h, (lru2, conv2, k, v) = jax.lax.scan(
        unit_body, x, (params["units"], lru_main, conv_main, cache.k, cache.v))
    lru = lru2.reshape((2 * n_units,) + cache.lru.shape[1:])
    conv = conv2.reshape((2 * n_units,) + cache.conv.shape[1:])

    if tail:
        def tail_body(hh, inp):
            pl, h0, cv0 = inp
            hh, h1, cv1 = _rec_block_step(cfg, pl, hh, h0, cv0)
            return hh, (h1, cv1)
        h, (t_lru, t_conv) = jax.lax.scan(
            tail_body, h, (params["tail"]["rec"],
                           cache.lru[2 * n_units:], cache.conv[2 * n_units:]))
        lru = jnp.concatenate([lru, t_lru], axis=0)
        conv = jnp.concatenate([conv, t_conv], axis=0)

    h = rmsnorm(h, params["final_norm"]["w"], cfg.rmsnorm_eps)
    logits = lm_logits(h, params["head"], cfg.vocab_size)
    return logits, cachelib.HybridCache(lru, conv, k, v, pos + 1)
