"""Seamless-M4T-v2 text backbone: encoder-decoder transformer (audio family).

The speech frontend (mel + conformer feature extractor) is the allowed stub:
`input_specs()` supplies precomputed frame embeddings [B, T_frames, d_model].
The backbone is NLLB-style: 24 encoder layers (bidirectional self-attention
over frames) + 24 decoder layers (causal self-attention + cross-attention
into the encoder memory).  kv=16 == n_heads (MHA).  RoPE is used for
encoder/decoder self-attention positions; cross-attention is position-free.

Decode state: self-attention KV cache + cross-attention K/V precomputed
once at prefill (the standard enc-dec serving optimization).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import shard
from repro.models import attention as attnlib
from repro.models import cache as cachelib
from repro.models import dense
from repro.models.common import (
    ModelConfig,
    padded_vocab,
    ParamDef,
    cross_entropy,
    embed_tokens,
    lm_logits,
    maybe_remat,
    mlp_defs,
    rmsnorm,
    rope,
)
from repro.models.common import swiglu


def _xattn_defs(cfg: ModelConfig, n: int) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim_
    L, A = (n,), ("layers",)
    return {
        "wq": ParamDef(L + (d, h, hd), A + ("embed_w", "heads", None)),
        "wk": ParamDef(L + (d, h, hd), A + ("embed_w", "kv_heads", None)),
        "wv": ParamDef(L + (d, h, hd), A + ("embed_w", "kv_heads", None)),
        "wo": ParamDef(L + (h, hd, d), A + ("heads", None, "embed_w"),
                       scale=0.02 / max(1, (2 * cfg.n_layers) ** 0.5)),
    }


def param_defs(cfg: ModelConfig) -> dict:
    ne, nd = cfg.enc_layers, cfg.dec_layers
    d = cfg.d_model
    return {
        "adapter": ParamDef((d, d), ("embed_w", None)),  # frame-embed adapter
        "embed": ParamDef((padded_vocab(cfg.vocab_size), d), ("vocab", "embed_w")),
        "encoder": {
            "attn": dense.attn_defs(cfg, ne),
            "mlp": mlp_defs(d, cfg.d_ff, ne),
            "ln_attn": {"w": ParamDef((ne, d), ("layers", None), init="zeros")},
            "ln_mlp": {"w": ParamDef((ne, d), ("layers", None), init="zeros")},
        },
        "enc_norm": {"w": ParamDef((d,), (None,), init="zeros")},
        "decoder": {
            "self": dense.attn_defs(cfg, nd),
            "cross": _xattn_defs(cfg, nd),
            "mlp": mlp_defs(d, cfg.d_ff, nd),
            "ln_self": {"w": ParamDef((nd, d), ("layers", None), init="zeros")},
            "ln_cross": {"w": ParamDef((nd, d), ("layers", None), init="zeros")},
            "ln_mlp": {"w": ParamDef((nd, d), ("layers", None), init="zeros")},
        },
        "final_norm": {"w": ParamDef((d,), (None,), init="zeros")},
        "head": ParamDef((d, padded_vocab(cfg.vocab_size)), ("embed_w", "vocab")),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------


def encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    """frames [B, T, d] (stubbed frontend output) -> memory [B, T, d]."""
    x = jnp.einsum("btd,de->bte", frames.astype(cfg.dtype), params["adapter"])

    def body(h, pl):
        h = shard.constrain(h, "batch", "seq", None)
        a, _, _ = dense.attention_full(cfg, pl["attn"],
                                       rmsnorm(h, pl["ln_attn"]["w"], cfg.rmsnorm_eps),
                                       causal=False)
        h = h + a
        m = swiglu(rmsnorm(h, pl["ln_mlp"]["w"], cfg.rmsnorm_eps),
                   pl["mlp"]["w_gate"], pl["mlp"]["w_up"], pl["mlp"]["w_down"])
        return h + m, None

    body = maybe_remat(body, cfg.remat)
    h, _ = jax.lax.scan(body, x, params["encoder"])
    return rmsnorm(h, params["enc_norm"]["w"], cfg.rmsnorm_eps)


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------


def _cross_attention_full(cfg, pl, x, mem_k, mem_v):
    """x [B,S,d]; mem_k/mem_v [B,T,H,Dh] precomputed."""
    q = jnp.einsum("...d,dhe->...he", x, pl["wq"])
    o = attnlib.full_attention(q, mem_k, mem_v, causal=False)
    return jnp.einsum("...he,hed->...d", o, pl["wo"])


def _cross_kv(cfg, pl, memory):
    k = jnp.einsum("btd,dhe->bthe", memory, pl["wk"])
    v = jnp.einsum("btd,dhe->bthe", memory, pl["wv"])
    return k, v


def _cross_attention_token(cfg, pl, x, k_l, v_l):
    """x [B,d]; k_l/v_l [B,T,H,Dh]."""
    q = jnp.einsum("bd,dhe->bhe", x, pl["wq"])
    T = k_l.shape[1]
    o = attnlib.decode_attention(q, k_l, v_l, jnp.asarray(T - 1, jnp.int32))
    return jnp.einsum("bhe,hed->bd", o, pl["wo"])


def decode_full(cfg: ModelConfig, params: dict, tokens: jax.Array,
                memory: jax.Array, *, window: int = 0, collect: bool = False):
    """Teacher-forced decoder pass.  Returns (hidden, (ks, vs, ck, cv))."""
    x = embed_tokens(params["embed"], tokens)

    def body(h, pl):
        h = shard.constrain(h, "batch", "seq", None)
        a, k, v = dense.attention_full(
            cfg, pl["self"], rmsnorm(h, pl["ln_self"]["w"], cfg.rmsnorm_eps),
            window=window)
        h = h + a
        ck, cv = _cross_kv(cfg, pl["cross"], memory)
        c = _cross_attention_full(
            cfg, pl["cross"], rmsnorm(h, pl["ln_cross"]["w"], cfg.rmsnorm_eps),
            ck, cv)
        h = h + c
        m = swiglu(rmsnorm(h, pl["ln_mlp"]["w"], cfg.rmsnorm_eps),
                   pl["mlp"]["w_gate"], pl["mlp"]["w_up"], pl["mlp"]["w_down"])
        h = h + m
        out = (k, v, ck, cv) if collect else None
        return h, out

    body = maybe_remat(body, cfg.remat)
    h, kv = jax.lax.scan(body, x, params["decoder"])
    return h, kv


# ---------------------------------------------------------------------------
# Registry API
# ---------------------------------------------------------------------------


def train_loss(cfg: ModelConfig, params: dict, batch: dict):
    memory = encode(cfg, params, batch["frames"])
    h, _ = decode_full(cfg, params, batch["tokens"], memory, window=cfg.window)
    h = rmsnorm(h, params["final_norm"]["w"], cfg.rmsnorm_eps)
    logits = lm_logits(h, params["head"], cfg.vocab_size)
    loss, _ = cross_entropy(logits, batch["labels"])
    return loss, {}


def prefill(cfg: ModelConfig, params: dict, batch: dict, *,
            cache_len: int, long_context: bool = False):
    """batch: {"frames": [B,T,d], "tokens": [B,S]} — encodes, runs the
    decoder prefix, returns last logits + EncDecCache."""
    window = cfg.long_context_window if long_context else cfg.window
    memory = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    S = tokens.shape[1]
    h, (ks, vs, ck, cv) = decode_full(cfg, params, tokens, memory,
                                      window=window, collect=True)
    hl = rmsnorm(h[:, -1], params["final_norm"]["w"], cfg.rmsnorm_eps)
    logits = lm_logits(hl, params["head"], cfg.vocab_size)
    ks, vs = ks.astype(cfg.kv_dtype), vs.astype(cfg.kv_dtype)
    if window:
        ks, vs = cachelib.ring_pack(ks, vs, window, S)
    else:
        pad = [(0, 0), (0, 0), (0, cache_len - S), (0, 0), (0, 0)]
        ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
    cache = cachelib.EncDecCache(ks, vs, ck, cv, jnp.asarray(S, jnp.int32))
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, *,
               long_context: bool = False, dtype=None):
    dtype = dtype or cfg.kv_dtype
    window = cfg.long_context_window if long_context else cfg.window
    s_len = min(window, cache_len) if window else cache_len
    return cachelib.EncDecCache.init(cfg.dec_layers, batch, s_len,
                                     cfg.n_frames, cfg.n_kv_heads,
                                     cfg.head_dim_, dtype)


def decode_step(cfg: ModelConfig, params: dict, cache, batch: dict):
    token = batch["token"]
    pos = cache.pos
    S = cache.cache_len
    # ring when the cache is windowed (long-context mode)
    ring = bool(cfg.long_context_window and S == cfg.long_context_window) or bool(cfg.window)
    slot = jnp.where(jnp.asarray(ring), pos % S, jnp.minimum(pos, S - 1))
    x = jnp.take(params["embed"], token, axis=0)

    def body(h, inp):
        pl, k_l, v_l, ck_l, cv_l = inp
        xin = rmsnorm(h, pl["ln_self"]["w"], cfg.rmsnorm_eps)
        k_new, v_new = dense.project_kv_token(cfg, pl["self"], xin, pos)
        k_l = cachelib.onehot_write(k_l, k_new, slot)
        v_l = cachelib.onehot_write(v_l, v_new, slot)
        a = dense.attention_decode(cfg, pl["self"], xin, k_l, v_l, pos, ring=ring)
        h = h + a
        c = _cross_attention_token(
            cfg, pl["cross"], rmsnorm(h, pl["ln_cross"]["w"], cfg.rmsnorm_eps),
            ck_l, cv_l)
        h = h + c
        m = swiglu(rmsnorm(h, pl["ln_mlp"]["w"], cfg.rmsnorm_eps),
                   pl["mlp"]["w_gate"], pl["mlp"]["w_up"], pl["mlp"]["w_down"])
        h = h + m
        return h, (k_l, v_l)

    h, (kc, vc) = jax.lax.scan(
        body, x,
        (params["decoder"], cache.self_k, cache.self_v,
         cache.cross_k, cache.cross_v))
    h = rmsnorm(h, params["final_norm"]["w"], cfg.rmsnorm_eps)
    logits = lm_logits(h, params["head"], cfg.vocab_size)
    new_cache = cachelib.EncDecCache(kc, vc, cache.cross_k, cache.cross_v, pos + 1)
    return logits, new_cache
