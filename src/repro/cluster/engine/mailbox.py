"""Mailbox: the cross-shard event channel.

Everything that crosses a node-group boundary flows through here, in one
``(time, seq)``-ordered heap: request arrivals (routing decisions read a
merged fleet view, so they are fleet events by nature), fault-trace
deliveries (a correlated domain outage posts one event per member node
at a single instant — every member shard sees the outage at the same
barrier), KV-shipping completions (a refugee's state landing on a node
that may live on a different shard than its donor), routing retries, and
deferred recovery re-deliveries.

The mailbox is the merge point the determinism argument rests on: the
runner always takes the globally least ``(time, seq)`` key across the
mailbox and every shard heap, and sequence numbers come from the same
fleet-wide allocator the shards use — so the interleaving of mailbox
deliveries with shard-local events is identical whatever the partition,
and shard count never changes the event stream.

Every cross-shard delivery also has a *minimum latency* — ship time is
bytes over interconnect bandwidth, retries wait out the policy's backoff
floor, a pre-wake takes the node's wake ramp.  ``post`` asserts the
invariant (``time >= posted-at``); the runner's windowed mode turns the
same floors into its conservative lookahead
(:func:`repro.cluster.engine.runner.cross_shard_floor_s`).
"""

from __future__ import annotations

import heapq

from repro.cluster.engine.events import Event

_INF = float("inf")


class Mailbox:
    """(time, seq)-ordered heap of fleet-scoped / cross-shard events."""

    __slots__ = ("heap", "posted")

    def __init__(self) -> None:
        self.heap: list[tuple[float, int, Event]] = []
        self.posted = 0

    def __len__(self) -> int:
        return len(self.heap)

    def post(self, ev: Event, *, now: float | None = None) -> Event:
        """Deliver `ev` at its own (time, seq) slot.  `now` (when given)
        asserts causality: nothing may be posted into the past."""
        assert now is None or ev.time >= now, \
            f"mailbox post into the past: {ev.describe()} at now={now!r}"
        heapq.heappush(self.heap, (ev.time, ev.seq, ev))
        self.posted += 1
        return ev

    def peek_time(self) -> float:
        return self.heap[0][0] if self.heap else _INF

    def peek_key(self) -> tuple[float, int]:
        h = self.heap
        return (h[0][0], h[0][1]) if h else (_INF, -1)

    def pop(self) -> Event:
        return heapq.heappop(self.heap)[2]
