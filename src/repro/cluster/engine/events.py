"""Typed event core of the cluster engine.

The monolithic loop in ``cluster/sim.py`` drove the simulation with ten
magic int codes (``range(10)``) and raw ``(time, seq, kind, payload)``
heap tuples whose payload shape depended on the kind — a ``(nid, epoch)``
pair here, a bare request there.  This module replaces both with a typed
surface shared by every engine layer:

* :class:`EventKind` — an ``IntEnum`` of the ten kinds.  The numeric
  values are the historical codes, so an event stream printed from the
  engine is directly comparable against any stream captured from the old
  loop.  ``EventKind.epoch_guarded`` names the kinds whose payload
  carries the scheduling-time phase epoch (a preemption or crash bumps
  the node's epoch, so a stale event still sitting in a heap is
  recognized and dropped when popped — the only event-invalidation path
  in the engine).
* Payload dataclasses — one shape per kind family (:class:`NodeRef`,
  :class:`IdleToken`, :class:`Shipment`, :class:`Retry`; arrivals carry
  the traced request itself and fault events the ``FaultEvent`` from the
  fault trace, both already typed).
* :class:`Event` — the scheduled unit: ``(time, seq, kind, payload)``
  with a total order on ``(time, seq)``.  The sequence number is issued
  by one fleet-wide counter (:class:`SeqAllocator`) whatever shard the
  event lives on, which is what makes the sharded engine's merged stream
  bit-identical to the sequential loop's: ties in time are broken by the
  same sequence numbers the monolithic heap would have assigned.

Heaps store ``(time, seq, Event)`` triples (``Event.entry``) so ordering
stays a C-level tuple comparison; handlers, the stream-capture hook, and
the obs layer only ever see the typed ``Event``.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any


class EventKind(enum.IntEnum):
    """The ten event kinds, numbered as the historical int codes."""

    ARRIVAL = 0       # a traced request enters the system
    PHASE_END = 1     # a node's running phase segment settles
    WAKE_END = 2      # a gated node finished powering back up
    GATE_END = 3      # an idle node finished ramping down
    IDLE_TIMER = 4    # autoscaler re-check of an idle node
    PREEMPT_END = 5   # a preempted decode segment's truncation settles
    FAULT = 6         # crash/recover/slow/normal from the fault trace
    CRASH_END = 7     # a dying node's final truncated charge settles
    SHIP_END = 8      # a refugee's KV finished landing on its recipient
    RETRY = 9         # capped-backoff re-route of an unrouteable request

    @property
    def epoch_guarded(self) -> bool:
        """Kinds whose payload pins the node's phase epoch at scheduling
        time (dropped on pop when the epoch has moved on)."""
        return self in _EPOCH_GUARDED

    @property
    def node_local(self) -> bool:
        """Kinds a :class:`~repro.cluster.engine.shard.NodeShard` owns —
        everything that times a single node's own state machine.  The
        complement (arrivals, faults, shipments, retries) crosses node
        boundaries and lives in the cross-shard
        :class:`~repro.cluster.engine.mailbox.Mailbox`."""
        return self in _NODE_LOCAL


_EPOCH_GUARDED = frozenset((
    EventKind.PHASE_END, EventKind.PREEMPT_END, EventKind.WAKE_END,
    EventKind.GATE_END, EventKind.CRASH_END,
))
_NODE_LOCAL = frozenset((
    EventKind.PHASE_END, EventKind.PREEMPT_END, EventKind.WAKE_END,
    EventKind.GATE_END, EventKind.CRASH_END, EventKind.IDLE_TIMER,
))


@dataclasses.dataclass(frozen=True, slots=True)
class NodeRef:
    """Payload of every epoch-guarded node event: which node, and the
    phase epoch the event was scheduled under."""

    node_id: int
    epoch: int


@dataclasses.dataclass(frozen=True, slots=True)
class IdleToken:
    """Payload of an IDLE_TIMER: the node and the ``power_state_since``
    stamp of the idle stretch that armed it — a node that served work
    and went idle again in between invalidates the stale timer."""

    node_id: int
    since: float


@dataclasses.dataclass(frozen=True, slots=True)
class Shipment:
    """Payload of a SHIP_END: the recipient node and the in-flight
    refugee whose KV is landing there."""

    node_id: int
    member: Any   # cluster.node._InFlight (kept opaque: engine-agnostic)


@dataclasses.dataclass(frozen=True, slots=True)
class Retry:
    """Payload of a RETRY: the unrouteable request and how many routing
    attempts it has already burned."""

    req: Any      # cluster.trace.TracedRequest
    attempts: int


@dataclasses.dataclass(slots=True)
class Event:
    """One scheduled occurrence.  Total order is ``(time, seq)``; the
    fleet-wide sequence counter makes simultaneous events deterministic
    (and unique, so comparison never reaches kind or payload)."""

    time: float
    seq: int
    kind: EventKind
    payload: Any

    def __lt__(self, other: "Event") -> bool:
        return (self.time, self.seq) < (other.time, other.seq)

    @property
    def key(self) -> tuple[float, int]:
        return (self.time, self.seq)

    @property
    def entry(self) -> tuple[float, int, "Event"]:
        """Heap representation: C-speed tuple ordering, typed cargo."""
        return (self.time, self.seq, self)

    def describe(self) -> str:
        """One-line canonical rendering, used by the event-stream
        equivalence gates (two engines replaying the same run must
        produce byte-identical describe() streams)."""
        p = self.payload
        if type(p) is NodeRef:
            body = f"n{p.node_id}@e{p.epoch}"
        elif type(p) is IdleToken:
            body = f"n{p.node_id}@s{p.since!r}"
        elif type(p) is Shipment:
            body = f"n{p.node_id}+req{p.member.req.request_id}"
        elif type(p) is Retry:
            body = f"req{p.req.request_id}#{p.attempts}"
        elif p is None:
            body = "-"
        else:   # arrival (TracedRequest) or FaultEvent
            rid = getattr(p, "request_id", None)
            if rid is not None:
                body = f"req{rid}"
            else:
                body = f"n{p.node_id}:{p.kind}"
        return f"{self.time!r} #{self.seq} {self.kind.name} {body}"


class SeqAllocator:
    """The fleet-wide monotone sequence counter.  Every event — whatever
    shard pushes it — draws from this one counter in handler order, which
    is what pins tie-breaking (and therefore the whole merged stream) to
    the sequential loop's behavior."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def __call__(self) -> int:
        v = self.value
        self.value = v + 1
        return v
