"""The sharded deterministic event engine behind `simulate_cluster`.

Layer map (bottom up):

    events.py   — EventKind IntEnum + typed payloads (NodeRef /
                  IdleToken / Shipment / Retry), the Event unit ordered
                  by (time, seq), and the fleet-wide SeqAllocator.
    shard.py    — NodeShard: one node group's heap + the node-event
                  bookkeeping (epoch stamping, idle-timer tokens).
    mailbox.py  — Mailbox: the (time, seq)-ordered cross-shard channel
                  (arrivals, faults, KV shipments, retries).
    runner.py   — Runner: merge mode (exact, any configuration),
                  windowed mode (barrier-parallel over decomposable
                  configurations, conservative lookahead via
                  cross_shard_floor_s), and the process-pool variant.

Determinism contract: sequence numbers are drawn from one fleet-wide
allocator at the same handler sites in the same order as the historical
monolithic loop, so merge-mode replay is bit-identical to the
sequential loop at every shard count — the property tests/test_engine.py
pins on seeded fault+preemption traces at shards {1, 2, 4, 8} and under
random partitions.
"""

from repro.cluster.engine.events import (  # noqa: F401
    Event,
    EventKind,
    IdleToken,
    NodeRef,
    Retry,
    SeqAllocator,
    Shipment,
)
from repro.cluster.engine.mailbox import Mailbox  # noqa: F401
from repro.cluster.engine.runner import (  # noqa: F401
    Runner,
    cross_shard_floor_s,
    partition_nodes,
)
from repro.cluster.engine.shard import NodeShard  # noqa: F401
