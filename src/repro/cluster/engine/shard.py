"""NodeShard: one node group's event heap and timer bookkeeping.

A shard owns a disjoint subset of the fleet and every *node-local* event
those nodes generate: phase segment ends, preemption and crash
settlements, wake/gate transition completions, idle timers.  The state
machines themselves stay in :class:`repro.cluster.node.ClusterNode` —
what the shard takes over from the old monolithic loop is the timer
bookkeeping around them: mapping a node's event hint ``(EventKind,
end_s)`` to a scheduled :class:`~repro.cluster.engine.events.Event`
(stamping the phase epoch for the guarded kinds), arming the
autoscaler's idle timers with the idle-stretch token, and keeping the
group's heap ordered by ``(time, seq)``.

Sequence numbers come from the fleet-wide
:class:`~repro.cluster.engine.events.SeqAllocator` the runner hands
every shard, so the merged stream across shards is bit-identical to the
sequential loop whatever the partition.

Cross-node events (arrivals, faults, KV shipments, retries) never enter
a shard heap — they live in the runner's
:class:`~repro.cluster.engine.mailbox.Mailbox`.
"""

from __future__ import annotations

import heapq
from typing import Sequence

from repro.cluster.engine.events import (
    Event,
    EventKind,
    IdleToken,
    NodeRef,
    SeqAllocator,
)
from repro.cluster.power import IDLE

_INF = float("inf")


class NodeShard:
    """One node group's heap plus its node-event bookkeeping."""

    __slots__ = ("index", "nodes", "by_id", "heap", "next_seq", "telemetry")

    def __init__(self, index: int, nodes: Sequence, next_seq: SeqAllocator):
        self.index = index
        self.nodes = list(nodes)
        self.by_id = {n.node_id: n for n in self.nodes}
        self.heap: list[tuple[float, int, Event]] = []
        self.next_seq = next_seq
        self.telemetry = None   # per-shard obs child (set by the runner)

    def __repr__(self) -> str:
        return (f"NodeShard({self.index}, "
                f"nodes={[n.node_id for n in self.nodes]}, "
                f"pending={len(self.heap)})")

    # --- scheduling ----------------------------------------------------
    def push(self, ev: Event) -> Event:
        heapq.heappush(self.heap, (ev.time, ev.seq, ev))
        return ev

    def push_node_event(self, node, hint) -> Event | None:
        """Schedule a node's event hint ``(EventKind, end_s)`` (or None).
        Guarded kinds get the node's phase epoch stamped at scheduling
        time; a later preemption or crash bumps the epoch and the stale
        event dies in the heap when popped."""
        if hint is None:
            return None
        kind, end_s = hint
        return self.push(Event(end_s, self.next_seq(), kind,
                               NodeRef(node.node_id, node.phase_epoch)))

    def arm_idle_timer(self, node, autoscaler, now: float) -> Event | None:
        """Ask the autoscaler whether (and when) to revisit an idle node.
        The timer carries the idle-epoch token so a node that served work
        and went idle again in between invalidates the stale timer."""
        if autoscaler is None or node.power_state != IDLE:
            return None
        t = autoscaler.on_idle(node, now)
        if t is None:
            return None
        return self.push(Event(t, self.next_seq(), EventKind.IDLE_TIMER,
                               IdleToken(node.node_id,
                                         node.power_state_since)))

    # --- consumption ---------------------------------------------------
    def peek_time(self) -> float:
        """Earliest pending local event's time (inf when drained)."""
        return self.heap[0][0] if self.heap else _INF

    def peek_key(self) -> tuple[float, int]:
        """Earliest pending local event's (time, seq) order key."""
        h = self.heap
        return (h[0][0], h[0][1]) if h else (_INF, -1)

    def pop(self) -> Event:
        return heapq.heappop(self.heap)[2]
