"""The Runner: shards, barriers, and the deterministic merge.

The engine advances a partitioned fleet through three execution modes,
all built from the same typed parts (events / shard heaps / mailbox):

* **merge** (the default, exact for every configuration): one fleet-wide
  ``(time, seq)`` order is maintained by always consuming the globally
  least key across the mailbox and every shard heap.  Because sequence
  numbers come from one fleet-wide :class:`SeqAllocator` drawn in
  handler order — the same order the old monolithic loop drew them in —
  the merged stream is bit-identical to the sequential loop *by
  construction*, whatever the partition.  Shard count never changes the
  event stream, the seven-bucket energy partition, or the ClusterReport.

* **windowed** (decomposable configurations): between consecutive
  mailbox deliveries (barriers), every shard drains its *local* events
  independently — no cross-heap peeking — up to the conservative
  horizon ``min(next mailbox key, barrier + cross_shard_floor_s(...))``.
  The floor is the minimum latency any cross-shard effect needs to
  propagate (KV ship time, retry backoff floor, wake ramp), so nothing
  a shard does inside the window can influence a peer before the next
  barrier.  Completions observed mid-window are *deferred* and replayed
  to the policy/preempter at the barrier in merged
  ``(finish, node, order)`` order — a partition-invariant order, so the
  report is identical for every partition (and identical to merge mode
  up to the ordering of completions landing at the exact same float
  instant on different nodes — the differential tests pin equality on
  the seeded traces).  Requires a decomposable configuration: no
  autoscaler (idle-gating reads fleet-wide awake counts between
  barriers), no fault trace (rescue re-routes mid-window), no telemetry
  (trace stamps encode the merge order).

* **windowed + workers** (process-pool): the windowed barrier protocol
  over ``multiprocessing`` fork workers, each owning its shard's node
  state machines for the whole run.  The parent owns the policy, the
  arrival trace and the record books, and routes over lightweight
  per-node views (load / power rank / accepting) refreshed at each
  barrier — so it additionally requires a policy that declares its
  fleet reads are view-expressible (``policy.fleet_reads`` in
  ``{"none", "counts"}``).  Worker nodes are finalized in-place and
  reduced to NodeStats; the caller's node objects keep their pre-run
  state (the report is the product).  With ``workers="auto"`` the pool
  sizes to ``min(shards, cpu_count)`` and degrades to the inline
  windowed loop when that is 1 — same barriers, same report.

Observability attaches **per shard** when ``obs_mode="sharded"``: each
shard gets a child Telemetry (own registry, own stamped tracer), fleet-
scoped hooks go to a fleet child, and at finalize the children fold into
the caller's Telemetry through the associative reductions
(:meth:`MetricsRegistry.merge` and the stamp-ordered
:meth:`EventTracer.absorb`) — byte-identical Prometheus text and Chrome
trace to the fused single-registry run.  ``obs_mode="fused"`` (the
facade default) reports into the one caller-supplied Telemetry exactly
as the monolithic loop did.
"""

from __future__ import annotations

import dataclasses
import heapq
import os
from typing import Callable, Sequence

from repro.cluster.engine.events import (
    Event,
    EventKind,
    Retry,
    SeqAllocator,
    Shipment,
)
from repro.cluster.engine.mailbox import Mailbox
from repro.cluster.engine.shard import NodeShard
from repro.cluster.faults import CRASH, RECOVER, SLOW, FaultTrace
from repro.cluster.metrics import (
    AbandonedRecord,
    ClusterReport,
    RequestRecord,
    per_node_stats,
)
from repro.cluster.policies import (
    objective_of_assignment,
    replica_registry,
    unique_profiles,
)
from repro.cluster.power import GATED, IDLE
from repro.energy.costs import kv_bytes_per_token

_INF = float("inf")

_ARRIVAL = EventKind.ARRIVAL
_PHASE_END = EventKind.PHASE_END
_WAKE_END = EventKind.WAKE_END
_GATE_END = EventKind.GATE_END
_IDLE_TIMER = EventKind.IDLE_TIMER
_PREEMPT_END = EventKind.PREEMPT_END
_FAULT = EventKind.FAULT
_CRASH_END = EventKind.CRASH_END
_SHIP_END = EventKind.SHIP_END
_RETRY = EventKind.RETRY


def partition_nodes(nodes: Sequence, shard_count: int) -> list[list]:
    """Deterministic contiguous partition into `shard_count` balanced
    groups (first ``len % shard_count`` groups take the extra node).
    Any partition yields the same merge-mode stream; this one keeps
    co-declared replicas near each other for the windowed modes."""
    n = len(nodes)
    shard_count = max(1, min(int(shard_count), n))
    base, extra = divmod(n, shard_count)
    out, i = [], 0
    for s in range(shard_count):
        size = base + (1 if s < extra else 0)
        out.append(list(nodes[i:i + size]))
        i += size
    return out


def cross_shard_floor_s(nodes: Sequence, policy,
                        faults: FaultTrace | None = None) -> float:
    """Conservative lookahead: the minimum simulated latency any
    runtime-generated cross-shard effect needs before it can land on a
    peer shard.  Three channels exist, all fault-mode-only (arrivals and
    the fault trace itself are preloaded, so they are barriers, not
    lookahead): a KV shipment takes at least one token's bytes over the
    fastest interconnect, a routing retry waits out the policy's backoff
    floor, and a pre-wake takes the wake ramp.  Without a fault trace no
    cross-shard event is ever generated mid-window and the floor is
    infinite — windows run to the next preloaded barrier."""
    if faults is None:
        return _INF
    floor = getattr(policy, "retry_floor_s", 1.0)  # 2**0 backoff floor
    for n in nodes:
        accel = n.hardware.accel
        floor = min(floor, kv_bytes_per_token(n.sim.cfg) / accel.ici_bw)
        if n.power is not None:
            floor = min(floor, n.power.wake_s)
    return floor


class Runner:
    """One simulation run over a sharded fleet.

    Parameters mirror ``simulate_cluster`` plus the engine knobs:
    `shard_count` / `partition` (explicit list of node groups), `mode`
    ("merge" or "windowed"), `workers` (windowed only: int or "auto"),
    `obs_mode` ("fused" or "sharded"), and `stream` — an optional
    callable receiving every consumed :class:`Event` in merge order
    (the event-stream equivalence gates feed it ``list.append`` and
    compare ``describe()`` lines)."""

    def __init__(self, trace, nodes: Sequence, policy, *,
                 zeta: float = 0.5, autoscaler=None, preempter=None,
                 faults: FaultTrace | None = None, telemetry=None,
                 shard_count: int = 1,
                 partition: Sequence[Sequence] | None = None,
                 mode: str = "merge", workers: int | str | None = None,
                 obs_mode: str = "fused",
                 stream: Callable[[Event], None] | None = None):
        if not nodes:
            raise ValueError("need at least one node")
        self.nodes = list(nodes)
        self.by_id = {n.node_id: n for n in self.nodes}
        if len(self.by_id) != len(self.nodes):
            raise ValueError("node_ids must be unique")
        if mode not in ("merge", "windowed"):
            raise ValueError(f"unknown mode {mode!r}")
        if obs_mode not in ("fused", "sharded"):
            raise ValueError(f"unknown obs_mode {obs_mode!r}")
        self.trace = trace
        self.policy = policy
        self.zeta = zeta
        self.autoscaler = autoscaler
        self.preempter = preempter
        self.faults = faults
        self.telemetry = telemetry
        self.mode = mode
        self.obs_mode = obs_mode
        self.stream = stream
        if partition is not None:
            groups = [list(g) for g in partition if len(g)]
            flat = [n.node_id for g in groups for n in g]
            if sorted(flat) != sorted(self.by_id):
                raise ValueError(
                    "partition must cover every node exactly once")
        else:
            groups = partition_nodes(self.nodes, shard_count)
        self.next_seq = SeqAllocator()
        self.shards = [NodeShard(i, g, self.next_seq)
                       for i, g in enumerate(groups)]
        self.shard_of = {n.node_id: sh
                         for sh in self.shards for n in sh.nodes}
        self.mailbox = Mailbox()
        if workers == "auto":
            workers = min(len(self.shards), os.cpu_count() or 1)
        self.workers = int(workers) if workers is not None else 1
        if mode == "windowed":
            self._check_decomposable()

    # ------------------------------------------------------------------
    def _check_decomposable(self) -> None:
        """Windowed execution requires a configuration whose only
        cross-shard couplings are the preloaded barriers."""
        why = None
        if self.autoscaler is not None:
            why = ("autoscaler gating reads fleet-wide awake counts "
                   "between barriers")
        elif self.faults is not None:
            why = "fault rescue re-routes across shards mid-window"
        elif self.telemetry is not None:
            why = "telemetry trace stamps encode the merge order"
        if why is None and self.workers > 1:
            if self.preempter is not None:
                why = "a preempter reads live fleet state at barriers"
            elif getattr(self.policy, "fleet_reads", "full") not in (
                    "none", "counts"):
                why = (f"policy {self.policy.name!r} does not declare "
                       f"view-expressible fleet reads "
                       f"(fleet_reads='none'|'counts')")
        if why is not None:
            raise ValueError(f"windowed mode unsupported here: {why} — "
                             f"use mode='merge' (exact for every "
                             f"configuration)")

    # ------------------------------------------------------------------
    def run(self) -> ClusterReport:
        if self.mode == "windowed" and self.workers > 1:
            return self._execute(pooled=True)
        return self._execute(pooled=False)

    # ------------------------------------------------------------------
    # The single entry point for merge / windowed-inline / pooled: shared
    # setup + bookkeeping closures (a faithful port of the monolithic
    # loop's, drawing seqs in the same handler order), then the
    # mode-specific consumption loop.
    def _execute(self, *, pooled: bool) -> ClusterReport:
        trace, nodes, policy = self.trace, self.nodes, self.policy
        by_id, zeta = self.by_id, self.zeta
        autoscaler, preempter = self.autoscaler, self.preempter
        faults, telemetry = self.faults, self.telemetry
        shards, shard_of, mailbox = self.shards, self.shard_of, self.mailbox
        next_seq = self.next_seq

        replicas = replica_registry(nodes)
        policy.attach(nodes, trace, zeta)
        if autoscaler is not None:
            autoscaler.attach(nodes)
        if preempter is not None:
            preempter.attach(nodes, trace, zeta)

        # --- observability wiring (fused = the monolith's single object;
        # sharded = per-shard children folded at finalize) --------------
        node_tel: dict[int, object] | None = None
        fleet_tel = None
        obs_children: list = []
        sharded_obs = telemetry is not None and self.obs_mode == "sharded"
        if sharded_obs:
            from repro.obs.metrics import MetricsRegistry
            from repro.obs.telemetry import Telemetry
            from repro.obs.tracing import EventTracer
            stamp = SeqAllocator()   # global append-order stamp
            node_tel = {}

            def _child(with_tracer: bool):
                tr = None
                if telemetry.tracer is not None and with_tracer:
                    tr = EventTracer(telemetry.tracer.max_events)
                    tr.stamp_source = stamp
                return Telemetry(registry=MetricsRegistry(), tracer=tr,
                                 auditor=telemetry.auditor)

            for sh in shards:
                child = _child(True)
                child.attach(sh.nodes, policy, trace, zeta)
                sh.telemetry = child
                obs_children.append(child)
                for n in sh.nodes:
                    node_tel[n.node_id] = child
            # The fleet child attaches the *whole* node list: it owns the
            # cross-shard families (model-labeled request histograms via
            # on_completion, policy-labeled decision counters), and a
            # single writer in global event order keeps their float sums
            # bit-identical to the fused run.  Its per-node channels stay
            # at their attach-time zeros (node-scoped hooks go to the
            # owning shard's child), which merge exactly.
            fleet_tel = _child(True)
            fleet_tel.attach(nodes, policy, trace, zeta)
            obs_children.append(fleet_tel)
        elif telemetry is not None:
            node_tel = {n.node_id: telemetry for n in nodes}
            fleet_tel = telemetry
        # per-run observer references, set unconditionally so reused
        # nodes/policies never carry a stale one from a previous run
        for n in nodes:
            n.telemetry = node_tel[n.node_id] if node_tel else None
        policy.telemetry = fleet_tel
        if autoscaler is not None:
            autoscaler.telemetry = fleet_tel
        if preempter is not None:
            preempter.telemetry = fleet_tel
        if telemetry is not None and self.obs_mode == "fused":
            telemetry.attach(nodes, policy, trace, zeta)
        sample_every = (telemetry.sample_every_s
                        if telemetry is not None else None)
        next_sample = 0.0

        fault_mode = faults is not None
        for req in trace:   # preload: arrivals in trace order, then faults
            mailbox.post(Event(req.arrival_s, next_seq(), _ARRIVAL, req))
        if fault_mode:
            for fev in faults:
                if fev.node_id not in by_id:
                    raise ValueError(f"fault trace names unknown node "
                                     f"{fev.node_id}")
                mailbox.post(Event(fev.time_s, next_seq(), _FAULT, fev))

        records: list[RequestRecord] = []
        abandoned: list[AbandonedRecord] = []
        makespan = trace.duration_s
        state = {"makespan": makespan, "arrivals_left": len(trace)}

        # --- rescue orchestration (fault runs only) --------------------
        def fallback_node(eligible):
            return min(eligible,
                       key=lambda n: (n.load(), n.power_rank, n.node_id))

        def abandon_request(req, now, reason, attempts, *,
                            member=None, model=""):
            wasted = 0.0
            if member is not None:
                for w_nid, e in sorted(member.energy_on.items()):
                    by_id[w_nid].book_waste(e)
                    wasted += e
                member.energy_on.clear()
            rec = AbandonedRecord(
                request_id=req.request_id, model=model,
                tau_in=req.tau_in, tau_out=req.tau_out,
                arrival_s=req.arrival_s, abandoned_s=now, reason=reason,
                attempts=attempts, wasted_j=wasted)
            abandoned.append(rec)
            state["makespan"] = max(state["makespan"], now)
            if fleet_tel is not None:
                fleet_tel.on_abandon(rec, now)

        def schedule_retry(req, attempts, now):
            delay = policy.retry_delay(req, attempts, now)
            if delay is None:
                abandon_request(req, now, "no_capacity", attempts)
                return
            mailbox.post(Event(now + delay, next_seq(), _RETRY,
                               Retry(req, attempts + 1)), now=now)

        def route_or_retry(req, attempts, now):
            eligible = [n for n in nodes if n.accepting]
            if not eligible:
                schedule_retry(req, attempts, now)
                return
            nid = policy.select(req, eligible, now)
            node = by_id.get(nid)
            if node is None or not node.accepting:
                node = fallback_node(eligible)
            if node_tel is not None:
                node_tel[node.node_id].on_retry(req, node.node_id,
                                                attempts, now)
            shard_of[node.node_id].push_node_event(
                node, node.enqueue(req, now))

        def rerun_or_abandon(member, home, now, reason):
            if (policy.allow_rerun(member.req, now)
                    and any(n.accepting for n in nodes)):
                for w_nid, e in sorted(member.energy_on.items()):
                    by_id[w_nid].book_waste(e)
                member.energy_on.clear()
                route_or_retry(member.req, 0, now)
            else:
                abandon_request(member.req, now, reason, 0,
                                member=member, model=home.model_name)

        def dispatch_refugee(member, home, now):
            if member.prefill_done is not None:
                if member.ckpt_tokens >= member.req.tau_in:
                    member.prefill_done = None
                elif member.ckpt_tokens <= 0:
                    rerun_or_abandon(member, home, now, "prefill_lost")
                    return
            candidates = [n for n in nodes
                          if n.accepting and n.model_name == home.model_name
                          and n.node_id != home.node_id]
            if candidates:
                recipient = fallback_node(candidates)
                tokens = (member.ckpt_tokens
                          if member.prefill_done is not None
                          else member.context)
                n_bytes = tokens * kv_bytes_per_token(home.sim.cfg)
                ship_s = n_bytes / recipient.hardware.accel.ici_bw
                ship_j = n_bytes * recipient.hardware.accel.j_per_byte_ici
                recipient.book_shipping(ship_s, ship_j)
                member.shipped_bytes += n_bytes
                home.n_migrations_out += 1
                if node_tel is not None:
                    node_tel[recipient.node_id].on_migration(
                        home, recipient, tokens, n_bytes, ship_s, ship_j,
                        now)
                mailbox.post(Event(now + ship_s, next_seq(), _SHIP_END,
                                   Shipment(recipient.node_id, member)),
                             now=now)
            else:
                rerun_or_abandon(member, home, now, "no_survivor")

        def handle_failed(node, now):
            while node.suspended:
                dispatch_refugee(node.suspended.popleft(), node, now)
            while node.waiting:
                route_or_retry(node.waiting.popleft(), 0, now)

        def apply_drains(now):
            updates = policy.drain_updates(nodes, now)
            if not updates:
                return
            for d_nid, drain in updates:
                dnode = by_id[d_nid]
                if drain and not dnode.draining and not dnode.failed:
                    dnode.draining = True
                    if node_tel is not None:
                        node_tel[d_nid].on_drain(dnode, True, now)
                    while dnode.suspended:
                        dispatch_refugee(dnode.suspended.popleft(), dnode,
                                         now)
                    while dnode.waiting:
                        route_or_retry(dnode.waiting.popleft(), 0, now)
                elif not drain and dnode.draining:
                    dnode.draining = False
                    if node_tel is not None:
                        node_tel[d_nid].on_drain(dnode, False, now)

        # correlated-kill aggregation: crash events sharing one timestamp
        # are one domain outage
        kill_batch = [None, 0]

        def flush_kill_batch():
            if kill_batch[0] is not None and fleet_tel is not None:
                fleet_tel.on_domain_outage(kill_batch[0], kill_batch[1])
            kill_batch[0], kill_batch[1] = None, 0

        def complete(node, c, now):
            """Book one finished request and echo it to the observers."""
            state["makespan"] = max(state["makespan"], c.finish_s)
            rec = RequestRecord(
                request_id=c.req.request_id,
                node_id=node.node_id,
                model=node.model_name,
                tau_in=c.req.tau_in,
                tau_out=c.req.tau_out,
                arrival_s=c.req.arrival_s,
                start_s=c.start_s,
                finish_s=c.finish_s,
                energy_j=c.energy_j,
                isolated_runtime_s=c.isolated_runtime_s,
                preemptions=c.preemptions,
                migrations=c.migrations,
                shipped_bytes=c.shipped_bytes,
                cached_tokens=c.cached_tokens,
            )
            records.append(rec)
            return rec

        def observe(rec, now):
            policy.observe_completion(rec, now)
            if autoscaler is not None:
                autoscaler.on_completion(rec, now)
            if preempter is not None:
                preempter.observe_completion(rec, now)
            if fleet_tel is not None:
                # completion writes the model-labeled request histograms
                # (shared across shards) — single fleet-child writer in
                # global event order keeps their sums bit-identical
                fleet_tel.on_completion(rec, now)

        def handle_arrival(req, now):
            state["arrivals_left"] -= 1
            if autoscaler is not None:
                prewoken = 0
                for nid in autoscaler.on_arrival(req, nodes, now):
                    node = by_id[nid]
                    if node.power_state == GATED:   # proactive pre-wake
                        shard_of[nid].push_node_event(
                            node, (_WAKE_END, node.begin_wake(now)))
                        prewoken += 1
                if fleet_tel is not None:
                    fleet_tel.on_prewake(autoscaler.name, prewoken)
            if fault_mode:
                eligible = [n for n in nodes if n.accepting]
                if not eligible:   # whole fleet down/draining: back off
                    schedule_retry(req, 0, now)
                    return
                nid = policy.select(req, eligible, now)
                node = by_id.get(nid)
                if node is None or not node.accepting:
                    node = fallback_node(eligible)
                    nid = node.node_id
            else:
                nid = policy.select(req, nodes, now)
                if nid not in by_id:
                    raise ValueError(
                        f"{policy.name} routed to unknown node {nid}")
                node = by_id[nid]
            if node_tel is not None:
                node_tel[nid].on_arrival(req, policy.name, nid,
                                         node.model_name, now)
            shard_of[nid].push_node_event(node, node.enqueue(req, now))
            if preempter is not None:
                victim = preempter.consider(req, node, nodes, now)
                if fleet_tel is not None:
                    fleet_tel.on_preempt_decision(preempter.name,
                                                  victim is not None)
                if victim is not None:
                    shard_of[nid].push_node_event(
                        node, node.preempt_decode(victim, now))

        def handle_event(ev, now):
            """The merge-order handler for every non-arrival kind — a
            faithful port of the monolithic loop's dispatch (seqs are
            drawn at the same sites in the same order)."""
            kind = ev.kind
            if kind is _PHASE_END:
                ref = ev.payload
                node = by_id[ref.node_id]
                if ref.epoch != node.phase_epoch:
                    return   # preempted; this end never happened
                completions, next_ev = node.on_phase_end(now)
                for c in completions:
                    observe(complete(node, c, now), now)
                sh = shard_of[ref.node_id]
                sh.push_node_event(node, next_ev)
                if next_ev is None:
                    if fault_mode and node.failed:
                        handle_failed(node, now)
                    else:
                        sh.arm_idle_timer(node, autoscaler, now)
                if fault_mode and completions:
                    apply_drains(now)
            elif kind is _PREEMPT_END:
                ref = ev.payload
                node = by_id[ref.node_id]
                if ref.epoch != node.phase_epoch:
                    return   # a crash got there first
                next_ev = node.on_preempt_end(now)
                sh = shard_of[ref.node_id]
                sh.push_node_event(node, next_ev)
                if next_ev is None:
                    if fault_mode and node.failed:
                        handle_failed(node, now)
                    else:
                        sh.arm_idle_timer(node, autoscaler, now)
            elif kind is _WAKE_END:
                ref = ev.payload
                node = by_id[ref.node_id]
                if ref.epoch != node.phase_epoch:
                    return   # node crashed mid-wake
                next_ev = node.on_wake_end(now)
                sh = shard_of[ref.node_id]
                sh.push_node_event(node, next_ev)
                if next_ev is None:   # pre-woken with nothing to do (yet)
                    sh.arm_idle_timer(node, autoscaler, now)
            elif kind is _GATE_END:
                ref = ev.payload
                node = by_id[ref.node_id]
                if ref.epoch != node.phase_epoch:
                    return   # node crashed mid-gate
                shard_of[ref.node_id].push_node_event(
                    node, node.on_gate_end(now))
            elif kind is _FAULT:
                fev = ev.payload
                node = by_id[fev.node_id]
                if node_tel is not None:
                    node_tel[fev.node_id].on_fault(fev, node, now)
                if fev.kind == CRASH:
                    if kill_batch[0] is not None and kill_batch[0] != now:
                        flush_kill_batch()
                    kill_batch[0] = now
                    kill_batch[1] += 1
                    crash_ev = node.begin_crash(now)
                    if crash_ev is not None:
                        shard_of[fev.node_id].push_node_event(node,
                                                              crash_ev)
                    elif node.failed:   # off-phase: crashed right here
                        handle_failed(node, now)
                elif fev.kind == RECOVER:
                    if node.failed:
                        next_ev = node.recover(now)
                        sh = shard_of[fev.node_id]
                        sh.push_node_event(node, next_ev)
                        if next_ev is None:
                            sh.arm_idle_timer(node, autoscaler, now)
                    elif node.crash_pending:
                        # re-deliver the recovery at the settle instant
                        mailbox.post(Event(
                            node.phase_end_s, next_seq(), _FAULT,
                            dataclasses.replace(
                                fev, time_s=node.phase_end_s)), now=now)
                elif fev.kind == SLOW:
                    node.slowdown = fev.value
                else:   # NORMAL: straggler episode over
                    node.slowdown = 1.0
                policy.on_fault(fev, nodes, now)
            elif kind is _CRASH_END:
                ref = ev.payload
                node = by_id[ref.node_id]
                if ref.epoch != node.phase_epoch:
                    return
                node.on_crash_settle(now)
                handle_failed(node, now)
            elif kind is _SHIP_END:
                ship = ev.payload
                node = by_id[ship.node_id]
                if not node.accepting:
                    # recipient died/drained while the KV was in flight
                    dispatch_refugee(ship.member, node, now)
                else:
                    shard_of[ship.node_id].push_node_event(
                        node, node.receive_migrant(ship.member, now))
            elif kind is _RETRY:
                route_or_retry(ev.payload.req, ev.payload.attempts, now)
            else:   # _IDLE_TIMER
                tok = ev.payload
                node = by_id[tok.node_id]
                if (node.power_state == IDLE
                        and node.power_state_since == tok.since
                        and node.can_gate
                        and autoscaler is not None):
                    gate = autoscaler.should_gate(node, now)
                    if fleet_tel is not None:
                        fleet_tel.on_gate_decision(autoscaler.name, gate)
                    sh = shard_of[tok.node_id]
                    if gate:
                        sh.push_node_event(node, node.begin_gate(now))
                    elif state["arrivals_left"] > 0:
                        # declined: re-check later (stops with the last
                        # arrival so the loop terminates)
                        sh.arm_idle_timer(node, autoscaler, now)

        # the fleet starts idle — armed in *fleet* order (not shard
        # order) so the initial timers draw the same sequence numbers as
        # the monolithic loop under any partition
        for n in nodes:
            shard_of[n.node_id].arm_idle_timer(n, autoscaler, 0.0)

        # --- consumption -----------------------------------------------
        if pooled:
            self._pooled_loop(observe, records, state)
        elif self.mode == "windowed":
            self._windowed_loop(handle_arrival, observe, records, state)
        else:
            stream = self.stream
            peekables = [mailbox] + shards
            while True:
                src = None
                best = (_INF, -1)
                for p in peekables:
                    k = p.peek_key()
                    if src is None or k < best:
                        best, src = k, p
                if best[1] < 0:
                    break   # every heap drained
                ev = src.pop()
                now = ev.time
                if stream is not None:
                    stream(ev)
                if sample_every is not None:
                    # sample fleet state as of the previous event,
                    # stamped on the period grid, before this one
                    # mutates it
                    while next_sample <= now:
                        if sharded_obs:
                            for n in nodes:   # fleet order, per-shard books
                                node_tel[n.node_id].sample([n], next_sample)
                        else:
                            telemetry.sample(nodes, next_sample)
                        next_sample += sample_every
                if ev.kind is _ARRIVAL:
                    handle_arrival(ev.payload, now)
                else:
                    handle_event(ev, now)

        flush_kill_batch()

        # --- settlement ------------------------------------------------
        makespan = state["makespan"]
        if pooled:
            node_stats, suspended_left = self._pool_finish(makespan)
            if suspended_left:
                raise RuntimeError(
                    "preempted requests left suspended at the end of the "
                    "trace — resume/rescue logic bug")
        else:
            if any(n.suspended for n in nodes):
                raise RuntimeError(
                    "preempted requests left suspended at the end of the "
                    "trace — resume/rescue logic bug")
            for n in nodes:   # close the books at the common horizon
                n.finalize(makespan)
            node_stats = per_node_stats(nodes, makespan)
        if len(records) + len(abandoned) != len(trace):
            raise RuntimeError(
                f"served {len(records)} + abandoned {len(abandoned)} != "
                f"{len(trace)} requests — event loop bug")
        records.sort(key=lambda r: r.request_id)
        abandoned.sort(key=lambda r: r.request_id)

        profiles = unique_profiles(nodes)
        queries = (trace.queries() if not abandoned
                   else [(r.tau_in, r.tau_out) for r in records])
        assigned = [r.model for r in records]
        objective = (objective_of_assignment(profiles, queries, assigned,
                                             zeta)
                     if records else 0.0)
        prof_of = {p.name: p for p in profiles}
        predicted = sum(float(prof_of[r.model].energy(r.tau_in, r.tau_out))
                        for r in records)

        report = ClusterReport(
            policy=policy.name,
            zeta=zeta,
            records=tuple(records),
            node_stats=node_stats,
            makespan_s=makespan,
            objective=objective,
            predicted_energy_j=predicted,
            replicas=tuple((name, tuple(nids))
                           for name, nids in replicas.items()),
            abandoned=tuple(abandoned),
        )
        if telemetry is not None:
            if obs_children:
                fleet_tel.finalize(nodes, report)
                for child in obs_children:
                    telemetry.registry.merge(child.registry)
                if telemetry.tracer is not None:
                    telemetry.tracer.absorb(
                        [c.tracer for c in obs_children
                         if c.tracer is not None])
            else:
                telemetry.finalize(nodes, report)
        return report

    # ------------------------------------------------------------------
    # Windowed mode (inline): barriers at mailbox deliveries; each shard
    # drains its local heap independently below the conservative horizon.
    def _windowed_loop(self, handle_arrival, observe, records,
                       state) -> None:
        mailbox, shards, by_id = self.mailbox, self.shards, self.by_id
        floor = cross_shard_floor_s(self.nodes, self.policy, self.faults)
        deferred: list[tuple[float, int, int, object]] = []

        def drain(sh: NodeShard, horizon: float) -> None:
            heap = sh.heap
            while heap and heap[0][0] < horizon:
                ev = heapq.heappop(heap)[2]
                ref = ev.payload
                node = by_id[ref.node_id]
                if ref.epoch != node.phase_epoch:
                    continue   # preempted: this end never happened
                now = ev.time
                if ev.kind is _PHASE_END:
                    completions, next_ev = node.on_phase_end(now)
                    for i, c in enumerate(completions):
                        rec = RequestRecord(
                            request_id=c.req.request_id,
                            node_id=node.node_id,
                            model=node.model_name,
                            tau_in=c.req.tau_in,
                            tau_out=c.req.tau_out,
                            arrival_s=c.req.arrival_s,
                            start_s=c.start_s,
                            finish_s=c.finish_s,
                            energy_j=c.energy_j,
                            isolated_runtime_s=c.isolated_runtime_s,
                            preemptions=c.preemptions,
                            migrations=c.migrations,
                            shipped_bytes=c.shipped_bytes,
                            cached_tokens=c.cached_tokens,
                        )
                        records.append(rec)
                        if c.finish_s > state["makespan"]:
                            state["makespan"] = c.finish_s
                        deferred.append((c.finish_s, node.node_id, i, rec))
                    sh.push_node_event(node, next_ev)
                elif ev.kind is _PREEMPT_END:
                    sh.push_node_event(node, node.on_preempt_end(now))
                else:   # pragma: no cover — decomposability precondition
                    raise AssertionError(
                        f"non-decomposable event {ev.kind.name} in a "
                        f"windowed shard")

        def flush() -> None:
            # replay completions to policy/preempter in a partition-
            # invariant merged order: (finish, node, intra-node order)
            if not deferred:
                return
            deferred.sort(key=lambda d: d[:3])
            for _, _, _, rec in deferred:
                observe(rec, rec.finish_s)
            deferred.clear()

        while len(mailbox):
            barrier = mailbox.peek_time()
            horizon = min(barrier, barrier + floor)   # floor is inf here
            for sh in shards:
                drain(sh, horizon)
            flush()
            ev = mailbox.pop()
            handle_arrival(ev.payload, ev.time)
        for sh in shards:
            drain(sh, _INF)
        flush()

    # ------------------------------------------------------------------
    # Pooled mode: the windowed barrier protocol with each shard's nodes
    # owned by a forked worker process for the whole run.
    def _pooled_loop(self, observe, records, state) -> None:
        import multiprocessing as mp
        try:
            ctx = mp.get_context("fork")
        except ValueError as exc:   # pragma: no cover — non-fork platform
            raise RuntimeError(
                "pooled windowed mode needs the fork start method; use "
                "workers=1") from exc
        policy, by_id, mailbox = self.policy, self.by_id, self.mailbox
        views = {n.node_id: _NodeView(n) for n in self.nodes}
        view_list = [views[n.node_id] for n in self.nodes]
        pool = []
        conn_of: dict[int, object] = {}
        for sh in self.shards:
            parent_conn, child_conn = ctx.Pipe()
            proc = ctx.Process(target=_shard_worker,
                               args=(child_conn, sh.nodes), daemon=True)
            proc.start()
            child_conn.close()
            pool.append((parent_conn, proc, sh))
            for n in sh.nodes:
                conn_of[n.node_id] = parent_conn
        self._pool = pool

        def apply_views(updates):
            for nid, load, rank, acc in updates:
                v = views[nid]
                v._load, v.power_rank, v.accepting = load, rank, acc

        deferred: list[tuple[float, int, int, object]] = []

        def drain_all(horizon: float) -> None:
            for conn, _, _ in pool:
                conn.send(("drain", horizon))
            for conn, _, _ in pool:
                recs, updates, mk = conn.recv()
                apply_views(updates)
                if mk > state["makespan"]:
                    state["makespan"] = mk
                for i, rec in enumerate(recs):
                    records.append(rec)
                    deferred.append((rec.finish_s, rec.node_id, i, rec))
            if deferred:
                deferred.sort(key=lambda d: d[:3])
                for _, _, _, rec in deferred:
                    observe(rec, rec.finish_s)
                deferred.clear()

        while len(mailbox):
            barrier = mailbox.peek_time()
            drain_all(barrier)
            ev = mailbox.pop()
            req, now = ev.payload, ev.time
            state["arrivals_left"] -= 1
            nid = policy.select(req, view_list, now)
            if nid not in by_id:
                raise ValueError(f"{policy.name} routed to unknown node "
                                 f"{nid}")
            conn = conn_of[nid]
            conn.send(("enqueue", nid, req, now))
            apply_views(conn.recv())
        drain_all(_INF)

    def _pool_finish(self, makespan: float):
        """Close every worker's books at the common horizon and fold the
        per-shard NodeStats back, in fleet node order."""
        stats_by_id, suspended = {}, False
        for conn, proc, _ in self._pool:
            conn.send(("finish", makespan))
            shard_stats, any_suspended = conn.recv()
            suspended = suspended or any_suspended
            for s in shard_stats:
                stats_by_id[s.node_id] = s
            conn.send(("exit",))
            conn.close()
            proc.join(timeout=30)
        return (tuple(stats_by_id[n.node_id] for n in self.nodes),
                suspended)


class _NodeView:
    """The parent-side routing view of a worker-owned node: static
    identity plus the dynamic counters a `fleet_reads="counts"` policy
    may consult (load, power rank, accepting)."""

    __slots__ = ("node_id", "model_name", "profile", "hardware",
                 "_load", "power_rank", "accepting")

    def __init__(self, node):
        self.node_id = node.node_id
        self.model_name = node.model_name
        self.profile = node.profile
        self.hardware = node.hardware
        self._load = node.load()
        self.power_rank = node.power_rank
        self.accepting = node.accepting

    def load(self) -> int:
        return self._load


def _shard_worker(conn, nodes) -> None:
    """Worker process body: owns one shard's node state machines,
    drains windows, applies barrier enqueues, finalizes in place."""
    by_id = {n.node_id: n for n in nodes}
    for n in nodes:
        n.telemetry = None
    shard = NodeShard(0, nodes, SeqAllocator())

    def view_updates():
        return [(n.node_id, n.load(), n.power_rank, n.accepting)
                for n in nodes]

    def drain(horizon):
        recs, makespan = [], 0.0
        heap = shard.heap
        while heap and heap[0][0] < horizon:
            ev = heapq.heappop(heap)[2]
            ref = ev.payload
            node = by_id[ref.node_id]
            if ref.epoch != node.phase_epoch:
                continue
            if ev.kind is _PHASE_END:
                completions, next_ev = node.on_phase_end(ev.time)
                for c in completions:
                    makespan = max(makespan, c.finish_s)
                    recs.append(RequestRecord(
                        request_id=c.req.request_id,
                        node_id=node.node_id,
                        model=node.model_name,
                        tau_in=c.req.tau_in,
                        tau_out=c.req.tau_out,
                        arrival_s=c.req.arrival_s,
                        start_s=c.start_s,
                        finish_s=c.finish_s,
                        energy_j=c.energy_j,
                        isolated_runtime_s=c.isolated_runtime_s,
                        preemptions=c.preemptions,
                        migrations=c.migrations,
                        shipped_bytes=c.shipped_bytes,
                        cached_tokens=c.cached_tokens,
                    ))
                shard.push_node_event(node, next_ev)
            else:   # pragma: no cover — decomposability precondition
                raise AssertionError(
                    f"non-decomposable event {ev.kind.name} in a pooled "
                    f"shard")
        return recs, makespan

    while True:
        msg = conn.recv()
        op = msg[0]
        if op == "drain":
            recs, mk = drain(msg[1])
            conn.send((recs, view_updates(), mk))
        elif op == "enqueue":
            _, nid, req, now = msg
            node = by_id[nid]
            shard.push_node_event(node, node.enqueue(req, now))
            conn.send(view_updates())
        elif op == "finish":
            makespan = msg[1]
            any_suspended = any(n.suspended for n in nodes)
            for n in nodes:
                n.finalize(makespan)
            conn.send((per_node_stats(nodes, makespan), any_suspended))
        else:   # "exit"
            conn.close()
            return
