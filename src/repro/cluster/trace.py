"""Arrival traces: streaming request workloads for the cluster simulator.

A trace is an immutable, time-sorted sequence of TracedRequests.  Shapes
(τin, τout) come from the same Alpaca-like distribution the offline case
study uses (repro.data.workloads); timestamps come from the arrival
processes in repro.data.workloads.arrival_times (Poisson, bursty/Gamma,
diurnal thinning) or are replayed from an explicit (t, query) list.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.data.workloads import (
    Query,
    WorkloadSpec,
    arrival_times,
    session_workload,
    timestamped_workload,
)


@dataclasses.dataclass(frozen=True)
class TracedRequest:
    """One streaming request: the offline Query plus an arrival time.

    Session requests (from `session_trace`) additionally carry the
    conversation metadata a KV prefix cache prices: `session_id` groups
    the turns of one conversation, `turn` orders them, and
    `prefix_tokens` counts how many of this turn's τin tokens re-submit
    the previous context (always < τin).  Plain requests keep the
    defaults (session_id = -1 ⇒ never cached)."""

    request_id: int
    arrival_s: float
    tau_in: int
    tau_out: int
    session_id: int = -1
    turn: int = 0
    prefix_tokens: int = 0

    @property
    def query(self) -> Query:
        return (self.tau_in, self.tau_out)


@dataclasses.dataclass(frozen=True)
class ArrivalTrace:
    name: str
    requests: tuple[TracedRequest, ...]

    def __len__(self) -> int:
        return len(self.requests)

    def __iter__(self):
        return iter(self.requests)

    def queries(self) -> list[Query]:
        """The offline view of the trace (feeds core.scheduler)."""
        return [r.query for r in self.requests]

    @property
    def duration_s(self) -> float:
        return self.requests[-1].arrival_s if self.requests else 0.0

    @property
    def mean_rate_qps(self) -> float:
        d = self.duration_s
        return len(self.requests) / d if d > 0 else float("inf")


def _build(name: str, times, queries: Sequence[Query]) -> ArrivalTrace:
    reqs = tuple(
        TracedRequest(i, float(t), int(q[0]), int(q[1]))
        for i, (t, q) in enumerate(sorted(zip(times, queries))))
    return ArrivalTrace(name=name, requests=reqs)


def _shaped_trace(name: str, pattern: str, n: int, rate_qps: float,
                  spec: WorkloadSpec | None, seed: int,
                  **arrival_kw) -> ArrivalTrace:
    """Delegates to data.workloads.timestamped_workload so the shape/arrival
    seed pairing lives in exactly one place.  A caller-supplied spec keeps
    its own seed; the `seed` argument applies only when no spec is given."""
    if spec is None:
        spec = WorkloadSpec(n_queries=n, seed=seed)
    else:
        spec = dataclasses.replace(spec, n_queries=n)
    items = timestamped_workload(spec, rate_qps=rate_qps, pattern=pattern,
                                 **arrival_kw)
    return timestamped_trace(items, name=name)


def poisson_trace(n: int, rate_qps: float, *,
                  spec: WorkloadSpec | None = None,
                  seed: int = 0) -> ArrivalTrace:
    """Memoryless arrivals at rate_qps over Alpaca-like shapes."""
    return _shaped_trace(f"poisson@{rate_qps:g}", "poisson", n, rate_qps,
                         spec, seed)


def bursty_trace(n: int, rate_qps: float, *, burstiness: float = 4.0,
                 spec: WorkloadSpec | None = None,
                 seed: int = 0) -> ArrivalTrace:
    """Gamma interarrivals with squared CV = burstiness (same mean rate)."""
    return _shaped_trace(f"bursty@{rate_qps:g}", "bursty", n, rate_qps,
                         spec, seed, burstiness=burstiness)


def diurnal_trace(n: int, rate_qps: float, *, amplitude: float = 0.8,
                  period_s: float = 600.0,
                  spec: WorkloadSpec | None = None,
                  seed: int = 0) -> ArrivalTrace:
    """Sinusoidally-modulated Poisson (thinning), mean rate = rate_qps."""
    return _shaped_trace(f"diurnal@{rate_qps:g}", "diurnal", n, rate_qps,
                         spec, seed, diurnal_amplitude=amplitude,
                         diurnal_period_s=period_s)


def onoff_trace(n: int, rate_qps: float, *, on_s: float = 30.0,
                off_s: float = 120.0, spec: WorkloadSpec | None = None,
                seed: int = 0) -> ArrivalTrace:
    """Square-wave traffic (Poisson bursts separated by silences, same
    mean rate) — the gate/wake-churn adversary for power management."""
    return _shaped_trace(f"onoff@{rate_qps:g}", "onoff", n, rate_qps,
                         spec, seed, onoff_on_s=on_s, onoff_off_s=off_s)


def replay_trace(queries: Sequence[Query], rate_qps: float, *,
                 pattern: str = "poisson", seed: int = 0,
                 name: str = "replay") -> ArrivalTrace:
    """Replay an explicit offline workload (e.g. the 500-query case study)
    under a synthetic arrival process — the offline→online bridge."""
    times = arrival_times(len(queries), rate_qps, pattern=pattern, seed=seed)
    return _build(name, times, queries)


def timestamped_trace(items: Sequence[tuple[float, Query]], *,
                      name: str = "timestamped") -> ArrivalTrace:
    """Wrap pre-timestamped (arrival_s, query) pairs (e.g. from
    repro.data.workloads.timestamped_workload) into a trace."""
    times = [t for t, _ in items]
    queries = [q for _, q in items]
    return _build(name, times, queries)


def session_trace(n_sessions: int, *, turns: int = 4, think_s: float = 20.0,
                  rate_qps: float = 0.2, pattern: str = "poisson",
                  spec: WorkloadSpec | None = None, seed: int = 0,
                  name: str | None = None, **arrival_kw) -> ArrivalTrace:
    """Multi-turn conversational arrivals: `n_sessions` seeded sessions of
    `turns` turns each (shared-prefix growth, Exp(think_s) gaps between a
    session's turns) with session starts shaped by any arrival `pattern`.
    Each TracedRequest carries (session_id, turn, prefix_tokens) so nodes
    with a KV prefix cache can price the warm prefix.  Same seed ⇒ the
    identical stream — replayable like arrival and fault traces."""
    items = session_workload(n_sessions, turns=turns, think_s=think_s,
                             rate_qps=rate_qps, pattern=pattern,
                             spec=spec if spec is not None else WorkloadSpec(),
                             seed=seed, **arrival_kw)
    reqs = tuple(
        TracedRequest(i, float(t), int(q[0]), int(q[1]), session_id=int(sid),
                      turn=int(turn), prefix_tokens=int(prefix))
        for i, (t, q, (sid, turn, prefix)) in enumerate(items))
    return ArrivalTrace(name=name or f"sessions@{rate_qps:g}x{turns}",
                        requests=reqs)
