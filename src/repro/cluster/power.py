"""Node power management: power states, transition costs, autoscalers.

At low arrival rates the fig4 idle columns dominate total cluster energy —
no routing policy can recover joules burned by powered-but-idle replicas.
This module adds the missing lever: nodes can be *gated* (powered down to
a residual draw) and woken back, with configurable transition latency and
energy, under a pluggable autoscaling policy.

Power-state lifecycle (ClusterNode drives it, the event loop times it)::

            enqueue/phase            idle-timer + policy ok
      ACTIVE <────────> IDLE ──────────────────────────> GATING
        ^                ^                                  │ gate_s
        │ wake done      │ wake done (no work)              v
      (work waiting)     WAKING <──────────────────────── GATED
                              arrival routed here / proactive wake

    * ACTIVE  — serving a phase; busy seconds/joules (accelerator idle+
                dynamic plus host serving draw, as before).
    * IDLE    — powered, no work: idle_power_w (accel idle · n + host idle).
    * GATED   — powered down: `PowerConfig.gated_w` residual (BMC, NIC).
    * GATING / WAKING — transitions: `transition_w` draw (defaults to the
                idle power — fans spin, links train) for gate_s / wake_s
                seconds plus the fixed extras gate_j / wake_j.

    Every second of a node's horizon lands in exactly one bucket
    (busy/idle/gated/transition) — gated time is never double-charged as
    idle; `tests/test_power.py` and the perf-suite conservation gate
    assert the partition to 1e-9.

Autoscalers see three moments: `on_idle` (a node just ran out of work —
arm a gate timer?), `should_gate` (the timer fired and the node is still
idle — commit?), and `on_arrival` (wake gated nodes proactively?).  A
request routed to a gated node always triggers an on-demand wake — work
is never stranded, whatever the policy does.

Three built-in policies:

    * ReactiveIdlePolicy   — gate a node once it has sat idle for
      `idle_timeout_s`, keeping at least `min_awake` nodes up (and, with
      `min_awake_per_model`, at least that many awake replicas of every
      hosted model — a fleet-wide floor alone can gate a model's entire
      replica set); wakes are purely on demand (first routed request pays
      the wake latency).
    * PredictiveRatePolicy — estimates the arrival rate over a sliding
      window and the mean service time from observed completions, sizes
      the awake fleet to `rate · service / target_util`, wakes gated
      nodes *ahead* of need on arrivals and gates down below it.  The
      reactive/predictive split is exactly the tradeoff the §6.3-style
      case study needs: reactive saves more joules but pays wake latency
      on the first request of every burst.
    * ReplicaRatePolicy    — the multi-replica refinement: the sizing
      variable is each model's *replica count*, not the node count.
      Per-model demand (completion rate × mean service time, learned
      causally from completions) sizes that model's awake replica set;
      replicas of an under-provisioned model pre-wake on arrivals while
      an over-provisioned model's spares gate down, independently per
      model.
    * SurvivabilityAutoscalePolicy — ReplicaRatePolicy with an
      MTTF-conditioned availability floor: under the steady-state
      unavailability q = MTTR/(MTTF+MTTR) of one fault domain, keeping a
      model awake in d independent domains bounds P[every awake replica
      down at once] by q^d, so d = ceil(ln p_outage_max / ln q) domains
      are required to meet the outage-probability target — demand may
      size the replica set *up* from there, but gating never drops a
      model's awake capacity below d distinct domains.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Sequence

from repro.cluster.faults import domain_groups, domain_index
from repro.cluster.metrics import replica_registry

# power-state tags (kept as plain strings: cheap, printable, json-able)
ACTIVE = "active"
IDLE = "idle"
GATED = "gated"
GATING = "gating"
WAKING = "waking"
# a crashed node (repro.cluster.faults): draws 0 W, serves nothing, and
# rejoins at IDLE on its recovery event.  Not an autoscaler state — a
# failed node is neither awake nor gateable, and `_awake` counting it
# would let the predictive policies size phantom capacity.
FAILED = "failed"


@dataclasses.dataclass(frozen=True)
class PowerConfig:
    """Transition costs and residual draw of a gateable node.

    Defaults are deliberately conservative for an A100-class server:
    ~15 s to bring the node back (power rails + model weights re-resident)
    against a 5 s ramp down, a 10 W gated residual, and transitions drawn
    at the node's idle power unless `transition_w` overrides it."""

    gated_w: float = 10.0          # residual draw while gated (BMC, NIC)
    wake_s: float = 15.0           # gated -> ready latency
    gate_s: float = 5.0            # idle -> gated latency
    wake_j: float = 0.0            # fixed extra energy per wake
    gate_j: float = 0.0            # fixed extra energy per gate-down
    transition_w: float | None = None   # draw during gate/wake (None = idle)

    def __post_init__(self):
        if min(self.gated_w, self.wake_s, self.gate_s,
               self.wake_j, self.gate_j) < 0:
            raise ValueError("PowerConfig fields must be non-negative")


class AutoscalePolicy:
    """Base autoscaler: never gates (the PR 1 always-on fleet)."""

    name = "always_on"
    telemetry = None   # repro.obs.Telemetry, set per-run by simulate_cluster

    def attach(self, nodes: Sequence) -> None:
        self.nodes = list(nodes)

    def on_idle(self, node, now: float) -> float | None:
        """Node just went idle.  Return an absolute time at which to
        re-check it for gating (an idle timer), or None to leave it up."""
        return None

    def should_gate(self, node, now: float) -> bool:
        """The idle timer fired and the node is still idle: commit?"""
        return False

    def on_arrival(self, req, nodes: Sequence, now: float) -> list[int]:
        """Node ids to wake proactively (before the request is routed)."""
        return []

    def on_completion(self, completion, now: float) -> None:
        """Observed a finished request (service-time feedback)."""

    # ------------------------------------------------------------------
    @staticmethod
    def _awake(nodes: Sequence) -> int:
        """Nodes currently up or on their way up (serving capacity that
        does not need a wake)."""
        return sum(1 for n in nodes if n.power_state in (ACTIVE, IDLE, WAKING))

    @staticmethod
    def _replicas(nodes: Sequence) -> dict[str, list]:
        """The shared replica registry (metrics.replica_registry — one
        grouping rule fleet-wide), resolved to live node objects."""
        by_id = {n.node_id: n for n in nodes}
        return {name: [by_id[i] for i in nids]
                for name, nids in replica_registry(nodes).items()}


class ReactiveIdlePolicy(AutoscalePolicy):
    """Gate after `idle_timeout_s` of idleness; wake on demand only.

    `min_awake` floors the fleet; `min_awake_per_model` floors every
    model's awake *replica set* — with replicated models the fleet floor
    alone can concentrate all awake capacity on one model and gate every
    replica of another, which the per-model floor forbids."""

    name = "reactive_idle"

    def __init__(self, idle_timeout_s: float = 30.0, *, min_awake: int = 1,
                 min_awake_per_model: int = 0):
        if idle_timeout_s < 0 or min_awake < 0 or min_awake_per_model < 0:
            raise ValueError("idle_timeout_s, min_awake and "
                             "min_awake_per_model must be >= 0")
        self.idle_timeout_s = idle_timeout_s
        self.min_awake = min_awake
        self.min_awake_per_model = min_awake_per_model

    def attach(self, nodes):
        super().attach(nodes)
        self._model_nodes = self._replicas(self.nodes)

    def on_idle(self, node, now):
        return now + self.idle_timeout_s

    def should_gate(self, node, now):
        if self._awake(self.nodes) <= self.min_awake:
            return False
        peers = self._model_nodes[node.profile.name]
        return self._awake(peers) > self.min_awake_per_model


class PredictiveRatePolicy(AutoscalePolicy):
    """Size the awake fleet from a sliding-window arrival-rate estimate.

    required ≈ ceil(rate · mean_service_s / target_util), clamped to
    [min_awake, fleet].  `mean_service_s` is learned from completions
    (queue-free service time, start→finish); until the first completion a
    `service_prior_s` seeds it.  Wakes happen ahead of routing on the
    arrival that pushes the estimate over capacity; gating goes through
    the same idle timer as the reactive policy but only below the
    requirement."""

    name = "predictive_rate"

    def __init__(self, window_s: float = 60.0, *, target_util: float = 0.6,
                 min_awake: int = 1, idle_timeout_s: float = 10.0,
                 service_prior_s: float = 2.0):
        if window_s <= 0 or not 0 < target_util <= 1:
            raise ValueError("window_s > 0 and target_util in (0, 1] required")
        self.window_s = window_s
        self.target_util = target_util
        self.min_awake = min_awake
        self.idle_timeout_s = idle_timeout_s
        self.service_prior_s = service_prior_s
        self._arrivals: deque[float] = deque()
        self._service_sum = 0.0
        self._service_n = 0

    def attach(self, nodes):
        super().attach(nodes)
        self._arrivals.clear()
        self._service_sum = 0.0
        self._service_n = 0

    # --- estimates ----------------------------------------------------
    def _rate(self, now: float) -> float:
        while self._arrivals and self._arrivals[0] < now - self.window_s:
            self._arrivals.popleft()
        span = min(self.window_s, max(now, 1e-9))
        return len(self._arrivals) / span

    def _service_s(self) -> float:
        if self._service_n == 0:
            return self.service_prior_s
        return self._service_sum / self._service_n

    def required_nodes(self, now: float) -> int:
        demand = self._rate(now) * self._service_s() / self.target_util
        return int(min(len(self.nodes),
                       max(self.min_awake, math.ceil(demand))))

    # --- hooks --------------------------------------------------------
    def on_arrival(self, req, nodes, now):
        self._arrivals.append(now)
        need = self.required_nodes(now)
        awake = self._awake(nodes)
        if awake >= need:
            return []
        gated = [n.node_id for n in nodes if n.power_state == GATED]
        return gated[:need - awake]

    def on_completion(self, completion, now):
        self._service_sum += completion.finish_s - completion.start_s
        self._service_n += 1

    def on_idle(self, node, now):
        return now + self.idle_timeout_s

    def should_gate(self, node, now):
        return self._awake(self.nodes) > self.required_nodes(now)


class ReplicaRatePolicy(AutoscalePolicy):
    """Per-model replica-count autoscaler: each model's awake replica set
    is sized from that model's own demand estimate.

    required_K ≈ ceil(rate_K · mean_service_K / target_util), clamped to
    [min_awake_per_model, |replicas of K|].  Both estimates are causal:
    rate_K counts completions of model K inside a sliding `window_s` (a
    router-agnostic proxy for the model's arrival share — the autoscaler
    sees arrivals *before* routing, so it cannot know their model), and
    mean_service_K averages observed start→finish times, seeded by
    `service_prior_s` until the first completion.  On every arrival the
    under-provisioned models pre-wake gated replicas; gating goes through
    the usual idle timer and commits only while the node's model is above
    its requirement.  This is the ISSUE-5 sizing change: replica counts
    per model, not node counts, are the autoscaling variable."""

    name = "replica_rate"

    def __init__(self, window_s: float = 60.0, *, target_util: float = 0.6,
                 min_awake_per_model: int = 1, idle_timeout_s: float = 10.0,
                 service_prior_s: float = 2.0):
        if window_s <= 0 or not 0 < target_util <= 1:
            raise ValueError("window_s > 0 and target_util in (0, 1] required")
        if min_awake_per_model < 0 or idle_timeout_s < 0:
            raise ValueError("min_awake_per_model and idle_timeout_s "
                             "must be >= 0")
        self.window_s = window_s
        self.target_util = target_util
        self.min_awake_per_model = min_awake_per_model
        self.idle_timeout_s = idle_timeout_s
        self.service_prior_s = service_prior_s

    def attach(self, nodes):
        super().attach(nodes)
        self._model_nodes = self._replicas(self.nodes)
        self._completions: dict[str, deque] = {
            name: deque() for name in self._model_nodes}
        self._service_sum: dict[str, float] = dict.fromkeys(
            self._model_nodes, 0.0)
        self._service_n: dict[str, int] = dict.fromkeys(self._model_nodes, 0)

    # --- per-model estimates ------------------------------------------
    def _rate(self, model: str, now: float) -> float:
        dq = self._completions[model]
        while dq and dq[0] < now - self.window_s:
            dq.popleft()
        span = min(self.window_s, max(now, 1e-9))
        return len(dq) / span

    def _service_s(self, model: str) -> float:
        n = self._service_n[model]
        return (self._service_sum[model] / n) if n else self.service_prior_s

    def required_replicas(self, model: str, now: float) -> int:
        demand = self._rate(model, now) * self._service_s(model) / \
            self.target_util
        return int(min(len(self._model_nodes[model]),
                       max(self.min_awake_per_model, math.ceil(demand))))

    # --- hooks --------------------------------------------------------
    def on_arrival(self, req, nodes, now):
        wake: list[int] = []
        for model, peers in self._model_nodes.items():
            need = self.required_replicas(model, now)
            awake = self._awake(peers)
            if awake >= need:
                continue
            gated = [n.node_id for n in peers if n.power_state == GATED]
            wake.extend(gated[:need - awake])
        return wake

    def on_completion(self, completion, now):
        model = completion.model
        if model not in self._completions:   # unseen model: defensive
            return
        self._completions[model].append(now)
        self._service_sum[model] += completion.finish_s - completion.start_s
        self._service_n[model] += 1

    def on_idle(self, node, now):
        return now + self.idle_timeout_s

    def should_gate(self, node, now):
        model = node.profile.name
        return (self._awake(self._model_nodes[model])
                > self.required_replicas(model, now))


class SurvivabilityAutoscalePolicy(ReplicaRatePolicy):
    """MTTF-conditioned replica autoscaler: demand sizes the awake set
    up, but an availability floor stops gating from shrinking it below
    the outage-probability target.

    Each fault domain (rack/PDU leg; one node per domain when no
    topology is given) is down with steady-state probability
    q = MTTR/(MTTF+MTTR) — the classic alternating-renewal availability
    model `data.workloads.fault_trace` draws from.  Domains fail
    independently, so a model kept awake in d distinct domains is
    entirely dark with probability q^d; meeting
    P[all awake replicas down] <= p_outage_max therefore requires

        d  =  ceil( ln(p_outage_max) / ln(q) )

    awake domains (clamped to [1, domains hosting the model] — a target
    tighter than the fleet can express saturates at every domain).  The
    floor conditions *gating only*: `should_gate` refuses any gate-down
    that would leave the node's model awake in fewer than d distinct
    domains, and `on_arrival` pre-wakes gated replicas — emptiest
    domains first — whenever the floor is violated (e.g. after crashes
    took domains out).  Demand sizing (`required_replicas`) is inherited
    unchanged from ReplicaRatePolicy."""

    name = "survivability_rate"

    def __init__(self, mttf_s: float, mttr_s: float, *,
                 p_outage_max: float = 1e-3, domains=None,
                 window_s: float = 60.0, target_util: float = 0.6,
                 min_awake_per_model: int = 1, idle_timeout_s: float = 10.0,
                 service_prior_s: float = 2.0):
        super().__init__(window_s, target_util=target_util,
                         min_awake_per_model=min_awake_per_model,
                         idle_timeout_s=idle_timeout_s,
                         service_prior_s=service_prior_s)
        if mttf_s <= 0 or mttr_s <= 0:
            raise ValueError("mttf_s and mttr_s must be > 0")
        if not 0.0 < p_outage_max < 1.0:
            raise ValueError("p_outage_max must be in (0, 1)")
        self.mttf_s = mttf_s
        self.mttr_s = mttr_s
        self.p_outage_max = p_outage_max
        self.unavailability = q = mttr_s / (mttf_s + mttr_s)
        self.required_domains = max(
            1, math.ceil(math.log(p_outage_max) / math.log(q)))
        groups = domain_groups(domains)
        self._dom_of = None if groups is None else domain_index(groups)

    def attach(self, nodes):
        super().attach(nodes)
        if self._dom_of is None:   # degenerate: every node its own domain
            self._dom_of = {n.node_id: n.node_id for n in self.nodes}
        missing = [n.node_id for n in self.nodes
                   if n.node_id not in self._dom_of]
        if missing:
            raise ValueError(
                f"nodes {missing} are in no fault domain — the topology "
                f"must cover the fleet")

    def _awake_domains(self, peers, *, excluding=None) -> set:
        return {self._dom_of[n.node_id] for n in peers
                if n is not excluding
                and n.power_state in (ACTIVE, IDLE, WAKING)}

    def required_awake_domains(self, model: str) -> int:
        hosted = {self._dom_of[n.node_id]
                  for n in self._model_nodes[model]}
        return min(self.required_domains, len(hosted))

    # --- hooks --------------------------------------------------------
    def on_arrival(self, req, nodes, now):
        wake = super().on_arrival(req, nodes, now)
        waking = set(wake)
        for model, peers in self._model_nodes.items():
            have = self._awake_domains(peers)
            have |= {self._dom_of[nid] for nid in waking
                     if any(n.node_id == nid for n in peers)}
            deficit = self.required_awake_domains(model) - len(have)
            if deficit <= 0:
                continue
            gated = sorted(
                (n for n in peers if n.power_state == GATED
                 and self._dom_of[n.node_id] not in have
                 and n.node_id not in waking),
                key=lambda n: (self._dom_of[n.node_id], n.node_id))
            picked: set = set()
            for n in gated:
                d = self._dom_of[n.node_id]
                if d in picked:
                    continue   # one wake per dark domain is enough
                wake.append(n.node_id)
                waking.add(n.node_id)
                picked.add(d)
                if len(picked) >= deficit:
                    break
        return wake

    def should_gate(self, node, now):
        if not super().should_gate(node, now):
            return False
        peers = self._model_nodes[node.profile.name]
        remaining = self._awake_domains(peers, excluding=node)
        return len(remaining) >= self.required_awake_domains(
            node.profile.name)
