"""Pluggable online routing policies.

A policy sees each request exactly once, at its arrival instant, and must
pick a node before the next arrival — the online counterpart of the
paper's offline partition.  The common interface:

    policy.attach(nodes, trace, zeta)   # once, before the event loop
    policy.select(req, nodes, now) -> node_id

`attach` may precompute whatever the policy's information model allows:
the load-based policies use nothing; the energy-aware policies use the
fitted LLMProfiles (the paper's offline-knowledge assumption for τout,
citing Zheng et al. for online estimation); the offline oracle uses the
*entire* trace and replays core.scheduler.schedule() — the paper's exact
optimum, serving as the lower bound every online policy is measured
against (the offline→online gap).

ZetaOnlinePolicy implements the paper's "dynamically normalize ... by the
largest known value" rule *causally*: its normalizers grow as requests
stream in, so early routing decisions use stale maxima — a genuine source
of online regret that vanishes as the trace warms up.

τout information models: the energy-aware policies take an optional
``tau_out_predictor`` (repro.cluster.predictors.TauOutPredictor).  Without
one they read the request's true τout — the paper's offline-knowledge
assumption.  With one they price each candidate model at its predicted
quantile, learning only from completions the event loop echoes through
``observe_completion`` — never from the trace — so fig4 can measure the
information gap (oracle-τout vs predicted-τout router) separately from
the commitment gap (oracle-τout router vs the offline replay).

Multi-replica fleets: several nodes may host the same model
(``replica_registry`` maps model → node ids).  ``ReplicaEnergyPolicy`` is
the replica-*set* router — it scores nodes, not models, folding each
replica's pending wake energy (amortized over an expected burst) into the
Eq. 2 argmin, so the fleet's power state shapes the objective instead of
just breaking ties.  ``ReplicaOraclePolicy`` is the replica-aware offline
bound: it replays ``core.scheduler.schedule_replicated`` — the same
model-level optimum as ``OfflineOraclePolicy``, with each model's bin
split into per-replica capacities — so the oracle commits to *node*
placement offline and the fig4 commitment gap stays apples-to-apples on
replicated fleets.

Preemption: ``PreemptionPolicy.consider`` is consulted by the event loop
at every arrival, after routing.  ``SLOPreemptionPolicy`` cuts the routed
node's decode segment — evicting the lowest-ζ-value active member — when
a higher-value arrival would otherwise wait past its slowdown SLO; the
victim suspends at the next decode step boundary (KV intact) and resumes
when a slot frees.
"""

from __future__ import annotations

from collections import deque
from typing import Sequence

import numpy as np

from repro.core.energy_model import LLMProfile, normalized_costs, objective_matrix
from repro.core.scheduler import (
    cached_costs,
    schedule,
    schedule_replicated,
    schedule_with_cache,
    schedule_with_liveness,
)
from repro.core.sweep import IncrementalScheduler

from repro.cluster.faults import (
    NORMAL,
    RECOVER,
    FaultTrace,
    domain_groups,
    domain_index,
)
from repro.cluster.metrics import replica_registry  # noqa: F401  (re-export)
from repro.cluster.predictors import TauOutPredictor
from repro.cluster.trace import ArrivalTrace, TracedRequest


def unique_profiles(nodes: Sequence) -> list[LLMProfile]:
    """Distinct hosted models in node order (replicas collapse)."""
    seen: dict[str, LLMProfile] = {}
    for n in nodes:
        seen.setdefault(n.profile.name, n.profile)
    return list(seen.values())


class RoutingPolicy:
    name = "base"
    telemetry = None   # repro.obs.Telemetry, set per-run by simulate_cluster
    #: What the policy reads off each candidate node at `select` time —
    #: the engine's process-pool runner keeps node state in worker
    #: processes and routes over light node views, so it only admits
    #: policies that declare a view-compatible information model:
    #:   "none"   — static attributes only (ids, hosted model, profile)
    #:   "counts" — also load()/power_rank/accepting (the shipped view)
    #:   "full"   — arbitrary node internals; merge/windowed modes only
    fleet_reads = "full"
    #: Soonest a displaced request can re-enter routing (the first rung
    #: of retry_delay's backoff ladder).  The sharded engine's
    #: conservative lookahead (engine.runner.cross_shard_floor_s) reads
    #: this: no cross-shard retry can land sooner than the floor.
    retry_floor_s = 1.0

    def attach(self, nodes: Sequence, trace: ArrivalTrace, zeta: float) -> None:
        pass

    def select(self, req: TracedRequest, nodes: Sequence, now: float) -> int:
        raise NotImplementedError

    def observe_completion(self, record, now: float) -> None:
        """Causal completion feedback (a metrics.RequestRecord): the only
        channel through which a non-oracle policy learns true τout."""

    # --- rescue hooks (consulted by the event loop on fault runs only) --
    def retry_delay(self, req: TracedRequest, attempts: int,
                    now: float) -> float | None:
        """Backoff before re-routing a request no node would accept:
        capped exponential (1, 2, 4, ... up to 60 s), giving up — return
        None to abandon — after 8 attempts.  Policies override for
        deadline-aware abandonment."""
        if attempts >= 8:
            return None
        return min(float(2 ** attempts), 60.0)

    def allow_rerun(self, req: TracedRequest, now: float) -> bool:
        """Whether a refugee decode with no surviving same-model replica
        may re-run from scratch on a different model (its accrued joules
        are wasted either way).  Default: abandon instead."""
        return False

    def on_fault(self, event, nodes: Sequence, now: float) -> None:
        """Fault-stream notification (a faults.FaultEvent, after the sim
        applied it) — the governance channel for failover policies."""

    def drain_updates(self, nodes: Sequence,
                      now: float) -> list[tuple[int, bool]] | None:
        """Straggler governance, polled at completion boundaries: return
        [(node_id, drain?), ...] to start/stop draining nodes (a draining
        node takes no new routes and ships its parked refugees off; its
        running decodes finish naturally).  Default: never drains."""
        return None

    # ------------------------------------------------------------------
    @staticmethod
    def _least_loaded(candidates: Sequence) -> int:
        # equal load breaks toward the node that can serve soonest
        # (powered < waking < gated < gating < failed); always-on fleets
        # have power_rank 0 everywhere, so the PR 1 ordering is unchanged
        best = min(candidates,
                   key=lambda n: (n.load(), n.power_rank, n.node_id))
        return best.node_id

    @staticmethod
    def _nodes_hosting(nodes: Sequence, model_name: str) -> list:
        hosts = [n for n in nodes if n.profile.name == model_name]
        return hosts or list(nodes)


class RoundRobinPolicy(RoutingPolicy):
    name = "round_robin"
    fleet_reads = "none"

    def __init__(self):
        self._i = 0

    def attach(self, nodes, trace, zeta):
        self._i = 0

    def select(self, req, nodes, now):
        nid = nodes[self._i % len(nodes)].node_id
        self._i += 1
        return nid


class RandomPolicy(RoutingPolicy):
    name = "random"
    fleet_reads = "none"

    def __init__(self, seed: int = 0):
        self.seed = seed
        self._rng = np.random.default_rng(seed)

    def attach(self, nodes, trace, zeta):
        self._rng = np.random.default_rng(self.seed)

    def select(self, req, nodes, now):
        return nodes[int(self._rng.integers(len(nodes)))].node_id


class LeastLoadedPolicy(RoutingPolicy):
    """Join-the-shortest-queue over waiting + in-flight counts."""

    name = "least_loaded"
    fleet_reads = "counts"

    def select(self, req, nodes, now):
        return self._least_loaded(nodes)


class _TauOutMixin:
    """Shared τout information model: oracle (read the trace's true value)
    or a TauOutPredictor fed causally from completions."""

    def _init_predictor(self, tau_out_predictor: TauOutPredictor | None):
        self.predictor = tau_out_predictor
        if tau_out_predictor is not None:
            self.name = f"{self.name}+tau_pred"

    def _reset_predictor(self):
        if self.predictor is not None:
            self.predictor.reset()

    def _tau_for(self, req, model_name: str | None) -> float:
        if self.predictor is None:
            return float(req.tau_out)
        return self.predictor.predict(model_name)

    def observe_completion(self, record, now):
        if self.predictor is not None:
            if self.telemetry is not None:
                # pre-update prediction vs truth: the error the router
                # actually acted on when it placed this request (peek is
                # O(1); None when no arrival priced this model since the
                # last observation, in which case there is no acted-on
                # prediction to score)
                pred = self.predictor.peek(record.model)
                if pred is not None:
                    self.telemetry.on_prediction_error(
                        self.name, record.model, pred, record.tau_out)
            self.predictor.observe(record.model, record.tau_out)


class GreedyEnergyPolicy(_TauOutMixin, RoutingPolicy):
    """Per-request argmin of predicted energy e_K(τin, τout); ties and
    replicas break toward the least-loaded host."""

    name = "greedy_energy"
    fleet_reads = "counts"

    def __init__(self, *, tau_out_predictor: TauOutPredictor | None = None):
        self._init_predictor(tau_out_predictor)

    def attach(self, nodes, trace, zeta):
        self._reset_predictor()

    def select(self, req, nodes, now):
        preds = [float(n.profile.energy(
                     req.tau_in, self._tau_for(req, n.profile.name)))
                 for n in nodes]
        best = min(preds)
        hosts = [n for n, p in zip(nodes, preds) if p <= best * (1 + 1e-12)]
        return self._least_loaded(hosts)


class ZetaOnlinePolicy(_TauOutMixin, RoutingPolicy):
    """Causal Eq. 2: ζ·ê − (1−ζ)·â with *running* normalizers.

    The paper normalizes by the largest energy/accuracy over the whole
    workload before optimizing; online, only requests seen so far are
    known, so the maxima grow as traffic streams in."""

    name = "zeta_online"
    fleet_reads = "counts"

    def __init__(self, zeta: float | None = None, *,
                 tau_out_predictor: TauOutPredictor | None = None):
        self.zeta_override = zeta
        self.zeta = 0.5
        self._e_max = 0.0
        self._a_max = 0.0
        self._init_predictor(tau_out_predictor)

    def attach(self, nodes, trace, zeta):
        self.zeta = self.zeta_override if self.zeta_override is not None else zeta
        self._e_max = 0.0
        self._a_max = 0.0
        self._reset_predictor()

    def _observe(self, req, nodes):
        """Fold a request into the running normalizers (every arrival must
        pass through here, whatever routing rule ends up deciding it).
        Under a predictor the normalizers, like the scores, are built from
        predicted τout — the true value is not observable at routing time."""
        e = np.array([float(n.profile.energy(
                          req.tau_in, self._tau_for(req, n.profile.name)))
                      for n in nodes])
        a = np.array([float(n.profile.accuracy(
                          req.tau_in, self._tau_for(req, n.profile.name)))
                      for n in nodes])
        self._e_max = max(self._e_max, float(e.max()))
        self._a_max = max(self._a_max, float(a.max()))
        return e, a

    def select(self, req, nodes, now):
        e, a = self._observe(req, nodes)
        obj = self.zeta * e / self._e_max - (1.0 - self.zeta) * a / self._a_max
        order = np.argsort(obj, kind="stable")
        best = [nodes[i] for i in order if obj[i] <= obj[order[0]] + 1e-12]
        return self._least_loaded(best)


class ZetaReplanPolicy(ZetaOnlinePolicy):
    """Periodic warm-start re-planner: zeta_online upgraded with the
    γ-capacitated offline partition, maintained incrementally online.

    Keeps a sliding window of the last `window` observed queries inside a
    ``core.sweep.IncrementalScheduler`` and, every `replan_every`
    arrivals, applies the delta (arriving queries in, expired window
    entries out) via ``reschedule`` — an O(delta) warm-start repair of the
    exact capacitated Eq. 2 optimum, not a cold re-solve.  An arrival that
    was part of the latest re-plan is routed to the model its slot got in
    the refreshed partition; arrivals between re-plans (replan_every > 1)
    and the pre-warmup prefix fall back to the causal zeta_online rule.

    `gamma` defaults to the fleet's replica shares, so the plan enforces
    the data-center partition of the paper's §6.3 case study causally —
    something the pointwise-argmin policies cannot express."""

    name = "zeta_replan"

    def __init__(self, zeta: float | None = None, *,
                 gamma: Sequence[float] | None = None,
                 window: int = 512, replan_every: int = 1,
                 min_queries: int = 4,
                 tau_out_predictor: TauOutPredictor | None = None):
        super().__init__(zeta, tau_out_predictor=tau_out_predictor)
        if window < 1 or replan_every < 1:
            raise ValueError("window and replan_every must be >= 1")
        if replan_every > window:
            raise ValueError("replan_every must be <= window (each replan "
                             "folds at most a window's worth of arrivals)")
        self.gamma_arg = None if gamma is None else tuple(gamma)
        self.window = window
        self.replan_every = replan_every
        self.min_queries = min_queries

    def attach(self, nodes, trace, zeta):
        super().attach(nodes, trace, zeta)
        self._profiles = unique_profiles(nodes)
        if self.gamma_arg is not None:
            self._gamma = self.gamma_arg
        else:  # replica shares: each model's fraction of the fleet
            hosts = {p.name: 0 for p in self._profiles}
            for n in nodes:
                hosts[n.profile.name] += 1
            self._gamma = tuple(hosts[p.name] / len(nodes)
                                for p in self._profiles)
        if len(self._gamma) != len(self._profiles):
            raise ValueError("gamma length must match the distinct models")
        self._sched: IncrementalScheduler | None = None
        self._window_ids: deque[int] = deque()
        self._pending: list[tuple[int, int]] = []

    def _replan(self) -> None:
        """Fold pending arrivals in, expired window entries out — one
        warm-start reschedule call for the whole delta."""
        if self._sched is None:
            self._sched = IncrementalScheduler(
                self._profiles, self._pending, self.zeta, self._gamma)
            self._window_ids.extend(range(len(self._pending)))
            if len(self._window_ids) > self.window:  # warmup > window
                expired = [self._window_ids.popleft() for _ in
                           range(len(self._window_ids) - self.window)]
                self._sched.reschedule(removed=expired)
        else:
            first_id = self._sched.next_id
            n_new = len(self._pending)
            expired = []
            while (self._window_ids
                   and len(self._window_ids) + n_new > self.window):
                expired.append(self._window_ids.popleft())
            self._sched.reschedule(added=self._pending, removed=expired)
            self._window_ids.extend(range(first_id, first_id + n_new))
        self._pending = []

    def select(self, req, nodes, now):
        # the plan's query uses the pooled τ̂out under a predictor (the
        # partition is chosen before the serving model is known)
        self._pending.append((req.tau_in,
                              int(round(self._tau_for(req, None)))))
        n_seen = (len(self._pending) if self._sched is None
                  else self._sched.next_id + len(self._pending))
        warmed = n_seen >= max(self.min_queries, len(self._profiles))
        if warmed and (self._sched is None
                       or len(self._pending) >= self.replan_every):
            # normalizers see every arrival: here explicitly, on the
            # fallback path inside super().select
            self._observe(req, nodes)
            self._replan()
            model = self._sched.model_of(self._sched.next_id - 1)
            hosts = self._nodes_hosting(nodes, model)
            return self._least_loaded(hosts)
        # pre-warmup / between re-plans: causal zeta_online fallback
        return super().select(req, nodes, now)


class OfflineOraclePolicy(RoutingPolicy):
    """Replays the paper's offline optimum (core.scheduler.schedule with
    coverage/disjointness only) over the full trace — the upper bound on
    what any online policy can achieve on the Eq. 2 objective."""

    name = "offline_oracle"

    def __init__(self):
        self._model_of: dict[int, str] = {}

    def attach(self, nodes, trace, zeta):
        profiles = unique_profiles(nodes)
        asg = schedule(profiles, trace.queries(), zeta, enforce_nonempty=False)
        self._model_of = {
            r.request_id: asg.model_names[int(k)]
            for r, k in zip(trace.requests, asg.assignee)}

    def select(self, req, nodes, now):
        hosts = self._nodes_hosting(nodes, self._model_of[req.request_id])
        return self._least_loaded(hosts)


class ReplicaEnergyPolicy(ZetaOnlinePolicy):
    """Replica-set router: the causal Eq. 2 argmin taken over *nodes*, with
    each replica's power state priced into the objective instead of only
    breaking ties.

    A gated (or still-gating) replica costs `pending_wake_j` to bring up
    before it can serve; that energy is shared by however many requests
    the wake ends up serving, so the router amortizes it over
    `wake_amortize` expected requests and adds the share to the candidate
    score on the same normalization as the energy term:

        score(node) = ζ·ê/ê_max − (1−ζ)·â/â_max
                      + ζ·(pending_wake_j / wake_amortize)/ê_max

    With every replica awake the wake term vanishes and the policy reduces
    exactly to zeta_online over the replica set; near-ties still break
    least-loaded-first, so replicas of the chosen model share load."""

    name = "replica_energy"

    def __init__(self, zeta: float | None = None, *,
                 wake_amortize: float = 8.0,
                 tau_out_predictor: TauOutPredictor | None = None):
        if wake_amortize <= 0:
            raise ValueError("wake_amortize must be > 0")
        super().__init__(zeta, tau_out_predictor=tau_out_predictor)
        self.wake_amortize = wake_amortize

    def select(self, req, nodes, now):
        e, a = self._observe(req, nodes)
        wake = np.array([n.pending_wake_j for n in nodes])
        obj = (self.zeta * (e + wake / self.wake_amortize) / self._e_max
               - (1.0 - self.zeta) * a / self._a_max)
        order = np.argsort(obj, kind="stable")
        best = [nodes[i] for i in order if obj[i] <= obj[order[0]] + 1e-12]
        return self._least_loaded(best)


class DomainSpreadPolicy(ZetaOnlinePolicy):
    """Survivability-aware router: the causal Eq. 2 argmin with a
    blast-radius anti-affinity term priced into the objective.

    Each node belongs to one fault domain (a rack or PDU leg from
    ``faults.FaultDomain`` / ``rack_pdu_topology``, or an explicit
    partition of node ids).  A correlated outage takes a whole domain at
    once, so the expected work lost to the next outage is proportional
    to how concentrated the fleet's in-flight work is — the router
    therefore charges each candidate the live-load fraction already
    sitting in its domain, on the same normalization as the energy term:

        score(node) = ζ·ê/ê_max − (1−ζ)·â/â_max
                      + ζ·spread_weight·(domain_load / fleet_load)

    With all load in one domain the penalty is maximal there and zero in
    an empty domain; with load perfectly spread the penalty is uniform
    and the policy reduces exactly to zeta_online.  Near-ties break
    toward the *emptiest domain* first (the hard anti-affinity guard:
    replicas of concurrent work land in different domains whenever the
    Eq. 2 scores cannot tell them apart), then least-loaded."""

    name = "domain_spread"

    def __init__(self, domains, zeta: float | None = None, *,
                 spread_weight: float = 0.25,
                 tau_out_predictor: TauOutPredictor | None = None):
        if spread_weight < 0:
            raise ValueError("spread_weight must be >= 0")
        super().__init__(zeta, tau_out_predictor=tau_out_predictor)
        groups = domain_groups(domains)
        if groups is None:
            raise ValueError("DomainSpreadPolicy needs a fault-domain "
                             "topology (FaultDomain or groups of node ids)")
        self._dom_of = domain_index(groups)
        self.n_domains = len(groups)
        self.spread_weight = spread_weight

    def attach(self, nodes, trace, zeta):
        super().attach(nodes, trace, zeta)
        missing = [n.node_id for n in nodes if n.node_id not in self._dom_of]
        if missing:
            raise ValueError(
                f"nodes {missing} are in no fault domain — the topology "
                f"must cover the fleet")

    def _domain_loads(self, nodes) -> dict[int, float]:
        loads: dict[int, float] = {}
        for n in nodes:
            d = self._dom_of[n.node_id]
            loads[d] = loads.get(d, 0.0) + n.load()
        return loads

    def select(self, req, nodes, now):
        e, a = self._observe(req, nodes)
        dom_load = self._domain_loads(nodes)
        fleet = sum(dom_load.values())
        conc = np.array([
            (dom_load[self._dom_of[n.node_id]] / fleet) if fleet else 0.0
            for n in nodes])
        obj = (self.zeta * e / self._e_max
               - (1.0 - self.zeta) * a / self._a_max
               + self.zeta * self.spread_weight * conc)
        order = np.argsort(obj, kind="stable")
        best = [nodes[i] for i in order if obj[i] <= obj[order[0]] + 1e-12]
        pick = min(best, key=lambda n: (dom_load[self._dom_of[n.node_id]],
                                        n.load(), n.power_rank, n.node_id))
        return pick.node_id


class SessionAffinityPolicy(ZetaOnlinePolicy):
    """Session-sticky router: the causal Eq. 2 argmin with a warm-prefix
    discount priced into the objective.

    The policy remembers, per session, the last node it routed that
    session to.  A follow-up turn carrying ``prefix_tokens`` re-used
    context can only hit the KV prefix cache on *that* node (caches are
    per-node and crash-volatile), so the remembered node's energy term is
    discounted by the fraction of the prompt the cache would absorb:

        obj(warm) −= ζ · affinity_weight · (prefix/τin) · ê_warm/ê_max

    The discount is an *estimate* folded into the same normalization the
    base argmin uses — the realized saving is whatever the node's cache
    actually serves (it may have evicted the entry).  First turns, cold
    sessions, and sessionless traffic score identically to zeta_online.
    When the remembered node is absent from the candidate list or not
    immediately serviceable (``power_rank != 0``: waking, gated, gating,
    or failed), the discount is skipped entirely and the policy falls
    back to the plain causal argmin — affinity never routes work into a
    dead or sleeping node."""

    name = "session_affinity"
    fleet_reads = "counts"

    def __init__(self, zeta: float | None = None, *,
                 affinity_weight: float = 0.5,
                 tau_out_predictor: TauOutPredictor | None = None):
        if affinity_weight < 0:
            raise ValueError("affinity_weight must be >= 0")
        super().__init__(zeta, tau_out_predictor=tau_out_predictor)
        self.affinity_weight = affinity_weight

    def attach(self, nodes, trace, zeta):
        super().attach(nodes, trace, zeta)
        self._warm: dict[int, int] = {}

    def select(self, req, nodes, now):
        e, a = self._observe(req, nodes)
        obj = self.zeta * e / self._e_max - (1.0 - self.zeta) * a / self._a_max
        warm_node = (self._warm.get(req.session_id)
                     if req.session_id >= 0 and req.prefix_tokens > 0
                     else None)
        if warm_node is not None:
            frac = min(req.prefix_tokens / max(req.tau_in, 1), 1.0)
            for i, n in enumerate(nodes):
                if n.node_id == warm_node and n.power_rank == 0:
                    obj[i] -= (self.zeta * self.affinity_weight * frac
                               * e[i] / self._e_max)
                    break
        order = np.argsort(obj, kind="stable")
        best = [nodes[i] for i in order if obj[i] <= obj[order[0]] + 1e-12]
        pick = self._least_loaded(best)
        if req.session_id >= 0:
            self._warm[req.session_id] = pick
        return pick


class ReplicaOraclePolicy(OfflineOraclePolicy):
    """Replica-aware offline oracle: replays
    ``core.scheduler.schedule_replicated`` over the full trace, committing
    each request to a *node* (a specific replica), not just a model.

    With the default ``gamma=None`` the model-level assignment is the
    unconstrained Eq. 2 optimum — identical objective to
    ``OfflineOraclePolicy`` — and each model's realized query count is
    split into balanced per-replica capacities, so the oracle bound on the
    objective is preserved while replica placement is priced by the same
    capacitated machinery the γ-constrained case study uses.  Passing
    ``gamma=`` instead prices the paper's data-center partition across
    the replica set."""

    name = "replica_oracle"

    def __init__(self, gamma: Sequence[float] | None = None):
        self.gamma_arg = None if gamma is None else tuple(gamma)
        self._node_of: dict[int, int] = {}

    def attach(self, nodes, trace, zeta):
        profiles = unique_profiles(nodes)
        registry = replica_registry(nodes)
        counts = [len(registry[p.name]) for p in profiles]
        self._node_of = {}
        if not len(trace):
            return
        rasg = schedule_replicated(profiles, trace.queries(), zeta, counts,
                                   gamma=self.gamma_arg)
        # global replica index -> node id, in the same flattening order
        rep_nodes = [nid for p in profiles for nid in registry[p.name]]
        for r, rr in zip(trace.requests, rasg.replica_of):
            self._node_of[r.request_id] = rep_nodes[int(rr)]

    def select(self, req, nodes, now):
        return self._node_of[req.request_id]


class FailoverPolicy(RoutingPolicy):
    """Fault-tolerant wrapper: any routing policy, plus rescue governance.

    Routing delegates to the wrapped `inner` policy (the sim already
    filters the candidate list to accepting nodes on fault runs), and the
    wrapper supplies the fault-run hooks:

      * *retry* — capped exponential backoff (`base_delay_s` doubling to
        `max_delay_s`) when no node accepts, up to `max_retries` attempts;
        deadline-aware: with `abandon_after_s` set, a request whose age
        exceeds it is abandoned instead of retried again.
      * *re-run* — `rerun=True` (default) lets a refugee with no
        surviving same-model replica restart from scratch on another
        model rather than be abandoned.
      * *straggler mitigation* — a causal per-node EWMA of realized
        service stretch ((finish − start) / isolated runtime, fed only by
        the `observe_completion` channel, never by telemetry or the fault
        trace) is compared against the fleet median at every completion;
        a node exceeding `straggle_threshold` × median (after
        `min_observations` samples, and never the last accepting replica
        of its model) is *drained* — it finishes its running work, ships
        parked refugees off, and takes no new routes.  A drained node is
        probed again after `drain_cooldown_s` (its EWMA resets), and a
        `normal`/`recover` fault event un-drains it immediately — the
        drain-before-gate loop of the straggler-governance design."""

    def __init__(self, inner: RoutingPolicy, *,
                 max_retries: int = 8, base_delay_s: float = 1.0,
                 max_delay_s: float = 60.0,
                 abandon_after_s: float | None = None,
                 rerun: bool = True,
                 straggle_threshold: float = 1.75,
                 min_observations: int = 4,
                 drain_cooldown_s: float = 120.0,
                 ewma_alpha: float = 0.3):
        if max_retries < 0 or base_delay_s <= 0 or max_delay_s < base_delay_s:
            raise ValueError("need max_retries >= 0 and "
                             "0 < base_delay_s <= max_delay_s")
        if straggle_threshold <= 1.0:
            raise ValueError("straggle_threshold must be > 1")
        if not 0.0 < ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        self.inner = inner
        self.name = f"failover({inner.name})"
        # routing is delegated, so the wrapper's information model (and
        # hence pool-runner eligibility) is exactly the inner policy's;
        # retry_floor_s mirrors the first backoff rung for the engine's
        # cross-shard lookahead
        self.fleet_reads = inner.fleet_reads
        self.retry_floor_s = base_delay_s
        self.max_retries = max_retries
        self.base_delay_s = base_delay_s
        self.max_delay_s = max_delay_s
        self.abandon_after_s = abandon_after_s
        self.rerun = rerun
        self.straggle_threshold = straggle_threshold
        self.min_observations = min_observations
        self.drain_cooldown_s = drain_cooldown_s
        self.ewma_alpha = ewma_alpha
        self._stretch: dict[int, tuple[int, float]] = {}  # nid -> (n, ewma)
        self._drained: dict[int, float] = {}              # nid -> drained_at
        self._undrain_now: set[int] = set()
        self._telemetry = None

    # simulate_cluster assigns `policy.telemetry` per run; forward it so
    # the wrapped policy's own hooks (e.g. prediction-error gauges) fire
    @property
    def telemetry(self):
        return self._telemetry

    @telemetry.setter
    def telemetry(self, value):
        self._telemetry = value
        self.inner.telemetry = value

    def attach(self, nodes, trace, zeta):
        self.inner.attach(nodes, trace, zeta)
        self._stretch = {}
        self._drained = {}
        self._undrain_now = set()

    def select(self, req, nodes, now):
        return self.inner.select(req, nodes, now)

    def observe_completion(self, record, now):
        self.inner.observe_completion(record, now)
        if record.isolated_runtime_s > 0:
            stretch = ((record.finish_s - record.start_s)
                       / record.isolated_runtime_s)
            n, ew = self._stretch.get(record.node_id, (0, 0.0))
            ew = (stretch if n == 0
                  else (1.0 - self.ewma_alpha) * ew
                  + self.ewma_alpha * stretch)
            self._stretch[record.node_id] = (n + 1, ew)

    def retry_delay(self, req, attempts, now):
        if (self.abandon_after_s is not None
                and now - req.arrival_s >= self.abandon_after_s):
            return None   # deadline-aware abandon: too old to keep trying
        if attempts >= self.max_retries:
            return None
        return min(self.base_delay_s * (2.0 ** attempts), self.max_delay_s)

    def allow_rerun(self, req, now):
        return self.rerun

    def on_fault(self, event, nodes, now):
        if event.kind in (RECOVER, NORMAL):
            # the disruption this node was drained (or suspect) for is
            # over: fresh slate, and un-drain at the next governance poll
            self._stretch.pop(event.node_id, None)
            if event.node_id in self._drained:
                self._undrain_now.add(event.node_id)

    def drain_updates(self, nodes, now):
        updates: list[tuple[int, bool]] = []
        for nid in sorted(self._drained):
            if (nid in self._undrain_now
                    or now - self._drained[nid] >= self.drain_cooldown_s):
                del self._drained[nid]
                self._undrain_now.discard(nid)
                self._stretch.pop(nid, None)   # probe with a fresh EWMA
                updates.append((nid, False))
        seasoned = {nid: ew for nid, (n, ew) in self._stretch.items()
                    if n >= self.min_observations}
        if len(seasoned) >= 2:
            med = float(np.median(list(seasoned.values())))
            if med > 0:
                for node in nodes:
                    nid = node.node_id
                    ew = seasoned.get(nid)
                    if (ew is None or nid in self._drained
                            or not node.accepting):
                        continue
                    if ew > self.straggle_threshold * med:
                        peers = [n for n in nodes
                                 if n.model_name == node.model_name
                                 and n.accepting and n.node_id != nid]
                        if peers:   # never drain the last replica standing
                            self._drained[nid] = now
                            updates.append((nid, True))
        return updates or None


class FailureAwareOraclePolicy(OfflineOraclePolicy):
    """Offline oracle re-solved against the realized fault trace: the
    Eq. 2 per-query argmin restricted to models that remain *reachable*
    on that trace (``core.scheduler.schedule_with_liveness``).

    Liveness notions:

      * ``"ever_after"`` (default) — a model is excluded for a query only
        when every hosting node is down at the query's arrival *and never
        recovers* (``FaultTrace.down_forever_from``).  Any capacity an
        online policy could reach via retry/backoff stays priced in, so
        the oracle objective is a provable lower bound on every online
        policy's realized objective over the same trace — the bound the
        fig4 availability cell asserts.
      * ``"at_arrival"`` — stricter realism: excluded when every host is
        down at the arrival instant (no waiting for recovery).

    ``domains=`` switches the liveness matrix to *domain-masked
    capacity*: instead of a boolean per model, each entry counts the
    distinct fault domains with at least one reachable host — the
    integer-count form ``schedule_with_liveness`` masks at count 0.
    Under correlated faults a domain is the unit that dies, so surviving
    *domains*, not surviving nodes, are the capacity the plan may rely
    on; the masking itself is identical (a model with zero live domains
    has zero live nodes), but the counts are the quantity a
    survivability bound reasons about.

    At serving time the planned model's hosts may all be dead or draining
    (the plan only guards against *permanent* loss): routing then falls
    back over whatever accepts, and `allow_rerun` keeps refugees alive
    across models — the oracle never abandons recoverable work."""

    name = "failure_oracle"

    def __init__(self, faults: FaultTrace, *, liveness: str = "ever_after",
                 domains=None):
        super().__init__()
        if liveness not in ("ever_after", "at_arrival"):
            raise ValueError(f"unknown liveness {liveness!r}")
        self.faults = faults
        self.liveness = liveness
        groups = domain_groups(domains)
        self._dom_of = None if groups is None else domain_index(groups)

    def attach(self, nodes, trace, zeta):
        profiles = unique_profiles(nodes)
        registry = replica_registry(nodes)
        down = (self.faults.is_down if self.liveness == "at_arrival"
                else self.faults.down_forever_from)
        if self._dom_of is None:
            live = np.ones((len(trace), len(profiles)), dtype=bool)
            for i, r in enumerate(trace.requests):
                for j, p in enumerate(profiles):
                    live[i, j] = any(not down(nid, r.arrival_s)
                                     for nid in registry[p.name])
        else:
            dom_of = self._dom_of
            missing = [n.node_id for n in nodes
                       if n.node_id not in dom_of]
            if missing:
                raise ValueError(
                    f"nodes {missing} are in no fault domain — the "
                    f"topology must cover the fleet")
            live = np.zeros((len(trace), len(profiles)), dtype=np.int64)
            for i, r in enumerate(trace.requests):
                for j, p in enumerate(profiles):
                    live[i, j] = len({dom_of[nid]
                                      for nid in registry[p.name]
                                      if not down(nid, r.arrival_s)})
        asg = schedule_with_liveness(profiles, trace.queries(), zeta, live)
        self._model_of = {
            r.request_id: asg.model_names[int(k)]
            for r, k in zip(trace.requests, asg.assignee)}

    def allow_rerun(self, req, now):
        return True


def realized_cache_hits(records) -> dict[int, int]:
    """request_id → realized KV prefix-cache hit (warm tokens served) from
    a completed run's ``ClusterReport.records`` — the hit sequence the
    cache-aware oracle is conditioned on."""
    return {r.request_id: r.cached_tokens
            for r in records if r.cached_tokens > 0}


class CacheAwareOraclePolicy(OfflineOraclePolicy):
    """Offline oracle re-solved against a *realized* prefix-cache hit
    sequence: the Eq. 2 per-query argmin over cost columns discounted by
    each request's warm tokens (``core.scheduler.schedule_with_cache``).

    The hit sequence comes from an already-completed run
    (``realized_cache_hits(report.records)``) — the oracle is conditioned
    on the cache behavior the online fleet actually exhibited, not on a
    hypothetical best-case reuse.  Scoring the online assignment under
    the *same* discounted matrix (``objective_of_assignment`` with
    ``cached=``) makes the bound exact: the oracle's row-wise argmin is
    ≤ any realized column choice, so oracle ≤ online holds per run by
    construction — the inequality the fig4 ``--sessions`` cell asserts."""

    name = "cache_oracle"

    def __init__(self, cached: dict[int, int]):
        super().__init__()
        self.cached = dict(cached)

    def attach(self, nodes, trace, zeta):
        profiles = unique_profiles(nodes)
        if not len(trace):
            self._model_of = {}
            return
        cached_vec = np.array(
            [self.cached.get(r.request_id, 0) for r in trace.requests],
            dtype=np.int64)
        asg = schedule_with_cache(profiles, trace.queries(), zeta, cached_vec)
        self._model_of = {
            r.request_id: asg.model_names[int(k)]
            for r, k in zip(trace.requests, asg.assignee)}


# ---------------------------------------------------------------------------
# Preemption policies (consulted by the event loop at every arrival)
# ---------------------------------------------------------------------------


class PreemptionPolicy:
    """Base preemption policy: sees every arrival (after routing), may ask
    the routed node to cut its running decode segment.  The base class
    never preempts — installing it is behaviorally identical to running
    without a preempter."""

    name = "no_preemption"
    telemetry = None   # repro.obs.Telemetry, set per-run by simulate_cluster

    def attach(self, nodes: Sequence, trace: ArrivalTrace, zeta: float) -> None:
        self.zeta = zeta

    def consider(self, req: TracedRequest, node, nodes: Sequence,
                 now: float) -> int | None:
        """Return the request_id of an active decode member to evict on
        `node` (the node `req` was just routed to), or None."""
        return None

    def observe_completion(self, record, now: float) -> None:
        """Causal completion feedback — same channel the routers get."""


class SLOPreemptionPolicy(_TauOutMixin, PreemptionPolicy):
    """Evict the lowest-ζ-value decode when a higher-value waiting request
    would miss its slowdown SLO.

    The freed slot goes to the *head* of the node's FIFO queue at the
    settle boundary, so that head — not necessarily the arrival that
    triggered the check — is the beneficiary the policy evaluates.
    Trigger: the routed node is mid-decode with a full batch (no slot
    until the segment boundary) and the boundary is further past the
    beneficiary's arrival than its SLO slack,
    `(slowdown_slo − 1) · r̂_iso` (its isolated runtime under the node's
    fitted profile).  Victim: the active member with the worst (highest)
    Eq. 2 per-query score on this node's model, among members with more
    than `min_remaining` decode steps left (a nearly-done decode frees
    its slot soon anyway — cutting it buys nothing).  The eviction only
    fires when the beneficiary's own score beats the victim's by at least
    `margin` — preemption trades the fleet's lowest-value work for
    higher-value work, never sideways.

    Scores use running ζ-normalizers fed by every arrival (the same
    causal normalization rule as zeta_online); `consider` is therefore
    called on every arrival even when no preemption can trigger.

    τout information model: the shared ``_TauOutMixin`` channel the
    routers use — without a `tau_out_predictor` the policy reads true
    output lengths (the paper's offline-knowledge assumption, matching
    the oracle-τout routers); with one, waiting requests are priced at
    the predicted quantile and in-flight victims at max(prediction,
    tokens already generated) — generated tokens are observable, a total
    length is not — learning only from the completions the event loop
    echoes through `observe_completion`."""

    name = "slo_preempt"

    def __init__(self, *, slowdown_slo: float = 3.0, min_remaining: int = 8,
                 margin: float = 0.0,
                 tau_out_predictor: TauOutPredictor | None = None):
        if slowdown_slo < 1.0:
            raise ValueError("slowdown_slo must be >= 1")
        if min_remaining < 0 or margin < 0:
            raise ValueError("min_remaining and margin must be >= 0")
        self.slowdown_slo = slowdown_slo
        self.min_remaining = min_remaining
        self.margin = margin
        self._init_predictor(tau_out_predictor)

    def attach(self, nodes, trace, zeta):
        super().attach(nodes, trace, zeta)
        self._profiles = unique_profiles(nodes)
        self._e_max = 0.0
        self._a_max = 0.0
        self._reset_predictor()

    def _waiting_query(self, req: TracedRequest, model: str):
        """(τin, τ̂out) of a not-yet-served request."""
        return (req.tau_in, self._tau_for(req, model))

    def _victim_query(self, member, model: str):
        """(τin, τ̂out) of an in-flight decode: its generated-token count
        is observed fact, so the estimate never undershoots it."""
        if self.predictor is None:
            return member.req.query
        return (member.req.tau_in,
                max(self.predictor.predict(model), float(member.generated)))

    def _fold(self, query) -> None:
        tin, tout = query
        for p in self._profiles:
            self._e_max = max(self._e_max, float(p.energy(tin, tout)))
            self._a_max = max(self._a_max, float(p.accuracy(tin, tout)))

    def _score(self, profile: LLMProfile, query) -> float:
        tin, tout = query
        return (self.zeta * float(profile.energy(tin, tout)) / self._e_max
                - (1.0 - self.zeta)
                * float(profile.accuracy(tin, tout)) / self._a_max)

    def consider(self, req, node, nodes, now):
        model = node.profile.name
        self._fold(self._waiting_query(req, model))  # every arrival feeds
        if (not node.in_decode or node.preempt_pending
                or len(node.active) < node.max_batch or not node.waiting):
            return None
        # the request the freed slot will actually admit: the FIFO head
        # (req itself when the queue was empty before this arrival)
        beneficiary = node.waiting[0]
        bq = self._waiting_query(beneficiary, model)
        r_iso = float(node.profile.runtime(*bq))
        wait_s = node.phase_end_s - beneficiary.arrival_s
        if wait_s <= (self.slowdown_slo - 1.0) * r_iso:
            return None    # the beneficiary makes its SLO by just queueing
        candidates = [m for m in node.active
                      if m.remaining > self.min_remaining]
        if not candidates:
            return None
        victim = max(
            candidates,
            key=lambda m: (self._score(node.profile,
                                       self._victim_query(m, model)),
                           m.req.request_id))
        if (self._score(node.profile, bq) + self.margin
                >= self._score(node.profile,
                               self._victim_query(victim, model))):
            return None    # the beneficiary is not worth more than the work
        return victim.req.request_id


DEFAULT_POLICIES = (
    RoundRobinPolicy,
    RandomPolicy,
    LeastLoadedPolicy,
    GreedyEnergyPolicy,
    ZetaOnlinePolicy,
)


def objective_of_assignment(
    profiles: Sequence[LLMProfile],
    queries: Sequence[tuple[int, int]],
    model_names: Sequence[str],
    zeta: float,
    *,
    cached: Sequence[int] | np.ndarray | None = None,
) -> float:
    """Eq. 2 value of an arbitrary (online) assignment, on the same
    normalization the offline scheduler uses — the yardstick for the
    offline→online gap.

    With ``cached=`` (a realized per-query warm-token sequence) the
    assignment is scored under the cache-discounted cost matrix
    (``core.scheduler.cached_costs``) — the same matrix the cache-aware
    oracle minimizes over, which is what makes oracle ≤ online exact."""
    if cached is None:
        costs = normalized_costs(profiles, queries)
    else:
        costs = cached_costs(profiles, queries, np.asarray(cached))
    C = objective_matrix(costs, zeta)
    col = {name: j for j, name in enumerate(costs.model_names)}
    idx = np.array([col[m] for m in model_names], dtype=int)
    return float(C[np.arange(len(queries)), idx].sum())
