"""Pluggable online routing policies.

A policy sees each request exactly once, at its arrival instant, and must
pick a node before the next arrival — the online counterpart of the
paper's offline partition.  The common interface:

    policy.attach(nodes, trace, zeta)   # once, before the event loop
    policy.select(req, nodes, now) -> node_id

`attach` may precompute whatever the policy's information model allows:
the load-based policies use nothing; the energy-aware policies use the
fitted LLMProfiles (the paper's offline-knowledge assumption for τout,
citing Zheng et al. for online estimation); the offline oracle uses the
*entire* trace and replays core.scheduler.schedule() — the paper's exact
optimum, serving as the lower bound every online policy is measured
against (the offline→online gap).

ZetaOnlinePolicy implements the paper's "dynamically normalize ... by the
largest known value" rule *causally*: its normalizers grow as requests
stream in, so early routing decisions use stale maxima — a genuine source
of online regret that vanishes as the trace warms up.

τout information models: the energy-aware policies take an optional
``tau_out_predictor`` (repro.cluster.predictors.TauOutPredictor).  Without
one they read the request's true τout — the paper's offline-knowledge
assumption.  With one they price each candidate model at its predicted
quantile, learning only from completions the event loop echoes through
``observe_completion`` — never from the trace — so fig4 can measure the
information gap (oracle-τout vs predicted-τout router) separately from
the commitment gap (oracle-τout router vs the offline replay).
"""

from __future__ import annotations

from collections import deque
from typing import Sequence

import numpy as np

from repro.core.energy_model import LLMProfile, normalized_costs, objective_matrix
from repro.core.scheduler import schedule
from repro.core.sweep import IncrementalScheduler

from repro.cluster.predictors import TauOutPredictor
from repro.cluster.trace import ArrivalTrace, TracedRequest


def unique_profiles(nodes: Sequence) -> list[LLMProfile]:
    """Distinct hosted models in node order (replicas collapse)."""
    seen: dict[str, LLMProfile] = {}
    for n in nodes:
        seen.setdefault(n.profile.name, n.profile)
    return list(seen.values())


class RoutingPolicy:
    name = "base"

    def attach(self, nodes: Sequence, trace: ArrivalTrace, zeta: float) -> None:
        pass

    def select(self, req: TracedRequest, nodes: Sequence, now: float) -> int:
        raise NotImplementedError

    def observe_completion(self, record, now: float) -> None:
        """Causal completion feedback (a metrics.RequestRecord): the only
        channel through which a non-oracle policy learns true τout."""

    # ------------------------------------------------------------------
    @staticmethod
    def _least_loaded(candidates: Sequence) -> int:
        # equal load breaks toward the node that can serve soonest
        # (powered < waking < gated < gating); always-on fleets have
        # power_rank 0 everywhere, so the PR 1 ordering is unchanged
        best = min(candidates,
                   key=lambda n: (n.load(), getattr(n, "power_rank", 0),
                                  n.node_id))
        return best.node_id

    @staticmethod
    def _nodes_hosting(nodes: Sequence, model_name: str) -> list:
        hosts = [n for n in nodes if n.profile.name == model_name]
        return hosts or list(nodes)


class RoundRobinPolicy(RoutingPolicy):
    name = "round_robin"

    def __init__(self):
        self._i = 0

    def attach(self, nodes, trace, zeta):
        self._i = 0

    def select(self, req, nodes, now):
        nid = nodes[self._i % len(nodes)].node_id
        self._i += 1
        return nid


class RandomPolicy(RoutingPolicy):
    name = "random"

    def __init__(self, seed: int = 0):
        self.seed = seed
        self._rng = np.random.default_rng(seed)

    def attach(self, nodes, trace, zeta):
        self._rng = np.random.default_rng(self.seed)

    def select(self, req, nodes, now):
        return nodes[int(self._rng.integers(len(nodes)))].node_id


class LeastLoadedPolicy(RoutingPolicy):
    """Join-the-shortest-queue over waiting + in-flight counts."""

    name = "least_loaded"

    def select(self, req, nodes, now):
        return self._least_loaded(nodes)


class _TauOutMixin:
    """Shared τout information model: oracle (read the trace's true value)
    or a TauOutPredictor fed causally from completions."""

    def _init_predictor(self, tau_out_predictor: TauOutPredictor | None):
        self.predictor = tau_out_predictor
        if tau_out_predictor is not None:
            self.name = f"{self.name}+tau_pred"

    def _reset_predictor(self):
        if self.predictor is not None:
            self.predictor.reset()

    def _tau_for(self, req, model_name: str | None) -> float:
        if self.predictor is None:
            return float(req.tau_out)
        return self.predictor.predict(model_name)

    def observe_completion(self, record, now):
        if self.predictor is not None:
            self.predictor.observe(record.model, record.tau_out)


class GreedyEnergyPolicy(_TauOutMixin, RoutingPolicy):
    """Per-request argmin of predicted energy e_K(τin, τout); ties and
    replicas break toward the least-loaded host."""

    name = "greedy_energy"

    def __init__(self, *, tau_out_predictor: TauOutPredictor | None = None):
        self._init_predictor(tau_out_predictor)

    def attach(self, nodes, trace, zeta):
        self._reset_predictor()

    def select(self, req, nodes, now):
        preds = [float(n.profile.energy(
                     req.tau_in, self._tau_for(req, n.profile.name)))
                 for n in nodes]
        best = min(preds)
        hosts = [n for n, p in zip(nodes, preds) if p <= best * (1 + 1e-12)]
        return self._least_loaded(hosts)


class ZetaOnlinePolicy(_TauOutMixin, RoutingPolicy):
    """Causal Eq. 2: ζ·ê − (1−ζ)·â with *running* normalizers.

    The paper normalizes by the largest energy/accuracy over the whole
    workload before optimizing; online, only requests seen so far are
    known, so the maxima grow as traffic streams in."""

    name = "zeta_online"

    def __init__(self, zeta: float | None = None, *,
                 tau_out_predictor: TauOutPredictor | None = None):
        self.zeta_override = zeta
        self.zeta = 0.5
        self._e_max = 0.0
        self._a_max = 0.0
        self._init_predictor(tau_out_predictor)

    def attach(self, nodes, trace, zeta):
        self.zeta = self.zeta_override if self.zeta_override is not None else zeta
        self._e_max = 0.0
        self._a_max = 0.0
        self._reset_predictor()

    def _observe(self, req, nodes):
        """Fold a request into the running normalizers (every arrival must
        pass through here, whatever routing rule ends up deciding it).
        Under a predictor the normalizers, like the scores, are built from
        predicted τout — the true value is not observable at routing time."""
        e = np.array([float(n.profile.energy(
                          req.tau_in, self._tau_for(req, n.profile.name)))
                      for n in nodes])
        a = np.array([float(n.profile.accuracy(
                          req.tau_in, self._tau_for(req, n.profile.name)))
                      for n in nodes])
        self._e_max = max(self._e_max, float(e.max()))
        self._a_max = max(self._a_max, float(a.max()))
        return e, a

    def select(self, req, nodes, now):
        e, a = self._observe(req, nodes)
        obj = self.zeta * e / self._e_max - (1.0 - self.zeta) * a / self._a_max
        order = np.argsort(obj, kind="stable")
        best = [nodes[i] for i in order if obj[i] <= obj[order[0]] + 1e-12]
        return self._least_loaded(best)


class ZetaReplanPolicy(ZetaOnlinePolicy):
    """Periodic warm-start re-planner: zeta_online upgraded with the
    γ-capacitated offline partition, maintained incrementally online.

    Keeps a sliding window of the last `window` observed queries inside a
    ``core.sweep.IncrementalScheduler`` and, every `replan_every`
    arrivals, applies the delta (arriving queries in, expired window
    entries out) via ``reschedule`` — an O(delta) warm-start repair of the
    exact capacitated Eq. 2 optimum, not a cold re-solve.  An arrival that
    was part of the latest re-plan is routed to the model its slot got in
    the refreshed partition; arrivals between re-plans (replan_every > 1)
    and the pre-warmup prefix fall back to the causal zeta_online rule.

    `gamma` defaults to the fleet's replica shares, so the plan enforces
    the data-center partition of the paper's §6.3 case study causally —
    something the pointwise-argmin policies cannot express."""

    name = "zeta_replan"

    def __init__(self, zeta: float | None = None, *,
                 gamma: Sequence[float] | None = None,
                 window: int = 512, replan_every: int = 1,
                 min_queries: int = 4,
                 tau_out_predictor: TauOutPredictor | None = None):
        super().__init__(zeta, tau_out_predictor=tau_out_predictor)
        if window < 1 or replan_every < 1:
            raise ValueError("window and replan_every must be >= 1")
        if replan_every > window:
            raise ValueError("replan_every must be <= window (each replan "
                             "folds at most a window's worth of arrivals)")
        self.gamma_arg = None if gamma is None else tuple(gamma)
        self.window = window
        self.replan_every = replan_every
        self.min_queries = min_queries

    def attach(self, nodes, trace, zeta):
        super().attach(nodes, trace, zeta)
        self._profiles = unique_profiles(nodes)
        if self.gamma_arg is not None:
            self._gamma = self.gamma_arg
        else:  # replica shares: each model's fraction of the fleet
            hosts = {p.name: 0 for p in self._profiles}
            for n in nodes:
                hosts[n.profile.name] += 1
            self._gamma = tuple(hosts[p.name] / len(nodes)
                                for p in self._profiles)
        if len(self._gamma) != len(self._profiles):
            raise ValueError("gamma length must match the distinct models")
        self._sched: IncrementalScheduler | None = None
        self._window_ids: deque[int] = deque()
        self._pending: list[tuple[int, int]] = []

    def _replan(self) -> None:
        """Fold pending arrivals in, expired window entries out — one
        warm-start reschedule call for the whole delta."""
        if self._sched is None:
            self._sched = IncrementalScheduler(
                self._profiles, self._pending, self.zeta, self._gamma)
            self._window_ids.extend(range(len(self._pending)))
            if len(self._window_ids) > self.window:  # warmup > window
                expired = [self._window_ids.popleft() for _ in
                           range(len(self._window_ids) - self.window)]
                self._sched.reschedule(removed=expired)
        else:
            first_id = self._sched.next_id
            n_new = len(self._pending)
            expired = []
            while (self._window_ids
                   and len(self._window_ids) + n_new > self.window):
                expired.append(self._window_ids.popleft())
            self._sched.reschedule(added=self._pending, removed=expired)
            self._window_ids.extend(range(first_id, first_id + n_new))
        self._pending = []

    def select(self, req, nodes, now):
        # the plan's query uses the pooled τ̂out under a predictor (the
        # partition is chosen before the serving model is known)
        self._pending.append((req.tau_in,
                              int(round(self._tau_for(req, None)))))
        n_seen = (len(self._pending) if self._sched is None
                  else self._sched.next_id + len(self._pending))
        warmed = n_seen >= max(self.min_queries, len(self._profiles))
        if warmed and (self._sched is None
                       or len(self._pending) >= self.replan_every):
            # normalizers see every arrival: here explicitly, on the
            # fallback path inside super().select
            self._observe(req, nodes)
            self._replan()
            model = self._sched.model_of(self._sched.next_id - 1)
            hosts = self._nodes_hosting(nodes, model)
            return self._least_loaded(hosts)
        # pre-warmup / between re-plans: causal zeta_online fallback
        return super().select(req, nodes, now)


class OfflineOraclePolicy(RoutingPolicy):
    """Replays the paper's offline optimum (core.scheduler.schedule with
    coverage/disjointness only) over the full trace — the upper bound on
    what any online policy can achieve on the Eq. 2 objective."""

    name = "offline_oracle"

    def __init__(self):
        self._model_of: dict[int, str] = {}

    def attach(self, nodes, trace, zeta):
        profiles = unique_profiles(nodes)
        asg = schedule(profiles, trace.queries(), zeta, enforce_nonempty=False)
        self._model_of = {
            r.request_id: asg.model_names[int(k)]
            for r, k in zip(trace.requests, asg.assignee)}

    def select(self, req, nodes, now):
        hosts = self._nodes_hosting(nodes, self._model_of[req.request_id])
        return self._least_loaded(hosts)


DEFAULT_POLICIES = (
    RoundRobinPolicy,
    RandomPolicy,
    LeastLoadedPolicy,
    GreedyEnergyPolicy,
    ZetaOnlinePolicy,
)


def objective_of_assignment(
    profiles: Sequence[LLMProfile],
    queries: Sequence[tuple[int, int]],
    model_names: Sequence[str],
    zeta: float,
) -> float:
    """Eq. 2 value of an arbitrary (online) assignment, on the same
    normalization the offline scheduler uses — the yardstick for the
    offline→online gap."""
    costs = normalized_costs(profiles, queries)
    C = objective_matrix(costs, zeta)
    col = {name: j for j, name in enumerate(costs.model_names)}
    idx = np.array([col[m] for m in model_names], dtype=int)
    return float(C[np.arange(len(queries)), idx].sum())
