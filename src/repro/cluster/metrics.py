"""Aggregate metrics for a cluster run.

Energy accounting is split into *busy* energy (accelerator dynamic+idle
during phases plus the host serving draw — exactly what the per-request
AnalyticLLMSimulator would report) and *idle* energy (node idle power over
the gaps), so the conservation invariant against the offline simulator can
be stated on busy energy alone while fleet-level J/token still includes
the cost of keeping under-utilized replicas powered.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class RequestRecord:
    request_id: int
    node_id: int
    model: str
    tau_in: int
    tau_out: int
    arrival_s: float
    start_s: float
    finish_s: float
    energy_j: float             # attributed busy-energy share
    isolated_runtime_s: float   # uncontended batch-1 service time

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s

    @property
    def queue_s(self) -> float:
        return self.start_s - self.arrival_s

    @property
    def slowdown(self) -> float:
        if self.isolated_runtime_s <= 0:
            return 1.0
        return self.latency_s / self.isolated_runtime_s


@dataclasses.dataclass(frozen=True)
class NodeStats:
    node_id: int
    model: str
    n_served: int
    busy_s: float
    busy_energy_j: float
    idle_energy_j: float
    utilization: float          # busy_s / makespan


@dataclasses.dataclass(frozen=True)
class ClusterReport:
    policy: str
    zeta: float
    records: tuple[RequestRecord, ...]
    node_stats: tuple[NodeStats, ...]
    makespan_s: float
    objective: float            # Eq. 2 value of the realized assignment
    predicted_energy_j: float   # Σ e_K(q) under the fitted profiles

    # --- totals -----------------------------------------------------------
    @property
    def total_busy_energy_j(self) -> float:
        return sum(s.busy_energy_j for s in self.node_stats)

    @property
    def total_idle_energy_j(self) -> float:
        return sum(s.idle_energy_j for s in self.node_stats)

    @property
    def total_energy_j(self) -> float:
        return self.total_busy_energy_j + self.total_idle_energy_j

    @property
    def total_tokens(self) -> int:
        return sum(r.tau_in + r.tau_out for r in self.records)

    @property
    def j_per_token(self) -> float:
        tok = self.total_tokens
        return self.total_energy_j / tok if tok else 0.0

    # --- latency ----------------------------------------------------------
    def latency_percentile(self, q: float) -> float:
        lat = [r.latency_s for r in self.records]
        return float(np.percentile(lat, q)) if lat else 0.0

    @property
    def latency_p50(self) -> float:
        return self.latency_percentile(50)

    @property
    def latency_p95(self) -> float:
        return self.latency_percentile(95)

    @property
    def latency_p99(self) -> float:
        return self.latency_percentile(99)

    @property
    def mean_latency_s(self) -> float:
        lat = [r.latency_s for r in self.records]
        return float(np.mean(lat)) if lat else 0.0

    def slo_attainment(self, *, slo_s: float | None = None,
                       slowdown: float = 3.0) -> float:
        """Fraction of requests meeting the SLO: an absolute deadline if
        slo_s is given, else latency ≤ slowdown × isolated runtime."""
        if not self.records:
            return 1.0
        if slo_s is not None:
            ok = sum(r.latency_s <= slo_s for r in self.records)
        else:
            ok = sum(r.slowdown <= slowdown for r in self.records)
        return ok / len(self.records)

    # --- display ----------------------------------------------------------
    def summary(self) -> str:
        return (f"{self.policy:>15s}: E={self.total_energy_j:12.0f}J "
                f"(busy={self.total_busy_energy_j:.0f} idle={self.total_idle_energy_j:.0f}) "
                f"pred={self.predicted_energy_j:.0f}J obj={self.objective:+.3f} "
                f"J/tok={self.j_per_token:7.2f} "
                f"p50={self.latency_p50:6.2f}s p95={self.latency_p95:6.2f}s "
                f"p99={self.latency_p99:6.2f}s "
                f"slo={self.slo_attainment():5.1%} "
                f"util={[round(s.utilization, 2) for s in self.node_stats]}")


def per_node_stats(nodes: Sequence, makespan_s: float) -> tuple[NodeStats, ...]:
    out = []
    for n in nodes:
        idle_s = max(0.0, makespan_s - n.busy_s)
        out.append(NodeStats(
            node_id=n.node_id,
            model=n.model_name,
            n_served=n.n_served,
            busy_s=n.busy_s,
            busy_energy_j=n.busy_energy_j,
            idle_energy_j=idle_s * n.idle_power_w,
            utilization=(n.busy_s / makespan_s) if makespan_s > 0 else 0.0,
        ))
    return tuple(out)
