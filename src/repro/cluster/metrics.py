"""Aggregate metrics for a cluster run.

Energy accounting is split into eight buckets per node:

  * *busy*       — accelerator dynamic+idle during phases plus the host
                   serving draw (exactly what the per-request
                   AnalyticLLMSimulator would report);
  * *idle*       — node idle power over powered-but-workless seconds;
  * *gated*      — the residual draw while powered down;
  * *transition* — gate/wake ramps (latency at transition power plus any
                   fixed per-transition joules);
  * *shipping*   — cross-node KV migration: bytes over the interconnect
                   at J/byte, on the recipient's meter (faulted runs only);
  * *checkpoint* — durable prefill-KV persistence (node.CheckpointConfig):
                   new-prefix bytes at j_per_byte_ckpt, charged at each
                   interval boundary (checkpointed runs only);
  * *cache_read* — KV prefix-cache hits (node.PrefixCacheConfig): the
                   warm prefix streamed back at j_per_byte_read
                   (session runs with a cache only);
  * *wasted*     — work lost to un-rescuable crashes, *moved* out of busy
                   (never double-counted) so re-run joules are visible.

The time buckets (busy/idle/gated/transition/failed — a crashed node
draws 0 W, so FAILED seconds carry no energy bucket; shipping,
checkpoint and cache_read are background NIC/storage DMA concurrent with
serving and stay outside the horizon partition) partition each node's
horizon exactly — one second lands in exactly one bucket, so gated time
is never double-charged as idle — and the sum of the eight energy buckets IS the
total energy (the conservation invariant gated in the perf suite at
1e-9).  The busy bucket alone carries the conservation invariant against
the offline simulator, while fleet-level J/token still includes the cost
of keeping under-utilized replicas powered (or the savings from gating
them)."""

from __future__ import annotations

import dataclasses
import functools
import json
from typing import Sequence

import numpy as np


def replica_registry(nodes: Sequence) -> dict[str, tuple[int, ...]]:
    """Per-model replica registry: model name → node ids hosting it, in
    node order (the order `core.scheduler.schedule_replicated` flattens
    replicas in).  The single grouping rule the routers, the replica
    oracle, the autoscalers, and the sim loop all size against."""
    reg: dict[str, list[int]] = {}
    for n in nodes:
        reg.setdefault(n.profile.name, []).append(n.node_id)
    return {name: tuple(nids) for name, nids in reg.items()}


@dataclasses.dataclass(frozen=True)
class RequestRecord:
    request_id: int
    node_id: int
    model: str
    tau_in: int
    tau_out: int
    arrival_s: float
    start_s: float
    finish_s: float
    energy_j: float             # attributed busy-energy share
    isolated_runtime_s: float   # uncontended batch-1 service time
    preemptions: int = 0        # suspend/resume round-trips en route
    migrations: int = 0         # cross-node KV shipments en route
    shipped_bytes: float = 0.0  # KV bytes moved across the interconnect
    cached_tokens: int = 0      # τin tokens served from the KV prefix cache

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s

    @property
    def queue_s(self) -> float:
        return self.start_s - self.arrival_s

    @property
    def slowdown(self) -> float:
        if self.isolated_runtime_s <= 0:
            return 1.0
        return self.latency_s / self.isolated_runtime_s


@dataclasses.dataclass(frozen=True)
class AbandonedRecord:
    """A request the fleet gave up on (faulted runs only): the retry
    budget ran out, the deadline passed, or a crash stranded its decode
    with no surviving replica.  Any joules it had already accrued were
    moved to the wasted bucket (`wasted_j` here), so conservation still
    closes over completed + abandoned work."""

    request_id: int
    model: str                  # last host's model ("" if never served)
    tau_in: int
    tau_out: int
    arrival_s: float
    abandoned_s: float          # when the fleet gave up
    reason: str                 # "no_capacity" | "deadline" | "no_survivor"
    attempts: int = 0           # routing attempts before giving up
    wasted_j: float = 0.0       # accrued joules moved to the wasted bucket


@dataclasses.dataclass(frozen=True)
class NodeStats:
    node_id: int
    model: str
    n_served: int
    busy_s: float
    busy_energy_j: float
    idle_energy_j: float
    utilization: float          # busy_s / makespan
    # --- power-management buckets (all zero for an always-on node) ----
    idle_s: float = 0.0
    gated_s: float = 0.0
    gated_energy_j: float = 0.0
    transition_s: float = 0.0
    transition_energy_j: float = 0.0
    horizon_s: float = 0.0      # busy+idle+gated+transition == horizon
    n_wakes: int = 0
    n_gates: int = 0
    # --- preemption counters (zero when no preempter is installed) ----
    n_preemptions: int = 0
    n_resumes: int = 0
    # --- fault buckets/counters (zero when no faults are injected) ----
    failed_s: float = 0.0           # crashed: 0 W, partitions the horizon
    shipping_s: float = 0.0         # background NIC DMA (outside horizon)
    shipping_energy_j: float = 0.0  # inbound KV migration joules
    wasted_energy_j: float = 0.0    # lost work, moved out of busy
    n_crashes: int = 0
    n_recoveries: int = 0
    n_migrations_in: int = 0
    n_migrations_out: int = 0
    # --- checkpoint bucket/counters (zero without a CheckpointConfig) --
    checkpoint_s: float = 0.0        # background storage DMA (outside horizon)
    checkpoint_energy_j: float = 0.0  # durable prefill-KV persistence joules
    n_checkpoints: int = 0
    n_restores: int = 0
    # --- prefix-cache bucket/counters (zero without a PrefixCacheConfig)
    cache_read_s: float = 0.0        # background cache DMA (outside horizon)
    cache_read_energy_j: float = 0.0  # warm-prefix read-back joules
    n_cache_hits: int = 0
    n_cache_misses: int = 0
    n_cache_evictions: int = 0
    cache_hit_tokens: int = 0        # Σ reused prefix tokens (reuse depth)

    @property
    def total_energy_j(self) -> float:
        return (self.busy_energy_j + self.idle_energy_j
                + self.gated_energy_j + self.transition_energy_j
                + self.shipping_energy_j + self.checkpoint_energy_j
                + self.cache_read_energy_j + self.wasted_energy_j)

    @property
    def accounted_s(self) -> float:
        return (self.busy_s + self.idle_s + self.gated_s
                + self.transition_s + self.failed_s)


@dataclasses.dataclass(frozen=True)
class ClusterReport:
    policy: str
    zeta: float
    records: tuple[RequestRecord, ...]
    node_stats: tuple[NodeStats, ...]
    makespan_s: float
    objective: float            # Eq. 2 value of the realized assignment
    predicted_energy_j: float   # Σ e_K(q) under the fitted profiles
    # model name -> node ids hosting a replica (the sim's replica registry)
    replicas: tuple[tuple[str, tuple[int, ...]], ...] = ()
    # requests the fleet gave up on (faulted runs only; empty otherwise)
    abandoned: tuple[AbandonedRecord, ...] = ()

    # --- totals -----------------------------------------------------------
    @property
    def total_busy_energy_j(self) -> float:
        return sum(s.busy_energy_j for s in self.node_stats)

    @property
    def total_idle_energy_j(self) -> float:
        return sum(s.idle_energy_j for s in self.node_stats)

    @property
    def total_gated_energy_j(self) -> float:
        return sum(s.gated_energy_j for s in self.node_stats)

    @property
    def total_transition_energy_j(self) -> float:
        return sum(s.transition_energy_j for s in self.node_stats)

    @property
    def total_shipping_energy_j(self) -> float:
        return sum(s.shipping_energy_j for s in self.node_stats)

    @property
    def total_wasted_energy_j(self) -> float:
        return sum(s.wasted_energy_j for s in self.node_stats)

    @property
    def total_checkpoint_energy_j(self) -> float:
        return sum(s.checkpoint_energy_j for s in self.node_stats)

    @property
    def total_cache_read_energy_j(self) -> float:
        return sum(s.cache_read_energy_j for s in self.node_stats)

    @property
    def total_energy_j(self) -> float:
        return (self.total_busy_energy_j + self.total_idle_energy_j
                + self.total_gated_energy_j + self.total_transition_energy_j
                + self.total_shipping_energy_j
                + self.total_checkpoint_energy_j
                + self.total_cache_read_energy_j
                + self.total_wasted_energy_j)

    @property
    def total_wakes(self) -> int:
        return sum(s.n_wakes for s in self.node_stats)

    @property
    def total_gates(self) -> int:
        return sum(s.n_gates for s in self.node_stats)

    @property
    def total_preemptions(self) -> int:
        return sum(s.n_preemptions for s in self.node_stats)

    @property
    def total_resumes(self) -> int:
        return sum(s.n_resumes for s in self.node_stats)

    @property
    def total_crashes(self) -> int:
        return sum(s.n_crashes for s in self.node_stats)

    @property
    def total_migrations(self) -> int:
        return sum(s.n_migrations_in for s in self.node_stats)

    @property
    def total_checkpoints(self) -> int:
        return sum(s.n_checkpoints for s in self.node_stats)

    @property
    def total_restores(self) -> int:
        return sum(s.n_restores for s in self.node_stats)

    @property
    def total_cache_hits(self) -> int:
        return sum(s.n_cache_hits for s in self.node_stats)

    @property
    def total_cache_misses(self) -> int:
        return sum(s.n_cache_misses for s in self.node_stats)

    @property
    def total_cache_evictions(self) -> int:
        return sum(s.n_cache_evictions for s in self.node_stats)

    @property
    def total_cache_hit_tokens(self) -> int:
        return sum(s.cache_hit_tokens for s in self.node_stats)

    @property
    def cache_hit_rate(self) -> float:
        """Hits over session-request admissions (non-session requests
        never consult the cache and don't count)."""
        n = self.total_cache_hits + self.total_cache_misses
        return self.total_cache_hits / n if n else 0.0

    def replica_counts(self) -> dict[str, int]:
        """Replicas hosted per model (from the sim's replica registry)."""
        return {name: len(nids) for name, nids in self.replicas}

    @property
    def total_tokens(self) -> int:
        return sum(r.tau_in + r.tau_out for r in self.records)

    @property
    def j_per_token(self) -> float:
        tok = self.total_tokens
        return self.total_energy_j / tok if tok else 0.0

    def energy_breakdown(self) -> dict[str, float]:
        """The eight-bucket split (joules) — sums to total_energy_j."""
        return {
            "busy": self.total_busy_energy_j,
            "idle": self.total_idle_energy_j,
            "gated": self.total_gated_energy_j,
            "transition": self.total_transition_energy_j,
            "shipping": self.total_shipping_energy_j,
            "checkpoint": self.total_checkpoint_energy_j,
            "cache_read": self.total_cache_read_energy_j,
            "wasted": self.total_wasted_energy_j,
        }

    # --- latency ----------------------------------------------------------
    # The latency/slowdown vectors are materialized once per report
    # (cached_property writes the instance __dict__ directly, so it works
    # on a frozen dataclass); every percentile/SLO query reads the array
    # instead of rebuilding a Python list per call.
    @functools.cached_property
    def _latencies(self) -> np.ndarray:
        return np.array([r.latency_s for r in self.records], dtype=float)

    @functools.cached_property
    def _slowdowns(self) -> np.ndarray:
        return np.array([r.slowdown for r in self.records], dtype=float)

    def latency_percentile(self, q: float) -> float:
        lat = self._latencies
        return float(np.percentile(lat, q)) if lat.size else 0.0

    @property
    def latency_p50(self) -> float:
        return self.latency_percentile(50)

    @property
    def latency_p95(self) -> float:
        return self.latency_percentile(95)

    @property
    def latency_p99(self) -> float:
        return self.latency_percentile(99)

    @property
    def mean_latency_s(self) -> float:
        lat = self._latencies
        return float(lat.mean()) if lat.size else 0.0

    def slo_attainment(self, *, slo_s: float | None = None,
                       slowdown: float = 3.0) -> float:
        """Fraction of requests meeting the SLO: an absolute deadline if
        slo_s is given, else latency ≤ slowdown × isolated runtime."""
        if not self.records:
            return 1.0
        if slo_s is not None:
            ok = int((self._latencies <= slo_s).sum())
        else:
            ok = int((self._slowdowns <= slowdown).sum())
        return ok / len(self.records)

    def goodput(self, *, slo_s: float | None = None,
                slowdown: float = 3.0) -> float:
        """Fraction of *offered* requests (completed + abandoned) that
        completed within the SLO — the availability metric: unlike
        `slo_attainment`, giving up on a request hurts this number."""
        offered = len(self.records) + len(self.abandoned)
        if offered == 0:
            return 1.0
        return self.slo_attainment(slo_s=slo_s,
                                   slowdown=slowdown) * len(self.records) / offered

    # --- structured export ------------------------------------------------
    def to_dict(self, *, include_records: bool = False) -> dict:
        """JSON-able snapshot: run identity, totals, the four-bucket
        energy split, latency summary, and per-node stats — what the
        benchmarks dump instead of parsing `summary()` strings.  Request
        records are bulky and off by default."""
        out = {
            "policy": self.policy,
            "zeta": self.zeta,
            "makespan_s": self.makespan_s,
            "objective": self.objective,
            "predicted_energy_j": self.predicted_energy_j,
            "total_energy_j": self.total_energy_j,
            "energy_breakdown_j": self.energy_breakdown(),
            "total_tokens": self.total_tokens,
            "j_per_token": self.j_per_token,
            "n_requests": len(self.records),
            "latency_s": {
                "mean": self.mean_latency_s,
                "p50": self.latency_p50,
                "p95": self.latency_p95,
                "p99": self.latency_p99,
            },
            "slo_attainment": self.slo_attainment(),
            "goodput": self.goodput(),
            "total_wakes": self.total_wakes,
            "total_gates": self.total_gates,
            "total_preemptions": self.total_preemptions,
            "total_resumes": self.total_resumes,
            "total_crashes": self.total_crashes,
            "total_migrations": self.total_migrations,
            "total_checkpoints": self.total_checkpoints,
            "total_restores": self.total_restores,
            "total_cache_hits": self.total_cache_hits,
            "total_cache_misses": self.total_cache_misses,
            "total_cache_evictions": self.total_cache_evictions,
            "total_cache_hit_tokens": self.total_cache_hit_tokens,
            "cache_hit_rate": self.cache_hit_rate,
            "n_abandoned": len(self.abandoned),
            "replicas": {name: list(nids) for name, nids in self.replicas},
            "node_stats": [dataclasses.asdict(s) for s in self.node_stats],
            "abandoned": [dataclasses.asdict(a) for a in self.abandoned],
        }
        if include_records:
            out["records"] = [dataclasses.asdict(r) for r in self.records]
        return out

    def to_json(self, *, include_records: bool = False) -> str:
        return json.dumps(self.to_dict(include_records=include_records),
                          sort_keys=True)

    @classmethod
    def from_registry(cls, registry) -> "ClusterReport":
        """Rebuild the aggregate report view from a telemetry registry
        (the end-of-run gauges `Telemetry.finalize` writes).  This is the
        reduction path the actor-sharded simulator will use: per-partition
        registries merge (`MetricsRegistry.merged`), then one report is
        read off the merged registry.  Per-request `records` and the
        replica registry are not representable as metrics, so they come
        back empty — totals, buckets and node stats are exact."""
        if "sim_run_info" not in registry:
            raise ValueError(
                "registry has no sim_run_info — was Telemetry.finalize run?")
        (policy_key, _), = registry["sim_run_info"].sorted_children()
        served_fam = registry["sim_node_served"]
        stats = []
        for (nid_s, model), child in served_fam.sorted_children():
            nid = int(nid_s)
            e = {b: registry.value("sim_node_energy_joules", nid, b)
                 for b in ("busy", "idle", "gated", "transition",
                           "shipping", "checkpoint", "cache_read", "wasted")}
            s = {b: registry.value("sim_node_seconds", nid, b)
                 for b in ("busy", "idle", "gated", "transition",
                           "failed", "shipping", "checkpoint", "cache_read")}
            stats.append(NodeStats(
                node_id=nid,
                model=model,
                n_served=int(child.value),
                busy_s=s["busy"],
                busy_energy_j=e["busy"],
                idle_energy_j=e["idle"],
                utilization=registry.value("sim_node_utilization",
                                           nid, model),
                idle_s=s["idle"],
                gated_s=s["gated"],
                gated_energy_j=e["gated"],
                transition_s=s["transition"],
                transition_energy_j=e["transition"],
                horizon_s=registry.value("sim_node_horizon_seconds", nid),
                n_wakes=int(registry.value("sim_node_wakes", nid)),
                n_gates=int(registry.value("sim_node_gates", nid)),
                n_preemptions=int(registry.value("sim_node_preemptions",
                                                 nid)),
                n_resumes=int(registry.value("sim_node_resumes", nid)),
                failed_s=s["failed"],
                shipping_s=s["shipping"],
                shipping_energy_j=e["shipping"],
                wasted_energy_j=e["wasted"],
                n_crashes=int(registry.value("sim_node_crashes", nid)),
                n_recoveries=int(registry.value("sim_node_recoveries", nid)),
                n_migrations_in=int(
                    registry.value("sim_node_migrations_in", nid)),
                n_migrations_out=int(
                    registry.value("sim_node_migrations_out", nid)),
                checkpoint_s=s["checkpoint"],
                checkpoint_energy_j=e["checkpoint"],
                n_checkpoints=int(registry.value("sim_node_checkpoints", nid)),
                n_restores=int(registry.value("sim_node_restores", nid)),
                cache_read_s=s["cache_read"],
                cache_read_energy_j=e["cache_read"],
                n_cache_hits=int(registry.value("sim_node_cache_hits", nid)),
                n_cache_misses=int(
                    registry.value("sim_node_cache_misses", nid)),
                n_cache_evictions=int(
                    registry.value("sim_node_cache_evictions", nid)),
                cache_hit_tokens=int(
                    registry.value("sim_node_cache_hit_tokens", nid)),
            ))
        stats.sort(key=lambda st: st.node_id)
        return cls(
            policy=policy_key[0],
            zeta=registry.value("sim_zeta"),
            records=(),
            node_stats=tuple(stats),
            makespan_s=registry.value("sim_makespan_seconds"),
            objective=registry.value("sim_objective"),
            predicted_energy_j=registry.value("sim_predicted_energy_joules"),
        )

    # --- display ----------------------------------------------------------
    def summary(self) -> str:
        power = ""
        if self.total_gates or self.total_gated_energy_j:
            power = (f"gated={self.total_gated_energy_j:.0f} "
                     f"trans={self.total_transition_energy_j:.0f} "
                     f"wakes={self.total_wakes} ")
        if self.total_preemptions:
            power += (f"preempt={self.total_preemptions} "
                      f"resume={self.total_resumes} ")
        if self.total_checkpoints or self.total_restores:
            power += (f"ckpt={self.total_checkpoints} "
                      f"restore={self.total_restores} ")
        if self.total_cache_hits or self.total_cache_evictions:
            power += (f"cache={self.cache_hit_rate:.0%} "
                      f"reuse={self.total_cache_hit_tokens} "
                      f"evict={self.total_cache_evictions} ")
        if self.total_crashes or self.abandoned:
            power += (f"crash={self.total_crashes} "
                      f"migrate={self.total_migrations} "
                      f"abandon={len(self.abandoned)} "
                      f"wasted={self.total_wasted_energy_j:.0f}J ")
        return (f"{self.policy:>15s}: E={self.total_energy_j:12.0f}J "
                f"(busy={self.total_busy_energy_j:.0f} idle={self.total_idle_energy_j:.0f}) "
                f"{power}"
                f"pred={self.predicted_energy_j:.0f}J obj={self.objective:+.3f} "
                f"J/tok={self.j_per_token:7.2f} "
                f"p50={self.latency_p50:6.2f}s p95={self.latency_p95:6.2f}s "
                f"p99={self.latency_p99:6.2f}s "
                f"slo={self.slo_attainment():5.1%} "
                f"util={[round(s.utilization, 2) for s in self.node_stats]}")


def per_node_stats(nodes: Sequence, makespan_s: float) -> tuple[NodeStats, ...]:
    """Snapshot the per-node accounting.  Nodes must have been finalized
    (books closed at the makespan) by the simulation loop."""
    out = []
    for n in nodes:
        out.append(NodeStats(
            node_id=n.node_id,
            model=n.model_name,
            n_served=n.n_served,
            busy_s=n.busy_s,
            busy_energy_j=n.busy_energy_j,
            idle_energy_j=n.idle_energy_j,
            utilization=(n.busy_s / makespan_s) if makespan_s > 0 else 0.0,
            idle_s=n.idle_s,
            gated_s=n.gated_s,
            gated_energy_j=n.gated_energy_j,
            transition_s=n.transition_s,
            transition_energy_j=n.transition_energy_j,
            horizon_s=n.horizon_s,
            n_wakes=n.n_wakes,
            n_gates=n.n_gates,
            n_preemptions=n.n_preemptions,
            n_resumes=n.n_resumes,
            failed_s=n.failed_s,
            shipping_s=n.shipping_s,
            shipping_energy_j=n.shipping_energy_j,
            wasted_energy_j=n.wasted_energy_j,
            n_crashes=n.n_crashes,
            n_recoveries=n.n_recoveries,
            n_migrations_in=n.n_migrations_in,
            n_migrations_out=n.n_migrations_out,
            checkpoint_s=n.checkpoint_s,
            checkpoint_energy_j=n.checkpoint_energy_j,
            n_checkpoints=n.n_checkpoints,
            n_restores=n.n_restores,
            cache_read_s=n.cache_read_s,
            cache_read_energy_j=n.cache_read_energy_j,
            n_cache_hits=n.n_cache_hits,
            n_cache_misses=n.n_cache_misses,
            n_cache_evictions=n.n_cache_evictions,
            cache_hit_tokens=n.cache_hit_tokens,
        ))
    return tuple(out)
