"""The discrete-event loop: streaming arrivals over a heterogeneous fleet.

Six event kinds drive the simulation — request arrivals (from the trace),
node phase completions (from the continuous-batching state machines),
preemption settlements (a decode segment cut at its next step boundary),
and the power-management triple: wake completions, gate completions, and
idle timers (armed by the autoscaler when a node runs out of work).
Events are processed in (time, sequence) order; the sequence counter makes
simultaneous events deterministic, so a fixed trace + policy (+ autoscaler
+ preempter) always yields a bit-identical ClusterReport.

Phase-shaped events (segment end, preemption settle) carry the node's
*phase epoch* at scheduling time: preempting a segment bumps the epoch, so
the stale segment-end event still sitting in the heap is recognized and
dropped when popped — the only event-invalidation path in the loop.

Without an `autoscaler=`, no idle timer is ever armed and no node ever
leaves the ACTIVE/IDLE pair; without a `preempter=`, no decode segment is
ever cut — the loop degenerates to the PR 1/PR 4 simulation exactly (the
differential tests in tests/test_preemption.py pin event-stream and
energy identity), keeping the offline-oracle replay baseline and its gap
numbers directly comparable across PRs.

Resume is not a separate event kind: a suspended request rejoins the
active set for free at the next phase start with a spare slot
(`ClusterNode._start_phase`), so its RESUMING instant always coincides
with an existing phase boundary.

The loop also builds the per-model *replica registry* (`replica_registry`,
shared with the policies module) — model name → node ids hosting a
replica, in node order — which is what the replica-aware router, oracle,
preemption policy, and autoscalers size against.

Completions are echoed to `policy.observe_completion` (τout predictor
feedback — the only causal channel through which a non-oracle router may
learn output lengths), `autoscaler.on_completion` (service-time feedback
for predictive fleet sizing), and `preempter.observe_completion` (the
same τout channel for a predictor-equipped preemption policy).

Observability (`telemetry=`, a repro.obs.Telemetry): the loop reports
arrivals/routing picks, preemption and autoscaler decisions, completions,
and — when `sample_every_s` is set — periodic queue-depth / batch /
bucket-energy samples; the nodes report phase settlements and power
transitions directly (repro.cluster.node).  Hooks are read-only: the
returned ClusterReport is byte-identical with telemetry on or off (the
perf-suite `metrics_overhead` gate pins both that and ≤5% overhead).
"""

from __future__ import annotations

import heapq
from typing import Sequence

from repro.cluster.metrics import ClusterReport, RequestRecord, per_node_stats
from repro.cluster.node import ClusterNode
from repro.cluster.policies import (
    PreemptionPolicy,
    RoutingPolicy,
    objective_of_assignment,
    replica_registry,
    unique_profiles,
)
from repro.cluster.power import GATED, IDLE, AutoscalePolicy
from repro.cluster.trace import ArrivalTrace

(_ARRIVAL, _PHASE_END, _WAKE_END, _GATE_END, _IDLE_TIMER,
 _PREEMPT_END) = range(6)

_EVENT_CODE = {"phase": _PHASE_END, "wake": _WAKE_END, "gate": _GATE_END,
               "preempt": _PREEMPT_END}
_EPOCH_GUARDED = (_PHASE_END, _PREEMPT_END)   # payload carries (nid, epoch)


def simulate_cluster(
    trace: ArrivalTrace,
    nodes: Sequence[ClusterNode],
    policy: RoutingPolicy,
    *,
    zeta: float = 0.5,
    autoscaler: AutoscalePolicy | None = None,
    preempter: PreemptionPolicy | None = None,
    telemetry=None,
) -> ClusterReport:
    """Serve the whole trace; returns the aggregate ClusterReport."""
    if not nodes:
        raise ValueError("need at least one node")
    by_id = {n.node_id: n for n in nodes}
    if len(by_id) != len(nodes):
        raise ValueError("node_ids must be unique")
    replicas = replica_registry(nodes)   # model -> node ids, in node order
    policy.attach(nodes, trace, zeta)
    if autoscaler is not None:
        autoscaler.attach(nodes)
    if preempter is not None:
        preempter.attach(nodes, trace, zeta)
    # telemetry is per-run; assign unconditionally so reused nodes/policies
    # never carry a stale reference from a previous instrumented run
    for n in nodes:
        n.telemetry = telemetry
    policy.telemetry = telemetry
    if autoscaler is not None:
        autoscaler.telemetry = telemetry
    if preempter is not None:
        preempter.telemetry = telemetry
    if telemetry is not None:
        telemetry.attach(nodes, policy, trace, zeta)
    sample_every = telemetry.sample_every_s if telemetry is not None else None
    next_sample = 0.0

    events: list[tuple[float, int, int, object]] = []
    seq = 0
    for req in trace:
        heapq.heappush(events, (req.arrival_s, seq, _ARRIVAL, req))
        seq += 1

    records: list[RequestRecord] = []
    makespan = trace.duration_s
    arrivals_left = len(trace)

    def push(node: ClusterNode, ev: tuple[str, float] | None) -> None:
        nonlocal seq
        if ev is not None:
            kind, end_s = ev
            code = _EVENT_CODE[kind]
            payload = ((node.node_id, node.phase_epoch)
                       if code in _EPOCH_GUARDED else node.node_id)
            heapq.heappush(events, (end_s, seq, code, payload))
            seq += 1

    def arm_idle_timer(node: ClusterNode, now: float) -> None:
        """Ask the autoscaler whether (and when) to revisit an idle node.
        The timer carries the idle-epoch token so a node that served work
        and went idle again in between invalidates the stale timer."""
        nonlocal seq
        if autoscaler is None or node.power_state != IDLE:
            return
        t = autoscaler.on_idle(node, now)
        if t is not None:
            heapq.heappush(events, (t, seq, _IDLE_TIMER,
                                    (node.node_id, node.power_state_since)))
            seq += 1

    for n in nodes:   # the fleet starts idle: give the autoscaler a shot
        arm_idle_timer(n, 0.0)

    while events:
        now, _, kind, payload = heapq.heappop(events)
        if sample_every is not None:
            # sample fleet state as of the previous event, stamped on the
            # period grid, before this event mutates it
            while next_sample <= now:
                telemetry.sample(nodes, next_sample)
                next_sample += sample_every
        if kind == _ARRIVAL:
            req = payload
            arrivals_left -= 1
            if autoscaler is not None:
                prewoken = 0
                for nid in autoscaler.on_arrival(req, nodes, now):
                    node = by_id[nid]
                    if node.power_state == GATED:   # proactive pre-wake
                        push(node, ("wake", node.begin_wake(now)))
                        prewoken += 1
                if telemetry is not None:
                    telemetry.on_prewake(autoscaler.name, prewoken)
            nid = policy.select(req, nodes, now)
            if nid not in by_id:
                raise ValueError(f"{policy.name} routed to unknown node {nid}")
            node = by_id[nid]
            if telemetry is not None:
                telemetry.on_arrival(req, policy.name, nid, node.model_name,
                                     now)
            push(node, node.enqueue(req, now))
            if preempter is not None:
                # the arrival is queued; the preempter may cut the routed
                # node's decode segment to make room for it at the boundary
                victim = preempter.consider(req, node, nodes, now)
                if telemetry is not None:
                    telemetry.on_preempt_decision(preempter.name,
                                                  victim is not None)
                if victim is not None:
                    push(node, node.preempt_decode(victim, now))
        elif kind == _PHASE_END:
            nid, epoch = payload
            node = by_id[nid]
            if epoch != node.phase_epoch:
                continue   # segment was preempted; this end never happened
            completions, next_ev = node.on_phase_end(now)
            for c in completions:
                makespan = max(makespan, c.finish_s)
                rec = RequestRecord(
                    request_id=c.req.request_id,
                    node_id=node.node_id,
                    model=node.model_name,
                    tau_in=c.req.tau_in,
                    tau_out=c.req.tau_out,
                    arrival_s=c.req.arrival_s,
                    start_s=c.start_s,
                    finish_s=c.finish_s,
                    energy_j=c.energy_j,
                    isolated_runtime_s=c.isolated_runtime_s,
                    preemptions=c.preemptions,
                )
                policy.observe_completion(rec, now)
                if autoscaler is not None:
                    autoscaler.on_completion(rec, now)
                if preempter is not None:
                    preempter.observe_completion(rec, now)
                if telemetry is not None:
                    telemetry.on_completion(rec, now)
                records.append(rec)
            push(node, next_ev)
            if next_ev is None:
                arm_idle_timer(node, now)
        elif kind == _PREEMPT_END:
            nid, epoch = payload
            node = by_id[nid]
            if epoch != node.phase_epoch:
                continue   # defensive: nothing invalidates settles today
            next_ev = node.on_preempt_end(now)
            push(node, next_ev)
            if next_ev is None:
                arm_idle_timer(node, now)
        elif kind == _WAKE_END:
            node = by_id[payload]
            next_ev = node.on_wake_end(now)
            push(node, next_ev)
            if next_ev is None:   # pre-woken with nothing to do (yet)
                arm_idle_timer(node, now)
        elif kind == _GATE_END:
            node = by_id[payload]
            push(node, node.on_gate_end(now))
        else:  # _IDLE_TIMER
            nid, token = payload
            node = by_id[nid]
            if (node.power_state == IDLE
                    and node.power_state_since == token
                    and node.can_gate
                    and autoscaler is not None):
                gate = autoscaler.should_gate(node, now)
                if telemetry is not None:
                    telemetry.on_gate_decision(autoscaler.name, gate)
                if gate:
                    push(node, node.begin_gate(now))
                elif arrivals_left > 0:
                    # declined (e.g. min_awake bound): re-check later — a
                    # node that never leaves IDLE must not be stranded
                    # powered after fleet conditions change.  Re-arming
                    # stops with the last arrival so the loop terminates.
                    arm_idle_timer(node, now)

    if len(records) != len(trace):
        raise RuntimeError(
            f"served {len(records)}/{len(trace)} requests — event loop bug")
    if any(n.suspended for n in nodes):
        raise RuntimeError("preempted requests left suspended at the end of "
                           "the trace — resume logic bug")
    records.sort(key=lambda r: r.request_id)
    for n in nodes:   # close every node's books at the common horizon
        n.finalize(makespan)

    profiles = unique_profiles(nodes)
    queries = trace.queries()
    assigned = [r.model for r in records]
    objective = (objective_of_assignment(profiles, queries, assigned, zeta)
                 if records else 0.0)
    prof_of = {p.name: p for p in profiles}
    predicted = sum(float(prof_of[r.model].energy(r.tau_in, r.tau_out))
                    for r in records)

    report = ClusterReport(
        policy=policy.name,
        zeta=zeta,
        records=tuple(records),
        node_stats=per_node_stats(nodes, makespan),
        makespan_s=makespan,
        objective=objective,
        predicted_energy_j=predicted,
        replicas=tuple((name, tuple(nids)) for name, nids in replicas.items()),
    )
    if telemetry is not None:
        telemetry.finalize(nodes, report)
    return report


def fresh_nodes(builders: Sequence) -> list[ClusterNode]:
    """Call a list of zero-arg node factories — each policy comparison needs
    pristine node state, so callers pass builders rather than nodes."""
    return [b() for b in builders]


def compare_policies(
    trace: ArrivalTrace,
    node_builders: Sequence,
    policies: Sequence[RoutingPolicy],
    *,
    zeta: float = 0.5,
    autoscaler_builder=None,
    preempter_builder=None,
) -> dict[str, ClusterReport]:
    """Run every policy on identical fresh clusters over the same trace.
    `autoscaler_builder`/`preempter_builder` are zero-arg factories
    (autoscalers and preemption policies hold per-run state, so they need
    the same fresh-per-run treatment as nodes)."""
    out: dict[str, ClusterReport] = {}
    for pol in policies:
        nodes = fresh_nodes(node_builders)
        scaler = autoscaler_builder() if autoscaler_builder is not None else None
        pre = preempter_builder() if preempter_builder is not None else None
        out[pol.name] = simulate_cluster(trace, nodes, pol, zeta=zeta,
                                         autoscaler=scaler, preempter=pre)
    return out
