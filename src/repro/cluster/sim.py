"""The simulation facade: one call serving a trace over a sharded engine.

`simulate_cluster` is the stable public entry point the benchmarks,
oracle replays and tests drive.  Since the engine refactor the event
loop itself lives in :mod:`repro.cluster.engine` — a typed event core
(:class:`~repro.cluster.engine.events.EventKind` + payload dataclasses
in place of the old ten magic int codes and raw ``(time, seq, kind,
payload)`` tuples), per-node-group :class:`NodeShard` heaps, a
cross-shard :class:`Mailbox`, and the :class:`Runner` that merges them
in fleet-wide ``(time, seq)`` order.  This module is a thin facade over
that engine in its exact **merge** mode, which is bit-identical to the
historical monolithic loop *by construction*: sequence numbers come
from one fleet-wide allocator drawn at the same handler sites in the
same order, so a fixed trace + policy (+ autoscaler + preempter + fault
trace) always yields a bit-identical ClusterReport — at any shard
count.

Shard count defaults to the ``REPRO_SIM_SHARDS`` environment variable
(1 when unset), letting CI run the whole suite against a sharded
partition without touching a single call site; pass ``shards=`` to pin
it per call.  The semantics of every event kind — arrivals, phase and
preemption settlements, the power triple (wake/gate/idle-timer), and
the fault quartet (fault, crash settle, KV-ship completion, retry) —
are documented on the engine modules; the rescue orchestration,
epoch-based invalidation and completion-echo contracts are unchanged
from the monolith (the engine's handlers are a line-faithful port,
differentially pinned by tests/test_engine.py).

Observability (`telemetry=`, a repro.obs.Telemetry) reports exactly as
before (fused mode: one registry/tracer/auditor); the engine can also
attach telemetry *per shard* and fold through the mergeable-registry
reduction — see :class:`Runner`'s ``obs_mode="sharded"``.
"""

from __future__ import annotations

import os
from typing import Sequence

from repro.cluster.engine.runner import Runner
from repro.cluster.faults import FaultTrace
from repro.cluster.metrics import ClusterReport
from repro.cluster.node import ClusterNode
from repro.cluster.policies import PreemptionPolicy, RoutingPolicy
from repro.cluster.power import AutoscalePolicy
from repro.cluster.trace import ArrivalTrace


def default_shards() -> int:
    """Shard count for facade calls: ``REPRO_SIM_SHARDS`` (default 1)."""
    try:
        return max(1, int(os.environ.get("REPRO_SIM_SHARDS", "1")))
    except ValueError:
        return 1


def simulate_cluster(
    trace: ArrivalTrace,
    nodes: Sequence[ClusterNode],
    policy: RoutingPolicy,
    *,
    zeta: float = 0.5,
    autoscaler: AutoscalePolicy | None = None,
    preempter: PreemptionPolicy | None = None,
    faults: FaultTrace | None = None,
    telemetry=None,
    shards: int | None = None,
) -> ClusterReport:
    """Serve the whole trace; returns the aggregate ClusterReport.
    `shards=None` reads REPRO_SIM_SHARDS (default 1); any value yields
    the identical report (merge mode is exact at every partition)."""
    return Runner(
        trace, nodes, policy, zeta=zeta, autoscaler=autoscaler,
        preempter=preempter, faults=faults, telemetry=telemetry,
        shard_count=default_shards() if shards is None else shards,
    ).run()


def fresh_nodes(builders: Sequence) -> list[ClusterNode]:
    """Call a list of zero-arg node factories — each policy comparison needs
    pristine node state, so callers pass builders rather than nodes."""
    return [b() for b in builders]


def compare_policies(
    trace: ArrivalTrace,
    node_builders: Sequence,
    policies: Sequence[RoutingPolicy],
    *,
    zeta: float = 0.5,
    autoscaler_builder=None,
    preempter_builder=None,
    faults: FaultTrace | None = None,
) -> dict[str, ClusterReport]:
    """Run every policy on identical fresh clusters over the same trace.
    `autoscaler_builder`/`preempter_builder` are zero-arg factories
    (autoscalers and preemption policies hold per-run state, so they need
    the same fresh-per-run treatment as nodes).  A `faults=` trace is
    replayed identically against every policy — the apples-to-apples
    availability comparison fig4's MTTF sweep plots."""
    out: dict[str, ClusterReport] = {}
    for pol in policies:
        nodes = fresh_nodes(node_builders)
        scaler = autoscaler_builder() if autoscaler_builder is not None else None
        pre = preempter_builder() if preempter_builder is not None else None
        out[pol.name] = simulate_cluster(trace, nodes, pol, zeta=zeta,
                                         autoscaler=scaler, preempter=pre,
                                         faults=faults)
    return out
