"""The discrete-event loop: streaming arrivals over a heterogeneous fleet.

Ten event kinds drive the simulation — request arrivals (from the trace),
node phase completions (from the continuous-batching state machines),
preemption settlements (a decode segment cut at its next step boundary),
the power-management triple: wake completions, gate completions, and
idle timers (armed by the autoscaler when a node runs out of work) — and,
when a `faults=` FaultTrace is supplied, the disruption quartet: fault
events (crash/recover/slow/normal from the trace), crash settlements (a
dying node's final decode truncation, quantized to the same step boundary
preemption uses), KV-shipping completions (a refugee's state landing on a
healthy replica), and routing retries (capped-backoff re-routes when no
node is accepting).  Events are processed in (time, sequence) order; the
sequence counter makes simultaneous events deterministic, so a fixed
trace + policy (+ autoscaler + preempter + fault trace) always yields a
bit-identical ClusterReport.

Phase-shaped events (segment end, preemption/crash settle) and the power
transitions carry the node's *phase epoch* at scheduling time: preempting
a segment — or crashing the node — bumps the epoch, so stale events still
sitting in the heap are recognized and dropped when popped, the only
event-invalidation path in the loop.

Rescue orchestration (fault runs only): when a node fails, its waiting
requests re-route through the policy over the *accepting* sub-fleet (with
capped exponential backoff via `policy.retry_delay` when nobody accepts,
abandoning when the policy gives up), and its suspended/active decodes
become refugees — each ships its KV to the least-loaded accepting replica
of the same model (bytes = context × KV-bytes/token, at the recipient's
interconnect bandwidth and J/byte, metered by `book_shipping`), resuming
for free at the recipient's next phase start.  With no surviving replica
the refugee is either re-run from scratch elsewhere (`policy.allow_rerun`)
or abandoned; either way its accrued joules move to the wasted bucket so
the cross-node settlement contract (donor's truncated charge + shipping +
recipient's resumed charge, or waste) closes to 1e-9.  A *prefill*
refugee (a checkpointed prefill the crash caught mid-prompt,
`node.CheckpointConfig`) ships only its durably persisted prefix —
bytes = ckpt_tokens × KV-bytes/token — and re-runs the unfinished
suffix in a `restore` phase on the recipient; one with nothing
checkpointed re-runs from scratch or abandons, wasting its accrued
joules.  Simultaneous crash events (a correlated FaultTrace killing a
whole rack/PDU domain at one instant) are additionally aggregated into
domain-outage counts and correlated-kill-size samples for telemetry.
`faults=None` skips every fault code path exactly — the no-fault loop
is bit-identical to previous PRs — and an *empty* FaultTrace differs
only by the eligible-node filter, which is the identity on a healthy
fleet.

Without an `autoscaler=`, no idle timer is ever armed and no node ever
leaves the ACTIVE/IDLE pair; without a `preempter=`, no decode segment is
ever cut — the loop degenerates to the PR 1/PR 4 simulation exactly (the
differential tests in tests/test_preemption.py pin event-stream and
energy identity), keeping the offline-oracle replay baseline and its gap
numbers directly comparable across PRs.

Resume is not a separate event kind: a suspended request rejoins the
active set for free at the next phase start with a spare slot
(`ClusterNode._start_phase`), so its RESUMING instant always coincides
with an existing phase boundary.

The loop also builds the per-model *replica registry* (`replica_registry`,
shared with the policies module) — model name → node ids hosting a
replica, in node order — which is what the replica-aware router, oracle,
preemption policy, and autoscalers size against.

Completions are echoed to `policy.observe_completion` (τout predictor
feedback — the only causal channel through which a non-oracle router may
learn output lengths), `autoscaler.on_completion` (service-time feedback
for predictive fleet sizing), and `preempter.observe_completion` (the
same τout channel for a predictor-equipped preemption policy).

Observability (`telemetry=`, a repro.obs.Telemetry): the loop reports
arrivals/routing picks, preemption and autoscaler decisions, completions,
and — when `sample_every_s` is set — periodic queue-depth / batch /
bucket-energy samples; the nodes report phase settlements and power
transitions directly (repro.cluster.node).  Hooks are read-only: the
returned ClusterReport is byte-identical with telemetry on or off (the
perf-suite `metrics_overhead` gate pins both that and ≤5% overhead).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Sequence

from repro.cluster.faults import CRASH, RECOVER, SLOW, FaultTrace
from repro.cluster.metrics import (
    AbandonedRecord,
    ClusterReport,
    RequestRecord,
    per_node_stats,
)
from repro.cluster.node import ClusterNode
from repro.cluster.policies import (
    PreemptionPolicy,
    RoutingPolicy,
    objective_of_assignment,
    replica_registry,
    unique_profiles,
)
from repro.cluster.power import GATED, IDLE, AutoscalePolicy
from repro.cluster.trace import ArrivalTrace
from repro.energy.costs import kv_bytes_per_token

(_ARRIVAL, _PHASE_END, _WAKE_END, _GATE_END, _IDLE_TIMER,
 _PREEMPT_END, _FAULT, _CRASH_END, _SHIP_END, _RETRY) = range(10)

_EVENT_CODE = {"phase": _PHASE_END, "wake": _WAKE_END, "gate": _GATE_END,
               "preempt": _PREEMPT_END, "crash": _CRASH_END}
# payload carries (nid, epoch); a crash bumps the epoch, so stale
# wake/gate completions on a crashed node die in the heap too (nothing
# else can bump the epoch mid-transition, so guarding them is free)
_EPOCH_GUARDED = (_PHASE_END, _PREEMPT_END, _WAKE_END, _GATE_END,
                  _CRASH_END)


def simulate_cluster(
    trace: ArrivalTrace,
    nodes: Sequence[ClusterNode],
    policy: RoutingPolicy,
    *,
    zeta: float = 0.5,
    autoscaler: AutoscalePolicy | None = None,
    preempter: PreemptionPolicy | None = None,
    faults: FaultTrace | None = None,
    telemetry=None,
) -> ClusterReport:
    """Serve the whole trace; returns the aggregate ClusterReport."""
    if not nodes:
        raise ValueError("need at least one node")
    by_id = {n.node_id: n for n in nodes}
    if len(by_id) != len(nodes):
        raise ValueError("node_ids must be unique")
    replicas = replica_registry(nodes)   # model -> node ids, in node order
    policy.attach(nodes, trace, zeta)
    if autoscaler is not None:
        autoscaler.attach(nodes)
    if preempter is not None:
        preempter.attach(nodes, trace, zeta)
    # telemetry is per-run; assign unconditionally so reused nodes/policies
    # never carry a stale reference from a previous instrumented run
    for n in nodes:
        n.telemetry = telemetry
    policy.telemetry = telemetry
    if autoscaler is not None:
        autoscaler.telemetry = telemetry
    if preempter is not None:
        preempter.telemetry = telemetry
    if telemetry is not None:
        telemetry.attach(nodes, policy, trace, zeta)
    sample_every = telemetry.sample_every_s if telemetry is not None else None
    next_sample = 0.0

    fault_mode = faults is not None
    events: list[tuple[float, int, int, object]] = []
    seq = 0
    for req in trace:
        heapq.heappush(events, (req.arrival_s, seq, _ARRIVAL, req))
        seq += 1
    if fault_mode:
        for fev in faults:
            if fev.node_id not in by_id:
                raise ValueError(f"fault trace names unknown node "
                                 f"{fev.node_id}")
            heapq.heappush(events, (fev.time_s, seq, _FAULT, fev))
            seq += 1

    records: list[RequestRecord] = []
    abandoned: list[AbandonedRecord] = []
    makespan = trace.duration_s
    arrivals_left = len(trace)

    def push(node: ClusterNode, ev: tuple[str, float] | None) -> None:
        nonlocal seq
        if ev is not None:
            kind, end_s = ev
            code = _EVENT_CODE[kind]
            payload = ((node.node_id, node.phase_epoch)
                       if code in _EPOCH_GUARDED else node.node_id)
            heapq.heappush(events, (end_s, seq, code, payload))
            seq += 1

    def arm_idle_timer(node: ClusterNode, now: float) -> None:
        """Ask the autoscaler whether (and when) to revisit an idle node.
        The timer carries the idle-epoch token so a node that served work
        and went idle again in between invalidates the stale timer."""
        nonlocal seq
        if autoscaler is None or node.power_state != IDLE:
            return
        t = autoscaler.on_idle(node, now)
        if t is not None:
            heapq.heappush(events, (t, seq, _IDLE_TIMER,
                                    (node.node_id, node.power_state_since)))
            seq += 1

    # --- rescue orchestration (fault runs only) ------------------------
    def fallback_node(eligible: list[ClusterNode]) -> ClusterNode:
        """Deterministic stand-in when the policy's pick is not accepting
        (e.g. a static oracle routing onto a crashed replica)."""
        return min(eligible,
                   key=lambda n: (n.load(), n.power_rank, n.node_id))

    def abandon_request(req, now: float, reason: str, attempts: int, *,
                        member=None, model: str = "") -> None:
        """Give up on a request; any joules a stranded refugee already
        accrued *move* to the wasted bucket on the node(s) that spent
        them, so conservation closes over completed + abandoned work."""
        nonlocal makespan
        wasted = 0.0
        if member is not None:
            for w_nid, e in sorted(member.energy_on.items()):
                by_id[w_nid].book_waste(e)
                wasted += e
            member.energy_on.clear()
        rec = AbandonedRecord(
            request_id=req.request_id, model=model,
            tau_in=req.tau_in, tau_out=req.tau_out,
            arrival_s=req.arrival_s, abandoned_s=now, reason=reason,
            attempts=attempts, wasted_j=wasted)
        abandoned.append(rec)
        makespan = max(makespan, now)
        if telemetry is not None:
            telemetry.on_abandon(rec, now)

    def schedule_retry(req, attempts: int, now: float) -> None:
        """No accepting node right now: ask the policy when (whether) to
        try again."""
        nonlocal seq
        delay = policy.retry_delay(req, attempts, now)
        if delay is None:
            abandon_request(req, now, "no_capacity", attempts)
            return
        heapq.heappush(events, (now + delay, seq, _RETRY,
                                (req, attempts + 1)))
        seq += 1

    def route_or_retry(req, attempts: int, now: float) -> None:
        """Re-route a displaced (or backed-off) request over the
        accepting sub-fleet; park it in the retry loop when empty."""
        eligible = [n for n in nodes if n.accepting]
        if not eligible:
            schedule_retry(req, attempts, now)
            return
        nid = policy.select(req, eligible, now)
        node = by_id.get(nid)
        if node is None or not node.accepting:
            node = fallback_node(eligible)
        if telemetry is not None:
            telemetry.on_retry(req, node.node_id, attempts, now)
        push(node, node.enqueue(req, now))

    def rerun_or_abandon(member, home: ClusterNode, now: float,
                         reason: str) -> None:
        """Last resort for an unshippable refugee: re-run its request
        from scratch on whoever accepts (`policy.allow_rerun`) or give
        up — the accrued joules move to the wasted bucket either way."""
        if (policy.allow_rerun(member.req, now)
                and any(n.accepting for n in nodes)):
            for w_nid, e in sorted(member.energy_on.items()):
                by_id[w_nid].book_waste(e)
            member.energy_on.clear()
            route_or_retry(member.req, 0, now)
        else:
            abandon_request(member.req, now, reason, 0,
                            member=member, model=home.model_name)

    def dispatch_refugee(member, home: ClusterNode, now: float) -> None:
        """Rescue one suspended refugee stranded on `home` (crashed or
        draining): ship its KV to the least-loaded accepting replica of
        the same model — bytes = context × KV-bytes/token (a *prefill*
        refugee ships only its checkpointed prefix: ckpt_tokens ×
        KV-bytes/token), pulled at the recipient's interconnect bandwidth
        and J/byte (a pull still works when the donor is dead) — or, with
        no surviving replica (or nothing durable to ship), re-run it from
        scratch elsewhere / abandon it, wasting the accrued joules."""
        nonlocal seq
        if member.prefill_done is not None:
            # mid-prompt refugee: only the durably persisted prefix moves
            if member.ckpt_tokens >= member.req.tau_in:
                # the full prompt is checkpointed — decode-ready after
                # the shipment, no suffix left to restore
                member.prefill_done = None
            elif member.ckpt_tokens <= 0:
                # crashed inside its first chunk: nothing durable exists
                rerun_or_abandon(member, home, now, "prefill_lost")
                return
        candidates = [n for n in nodes
                      if n.accepting and n.model_name == home.model_name
                      and n.node_id != home.node_id]
        if candidates:
            recipient = fallback_node(candidates)
            tokens = (member.ckpt_tokens if member.prefill_done is not None
                      else member.context)
            n_bytes = tokens * kv_bytes_per_token(home.sim.cfg)
            ship_s = n_bytes / recipient.hardware.accel.ici_bw
            ship_j = n_bytes * recipient.hardware.accel.j_per_byte_ici
            recipient.book_shipping(ship_s, ship_j)
            member.shipped_bytes += n_bytes
            home.n_migrations_out += 1
            if telemetry is not None:
                telemetry.on_migration(home, recipient, tokens,
                                       n_bytes, ship_s, ship_j, now)
            heapq.heappush(events, (now + ship_s, seq, _SHIP_END,
                                    (recipient.node_id, member)))
            seq += 1
        else:
            # no same-model survivor: the KV (checkpointed or live) has
            # nowhere to land
            rerun_or_abandon(member, home, now, "no_survivor")

    def handle_failed(node: ClusterNode, now: float) -> None:
        """A node just went FAILED: every suspended decode becomes a
        refugee to rescue, every queued request re-routes."""
        while node.suspended:
            dispatch_refugee(node.suspended.popleft(), node, now)
        while node.waiting:
            route_or_retry(node.waiting.popleft(), 0, now)

    def apply_drains(now: float) -> None:
        """Straggler governance: let the policy drain (or un-drain)
        nodes.  Draining stops new routes, ships parked refugees off,
        and re-routes the queue; running decodes finish naturally —
        drain-before-gate, never mid-flight abandonment."""
        updates = policy.drain_updates(nodes, now)
        if not updates:
            return
        for d_nid, drain in updates:
            dnode = by_id[d_nid]
            if drain and not dnode.draining and not dnode.failed:
                dnode.draining = True
                if telemetry is not None:
                    telemetry.on_drain(dnode, True, now)
                while dnode.suspended:
                    dispatch_refugee(dnode.suspended.popleft(), dnode, now)
                while dnode.waiting:
                    route_or_retry(dnode.waiting.popleft(), 0, now)
            elif not drain and dnode.draining:
                dnode.draining = False
                if telemetry is not None:
                    telemetry.on_drain(dnode, False, now)

    # correlated-kill aggregation: crash events sharing one timestamp are
    # one domain outage (pre-loaded fault events pop contiguously at equal
    # time — lower sequence numbers than any runtime-pushed event)
    kill_batch = [None, 0]   # [timestamp, crash count]

    def flush_kill_batch() -> None:
        if kill_batch[0] is not None and telemetry is not None:
            telemetry.on_domain_outage(kill_batch[0], kill_batch[1])
        kill_batch[0], kill_batch[1] = None, 0

    for n in nodes:   # the fleet starts idle: give the autoscaler a shot
        arm_idle_timer(n, 0.0)

    while events:
        now, _, kind, payload = heapq.heappop(events)
        if sample_every is not None:
            # sample fleet state as of the previous event, stamped on the
            # period grid, before this event mutates it
            while next_sample <= now:
                telemetry.sample(nodes, next_sample)
                next_sample += sample_every
        if kind == _ARRIVAL:
            req = payload
            arrivals_left -= 1
            if autoscaler is not None:
                prewoken = 0
                for nid in autoscaler.on_arrival(req, nodes, now):
                    node = by_id[nid]
                    if node.power_state == GATED:   # proactive pre-wake
                        push(node, ("wake", node.begin_wake(now)))
                        prewoken += 1
                if telemetry is not None:
                    telemetry.on_prewake(autoscaler.name, prewoken)
            if fault_mode:
                eligible = [n for n in nodes if n.accepting]
                if not eligible:   # whole fleet down/draining: back off
                    schedule_retry(req, 0, now)
                    continue
                nid = policy.select(req, eligible, now)
                node = by_id.get(nid)
                if node is None or not node.accepting:
                    node = fallback_node(eligible)
                    nid = node.node_id
            else:
                nid = policy.select(req, nodes, now)
                if nid not in by_id:
                    raise ValueError(
                        f"{policy.name} routed to unknown node {nid}")
                node = by_id[nid]
            if telemetry is not None:
                telemetry.on_arrival(req, policy.name, nid, node.model_name,
                                     now)
            push(node, node.enqueue(req, now))
            if preempter is not None:
                # the arrival is queued; the preempter may cut the routed
                # node's decode segment to make room for it at the boundary
                victim = preempter.consider(req, node, nodes, now)
                if telemetry is not None:
                    telemetry.on_preempt_decision(preempter.name,
                                                  victim is not None)
                if victim is not None:
                    push(node, node.preempt_decode(victim, now))
        elif kind == _PHASE_END:
            nid, epoch = payload
            node = by_id[nid]
            if epoch != node.phase_epoch:
                continue   # segment was preempted; this end never happened
            completions, next_ev = node.on_phase_end(now)
            for c in completions:
                makespan = max(makespan, c.finish_s)
                rec = RequestRecord(
                    request_id=c.req.request_id,
                    node_id=node.node_id,
                    model=node.model_name,
                    tau_in=c.req.tau_in,
                    tau_out=c.req.tau_out,
                    arrival_s=c.req.arrival_s,
                    start_s=c.start_s,
                    finish_s=c.finish_s,
                    energy_j=c.energy_j,
                    isolated_runtime_s=c.isolated_runtime_s,
                    preemptions=c.preemptions,
                    migrations=c.migrations,
                    shipped_bytes=c.shipped_bytes,
                )
                policy.observe_completion(rec, now)
                if autoscaler is not None:
                    autoscaler.on_completion(rec, now)
                if preempter is not None:
                    preempter.observe_completion(rec, now)
                if telemetry is not None:
                    telemetry.on_completion(rec, now)
                records.append(rec)
            push(node, next_ev)
            if next_ev is None:
                if fault_mode and node.failed:
                    # crash quantized to this settle: rescue the refugees
                    handle_failed(node, now)
                else:
                    arm_idle_timer(node, now)
            if fault_mode and completions:
                apply_drains(now)   # fed by the observe_completion EWMA
        elif kind == _PREEMPT_END:
            nid, epoch = payload
            node = by_id[nid]
            if epoch != node.phase_epoch:
                continue   # a crash got there first: this settle is void
            next_ev = node.on_preempt_end(now)
            push(node, next_ev)
            if next_ev is None:
                if fault_mode and node.failed:
                    handle_failed(node, now)
                else:
                    arm_idle_timer(node, now)
        elif kind == _WAKE_END:
            nid, epoch = payload
            node = by_id[nid]
            if epoch != node.phase_epoch:
                continue   # node crashed mid-wake
            next_ev = node.on_wake_end(now)
            push(node, next_ev)
            if next_ev is None:   # pre-woken with nothing to do (yet)
                arm_idle_timer(node, now)
        elif kind == _GATE_END:
            nid, epoch = payload
            node = by_id[nid]
            if epoch != node.phase_epoch:
                continue   # node crashed mid-gate
            push(node, node.on_gate_end(now))
        elif kind == _FAULT:
            fev = payload
            node = by_id[fev.node_id]
            if telemetry is not None:
                telemetry.on_fault(fev, node, now)
            if fev.kind == CRASH:
                if kill_batch[0] is not None and kill_batch[0] != now:
                    flush_kill_batch()
                kill_batch[0] = now
                kill_batch[1] += 1
                crash_ev = node.begin_crash(now)
                if crash_ev is not None:
                    push(node, crash_ev)   # truncation settle scheduled
                elif node.failed:          # off-phase: crashed right here
                    handle_failed(node, now)
                # else: pending at an already-scheduled settle — the
                # _PHASE_END/_PREEMPT_END handler completes it
            elif fev.kind == RECOVER:
                if node.failed:
                    next_ev = node.recover(now)
                    push(node, next_ev)
                    if next_ev is None:
                        arm_idle_timer(node, now)
                elif node.crash_pending:
                    # the crash is still quantizing to its boundary: a
                    # node cannot recover before its failure lands —
                    # re-deliver the recovery at the settle instant (the
                    # settle event pops first there: earlier sequence)
                    heapq.heappush(
                        events,
                        (node.phase_end_s, seq, _FAULT,
                         dataclasses.replace(fev,
                                             time_s=node.phase_end_s)))
                    seq += 1
            elif fev.kind == SLOW:
                node.slowdown = fev.value
            else:   # NORMAL: straggler episode over
                node.slowdown = 1.0
            policy.on_fault(fev, nodes, now)
        elif kind == _CRASH_END:
            nid, epoch = payload
            node = by_id[nid]
            if epoch != node.phase_epoch:
                continue
            node.on_crash_settle(now)
            handle_failed(node, now)
        elif kind == _SHIP_END:
            nid, member = payload
            node = by_id[nid]
            if not node.accepting:
                # the recipient died (or started draining) while the KV
                # was in flight: ship onward from its books
                dispatch_refugee(member, node, now)
            else:
                push(node, node.receive_migrant(member, now))
        elif kind == _RETRY:
            req, attempts = payload
            route_or_retry(req, attempts, now)
        else:  # _IDLE_TIMER
            nid, token = payload
            node = by_id[nid]
            if (node.power_state == IDLE
                    and node.power_state_since == token
                    and node.can_gate
                    and autoscaler is not None):
                gate = autoscaler.should_gate(node, now)
                if telemetry is not None:
                    telemetry.on_gate_decision(autoscaler.name, gate)
                if gate:
                    push(node, node.begin_gate(now))
                elif arrivals_left > 0:
                    # declined (e.g. min_awake bound): re-check later — a
                    # node that never leaves IDLE must not be stranded
                    # powered after fleet conditions change.  Re-arming
                    # stops with the last arrival so the loop terminates.
                    arm_idle_timer(node, now)

    flush_kill_batch()
    if len(records) + len(abandoned) != len(trace):
        raise RuntimeError(
            f"served {len(records)} + abandoned {len(abandoned)} != "
            f"{len(trace)} requests — event loop bug")
    if any(n.suspended for n in nodes):
        raise RuntimeError("preempted requests left suspended at the end of "
                           "the trace — resume/rescue logic bug")
    records.sort(key=lambda r: r.request_id)
    abandoned.sort(key=lambda r: r.request_id)
    for n in nodes:   # close every node's books at the common horizon
        n.finalize(makespan)

    profiles = unique_profiles(nodes)
    # abandoned requests have no realized assignment: the objective is
    # evaluated over the completed records' own queries (identical to the
    # full trace when nothing was abandoned — record order is request_id
    # order, which is trace order)
    queries = (trace.queries() if not abandoned
               else [(r.tau_in, r.tau_out) for r in records])
    assigned = [r.model for r in records]
    objective = (objective_of_assignment(profiles, queries, assigned, zeta)
                 if records else 0.0)
    prof_of = {p.name: p for p in profiles}
    predicted = sum(float(prof_of[r.model].energy(r.tau_in, r.tau_out))
                    for r in records)

    report = ClusterReport(
        policy=policy.name,
        zeta=zeta,
        records=tuple(records),
        node_stats=per_node_stats(nodes, makespan),
        makespan_s=makespan,
        objective=objective,
        predicted_energy_j=predicted,
        replicas=tuple((name, tuple(nids)) for name, nids in replicas.items()),
        abandoned=tuple(abandoned),
    )
    if telemetry is not None:
        telemetry.finalize(nodes, report)
    return report


def fresh_nodes(builders: Sequence) -> list[ClusterNode]:
    """Call a list of zero-arg node factories — each policy comparison needs
    pristine node state, so callers pass builders rather than nodes."""
    return [b() for b in builders]


def compare_policies(
    trace: ArrivalTrace,
    node_builders: Sequence,
    policies: Sequence[RoutingPolicy],
    *,
    zeta: float = 0.5,
    autoscaler_builder=None,
    preempter_builder=None,
    faults: FaultTrace | None = None,
) -> dict[str, ClusterReport]:
    """Run every policy on identical fresh clusters over the same trace.
    `autoscaler_builder`/`preempter_builder` are zero-arg factories
    (autoscalers and preemption policies hold per-run state, so they need
    the same fresh-per-run treatment as nodes).  A `faults=` trace is
    replayed identically against every policy — the apples-to-apples
    availability comparison fig4's MTTF sweep plots."""
    out: dict[str, ClusterReport] = {}
    for pol in policies:
        nodes = fresh_nodes(node_builders)
        scaler = autoscaler_builder() if autoscaler_builder is not None else None
        pre = preempter_builder() if preempter_builder is not None else None
        out[pol.name] = simulate_cluster(trace, nodes, pol, zeta=zeta,
                                         autoscaler=scaler, preempter=pre,
                                         faults=faults)
    return out
