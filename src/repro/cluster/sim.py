"""The discrete-event loop: streaming arrivals over a heterogeneous fleet.

Two event kinds drive the simulation — request arrivals (from the trace)
and node phase completions (from the continuous-batching state machines).
Events are processed in (time, sequence) order; the sequence counter makes
simultaneous events deterministic, so a fixed trace + policy always yields
a bit-identical ClusterReport.
"""

from __future__ import annotations

import heapq
from typing import Sequence

from repro.cluster.metrics import ClusterReport, RequestRecord, per_node_stats
from repro.cluster.node import ClusterNode
from repro.cluster.policies import (
    RoutingPolicy,
    objective_of_assignment,
    unique_profiles,
)
from repro.cluster.trace import ArrivalTrace

_ARRIVAL, _PHASE_END = 0, 1


def simulate_cluster(
    trace: ArrivalTrace,
    nodes: Sequence[ClusterNode],
    policy: RoutingPolicy,
    *,
    zeta: float = 0.5,
) -> ClusterReport:
    """Serve the whole trace; returns the aggregate ClusterReport."""
    if not nodes:
        raise ValueError("need at least one node")
    by_id = {n.node_id: n for n in nodes}
    if len(by_id) != len(nodes):
        raise ValueError("node_ids must be unique")
    policy.attach(nodes, trace, zeta)

    events: list[tuple[float, int, int, object]] = []
    seq = 0
    for req in trace:
        heapq.heappush(events, (req.arrival_s, seq, _ARRIVAL, req))
        seq += 1

    records: list[RequestRecord] = []
    makespan = trace.duration_s

    def push_phase(node: ClusterNode, end_s: float | None) -> None:
        nonlocal seq
        if end_s is not None:
            heapq.heappush(events, (end_s, seq, _PHASE_END, node.node_id))
            seq += 1

    while events:
        now, _, kind, payload = heapq.heappop(events)
        if kind == _ARRIVAL:
            req = payload
            nid = policy.select(req, nodes, now)
            if nid not in by_id:
                raise ValueError(f"{policy.name} routed to unknown node {nid}")
            push_phase(by_id[nid], by_id[nid].enqueue(req, now))
        else:
            node = by_id[payload]
            completions, next_end = node.on_phase_end(now)
            for c in completions:
                makespan = max(makespan, c.finish_s)
                records.append(RequestRecord(
                    request_id=c.req.request_id,
                    node_id=node.node_id,
                    model=node.model_name,
                    tau_in=c.req.tau_in,
                    tau_out=c.req.tau_out,
                    arrival_s=c.req.arrival_s,
                    start_s=c.start_s,
                    finish_s=c.finish_s,
                    energy_j=c.energy_j,
                    isolated_runtime_s=c.isolated_runtime_s,
                ))
            push_phase(node, next_end)

    if len(records) != len(trace):
        raise RuntimeError(
            f"served {len(records)}/{len(trace)} requests — event loop bug")
    records.sort(key=lambda r: r.request_id)

    profiles = unique_profiles(nodes)
    queries = trace.queries()
    assigned = [r.model for r in records]
    objective = (objective_of_assignment(profiles, queries, assigned, zeta)
                 if records else 0.0)
    prof_of = {p.name: p for p in profiles}
    predicted = sum(float(prof_of[r.model].energy(r.tau_in, r.tau_out))
                    for r in records)

    return ClusterReport(
        policy=policy.name,
        zeta=zeta,
        records=tuple(records),
        node_stats=per_node_stats(nodes, makespan),
        makespan_s=makespan,
        objective=objective,
        predicted_energy_j=predicted,
    )


def fresh_nodes(builders: Sequence) -> list[ClusterNode]:
    """Call a list of zero-arg node factories — each policy comparison needs
    pristine node state, so callers pass builders rather than nodes."""
    return [b() for b in builders]


def compare_policies(
    trace: ArrivalTrace,
    node_builders: Sequence,
    policies: Sequence[RoutingPolicy],
    *,
    zeta: float = 0.5,
) -> dict[str, ClusterReport]:
    """Run every policy on identical fresh clusters over the same trace."""
    out: dict[str, ClusterReport] = {}
    for pol in policies:
        nodes = fresh_nodes(node_builders)
        out[pol.name] = simulate_cluster(trace, nodes, pol, zeta=zeta)
    return out
