"""Online cluster-serving simulator: the offline→online bridge.

The paper derives workload-based energy models and uses them for *offline*
energy-optimal scheduling over a known workload.  This package serves the
same workloads as *streaming traffic* against a heterogeneous fleet and
quantifies the offline→online optimality gap — and, since PR 4, manages
the fleet's *power*: node power-gating under pluggable autoscalers,
per-phase DVFS, and non-oracle τout prediction.  PR 5 adds the last open
lever from PR 1's list: first-class *multi-replica models* (several nodes
hosting one model, with a replica registry, a wake-cost-aware replica-set
router, per-model replica-count autoscaling, and a replica-aware offline
oracle) and *decode-boundary preemption* (suspend a decode at its next
step boundary with the KV position intact, resume for free when a slot
opens — energy split exactly by the closed-form decode integral).  This
PR adds *failure realism*: seeded fault injection (crashes, recoveries,
stragglers — faults.py), cross-node migration rescue (a crashed node's
refugees ship their KV to a healthy replica under an explicit
interconnect cost model), straggler governance and retry/abandon
policies (FailoverPolicy), and a failure-aware offline oracle
(FailureAwareOraclePolicy) that re-solves the paper's assignment against
the realized fault trace.  The latest layer is *blast-radius realism*:
correlated failure domains (FaultDomain rack/PDU topologies whose whole
leaf fails at once), prefill checkpointing (CheckpointConfig: durable
KV persistence at token-interval boundaries — a crash loses at most one
interval instead of the whole prefill, restored refugees pay only the
closed-form unfinished-suffix cost), and survivability-aware control
(DomainSpreadPolicy anti-affinity routing, the MTTF-conditioned
SurvivabilityAutoscalePolicy availability floor, and domain-masked
capacity in the failure-aware oracle).  The newest layer is
*conversational serving*: multi-turn session traces (session_trace —
each turn's prompt re-submits the grown shared prefix after a think-time
gap), a per-node KV prefix cache (PrefixCacheConfig: LRU over sessions,
capacity in kv_bytes_per_token units, crash-volatile) that serves a warm
turn with the exact telescoping suffix prefill prefill_cost(τin) −
prefill_cost(cached) plus a closed-form cache-read DMA term (the eighth
`cache_read` energy bucket), session-sticky routing
(SessionAffinityPolicy), and a cache-aware oracle
(CacheAwareOraclePolicy) conditioned on the realized hit sequence.

Module map (the event model, and how the pieces plug together):

    trace.py      — TracedRequest / ArrivalTrace + generators (Poisson,
                    bursty Gamma, diurnal thinning, on/off square-wave
                    churn, replay of the offline Alpaca-like case-study
                    workload, and session_trace — multi-turn sessions
                    whose TracedRequests carry session_id/turn/
                    prefix_tokens).  A trace is the only stochastic
                    input; everything downstream is deterministic.
    faults.py     — FaultEvent / FaultTrace / FaultInjector: seeded node
                    crash–recovery and straggler onset–clear processes
                    (exponential MTTF/MTTR alternating renewals, per-node,
                    from data.workloads.fault_trace).  A FaultTrace is the
                    second stochastic input; replaying the same trace over
                    the same arrival trace is byte-identical, and passing
                    faults=None (the default) leaves the loop bit-identical
                    to the pre-fault simulator.  FaultDomain models the
                    node → rack → PDU co-failure topology
                    (rack_pdu_topology builds it); a correlated trace runs
                    one crash/recover renewal per leaf domain, killing
                    every member simultaneously — the one-node-per-domain
                    degenerate topology reproduces the independent traces
                    bit-identically.
    node.py       — ClusterNode: one model replica on one hardware Node.
                    Continuous batching at phase granularity (batched
                    prefill, decode segments to the next completion
                    boundary, joiner prefills in between).  Per-phase
                    time/energy delegates to repro.energy.simulator, so an
                    uncontended node conserves energy against the
                    per-request AnalyticLLMSimulator.  Owns the power-state
                    machine, the per-phase DVFS governor (below), and the
                    optional per-node KV prefix cache (PrefixCacheConfig:
                    LRU admission/eviction at request-arrival boundaries,
                    a hit starts the warm request as a dedicated batch-1
                    suffix prefill charged prefill_cost(τin) −
                    prefill_cost(cached), a crash invalidates the whole
                    cache).
    power.py      — PowerConfig (transition latency/energy, gated residual
                    draw) and autoscalers: reactive_idle (gate after an
                    idle timeout, wake on demand) and predictive_rate
                    (sliding-window arrival-rate estimate sizes the awake
                    fleet, pre-waking ahead of need).
    predictors.py — TauOutPredictor: per-model empirical τout quantiles
                    over a sliding completion window (Zheng-et-al-style
                    length estimation) — the non-oracle information model
                    for the routers.
    policies.py   — online routers: round_robin, random, least_loaded,
                    greedy_energy (profile-predicted argmin), zeta_online
                    (Eq. 2 with causal running normalizers), zeta_replan
                    (the γ-capacitated partition maintained online over a
                    sliding window via core.sweep.IncrementalScheduler's
                    warm-start reschedule), replica_energy (the replica-
                    set router: wake-cost-aware Eq. 2 argmin over nodes —
                    a gated replica's wake energy, amortized over an
                    expected burst, is priced into the objective instead
                    of only breaking ties), offline_oracle (replays
                    core.scheduler.schedule() over the full trace — the
                    lower bound on the Eq. 2 objective), and
                    replica_oracle (schedule_replicated replay: the same
                    bound, committed to per-node replica placement).
                    Preemption policies live here too: SLOPreemptionPolicy
                    evicts the lowest-ζ-value active decode when the
                    higher-value queue-head request (the one the freed
                    slot actually admits) would miss its slowdown SLO —
                    causally, under an optional tau_out_predictor.  The
                    energy-aware policies accept tau_out_predictor= to
                    downgrade their information model from oracle to
                    learned.  Failure handling: FailoverPolicy wraps any
                    inner router with capped-exponential-backoff retry,
                    deadline-aware abandonment, crash re-run consent, and
                    EWMA-latency straggler detection that drains chronic
                    stragglers (never a model's last accepting replica)
                    and undrains them on recovery or cooldown;
                    FailureAwareOraclePolicy extends the offline oracle
                    with a liveness mask — the assignment argmin excludes
                    models whose every host is down forever from a
                    query's arrival, so the bound stays meaningful under
                    faults.  Session serving: SessionAffinityPolicy
                    steers a follow-up turn back to the node whose cache
                    is warm (the energy term discounted by the warm-
                    prefix fraction, skipped when that node is waking/
                    gated/failed), and CacheAwareOraclePolicy re-solves
                    the offline optimum over cost columns discounted by
                    the *realized* hit sequence
                    (realized_cache_hits(report.records)) — scoring the
                    online assignment under the same discounted matrix
                    keeps oracle ≤ online exact per run.
                    New policies subclass RoutingPolicy and
                    implement select(req, nodes, now); attach() gives them
                    the fleet and (for oracle-grade information models)
                    the trace; observe_completion() is their causal
                    feedback channel, and the fault hooks (retry_delay,
                    on_fault, drain_updates, allow_rerun) have safe
                    defaults so existing policies run unchanged under
                    fault injection.
    engine/       — the sharded discrete-event engine (see the layer
                    diagram below).  events.py types the ten event kinds
                    (EventKind: arrivals, node phase completions,
                    preemption settlements, wake/gate completions,
                    autoscaler idle timers, fault events, crash-
                    quantization settlements, KV-shipment completions,
                    retry re-submissions) with payload dataclasses —
                    phase-shaped events carry the node's phase epoch so
                    a preempted (or crashed) segment's stale end event
                    is dropped.  shard.py owns one node group's heap;
                    mailbox.py is the cross-shard channel; runner.py
                    merges them in fleet-wide (time, seq) order (exact
                    at any partition), runs barrier-windowed parallel
                    drains over decomposable configs, and orchestrates
                    the rescue path: a crashed node's refugees migrate,
                    re-run, or are abandoned with their joules booked
                    as wasted.
    sim.py        — the facade over the engine's exact merge mode:
                    simulate_cluster (shard count from REPRO_SIM_SHARDS,
                    default 1 — any value is bit-identical), and
                    compare_policies() rerunning a trace (and fault
                    trace) over fresh fleets for an apples-to-apples
                    policy table.
    metrics.py    — ClusterReport: the eight-bucket busy/idle/gated/
                    transition/shipping/checkpoint/wasted/cache_read
                    energy split (the time buckets partition each node's
                    horizon — FAILED time draws exactly 0 W; shipping,
                    checkpointing, and cache reads are background
                    NIC/DMA — and the buckets sum exactly to total
                    energy), cache hit/miss/eviction counters and
                    hit-token reuse depth, J/token, latency p50/p95/
                    p99, slowdown-SLO attainment, goodput under
                    abandonment, per-node utilization, AbandonedRecords,
                    and the realized Eq. 2 objective used to measure the
                    gap to the offline oracle.  `from_registry` rebuilds
                    the aggregate view from a telemetry registry — the
                    reduction path for sharded runs.
    ../obs/       — the observability layer (repro.obs): a Telemetry
                    facade bundling a mergeable MetricsRegistry, an
                    optional Chrome-trace EventTracer, and an optional
                    live InvariantAuditor.  Pass telemetry= to
                    simulate_cluster; hooks are read-only observers, so
                    the ClusterReport is byte-identical on or off (the
                    perf-suite `metrics_overhead` gate bounds the cost
                    and pins the identity exact).

Engine layers (one simulation run; the perf-suite `sharded_replay`
gate pins every artifact byte-identical across partitions)::

                    ArrivalTrace + FaultTrace (preloaded, (time, seq))
                                        │
                                        v
                   ┌─────────────── Mailbox ────────────────┐
                   │  cross-shard: arrivals, domain faults,  │
                   │  KV shipments, retries, pre-wakes       │
                   └──┬──────────────┬──────────────┬────────┘
          routed by policies.py over the merged fleet view
                      │              │              │
                      v              v              v
                ┌──────────┐   ┌──────────┐   ┌──────────┐
                │ NodeShard│   │ NodeShard│   │ NodeShard│   one heap +
                │  nodes   │   │  nodes   │   │  nodes   │   node-local
                │  0..i    │   │  i+1..j  │   │  j+1..n  │   events each
                └────┬─────┘   └────┬─────┘   └────┬─────┘
                     └──────────────┼──────────────┘
                                    v
        Runner — merge mode: consume the globally least (time, seq)
        across mailbox + shards, sequence numbers from ONE fleet-wide
        allocator at the monolith's handler sites (bit-identical by
        construction, any partition, any configuration); windowed
        mode: shards drain independently below the conservative
        horizon min(next barrier, now + cross_shard_floor_s) between
        mailbox barriers, completions replayed in a partition-
        invariant order (decomposable configs; workers>1 forks the
        shards into a process pool routing over light node views).
                                    │
                                    v
        obs/ children attach per shard + one fleet child, fold at
        finalize through the mergeable-registry reduction; tracer
        records carry fleet-order stamps so absorbed traces replay
        in merge order.  →  ClusterReport (eight-bucket partition)

Power-state lifecycle (driven by ClusterNode, timed by sim.py).
Telemetry hooks fire at the marked (*) edges: `on_power_begin` as a
WAKING/GATING ramp starts, `on_power_span` as it completes, the
autoscaler's gate verdicts/pre-wakes via `on_gate_decision`/`on_prewake`,
and `on_fault` as a fault event lands::

        enqueue / next phase         idle timer + autoscaler ok
    ACTIVE <────────────> IDLE ─────────────────────────────> GATING*
       ^  │                ^  │                                  │ gate_s
       │  │ wake done      │  │ wake done (no queued work)       v
       │  │ (work waiting) │ WAKING* <──────────────────────── GATED
       │  │                │    on-demand (routed request,       │
       │  │                │    landed migrant) or pre-wake      │
       │  v                │                                     v
       │ FAILED* <─────────┴─(crash fault event, from any state)─┘
       │   │  crash quantized to the next exact charge boundary:
       │   │  mid-decode settles the truncated segment first (the
       │   │  donor half of the cross-node split), then 0 W while
       │   │  down; active members become suspended *refugees*
       │   └──────> recovery fault event: FAILED → IDLE, rejoins
       └──────────  the eligible set (serves anything queued)

    Two governance overlays are orthogonal to the power state:
    DRAINING (FailoverPolicy flagged a chronic straggler: the node
    finishes in-flight work but accepts no new routing; suspended work
    migrates off; cleared on recovery/cooldown) and SLOW (a straggler
    fault stretches every phase by σ — same work, σ× the wall time, the
    stalled extra seconds at accelerator static draw).

Request lifecycle (PREEMPTED/RESUMING added by the preemption layer;
MIGRATING/RETRY/ABANDONED by the fault layer; CHECKPOINTING/RESTORING
by the checkpoint layer).  Telemetry hooks: `on_arrival` at routing,
`on_phase_settle` (plus the auditor's conservation checks) at every
prefill/decode/restore charge, `on_preempt_split` at a preemption or
crash settlement (auditing the split-energy identity), `on_migration`
as a KV shipment starts, `on_checkpoint` at every durable persist,
`on_restore` as a suffix re-run begins, `on_retry`/`on_abandon` on the
failover path, `on_completion` at DONE.  The prefix-cache layer adds
`on_cache_lookup` at every session-request admission, `on_cache_hit`
(plus the auditor's telescoping + closed-form cache-read checks) as a
warm suffix prefill starts, `on_cache_evict` at an LRU displacement, and
`on_cache_invalidate` as a crash wipes a node's cache::

              routed*       joiner prefill*         last token*
    WAITING ──────────> QUEUED ─────────> DECODING ──────────> DONE
       ^  ^                                │    ^
       │  │        preempter picks victim; │    │ RESUMING: rejoins the
       │  │        segment cut at the next │    │ active set at a phase
       │  │        decode step boundary*   v    │ start with a free slot
       │  │                               PREEMPTED (suspended: KV
       │  │                                position intact, zero-cost
       │  │                                resume — never re-prefilled)
       │  │                                │ host node crashes (or is
       │  │                                │ drained off a straggler)
       │  │                                v
       │  │  KV landed on the recipient  MIGRATING* — refugee's KV ships
       │  ├──────────────────────────────  to an accepting same-model
       │  │                                node: bytes/ici_bw seconds,
       │  │                                bytes·j_per_byte_ici joules on
       │  │                                the recipient's meter; resumes
       │  │                                via the PREEMPTED path
       │  │  re-run from scratch (crash   │ no accepting same-model node
       │  └─────────────────────────────  v
       │     mid-prefill, or rerun=True) RESCUE FAILED → accrued joules
       │ retry* (capped exponential       move busy → wasted* and the
       └── backoff while no node          request books an
           accepts; deadline/attempts     AbandonedRecord (reason:
           exhausted → abandoned*)        no_survivor/no_capacity/
                                          deadline)

    A preempted request keeps everything it has generated; the truncated
    decode segment is charged for exactly the steps it ran (the closed-
    form integral split at the boundary — the two halves sum to the
    unpreempted decode_cost to 1e-9), and the slot it frees admits the
    queue-head request the preemption policy cut it for.  A crash is the
    same split crossing nodes: the donor's truncated charge + the
    shipping energy + the recipient's resumed charge reconcile against
    the unfaulted closed form to 1e-9, and un-rescuable work is booked
    as wasted so conservation still closes.

    Under a CheckpointConfig the prefill itself gains two states.  A
    prefill runs as a chain of interval_tokens-sized chunks (each
    chunk's charge is the exact closed-form difference prefill_cost(b₂)
    − prefill_cost(b₁) at one pinned operating point, so the chain
    telescopes to the unchunked prefill to 1e-9); at every interior
    boundary the request is CHECKPOINTING — the fresh KV prefix
    persists durably at bytes·j_per_byte_ckpt joules over bytes/ckpt_bw
    background-DMA seconds (the seventh `checkpoint` bucket, outside
    the horizon partition like shipping).  A crash quantized to a chunk
    boundary wastes only that chunk's charge (members roll back to the
    last durable checkpoint); the refugee ships its checkpointed prefix
    like a decode refugee and enters RESTORING on the recipient — a
    dedicated batch-1 phase charging prefill_cost(τin) −
    prefill_cost(ckpt), the telescoping suffix — after which it is
    decode-ready.  Without a CheckpointConfig the crash semantics are
    bit-identical to the pre-checkpoint simulator (a mid-prefill crash
    completes the pass, then ships the full KV).

    Under a PrefixCacheConfig a session request's admission consults the
    node's KV prefix cache.  A hit (the session's entry holds cached > 0
    of this turn's prefix_tokens) pins the entry and the request later
    starts as a dedicated batch-1 *warm suffix prefill* — charged the
    exact telescoping difference prefill_cost(τin) − prefill_cost(cached)
    at one pinned operating point, the same contract as the checkpoint
    RESTORING phase — plus a closed-form cache-read term: cached ·
    kv_bytes_per_token bytes at bytes/read_bw background-DMA seconds and
    bytes·j_per_byte_read joules (the eighth `cache_read` bucket, outside
    the horizon partition like shipping).  A miss reserves τin + τout
    tokens for the session, LRU-evicting unpinned entries; a crash
    invalidates the node's whole cache (warm state is volatile), while
    power-gating preserves it.  Without a PrefixCacheConfig (the default)
    every path is bit-identical to the cache-free simulator.

DVFS operating-point semantics: an AcceleratorSpec exposes discrete
`dvfs_scales`; at scale s, peak_flops ∝ s, hbm_bw keeps its `dvfs_bw_floor`
fraction plus the coupled remainder, dyn_w ∝ s^α, idle_w fixed.  A node
with dvfs="per_phase" asks the simulator for the energy-minimal point per
phase (closed-form evaluation per candidate, host draw included), so
compute-bound prefill runs near max clock while bandwidth-bound decode
underclocks; freq_scale= pins a fixed point instead.

Gap definitions measured by benchmarks/fig4_online_gap.py:

    commitment gap  — oracle-τout online router vs the offline-oracle
                      replay: the cost of routing one request at a time,
                      with full per-request knowledge.
    information gap — predicted-τout router vs the same router with
                      oracle τout: the cost of *not knowing* output
                      lengths, isolated from the commitment gap.
    availability    — the fault axis: energy, SLO attainment, and
                      goodput vs node MTTF, FailoverPolicy rescue vs
                      no-fault baseline vs the failure-aware oracle
                      bound on the realized fault trace.

Entry points: benchmarks/fig4_online_gap.py (arrival-rate × ζ sweep,
power-gating and DVFS columns, the two-gap split) and
examples/cluster_sim.py (a narrated single run).
"""

from repro.cluster.engine import (  # noqa: F401
    Event,
    EventKind,
    Mailbox,
    NodeShard,
    Runner,
    cross_shard_floor_s,
    partition_nodes,
)
from repro.cluster.faults import (  # noqa: F401
    FaultDomain,
    FaultEvent,
    FaultInjector,
    FaultTrace,
    domain_groups,
    domain_index,
    rack_pdu_topology,
)
from repro.cluster.metrics import (  # noqa: F401
    AbandonedRecord,
    ClusterReport,
    NodeStats,
    RequestRecord,
)
from repro.cluster.node import (  # noqa: F401
    CheckpointConfig,
    ClusterNode,
    PrefixCacheConfig,
)
from repro.cluster.policies import (  # noqa: F401
    DEFAULT_POLICIES,
    CacheAwareOraclePolicy,
    DomainSpreadPolicy,
    FailoverPolicy,
    FailureAwareOraclePolicy,
    GreedyEnergyPolicy,
    LeastLoadedPolicy,
    OfflineOraclePolicy,
    PreemptionPolicy,
    RandomPolicy,
    ReplicaEnergyPolicy,
    ReplicaOraclePolicy,
    RoundRobinPolicy,
    RoutingPolicy,
    SLOPreemptionPolicy,
    SessionAffinityPolicy,
    ZetaOnlinePolicy,
    ZetaReplanPolicy,
    objective_of_assignment,
    realized_cache_hits,
    replica_registry,
)
from repro.cluster.power import (  # noqa: F401
    AutoscalePolicy,
    PowerConfig,
    PredictiveRatePolicy,
    ReactiveIdlePolicy,
    ReplicaRatePolicy,
    SurvivabilityAutoscalePolicy,
)
from repro.cluster.predictors import TauOutPredictor  # noqa: F401
from repro.cluster.sim import compare_policies, fresh_nodes, simulate_cluster  # noqa: F401
from repro.cluster.trace import (  # noqa: F401
    ArrivalTrace,
    TracedRequest,
    bursty_trace,
    diurnal_trace,
    onoff_trace,
    poisson_trace,
    replay_trace,
    session_trace,
    timestamped_trace,
)
