"""Online cluster-serving simulator: the offline→online bridge.

The paper derives workload-based energy models and uses them for *offline*
energy-optimal scheduling over a known workload.  This package serves the
same workloads as *streaming traffic* against a heterogeneous fleet and
quantifies the offline→online optimality gap.

Module map (the event model, and how the pieces plug together):

    trace.py    — TracedRequest / ArrivalTrace + generators (Poisson,
                  bursty Gamma, diurnal thinning, replay of the offline
                  Alpaca-like case-study workload).  A trace is the only
                  stochastic input; everything downstream is deterministic.
    node.py     — ClusterNode: one model replica on one hardware Node.
                  Continuous batching at phase granularity (batched prefill,
                  decode segments to the next completion boundary, joiner
                  prefills in between).  Per-phase time/energy delegates to
                  repro.energy.simulator, so an uncontended node conserves
                  energy against the per-request AnalyticLLMSimulator.
    policies.py — online routers: round_robin, random, least_loaded,
                  greedy_energy (profile-predicted argmin), zeta_online
                  (Eq. 2 with causal running normalizers), zeta_replan
                  (the γ-capacitated partition maintained online over a
                  sliding window via core.sweep.IncrementalScheduler's
                  warm-start reschedule), and offline_oracle (replays
                  core.scheduler.schedule() over the full trace — the
                  lower bound on the Eq. 2 objective).
                  New policies subclass RoutingPolicy and implement
                  select(req, nodes, now); attach() gives them the fleet
                  and (for oracle-grade information models) the trace.
    sim.py      — the discrete-event loop.  Two event kinds: arrivals and
                  node phase completions, processed in (time, seq) order so
                  ties are deterministic.  compare_policies() reruns a trace
                  over fresh fleets for an apples-to-apples policy table.
    metrics.py  — ClusterReport: busy vs idle energy split, J/token,
                  latency p50/p95/p99, slowdown-SLO attainment, per-node
                  utilization, and the realized Eq. 2 objective used to
                  measure the gap to the offline oracle.

Entry points: benchmarks/fig4_online_gap.py (arrival-rate × ζ sweep) and
examples/cluster_sim.py (a narrated single run).
"""

from repro.cluster.metrics import ClusterReport, NodeStats, RequestRecord  # noqa: F401
from repro.cluster.node import ClusterNode  # noqa: F401
from repro.cluster.policies import (  # noqa: F401
    DEFAULT_POLICIES,
    GreedyEnergyPolicy,
    LeastLoadedPolicy,
    OfflineOraclePolicy,
    RandomPolicy,
    RoundRobinPolicy,
    RoutingPolicy,
    ZetaOnlinePolicy,
    ZetaReplanPolicy,
)
from repro.cluster.sim import compare_policies, fresh_nodes, simulate_cluster  # noqa: F401
from repro.cluster.trace import (  # noqa: F401
    ArrivalTrace,
    TracedRequest,
    bursty_trace,
    diurnal_trace,
    poisson_trace,
    replay_trace,
    timestamped_trace,
)
