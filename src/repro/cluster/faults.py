"""Fault injection: seeded node crashes, recoveries, stragglers, and
correlated failure domains.

The cluster simulator assumed every node survives the horizon; this
module supplies the disruption stream that breaks that assumption in a
*replayable* way.  A :class:`FaultTrace` is the failure-side counterpart
of an :class:`~repro.cluster.trace.ArrivalTrace` — an immutable,
time-sorted tuple of :class:`FaultEvent`\\ s that, together with the
arrival trace, fully determines a faulted run (same traces + policy →
bit-identical ClusterReport; an *empty* fault trace is bit-identical to
running with no faults at all — both invariants are pinned in
tests/test_faults.py).

Four event kinds:

  * ``crash``   — the node fails.  Takes effect at the next decode step
                  boundary (the in-flight token finishes) or prefill end,
                  immediately when off-phase — so every energy charge
                  stays an exact closed-form boundary charge.  Active and
                  suspended decodes become *refugees*: the sim ships their
                  KV to a healthy replica (``node.py``/``sim.py``
                  migration) or books their accrued joules as wasted.
                  Checkpointed prefills (``node.CheckpointConfig``) ship
                  their persisted prefix the same way and restart paying
                  only the closed-form cost of the unfinished suffix.
  * ``recover`` — the node powers back up into IDLE and rejoins the
                  eligible set.
  * ``slow``    — a sustained straggler begins: every subsequent phase is
                  stretched by ``value`` (σ ≥ 1) in wall time, with the
                  extra seconds burning static power (see
                  ``ClusterNode._stretched``).
  * ``normal``  — the straggler ends (σ back to 1).

:class:`FaultInjector` draws the stream from configurable exponential
MTTF/MTTR holding times (delegating to
:func:`repro.data.workloads.fault_trace`, the seeded generator exported
next to the arrival-time generators), mapping generator node indexes onto
real fleet node ids.

Correlated blast radii: real fleets do not fail one node at a time — a
rack switch or PDU leg takes out every node behind it at once.
:class:`FaultDomain` models the fleet topology as a node → rack → PDU
tree; ``FaultDomain.groups()`` flattens it into the co-failure partition
(one tuple of node ids per leaf domain) that the correlated generator
consumes: each group runs ONE crash/recover renewal process whose events
are emitted simultaneously for every member.  Per-node independent
faults are the degenerate one-node-per-domain topology — bit-identical
to the PR 7 traces, pinned in tests.
"""

from __future__ import annotations

import dataclasses
import math
from bisect import bisect_right
from typing import Iterator, Sequence

from repro.data.workloads import fault_trace as _raw_fault_trace

CRASH = "crash"
RECOVER = "recover"
SLOW = "slow"
NORMAL = "normal"
FAULT_KINDS = (CRASH, RECOVER, SLOW, NORMAL)

# kinds whose `value` carries no payload — anything but the 1.0 default
# is an authoring error (e.g. a slowdown factor attached to a crash)
_UNIT_VALUE_KINDS = frozenset((CRASH, RECOVER, NORMAL))


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One disruption: at `time_s`, `node_id` crashes / recovers /
    starts straggling at factor `value` / returns to normal."""

    time_s: float
    node_id: int
    kind: str
    value: float = 1.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind == SLOW and self.value < 1.0:
            raise ValueError("straggler slowdown must be >= 1")
        if self.kind in _UNIT_VALUE_KINDS and self.value != 1.0:
            raise ValueError(
                f"{self.kind!r} events carry no payload: value must be 1.0, "
                f"got {self.value}")


@dataclasses.dataclass(frozen=True)
class FaultDomain:
    """One blast radius in the fleet topology (node → rack → PDU tree).

    A domain either holds node ids directly (a leaf: one rack, one PDU
    leg) or groups child domains — never both.  ``groups()`` flattens
    the tree into the co-failure partition the correlated generator
    consumes: one tuple of node ids per leaf domain, in tree order."""

    name: str
    nodes: tuple[int, ...] = ()
    children: tuple["FaultDomain", ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "nodes", tuple(self.nodes))
        object.__setattr__(self, "children", tuple(self.children))
        if self.nodes and self.children:
            raise ValueError(
                f"FaultDomain {self.name!r} holds nodes or children, not both")

    @property
    def all_nodes(self) -> tuple[int, ...]:
        """Every node id under this domain, in tree order."""
        if self.nodes:
            return self.nodes
        out: list[int] = []
        for child in self.children:
            out.extend(child.all_nodes)
        return tuple(out)

    def groups(self) -> tuple[tuple[int, ...], ...]:
        """Co-failure partition: one node-id tuple per leaf domain."""
        if self.nodes:
            return (self.nodes,)
        out: list[tuple[int, ...]] = []
        for child in self.children:
            out.extend(child.groups())
        return tuple(out)


def rack_pdu_topology(node_ids: Sequence[int], *, rack_size: int,
                      racks_per_pdu: int | None = None) -> FaultDomain:
    """Standard node → rack → PDU tree over `node_ids`: consecutive runs
    of `rack_size` ids share a rack; with `racks_per_pdu`, consecutive
    runs of racks share a PDU leg.  The co-failure granularity is the
    rack (the leaf level) — pass ``FaultDomain(name, nodes=...)`` groups
    directly for coarser PDU-sized blast radii."""
    if rack_size < 1:
        raise ValueError(f"rack_size must be >= 1, got {rack_size}")
    ids = tuple(node_ids)
    if not ids:
        raise ValueError("need at least one node id")
    racks = tuple(
        FaultDomain(name=f"rack{r}", nodes=ids[i:i + rack_size])
        for r, i in enumerate(range(0, len(ids), rack_size)))
    if racks_per_pdu is None:
        return FaultDomain(name="cluster", children=racks)
    if racks_per_pdu < 1:
        raise ValueError(f"racks_per_pdu must be >= 1, got {racks_per_pdu}")
    pdus = tuple(
        FaultDomain(name=f"pdu{p}", children=racks[i:i + racks_per_pdu])
        for p, i in enumerate(range(0, len(racks), racks_per_pdu)))
    return FaultDomain(name="cluster", children=pdus)


def domain_groups(
    domains: "FaultDomain | Sequence[Sequence[int]] | None",
) -> tuple[tuple[int, ...], ...] | None:
    """Normalize a domain spec — a FaultDomain tree or a flat partition —
    into the canonical tuple-of-tuples co-failure partition."""
    if domains is None:
        return None
    if isinstance(domains, FaultDomain):
        return domains.groups()
    return tuple(tuple(g) for g in domains)


def domain_index(
    domains: "FaultDomain | Sequence[Sequence[int]]",
) -> dict[int, int]:
    """node id → co-failure group ordinal.  Raises on a node claimed by
    two domains; nodes absent from `domains` are simply missing (callers
    treat them as singleton domains of their own)."""
    out: dict[int, int] = {}
    for gi, group in enumerate(domain_groups(domains)):
        for nid in group:
            if nid in out:
                raise ValueError(f"node {nid} appears in two fault domains")
            out[nid] = gi
    return out


@dataclasses.dataclass(frozen=True)
class FaultTrace:
    """Immutable, time-sorted fault stream (replayable alongside the
    arrival trace).  `domains`, when set, records the co-failure
    partition (tuples of node ids) the trace was generated under —
    metadata consumed by survivability-aware policies, not by replay.

    `__post_init__` builds a per-node [crash, recover) interval index
    once (bisected by `is_down`) and rejects malformed streams: a
    RECOVER with no preceding CRASH is an authoring error.  A repeated
    CRASH while already down stays idempotent — correlated domain traces
    legitimately re-kill a node that a wider outage already took down."""

    name: str
    events: tuple[FaultEvent, ...]
    domains: tuple[tuple[int, ...], ...] | None = None

    def __post_init__(self):
        times = [ev.time_s for ev in self.events]
        if times != sorted(times):
            raise ValueError("fault events must be time-sorted")
        index: dict[int, tuple[list[float], list[float]]] = {}
        open_at: dict[int, float] = {}
        for ev in self.events:
            if ev.kind == CRASH:
                open_at.setdefault(ev.node_id, ev.time_s)
            elif ev.kind == RECOVER:
                if ev.node_id not in open_at:
                    raise ValueError(
                        f"recover for node {ev.node_id} at t={ev.time_s} "
                        "with no preceding crash")
                starts, ends = index.setdefault(ev.node_id, ([], []))
                starts.append(open_at.pop(ev.node_id))
                ends.append(ev.time_s)
        for nid, t0 in open_at.items():
            starts, ends = index.setdefault(nid, ([], []))
            starts.append(t0)
            ends.append(math.inf)
        object.__setattr__(
            self, "_down_index",
            {nid: (tuple(s), tuple(e)) for nid, (s, e) in index.items()})
        if self.domains is not None:
            object.__setattr__(self, "domains",
                               tuple(tuple(g) for g in self.domains))
            domain_index(self.domains)  # raises on overlapping domains

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self) -> Iterator[FaultEvent]:
        return iter(self.events)

    def down_intervals(self, node_id: int) -> list[tuple[float, float]]:
        """[crash, recover) spans for one node; an unrecovered crash
        yields an interval open to +inf."""
        starts, ends = self._down_index.get(node_id, ((), ()))
        return list(zip(starts, ends))

    def is_down(self, node_id: int, t: float) -> bool:
        starts, ends = self._down_index.get(node_id, ((), ()))
        i = bisect_right(starts, t) - 1
        return i >= 0 and t < ends[i]

    def down_forever_from(self, node_id: int, t: float) -> bool:
        """True when the node is down at `t` and never recovers — the
        liveness notion the failure-aware oracle excludes capacity by
        (a model is only *lost* to a request if every host is gone for
        good; anything that recovers is still reachable via retry)."""
        spans = self.down_intervals(node_id)
        return bool(spans) and spans[-1][1] == math.inf and spans[-1][0] <= t


@dataclasses.dataclass(frozen=True)
class FaultInjector:
    """Seeded crash/recovery + straggler event source.

    Holding times are exponential: nodes stay up for Exp(`mttf_s`) and
    down for Exp(`mttr_s`); independently, they run healthy for
    Exp(`straggle_mttf_s`) and straggle for Exp(`straggle_mttr_s`) at a
    slowdown drawn uniformly from `slowdown_range`.  A None MTTF disables
    that process.  `generate` is deterministic in (seed, node_ids,
    horizon_s) — the replayable-trace contract.

    `domains` (a FaultDomain tree or flat node-id partition) switches the
    crash/recover process to *correlated* mode: one renewal process per
    co-failure group, emitting simultaneous events for every member
    (straggling stays per-node — a slow NIC is not a rack event).  The
    partition must cover `node_ids` exactly.  One-node-per-domain is
    bit-identical to `domains=None`."""

    mttf_s: float | None = None
    mttr_s: float = 60.0
    straggle_mttf_s: float | None = None
    straggle_mttr_s: float = 30.0
    slowdown_range: tuple[float, float] = (1.5, 3.0)
    seed: int = 0
    domains: "FaultDomain | tuple[tuple[int, ...], ...] | None" = None

    def generate(self, node_ids: Sequence[int],
                 horizon_s: float) -> FaultTrace:
        ids = list(node_ids)
        id_groups = domain_groups(self.domains)
        idx_groups = None
        if id_groups is not None:
            pos = {nid: i for i, nid in enumerate(ids)}
            unknown = sorted({n for g in id_groups for n in g} - pos.keys())
            if unknown:
                raise ValueError(
                    f"fault domains name node ids not in the fleet: {unknown}")
            idx_groups = tuple(tuple(pos[n] for n in g) for g in id_groups)
        raw = _raw_fault_trace(
            len(ids), horizon_s,
            mttf_s=self.mttf_s, mttr_s=self.mttr_s,
            straggle_mttf_s=self.straggle_mttf_s,
            straggle_mttr_s=self.straggle_mttr_s,
            slowdown_range=self.slowdown_range, seed=self.seed,
            domains=idx_groups)
        events = tuple(FaultEvent(t, ids[idx], kind, value)
                       for t, idx, kind, value in raw)
        name = f"faults@mttf={self.mttf_s}/seed={self.seed}"
        if id_groups is not None:
            name += f"/domains={len(id_groups)}"
        return FaultTrace(name=name, events=events, domains=id_groups)
