"""Fault injection: seeded node crashes, recoveries, and stragglers.

The cluster simulator assumed every node survives the horizon; this
module supplies the disruption stream that breaks that assumption in a
*replayable* way.  A :class:`FaultTrace` is the failure-side counterpart
of an :class:`~repro.cluster.trace.ArrivalTrace` — an immutable,
time-sorted tuple of :class:`FaultEvent`\\ s that, together with the
arrival trace, fully determines a faulted run (same traces + policy →
bit-identical ClusterReport; an *empty* fault trace is bit-identical to
running with no faults at all — both invariants are pinned in
tests/test_faults.py).

Four event kinds:

  * ``crash``   — the node fails.  Takes effect at the next decode step
                  boundary (the in-flight token finishes) or prefill end,
                  immediately when off-phase — so every energy charge
                  stays an exact closed-form boundary charge.  Active and
                  suspended decodes become *refugees*: the sim ships their
                  KV to a healthy replica (``node.py``/``sim.py``
                  migration) or books their accrued joules as wasted.
  * ``recover`` — the node powers back up into IDLE and rejoins the
                  eligible set.
  * ``slow``    — a sustained straggler begins: every subsequent phase is
                  stretched by ``value`` (σ ≥ 1) in wall time, with the
                  extra seconds burning static power (see
                  ``ClusterNode._stretched``).
  * ``normal``  — the straggler ends (σ back to 1).

:class:`FaultInjector` draws the stream from configurable exponential
MTTF/MTTR holding times (delegating to
:func:`repro.data.workloads.fault_trace`, the seeded generator exported
next to the arrival-time generators), mapping generator node indexes onto
real fleet node ids.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator, Sequence

from repro.data.workloads import fault_trace as _raw_fault_trace

CRASH = "crash"
RECOVER = "recover"
SLOW = "slow"
NORMAL = "normal"
FAULT_KINDS = (CRASH, RECOVER, SLOW, NORMAL)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One disruption: at `time_s`, `node_id` crashes / recovers /
    starts straggling at factor `value` / returns to normal."""

    time_s: float
    node_id: int
    kind: str
    value: float = 1.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind == SLOW and self.value < 1.0:
            raise ValueError("straggler slowdown must be >= 1")


@dataclasses.dataclass(frozen=True)
class FaultTrace:
    """Immutable, time-sorted fault stream (replayable alongside the
    arrival trace)."""

    name: str
    events: tuple[FaultEvent, ...]

    def __post_init__(self):
        times = [ev.time_s for ev in self.events]
        if times != sorted(times):
            raise ValueError("fault events must be time-sorted")

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self) -> Iterator[FaultEvent]:
        return iter(self.events)

    def down_intervals(self, node_id: int) -> list[tuple[float, float]]:
        """[crash, recover) spans for one node; an unrecovered crash
        yields an interval open to +inf."""
        out: list[tuple[float, float]] = []
        start: float | None = None
        for ev in self.events:
            if ev.node_id != node_id:
                continue
            if ev.kind == CRASH and start is None:
                start = ev.time_s
            elif ev.kind == RECOVER and start is not None:
                out.append((start, ev.time_s))
                start = None
        if start is not None:
            out.append((start, math.inf))
        return out

    def is_down(self, node_id: int, t: float) -> bool:
        return any(a <= t < b for a, b in self.down_intervals(node_id))

    def down_forever_from(self, node_id: int, t: float) -> bool:
        """True when the node is down at `t` and never recovers — the
        liveness notion the failure-aware oracle excludes capacity by
        (a model is only *lost* to a request if every host is gone for
        good; anything that recovers is still reachable via retry)."""
        spans = self.down_intervals(node_id)
        return bool(spans) and spans[-1][1] == math.inf and spans[-1][0] <= t


@dataclasses.dataclass(frozen=True)
class FaultInjector:
    """Seeded crash/recovery + straggler event source.

    Holding times are exponential: nodes stay up for Exp(`mttf_s`) and
    down for Exp(`mttr_s`); independently, they run healthy for
    Exp(`straggle_mttf_s`) and straggle for Exp(`straggle_mttr_s`) at a
    slowdown drawn uniformly from `slowdown_range`.  A None MTTF disables
    that process.  `generate` is deterministic in (seed, node_ids,
    horizon_s) — the replayable-trace contract."""

    mttf_s: float | None = None
    mttr_s: float = 60.0
    straggle_mttf_s: float | None = None
    straggle_mttr_s: float = 30.0
    slowdown_range: tuple[float, float] = (1.5, 3.0)
    seed: int = 0

    def generate(self, node_ids: Sequence[int],
                 horizon_s: float) -> FaultTrace:
        raw = _raw_fault_trace(
            len(node_ids), horizon_s,
            mttf_s=self.mttf_s, mttr_s=self.mttr_s,
            straggle_mttf_s=self.straggle_mttf_s,
            straggle_mttr_s=self.straggle_mttr_s,
            slowdown_range=self.slowdown_range, seed=self.seed)
        ids = list(node_ids)
        events = tuple(FaultEvent(t, ids[idx], kind, value)
                       for t, idx, kind, value in raw)
        return FaultTrace(
            name=f"faults@mttf={self.mttf_s}/seed={self.seed}",
            events=events)
