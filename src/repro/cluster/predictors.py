"""Non-oracle τout prediction for online routing.

The paper's scheduler assumes τout is known when a query is routed — an
offline-oracle assumption it flags itself, citing Zheng et al. (response-
length perception) for online estimation.  This module supplies the
online counterpart: per-model *empirical quantile* predictors fit over a
sliding window of observed completions, so a router's information model
can be downgraded from "knows every output length" to "has seen recent
traffic", and the two gaps that were previously conflated become
separately measurable in benchmarks/fig4_online_gap.py:

    information gap  = predictor router − oracle-τout router   (same
                       commitment rule, degraded τout knowledge)
    commitment gap   = oracle-τout router − offline oracle     (full
                       knowledge, online one-shot routing)

Causality: a completion is the only moment τout is revealed, so
observations enter through ``RoutingPolicy.observe_completion`` (wired by
the event loop), never from the trace.  Until a model has `min_obs`
completions the predictor falls back to the pooled cross-model window,
and before any completion at all to a fixed `prior` guess — it never
peeks at a pending request's true τout.

Quantile choice: the energy models are increasing in τout, so a median
(0.5) predictor under-provisions on the heavy Alpaca-like tail; the
default 0.7 hedges upward, the same skew Zheng et al. adopt for
scheduling (over- rather than under-predict lengths).
"""

from __future__ import annotations

from collections import deque

import numpy as np


class TauOutPredictor:
    """Per-model empirical τout quantiles over a sliding history window."""

    def __init__(self, *, quantile: float = 0.7, window: int = 256,
                 prior: float = 64.0, min_obs: int = 8):
        if not 0.0 < quantile < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {quantile}")
        if window < 1 or min_obs < 1:
            raise ValueError("window and min_obs must be >= 1")
        self.quantile = quantile
        self.window = window
        self.prior = float(prior)
        self.min_obs = min_obs
        self._per_model: dict[str, deque] = {}
        self._pooled: deque = deque(maxlen=window)
        self.n_observed = 0
        # predictions only change on completions, but are read O(k) times
        # per arrival — memoize per model key between observations
        self._cache: dict[str | None, float] = {}

    def observe(self, model: str, tau_out: int) -> None:
        """Fold one completed request's revealed output length."""
        dq = self._per_model.get(model)
        if dq is None:
            dq = self._per_model[model] = deque(maxlen=self.window)
        dq.append(int(tau_out))
        self._pooled.append(int(tau_out))
        self.n_observed += 1
        self._cache.clear()

    def predict(self, model: str | None = None) -> float:
        """τ̂out for a request about to be served by `model` (pooled
        estimate when model is None or its history is too thin)."""
        out = self._cache.get(model)
        if out is not None:
            return out
        dq = self._per_model.get(model) if model is not None else None
        if dq is not None and len(dq) >= self.min_obs:
            out = float(np.quantile(np.asarray(dq), self.quantile))
        elif len(self._pooled) >= self.min_obs:
            out = float(np.quantile(np.asarray(self._pooled), self.quantile))
        else:
            out = self.prior
        self._cache[model] = out
        return out

    def peek(self, model: str | None = None) -> float | None:
        """The memoized prediction for `model`, if one was computed since
        the last observation — O(1), no quantile work.  Telemetry uses
        this to report the error of predictions the router actually acted
        on without adding quantile computations to the completion path."""
        return self._cache.get(model)

    def reset(self) -> None:
        self._per_model.clear()
        self._pooled.clear()
        self._cache.clear()
        self.n_observed = 0
