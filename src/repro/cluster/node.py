"""Per-node serving state machine with continuous batching + power states.

A ClusterNode hosts one model replica on one hardware Node and serves the
requests a routing policy sends it.  Service is phase-granular:

  * prefill phase — up to max_batch waiting requests are admitted together
    and their (padded) prompts processed in one batched pass;
  * decode segment — the active batch decodes until the *next completion
    boundary* (the smallest remaining τout among members), after which
    finished requests leave and new waiting requests may join via a joiner
    prefill.  This is iteration-level continuous batching coarsened to
    completion boundaries, which keeps the event count O(requests) instead
    of O(tokens).

Time and energy per phase delegate to repro.energy.simulator
(AnalyticLLMSimulator.prefill_cost / decode_cost) on the node's hardware
(repro.energy.hardware.Node), so an uncontended node reproduces the
per-request simulator's PhaseBreakdown exactly — the energy-conservation
invariant tested in tests/test_cluster.py.

Power management (repro.cluster.power) adds the off-phase lifecycle:
besides serving (ACTIVE) the node can sit powered (IDLE), be powered down
(GATED, residual draw) or be mid-transition (GATING/WAKING, with
configurable latency and energy).  Every second of the node's horizon is
accounted to exactly one of the busy/idle/gated/transition buckets —
gated seconds are never double-charged as idle — and the sum of the four
energy buckets IS the node's total energy (the conservation invariant the
perf suite gates at 1e-9).  A request routed to a gated node triggers an
on-demand wake; autoscalers may gate idle nodes and pre-wake gated ones.

Per-phase DVFS (`dvfs="per_phase"`): before charging a phase the node asks
the simulator for the energy-minimal operating point over
`accel.dvfs_scales` (closed-form evaluation per candidate, host serving
draw included as `extra_w`), so compute-bound prefills run near max clock
while bandwidth-bound decode segments underclock — the per-phase split of
Fernandez et al.  `freq_scale=` pins a fixed operating point instead
(the fixed-frequency baseline fig4 compares against).

Decode-boundary preemption (`preempt_decode`): a running decode segment
can be cut at the *next step boundary* after the request instant — the
in-flight token finishes, nothing re-runs.  The truncated segment is
charged via the same closed-form integral as the full one, split at the
boundary: because the integral is exactly additive in the step count
(decode_cost(c, a) + decode_cost(c+a, b) == decode_cost(c, a+b)), the
two halves of a preempted decode sum to the unpreempted `decode_cost` to
1e-9 — the perf-suite `preemption_split` gate.  The evicted member keeps
its KV position (`_InFlight.generated`) in the node's `suspended` list
and later *resumes* by rejoining the active set at a phase start for
free: no re-prefill, the Fernandez-et-al observation that decode
interruption is cheap while prefill re-work is not.  Decode segments are
charged when they settle (segment end or preemption boundary), never up
front, so a truncated segment is only ever charged once.

Faults (repro.cluster.faults) extend the lifecycle with a FAILED state
and two extra energy buckets:

  * crash — quantized to the same decode step boundary preemption uses
    (the in-flight token finishes; a prefill completes first; off-phase
    crashes are immediate), so the dying node's last charge is still an
    exact closed-form boundary charge.  Every active/suspended member
    becomes a *refugee* with its KV position and accrued joules intact;
    the sim loop ships refugees to a healthy replica (`receive_migrant`)
    or books their joules as wasted (`book_waste`).  FAILED time draws
    0 W into the `failed_s` bucket until the recovery event.
  * shipping — a migrated member's KV bytes cross the interconnect on
    the *recipient's* meter (`book_shipping`: bytes/ici_bw seconds at
    j_per_byte_ici — a pull over the NIC, which still works when the
    donor is dead).  Shipping runs as background DMA concurrent with
    serving, so `shipping_s` is tracked but excluded from the horizon
    partition; `shipping_energy_j` joins the energy total.
  * wasted — work lost to an un-rescuable crash *moves* from the busy
    bucket to `wasted_energy_j` (never double-counted), so the fleet
    invariant "per-request attributed energy == Σ busy" and the full
    partition busy+idle+gated+transition+shipping+wasted == total both
    stay exact to 1e-9.

Prefill checkpointing (`CheckpointConfig`): decode interruption is cheap
(KV intact, resume free) but prefill interruption was all-or-nothing —
a mid-prefill crash quantized to the *prefill end* (the whole pass
completes, then ships).  With a checkpoint policy the batch prefill runs
as a sequence of chunk phases cut at `interval_tokens` boundaries; each
chunk charges the exact closed-form difference
prefill_cost(b_k) − prefill_cost(b_{k−1}) (the roofline pass is additive
over prompt prefixes, so the chunk sum telescopes to the unchunked pass
to float exactness and chunking changes *when* energy settles, never how
much) and each interior boundary persists the new KV prefix — bytes =
new_tokens × kv_bytes_per_token charged at `j_per_byte_ckpt` into the
seventh energy bucket (`checkpoint_s` stays outside the horizon
partition like shipping: background DMA concurrent with the next
chunk).  A crash now quantizes to the *chunk* boundary: the in-flight
chunk's charge moves busy → wasted (lost work bounded by one interval —
against the per-boundary persistence overhead, the tradeoff fig4's
blast-radius cell sweeps), members roll back to their last checkpoint,
and the sim ships the persisted prefix to a healthy replica where a
`restore` phase re-runs only the unfinished suffix
(prefill_cost(τin) − prefill_cost(ckpt), batch-1, the same telescoping
identity) before the request continues as an ordinary decode; a crash
mid-restore likewise wastes the restore charge and requeues the still-
checkpointed refugee.  An *uncheckpointed* prefill refugee (crashed in
its first chunk) has nothing durable to ship: it re-runs from scratch
on a survivor or abandons, its accrued joules booked wasted.
`checkpoint=None` keeps the old semantics bit-identically (a
mid-prefill crash completes the pass, then ships full KV).

KV prefix cache (`PrefixCacheConfig`): multi-turn sessions re-submit
their whole previous context as a shared prefix each turn.  With a
cache, a completed turn's KV stays resident keyed by session_id; the
next turn's admission (`enqueue` → `_cache_admit`) looks the session up
— a warm entry grants a *pending hit* and the turn later prefills as a
dedicated batch-1 phase charged prefill_cost(τin) − prefill_cost(cached)
at one pinned operating point (the same telescoping identity chunks and
restores use), plus a closed-form cache-read term: cached ×
kv_bytes_per_token bytes streamed back at `read_bw` (background DMA —
seconds outside the horizon partition) and `j_per_byte_read` — the
eighth energy bucket (`cache_read`).  Capacity is bookkept in reserved
tokens; LRU eviction happens only at admission boundaries, pending-hit
entries pinned.  A crash invalidates the whole cache (entries and
pending hits — rescued requests re-admit cold elsewhere); gating does
not.  `prefix_cache=None` (default) leaves every code path and every
accounting bucket bit-identical to the cache-less simulator.

Stragglers: a `slow` fault sets `self.slowdown = σ`; each phase fixes
the factor at its start (`phase_stretch`) and is charged the *stretch
transform* (t, e) → (σ·t, e + (σ−1)·t·accel_static_w): the same work at
σ× the wall time, with the extra seconds burning accelerator static
power.  The transform is linear in t, so the preemption split identity
survives stretching exactly.
"""

from __future__ import annotations

import dataclasses
from collections import Counter, OrderedDict, deque

from repro.core.energy_model import LLMProfile
from repro.energy.costs import kv_bytes_per_token
from repro.energy.hardware import Node, SWING_NODE
from repro.energy.simulator import AnalyticLLMSimulator
from repro.models.common import ModelConfig

from repro.cluster.power import (
    ACTIVE,
    FAILED,
    GATED,
    GATING,
    IDLE,
    WAKING,
    PowerConfig,
)
from repro.cluster.trace import TracedRequest

# event hints returned to the engine: (EventKind, absolute time) — the
# owning NodeShard stamps the phase epoch and schedules the typed Event
from repro.cluster.engine.events import EventKind

_PHASE = EventKind.PHASE_END
_WAKE = EventKind.WAKE_END
_GATE = EventKind.GATE_END
_PREEMPT = EventKind.PREEMPT_END
_CRASH = EventKind.CRASH_END


@dataclasses.dataclass
class _InFlight:
    req: TracedRequest
    start_s: float              # first service (prefill start)
    generated: int = 0          # decode tokens produced so far
    energy_j: float = 0.0       # attributed share of phase energy
    preemptions: int = 0        # times this request was suspended
    migrations: int = 0         # cross-node KV shipments en route
    shipped_bytes: float = 0.0  # KV bytes moved across the interconnect
    # per-node slice of energy_j: where each accrued joule's busy bucket
    # lives, so an abandoned refugee's waste can be booked back on the
    # node(s) that actually spent the energy (conservation stays per-node)
    energy_on: dict = dataclasses.field(default_factory=dict)
    # prefill-checkpoint state: None once the prompt is fully processed
    # (every pre-checkpoint member); an int marks a *prefill refugee*
    # whose prompt is processed only to that token — restorable from
    # ckpt_tokens (the durably persisted prefix) on a healthy node
    prefill_done: int | None = None
    ckpt_tokens: int = 0
    # KV prefix-cache hit: how many of this request's τin tokens were
    # served from the node's warm cache (its prefill charged only the
    # uncached suffix); 0 for misses and non-session requests
    cached_tokens: int = 0

    @property
    def remaining(self) -> int:
        return self.req.tau_out - self.generated

    @property
    def context(self) -> int:
        return self.req.tau_in + self.generated


@dataclasses.dataclass(frozen=True)
class Completion:
    req: TracedRequest
    start_s: float
    finish_s: float
    energy_j: float             # attributed accelerator+host joules
    isolated_runtime_s: float   # batch-1 uncontended service time (slowdown SLO)
    preemptions: int = 0        # suspend/resume round-trips en route
    migrations: int = 0         # cross-node KV shipments en route
    shipped_bytes: float = 0.0  # KV bytes moved across the interconnect
    cached_tokens: int = 0      # τin tokens served from the KV prefix cache


@dataclasses.dataclass(frozen=True)
class PrefixCacheConfig:
    """Per-node KV prefix cache for multi-turn sessions: completed turns
    leave their KV (prompt + generated answer) resident, keyed by
    session_id, so the next turn's shared prefix prefills only the
    uncached suffix — the exact closed-form difference
    prefill_cost(τin) − prefill_cost(cached) at one pinned operating
    point, the same telescoping contract checkpoint chunks and restores
    use.  The warm prefix streams back from the cache as background DMA:
    `read_bw` bytes/s (seconds outside the horizon partition, like
    shipping/checkpoint) at `j_per_byte_read` joules per byte — the
    eighth energy bucket (`cache_read`).  Capacity is `capacity_bytes`
    of KV (entries sized via kv_bytes_per_token); eviction is LRU at
    request-admission boundaries, entries with an in-flight pending hit
    pinned.  A crash wipes the cache (KV dies with the node); gating
    does not."""

    capacity_bytes: float = 64e9
    j_per_byte_read: float = 5.0e-11
    read_bw: float = 64e9

    def __post_init__(self):
        if self.capacity_bytes <= 0:
            raise ValueError(
                f"capacity_bytes must be > 0, got {self.capacity_bytes}")
        if self.j_per_byte_read < 0:
            raise ValueError("j_per_byte_read must be >= 0")
        if self.read_bw <= 0:
            raise ValueError("read_bw must be > 0")


@dataclasses.dataclass
class _CacheEntry:
    """One session's resident KV: `tokens` are valid (persisted by a
    completed turn), `reserved` is the capacity held (the admitted
    turn's full τin + τout), `pinned` counts in-flight pending hits
    that protect the entry from eviction."""

    tokens: int = 0
    reserved: int = 0
    pinned: int = 0


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    """Prefill checkpoint policy: cut the batch prefill at
    `interval_tokens` boundaries and durably persist the new KV prefix at
    each interior boundary.  Persistence is background DMA to node-local
    durable storage: `ckpt_bw` bytes/s concurrent with the next chunk
    (the seconds stay outside the horizon partition, like shipping) at
    `j_per_byte_ckpt` joules per byte — the seventh energy bucket.
    Smaller intervals lose less work per crash but persist more often;
    fig4's blast-radius cell sweeps the tradeoff."""

    interval_tokens: int = 256
    j_per_byte_ckpt: float = 2.0e-10
    ckpt_bw: float = 16e9

    def __post_init__(self):
        if self.interval_tokens < 1:
            raise ValueError(
                f"interval_tokens must be >= 1, got {self.interval_tokens}")
        if self.j_per_byte_ckpt < 0:
            raise ValueError("j_per_byte_ckpt must be >= 0")
        if self.ckpt_bw <= 0:
            raise ValueError("ckpt_bw must be > 0")


class ClusterNode:
    """One model replica on one hardware node, with a waiting queue, a
    continuously-batched active set, and a power-state machine.  Driven by
    repro.cluster.sim."""

    def __init__(
        self,
        node_id: int,
        model_cfg: ModelConfig,
        profile: LLMProfile,
        hardware: Node = SWING_NODE,
        *,
        max_batch: int = 8,
        kv_cache: bool = True,
        decode_chunk: int = 256,   # legacy reference-loop chunk (decode_cost
                                   # itself is closed-form and chunk-free)
        power: PowerConfig | None = None,
        dvfs: str = "off",         # "off" (pinned freq_scale) | "per_phase"
        freq_scale: float = 1.0,   # fixed operating point when dvfs="off"
        telemetry=None,            # repro.obs.Telemetry (sim.py also sets it)
        checkpoint: CheckpointConfig | None = None,
        prefix_cache: PrefixCacheConfig | None = None,
    ):
        if dvfs not in ("off", "per_phase"):
            raise ValueError(f"dvfs must be 'off' or 'per_phase', got {dvfs!r}")
        self.node_id = node_id
        self.model_cfg = model_cfg
        self.profile = profile
        self.max_batch = max_batch
        self.power = power if power is not None else PowerConfig()
        self.dvfs = dvfs
        self.freq_scale = freq_scale
        self.telemetry = telemetry
        self.checkpoint = checkpoint
        self.prefix_cache = prefix_cache
        self.sim = AnalyticLLMSimulator(
            model_cfg, hardware, batch=1, kv_cache=kv_cache,
            noise_sigma=0.0, decode_chunk=decode_chunk)
        self.hardware = self.sim.node  # n_accel resolved to fit the weights

        # KV prefix cache (None ⇒ every path below is untouched):
        # session_id → _CacheEntry in LRU order (admission touches),
        # capacity bookkept in reserved tokens (capacity_bytes /
        # kv_bytes_per_token; a KV-free model caches unboundedly)
        self._cache: OrderedDict[int, _CacheEntry] = OrderedDict()
        self._cache_tokens = 0
        self._pending_hits: dict[int, int] = {}   # request_id → hit tokens
        self._cache_cap_tokens: int | None = None
        if prefix_cache is not None:
            kvb = kv_bytes_per_token(self.sim.cfg)
            self._cache_cap_tokens = (
                int(prefix_cache.capacity_bytes // kvb) if kvb > 0 else None)

        self.waiting: deque[TracedRequest] = deque()
        self.active: list[_InFlight] = []
        self.suspended: deque[_InFlight] = deque()   # preempted, KV intact
        self._phase_end_s: float | None = None
        self._phase_members: list[_InFlight] = []
        self._phase_steps: int = 0
        # decode-segment bookkeeping (settle-time charging + preemption)
        self._phase_kind: str | None = None      # "prefill" | "decode"
        self._phase_start_s: float = 0.0
        self._phase_base: int = 0                # decode base context
        self._phase_scale: float = 1.0           # chosen operating point
        self._phase_t: float = 0.0               # full-segment time
        self._phase_e: float = 0.0               # full-segment accel joules
        self._phase_epoch: int = 0               # invalidates stale events
        self._preempt_steps: int | None = None   # pending truncation point
        self._preempt_victims: list[_InFlight] = []

        # fault state (repro.cluster.faults drives the transitions)
        self.slowdown = 1.0          # current straggler factor (σ >= 1)
        self.draining = False        # governance: accept no new routes
        self._phase_stretch = 1.0    # σ fixed at the running phase's start
        self._crash_pending = False  # crash lands at the next boundary
        self._crash_steps: int | None = None   # decode truncation point

        # checkpointed-prefill chunk state (None/0 outside a chunked
        # prefill): the running chunk's upper boundary, the full padded
        # prompt length, and the chunk's charged joules (what a crash at
        # the chunk settle moves busy → wasted)
        self._ckpt_chunk_to: int | None = None
        self._ckpt_total = 0
        self._ckpt_chunk_charge = 0.0
        # restore-phase state: the prefill refugee whose suffix is being
        # re-run, and its charged joules (wasted if the node dies mid-way)
        self._restore_member: _InFlight | None = None
        self._restore_charge = 0.0

        # power-state machine (starts powered and idle at t = 0)
        self._pstate = IDLE
        self._pstate_since = 0.0

        # aggregate accounting: time and energy buckets.  failed_s draws
        # exactly 0 W (a crashed node is off the PDU), so it partitions
        # the horizon without an energy bucket of its own; shipping_s is
        # background NIC DMA concurrent with serving and stays *outside*
        # the horizon partition while shipping_energy_j joins the total.
        self.busy_s = 0.0
        self.busy_energy_j = 0.0
        self.idle_s = 0.0
        self.idle_energy_j = 0.0
        self.gated_s = 0.0
        self.gated_energy_j = 0.0
        self.transition_s = 0.0
        self.transition_energy_j = 0.0
        self.failed_s = 0.0
        self.shipping_s = 0.0
        self.shipping_energy_j = 0.0
        self.wasted_energy_j = 0.0
        self.checkpoint_s = 0.0        # background DMA, like shipping_s
        self.checkpoint_energy_j = 0.0
        self.cache_read_s = 0.0        # background DMA, like shipping_s
        self.cache_read_energy_j = 0.0
        self.horizon_s = 0.0       # set by finalize()
        self.n_served = 0
        self.n_wakes = 0
        self.n_gates = 0
        self.n_preemptions = 0
        self.n_resumes = 0
        self.n_crashes = 0
        self.n_recoveries = 0
        self.n_migrations_in = 0
        self.n_migrations_out = 0
        self.n_checkpoints = 0         # member-boundary persists taken
        self.n_restores = 0            # suffix restore phases begun
        self.n_cache_hits = 0          # warm-prefix admissions
        self.n_cache_misses = 0        # cold session admissions
        self.n_cache_evictions = 0     # LRU entry evictions (+ overflows)
        self.cache_hit_tokens = 0      # Σ reused prefix tokens (reuse depth)
        self.freq_choices: Counter = Counter()   # (phase_kind, scale) -> count

    # ------------------------------------------------------------------
    @property
    def model_name(self) -> str:
        return self.profile.name

    @property
    def busy(self) -> bool:
        return self._phase_end_s is not None

    def load(self) -> int:
        """Queue depth + in-flight + suspended count (the least-loaded
        policy signal; suspended work still owes this node decode time)."""
        return len(self.waiting) + len(self.active) + len(self.suspended)

    @property
    def idle_power_w(self) -> float:
        a, h = self.hardware.accel, self.hardware.host
        return a.idle_w * self.hardware.n_accel + h.idle_w

    @property
    def transition_power_w(self) -> float:
        w = self.power.transition_w
        return self.idle_power_w if w is None else w

    # --- power-state surface (read by sim loop, autoscalers, policies) --
    @property
    def power_state(self) -> str:
        return self._pstate

    @property
    def power_state_since(self) -> float:
        return self._pstate_since

    @property
    def awake(self) -> bool:
        return self._pstate in (ACTIVE, IDLE)

    @property
    def failed(self) -> bool:
        return self._pstate == FAILED

    @property
    def accepting(self) -> bool:
        """Routable: not crashed (nor about to be — a pending crash is
        already fatal) and not being drained by governance."""
        return (self._pstate != FAILED and not self.draining
                and not self._crash_pending)

    @property
    def crash_pending(self) -> bool:
        """A crash is quantizing to its charge boundary (the node is
        still finishing the in-flight work before going FAILED).  The
        sim loop defers a recovery event that pops in this window — a
        node cannot recover from a failure that has not landed yet."""
        return self._crash_pending

    @property
    def phase_stretch(self) -> float:
        """Straggler factor σ of the running (or just-settled) phase —
        fixed at phase start, read by the auditor's split-charge check."""
        return self._phase_stretch

    @property
    def accel_static_w(self) -> float:
        """Accelerator static draw — what a straggler's stalled extra
        seconds burn (the host serving draw is charged on wall time
        separately in `_charge`)."""
        return self.hardware.accel.idle_w * self.hardware.n_accel

    @property
    def can_gate(self) -> bool:
        return (self._pstate == IDLE and not self.waiting and not self.active
                and not self.suspended)

    @property
    def in_decode(self) -> bool:
        """Mid-decode-segment — the only phase kind that can be preempted."""
        return self._phase_end_s is not None and self._phase_kind == "decode"

    @property
    def preempt_pending(self) -> bool:
        return self._preempt_steps is not None

    @property
    def phase_end_s(self) -> float | None:
        """Absolute end time of the running phase (None when idle) — the
        preemption policy's wait estimate for a queued arrival."""
        return self._phase_end_s

    @property
    def phase_epoch(self) -> int:
        """Monotone phase generation counter: a scheduled phase/preempt
        event is valid only if its epoch still matches (preemption is the
        one path that invalidates an already-scheduled segment end)."""
        return self._phase_epoch

    @property
    def pending_wake_j(self) -> float:
        """Energy a fresh request routed here would spend waking the node:
        zero while powered or already waking, the full transition cost
        while gated (or ramping down, since the gate must finish first).
        The wake-cost-aware router folds this into its argmin."""
        if self._pstate in (GATED, GATING):
            return self.power.wake_j + self.power.wake_s * self.transition_power_w
        return 0.0

    @property
    def power_rank(self) -> int:
        """Tie-break key for routing: who serves a fresh request soonest.
        0 = powered (idle/active), 1 = waking, 2 = gated (one wake away),
        3 = gating (must finish ramping down, then wake), 4 = failed
        (serves nothing until its recovery event)."""
        return {ACTIVE: 0, IDLE: 0, WAKING: 1, GATED: 2, GATING: 3,
                FAILED: 4}[self._pstate]

    # --- time/energy bucket accounting ---------------------------------
    def _accrue(self, now: float) -> None:
        """Close the open interval of the current state at `now`.  ACTIVE
        time/energy is charged per phase by _charge (exact closed forms),
        so only the off-phase states accrue here."""
        dt = now - self._pstate_since
        if dt <= 0.0:
            return
        if self._pstate == IDLE:
            self.idle_s += dt
            self.idle_energy_j += dt * self.idle_power_w
        elif self._pstate == GATED:
            self.gated_s += dt
            self.gated_energy_j += dt * self.power.gated_w
        elif self._pstate in (GATING, WAKING):
            self.transition_s += dt
            self.transition_energy_j += dt * self.transition_power_w
        elif self._pstate == FAILED:
            self.failed_s += dt   # off the PDU: 0 W by definition

    def _set_state(self, state: str, now: float) -> None:
        if state == self._pstate:
            return
        self._accrue(now)
        self._pstate = state
        self._pstate_since = now

    def finalize(self, end_s: float) -> None:
        """Close the books at the end of a simulation.  The node's horizon
        is the report makespan, extended if a power transition was still
        settling past it (that time is accounted, not dropped — the
        conservation invariant stays exact)."""
        horizon = max(end_s, self._pstate_since)
        self._accrue(horizon)
        self._pstate_since = horizon
        self.horizon_s = horizon

    @property
    def total_energy_j(self) -> float:
        return (self.busy_energy_j + self.idle_energy_j
                + self.gated_energy_j + self.transition_energy_j
                + self.shipping_energy_j + self.checkpoint_energy_j
                + self.cache_read_energy_j + self.wasted_energy_j)

    @property
    def accounted_s(self) -> float:
        return (self.busy_s + self.idle_s + self.gated_s
                + self.transition_s + self.failed_s)

    # ------------------------------------------------------------------
    def enqueue(self, req: TracedRequest, now: float
                ) -> tuple[str, float] | None:
        """Accept a routed request.  Returns the next timed event this
        creates — (EventKind.PHASE_END, end_s) if an idle node starts
        serving, (EventKind.WAKE_END, end_s) if a gated node begins its
        on-demand wake — or
        None when the request just queues (node busy or mid-transition)."""
        if self._pstate == FAILED:
            raise RuntimeError(
                f"request routed to failed node {self.node_id} — the sim "
                f"loop must filter to accepting nodes")
        self._cache_admit(req)
        self.waiting.append(req)
        if self._pstate == GATED:
            return (_WAKE, self.begin_wake(now))
        if self._pstate in (WAKING, GATING) or self.busy:
            return None
        return self._phase_event(self._start_phase(now))

    # --- power transitions ---------------------------------------------
    def begin_wake(self, now: float) -> float:
        """Start powering the node back up; returns the ready time."""
        assert self._pstate == GATED, f"wake from {self._pstate}"
        self._set_state(WAKING, now)
        self.transition_energy_j += self.power.wake_j
        self.n_wakes += 1
        if self.telemetry is not None:
            self.telemetry.on_power_begin(self, "wake", now)
        return now + self.power.wake_s

    def on_wake_end(self, now: float) -> tuple[str, float] | None:
        """Node is powered again: serve whatever queued during the wake."""
        assert self._pstate == WAKING, f"wake ended in {self._pstate}"
        span_start = self._pstate_since
        self._set_state(IDLE, now)
        if self.telemetry is not None:
            self.telemetry.on_power_span(self, "wake", span_start, now)
        return self._phase_event(self._start_phase(now))

    def begin_gate(self, now: float) -> tuple[str, float]:
        """Start ramping an idle node down; uninterruptible (an arrival
        during the ramp queues, then triggers a wake once gated)."""
        assert self.can_gate, f"gate from {self._pstate} (work pending?)"
        self._set_state(GATING, now)
        self.transition_energy_j += self.power.gate_j
        self.n_gates += 1
        if self.telemetry is not None:
            self.telemetry.on_power_begin(self, "gate", now)
        return (_GATE, now + self.power.gate_s)

    def on_gate_end(self, now: float) -> tuple[str, float] | None:
        assert self._pstate == GATING, f"gate ended in {self._pstate}"
        span_start = self._pstate_since
        self._set_state(GATED, now)
        if self.telemetry is not None:
            self.telemetry.on_power_span(self, "gate", span_start, now)
        if self.waiting or self.suspended:
            # something arrived mid-ramp (a queued request, or a migrant
            # whose KV landed during the ramp): wake right back up
            return (_WAKE, self.begin_wake(now))
        return None

    # --- phases ---------------------------------------------------------
    @staticmethod
    def _phase_event(end_s: float | None) -> tuple[str, float] | None:
        return None if end_s is None else (_PHASE, end_s)

    def _charge(self, members: list[_InFlight], t: float, e_accel: float, *,
                kind: str, start_s: float, scale: float) -> None:
        e_total = e_accel + self.sim.host_power_w * t
        self.busy_s += t
        self.busy_energy_j += e_total
        share = e_total / len(members)
        nid = self.node_id
        for m in members:
            m.energy_j += share
            m.energy_on[nid] = m.energy_on.get(nid, 0.0) + share
        if self.telemetry is not None:
            self.telemetry.on_phase_settle(self, kind, start_s, t, e_total,
                                           len(members), scale)

    def _stretched(self, t: float, e_accel: float) -> tuple[float, float]:
        """Apply the running phase's straggler factor: same work, σ× the
        wall time, the extra (σ−1)·t seconds at accelerator static draw.
        Exactly the identity transform at σ == 1, and linear in t, so the
        decode split additivity survives stretching to 1e-9."""
        s = self._phase_stretch
        if s == 1.0:
            return t, e_accel
        return s * t, e_accel + (s - 1.0) * t * self.accel_static_w

    def _prefill(self, tau_in: int, batch: int) -> tuple[float, float, float]:
        if self.dvfs == "per_phase":
            s, t, e = self.sim.best_prefill_frequency(
                tau_in, batch=batch, extra_w=self.sim.host_power_w)
        else:
            s = self.freq_scale
            t, e = self.sim.prefill_cost(tau_in, batch=batch, freq_scale=s)
        self.freq_choices[("prefill", s)] += 1
        return s, t, e

    def _decode(self, base: int, n_steps: int, batch: int
                ) -> tuple[float, float, float]:
        if self.dvfs == "per_phase":
            s, t, e = self.sim.best_decode_frequency(
                base, n_steps, batch=batch, extra_w=self.sim.host_power_w)
        else:
            s = self.freq_scale
            t, e = self.sim.decode_cost(base, n_steps, batch=batch,
                                        freq_scale=s)
        self.freq_choices[("decode", s)] += 1
        return s, t, e

    # --- KV prefix cache: admission, LRU eviction, invalidation ---------
    def _cache_admit(self, req: TracedRequest) -> None:
        """Request-admission boundary of the KV prefix cache: look the
        session up (a warm entry grants a pending hit of
        min(valid tokens, prefix_tokens) — clamped below τin so a suffix
        always remains to prefill), touch its LRU position, and reserve
        capacity for this turn's full context (τin + τout tokens, valid
        once the turn completes here).  Reserving may LRU-evict unpinned
        colder sessions; a turn too large to ever fit is simply not
        cached (its own pending hit, if any, still serves — the pinned
        entry survives at its old size until the hit lands)."""
        cfg = self.prefix_cache
        if cfg is None or req.session_id < 0:
            return
        key = req.session_id
        entry = self._cache.get(key)
        hit = 0
        if entry is not None:
            hit = min(entry.tokens, req.prefix_tokens, req.tau_in - 1)
            self._cache.move_to_end(key)
        if hit > 0:
            self._pending_hits[req.request_id] = hit
            entry.pinned += 1
            self.n_cache_hits += 1
            self.cache_hit_tokens += hit
        else:
            self.n_cache_misses += 1
        if self.telemetry is not None:
            self.telemetry.on_cache_lookup(self, req, hit)
        new_reserved = req.tau_in + req.tau_out
        held = entry.reserved if entry is not None else 0
        cap = self._cache_cap_tokens
        if cap is not None and new_reserved > held:
            need = self._cache_tokens - held + new_reserved
            if need > cap:
                self._cache_evict_lru(need - cap, keep=key)
            if self._cache_tokens - held + new_reserved > cap:
                # no room even after evicting everything unpinned: drop
                # the entry unless a pending hit still pins it
                if entry is not None and entry.pinned == 0:
                    self._cache_drop(key)
                return
        if entry is None:
            self._cache[key] = _CacheEntry(tokens=0, reserved=new_reserved)
            self._cache_tokens += new_reserved
        elif new_reserved > entry.reserved:
            self._cache_tokens += new_reserved - entry.reserved
            entry.reserved = new_reserved

    def _cache_drop(self, key: int) -> None:
        entry = self._cache.pop(key)
        self._cache_tokens -= entry.reserved
        self.n_cache_evictions += 1
        if self.telemetry is not None:
            self.telemetry.on_cache_evict(self, key, entry.reserved)

    def _cache_evict_lru(self, excess_tokens: int, *, keep: int) -> None:
        """Evict unpinned entries in LRU order until `excess_tokens` of
        reserved capacity are freed (or nothing evictable remains)."""
        for key in list(self._cache.keys()):
            if excess_tokens <= 0:
                break
            if key == keep:
                continue
            entry = self._cache[key]
            if entry.pinned > 0:
                continue
            excess_tokens -= entry.reserved
            self._cache_drop(key)

    def _cache_invalidate(self, now: float) -> None:
        """A crash kills every resident KV prefix: the cache empties and
        all pending hits die with it (rescued requests re-admit — cold —
        wherever the sim loop re-routes them)."""
        if self.prefix_cache is None:
            return
        n = len(self._cache)
        self._cache.clear()
        self._cache_tokens = 0
        self._pending_hits.clear()
        if n and self.telemetry is not None:
            self.telemetry.on_cache_invalidate(self, n, now)

    def _cache_commit(self, m: _InFlight) -> None:
        """A session turn completed here: its KV (prompt + answer) is now
        resident, so mark the entry's valid-token high-water mark — up to
        the capacity actually reserved at admission.  LRU order is
        untouched (only admissions rank recency)."""
        if self.prefix_cache is None or m.req.session_id < 0:
            return
        entry = self._cache.get(m.req.session_id)
        if entry is not None:
            entry.tokens = max(entry.tokens,
                               min(m.req.tau_in + m.generated, entry.reserved))

    def _start_cached_prefill(self, req: TracedRequest, now: float) -> float:
        """Batch-1 joiner prefill over a warm KV prefix: charge only the
        uncached suffix — the closed-form difference prefill_cost(τin) −
        prefill_cost(cached) at one pinned operating point, the exact
        telescoping identity restores use — plus the closed-form
        cache-read term for streaming the warm prefix back (background
        DMA: seconds outside the horizon partition, joules into the
        eighth bucket).  Runs unchunked even under a CheckpointConfig
        (the suffix is one restore-like pass)."""
        cfg = self.prefix_cache
        cached = self._pending_hits.pop(req.request_id)
        entry = self._cache.get(req.session_id)
        if entry is not None and entry.pinned > 0:
            entry.pinned -= 1
        tau = req.tau_in
        assert 0 < cached < tau, (cached, tau)
        m = _InFlight(req, start_s=now, cached_tokens=cached)
        if self.dvfs == "per_phase":
            s, _, _ = self.sim.best_prefill_frequency(
                tau, batch=1, extra_w=self.sim.host_power_w)
        else:
            s = self.freq_scale
        self.freq_choices[("prefill", s)] += 1
        t_full, e_full = self.sim.prefill_cost(tau, batch=1, freq_scale=s)
        t_base, e_base = self.sim.prefill_cost(cached, batch=1, freq_scale=s)
        t, e = self._stretched(t_full - t_base, e_full - e_base)
        self._set_state(ACTIVE, now)
        self._charge([m], t, e, kind="prefill", start_s=now, scale=s)
        n_bytes = cached * kv_bytes_per_token(self.sim.cfg)
        read_s = n_bytes / cfg.read_bw
        read_j = n_bytes * cfg.j_per_byte_read
        self.cache_read_s += read_s
        self.cache_read_energy_j += read_j
        self.active.append(m)
        self._phase_members = [m]
        self._phase_steps = 0
        self._phase_kind = "prefill"
        self._phase_start_s = now
        self._phase_scale = s
        self._phase_end_s = now + t
        if self.telemetry is not None:
            self.telemetry.on_cache_hit(self, tau, cached, n_bytes,
                                        read_s, read_j, s)
        return self._phase_end_s

    def _start_phase(self, now: float) -> float | None:
        """Pick the next phase; returns its end time (None if going idle).

        Slot order: waiting requests first (a preemption was triggered
        *for* an arrival, which must not lose the freed slot back to its
        own victim), then suspended requests resume into whatever slots
        remain — a resume is free (KV position intact, no re-prefill), the
        member simply rejoins the active set for the coming segments.  A
        *prefill refugee* at the head of the suspended queue cannot
        resume for free (its prompt is only part-processed): it gets a
        dedicated batch-1 `restore` phase re-running the unfinished
        suffix, which — like a joiner prefill — runs before any decode
        segment (FIFO order over the suspended queue is preserved, so
        decode-ready refugees behind it wait for the restore)."""
        self._phase_epoch += 1
        self._phase_stretch = self.slowdown   # σ fixed for this phase
        slots = self.max_batch - len(self.active)
        joiners = [self.waiting.popleft()
                   for _ in range(min(slots, len(self.waiting)))]
        slots -= len(joiners)
        if slots > 0 and self.suspended:
            resumed = []
            while (len(resumed) < slots and self.suspended
                   and self.suspended[0].prefill_done is None):
                resumed.append(self.suspended.popleft())
            slots -= len(resumed)
            self.n_resumes += len(resumed)
            self.active.extend(resumed)
        if joiners and self._pending_hits:
            # a warm-prefix joiner gets a dedicated batch-1 telescoped
            # prefill (like a restore); the other joiners go back to the
            # head of the queue, order intact, for the next phase start
            i = next((i for i, r in enumerate(joiners)
                      if r.request_id in self._pending_hits), None)
            if i is not None:
                warm = joiners.pop(i)
                for r in reversed(joiners):
                    self.waiting.appendleft(r)
                return self._start_cached_prefill(warm, now)
        if joiners:
            # (joiner) prefill for as many waiting requests as fit
            members = [_InFlight(r, start_s=now) for r in joiners]
            if self.checkpoint is not None:
                return self._begin_chunked_prefill(members, now)
            s, t, e = self._prefill(max(r.tau_in for r in joiners),
                                    len(joiners))
            t, e = self._stretched(t, e)
            self._set_state(ACTIVE, now)
            self._charge(members, t, e, kind="prefill", start_s=now, scale=s)
            self.active.extend(members)
            self._phase_members = members
            self._phase_steps = 0
            self._phase_kind = "prefill"
            self._phase_start_s = now
            self._phase_scale = s
            self._phase_end_s = now + t
            return self._phase_end_s
        if (self.suspended and self.suspended[0].prefill_done is not None
                and slots > 0):
            return self._start_restore(now)
        if self.active:
            # decode to the next completion boundary (padded batch: every
            # step attends up to the longest member context); closed-form
            # and memoized on (base, n_steps, batch, freq), so bursts of
            # identical requests price each segment shape exactly once.
            # The charge is deferred to settle time (segment end or
            # preemption boundary) so a truncated segment is charged once,
            # for exactly the steps it ran.
            n_steps = min(m.remaining for m in self.active)
            base = max(m.context for m in self.active)
            s, t, e = self._decode(base, n_steps, len(self.active))
            t, e = self._stretched(t, e)
            self._set_state(ACTIVE, now)
            self._phase_members = list(self.active)
            self._phase_steps = n_steps
            self._phase_kind = "decode"
            self._phase_start_s = now
            self._phase_base = base
            self._phase_scale = s
            self._phase_t = t
            self._phase_e = e
            self._phase_end_s = now + t
            return self._phase_end_s
        self._set_state(IDLE, now)
        self._phase_kind = None
        self._phase_end_s = None
        return None

    def on_phase_end(self, now: float
                     ) -> tuple[list[Completion], tuple[str, float] | None]:
        """Advance past the finished phase.  Returns (completions, next
        phase event or None if the node went idle)."""
        assert self._phase_end_s is not None
        if self._ckpt_chunk_to is not None:
            # checkpointed-prefill chunk boundary
            if self._crash_pending:
                self._waste_inflight_chunk(now)
                return [], None
            if self._ckpt_chunk_to < self._ckpt_total:
                return [], self._phase_event(self._settle_prefill_chunk(now))
            # final boundary: the full (padded) prompt is processed
            for m in self._phase_members:
                m.prefill_done = None
            self._clear_chunk_state()
        elif self._phase_kind == "restore":
            if self._crash_pending:
                self._waste_restore(now)
                return [], None
            m = self._restore_member
            self._restore_member = None
            self._restore_charge = 0.0
            m.prefill_done = None
            self.active.append(m)   # completion check below catches τout==0
        if self._phase_kind == "decode":   # settle the deferred charge
            self._charge(self._phase_members, self._phase_t, self._phase_e,
                         kind="decode", start_s=self._phase_start_s,
                         scale=self._phase_scale)
        done: list[Completion] = []
        for m in self._phase_members:
            m.generated += self._phase_steps
        # τout == 0 requests complete straight after their prefill, so this
        # check runs after every phase, not only decode segments
        finished = [m for m in self.active if m.remaining <= 0]
        if finished:
            self.active = [m for m in self.active if m.remaining > 0]
            for m in finished:
                self.n_served += 1
                self._cache_commit(m)
                done.append(Completion(
                    req=m.req,
                    start_s=m.start_s,
                    finish_s=now,
                    energy_j=m.energy_j,
                    isolated_runtime_s=self.sim.simulate(
                        m.req.tau_in, m.req.tau_out).runtime_s,
                    preemptions=m.preemptions,
                    migrations=m.migrations,
                    shipped_bytes=m.shipped_bytes,
                    cached_tokens=m.cached_tokens,
                ))
        self._phase_members = []
        self._phase_steps = 0
        self._phase_kind = None
        self._phase_end_s = None
        if self._crash_pending:
            # the crash was quantized to this settle (prefill end, or a
            # decode that reached its natural boundary first): members
            # finishing exactly here completed legitimately — the
            # in-flight work is never re-run — and the rest are refugees
            self._complete_crash(now)
            return done, None
        return done, self._phase_event(self._start_phase(now))

    # --- checkpointed prefill: chunks, persistence, restore -------------
    def _clear_chunk_state(self) -> None:
        self._ckpt_chunk_to = None
        self._ckpt_total = 0
        self._ckpt_chunk_charge = 0.0

    def _begin_chunked_prefill(self, members: list[_InFlight],
                               now: float) -> float:
        """First chunk of a checkpointed prefill.  One operating point
        (and one straggler stretch) is fixed for the whole prefill — a
        per-chunk re-pick would break the telescoping identity that makes
        the chunk sum equal the unchunked `prefill_cost` exactly."""
        total = max(m.req.tau_in for m in members)
        batch = len(members)
        if self.dvfs == "per_phase":
            s, _, _ = self.sim.best_prefill_frequency(
                total, batch=batch, extra_w=self.sim.host_power_w)
        else:
            s = self.freq_scale
        self.freq_choices[("prefill", s)] += 1
        for m in members:
            m.prefill_done = 0
        b1 = min(self.checkpoint.interval_tokens, total)
        t, e = self.sim.prefill_cost(b1, batch=batch, freq_scale=s)
        t, e = self._stretched(t, e)
        self._set_state(ACTIVE, now)
        self._charge(members, t, e, kind="prefill", start_s=now, scale=s)
        self.active.extend(members)
        self._phase_members = members
        self._phase_steps = 0
        self._phase_kind = "prefill"
        self._phase_start_s = now
        self._phase_scale = s
        self._ckpt_chunk_to = b1
        self._ckpt_total = total
        self._ckpt_chunk_charge = e + self.sim.host_power_w * t
        self._phase_end_s = now + t
        return self._phase_end_s

    def _settle_prefill_chunk(self, now: float) -> float:
        """An interior chunk boundary lands: advance every member's
        processed-prompt position, durably persist the new KV prefix
        (bytes = new tokens × kv_bytes_per_token into the checkpoint
        bucket), and charge the next chunk — the exact closed-form
        difference prefill_cost(b₂) − prefill_cost(b₁) at the phase's
        pinned operating point."""
        b = self._ckpt_chunk_to
        members = self._phase_members
        new_tokens = 0
        n_members = 0
        for m in members:
            done = min(b, m.req.tau_in)
            m.prefill_done = done
            if done > m.ckpt_tokens:
                new_tokens += done - m.ckpt_tokens
                m.ckpt_tokens = done
                n_members += 1
                self.n_checkpoints += 1
        if new_tokens > 0:
            n_bytes = new_tokens * kv_bytes_per_token(self.sim.cfg)
            ckpt_s = n_bytes / self.checkpoint.ckpt_bw
            ckpt_j = n_bytes * self.checkpoint.j_per_byte_ckpt
            self.checkpoint_s += ckpt_s
            self.checkpoint_energy_j += ckpt_j
            if self.telemetry is not None:
                self.telemetry.on_checkpoint(self, new_tokens, n_bytes,
                                             ckpt_s, ckpt_j, n_members)
        b2 = min(b + self.checkpoint.interval_tokens, self._ckpt_total)
        batch = len(members)
        s = self._phase_scale
        t1, e1 = self.sim.prefill_cost(b, batch=batch, freq_scale=s)
        t2, e2 = self.sim.prefill_cost(b2, batch=batch, freq_scale=s)
        t, e = self._stretched(t2 - t1, e2 - e1)
        self._charge(members, t, e, kind="prefill", start_s=now, scale=s)
        self._ckpt_chunk_to = b2
        self._ckpt_chunk_charge = e + self.sim.host_power_w * t
        self._phase_start_s = now
        self._phase_end_s = now + t
        return self._phase_end_s

    def _waste_inflight_chunk(self, now: float) -> None:
        """A crash quantized to this chunk boundary: the in-flight
        chunk's work dies with the node — its charge moves busy → wasted
        (deducting the exact per-member shares `_charge` attributed) and
        every member rolls back to its last durable checkpoint.  Lost
        work is bounded by one interval — the finer quantization that
        checkpointing buys over the complete-the-whole-prefill crash
        semantics of checkpoint=None."""
        charge = self._ckpt_chunk_charge
        share = charge / len(self._phase_members)
        nid = self.node_id
        for m in self._phase_members:
            m.energy_j -= share
            m.energy_on[nid] -= share
            m.prefill_done = min(m.ckpt_tokens, m.req.tau_in)
        self.book_waste(charge)
        self._clear_chunk_state()
        self._phase_members = []
        self._phase_kind = None
        self._phase_end_s = None
        self._complete_crash(now)

    def _start_restore(self, now: float) -> float:
        """Batch-1 restore phase for the prefill refugee at the head of
        the suspended queue: re-run only the unfinished suffix of its
        prompt — the closed-form difference prefill_cost(τin) −
        prefill_cost(ckpt), the same telescoping identity the chunks use
        — after which the member is decode-ready like any resume."""
        m = self.suspended.popleft()
        tau = m.req.tau_in
        base = m.ckpt_tokens
        assert 0 < base < tau, (base, tau)   # sim.py normalizes the rest
        if self.dvfs == "per_phase":
            s, _, _ = self.sim.best_prefill_frequency(
                tau, batch=1, extra_w=self.sim.host_power_w)
        else:
            s = self.freq_scale
        self.freq_choices[("restore", s)] += 1
        t_full, e_full = self.sim.prefill_cost(tau, batch=1, freq_scale=s)
        t_base, e_base = self.sim.prefill_cost(base, batch=1, freq_scale=s)
        t, e = self._stretched(t_full - t_base, e_full - e_base)
        self._set_state(ACTIVE, now)
        self._charge([m], t, e, kind="restore", start_s=now, scale=s)
        self.n_restores += 1
        self._restore_member = m
        self._restore_charge = e + self.sim.host_power_w * t
        self._phase_members = [m]
        self._phase_steps = 0
        self._phase_kind = "restore"
        self._phase_start_s = now
        self._phase_scale = s
        self._phase_end_s = now + t
        if self.telemetry is not None:
            self.telemetry.on_restore(self, tau, base, s)
        return self._phase_end_s

    def _waste_restore(self, now: float) -> None:
        """A crash quantized to the restore settle: the re-run suffix
        dies with the node (charge moves busy → wasted) and the member —
        still holding its durable checkpoint — goes back to the suspended
        queue as a prefill refugee for the sim loop to re-dispatch."""
        m = self._restore_member
        charge = self._restore_charge
        m.energy_j -= charge
        m.energy_on[self.node_id] -= charge
        self.suspended.append(m)
        self.book_waste(charge)
        self._restore_member = None
        self._restore_charge = 0.0
        self._phase_members = []
        self._phase_kind = None
        self._phase_end_s = None
        self._complete_crash(now)

    # --- decode-boundary preemption ------------------------------------
    def _decode_time_at(self, n_steps: int) -> float:
        """Closed-form time of the running segment truncated to n_steps
        (memoized — the binary search below costs O(log n) cached evals)."""
        t, _ = self.sim.decode_cost(self._phase_base, n_steps,
                                    batch=len(self._phase_members),
                                    freq_scale=self._phase_scale)
        return t

    def _segment_time_at(self, n_steps: int) -> float:
        """Wall time of the running segment truncated to n_steps — the
        closed form under the phase's straggler stretch (what elapsed
        simulation time actually compares against)."""
        return self._phase_stretch * self._decode_time_at(n_steps)

    def preempt_decode(self, request_id: int, now: float
                       ) -> tuple[str, float] | None:
        """Ask to evict `request_id` from the running decode segment at the
        next step boundary ≥ `now` (the in-flight token always finishes —
        nothing is re-run, so the energy split is exact).  Returns the
        (EventKind.PREEMPT_END, settle_s) event, or None when there is
        nothing to
        preempt: not mid-decode, a preemption already pending, the victim
        is not an active member, or the segment ends before another step
        boundary anyway.  The already-scheduled segment-end event is
        invalidated by bumping the phase epoch."""
        if not self.in_decode or self.preempt_pending:
            return None
        member = next((m for m in self.active
                       if m.req.request_id == request_id), None)
        if member is None:
            return None
        lo = self._boundary_at(now)
        if lo >= self._phase_steps:
            return None                    # segment finishing anyway
        self._preempt_steps = lo
        self._preempt_victims = [member]
        self._phase_epoch += 1             # stale segment-end event dies
        self._phase_end_s = self._phase_start_s + self._segment_time_at(lo)
        return (_PREEMPT, self._phase_end_s)

    def _boundary_at(self, now: float) -> int:
        """Smallest n with wall-time(n) >= now − phase start: the boundary
        of the token in flight at `now` (never in the past — causality
        holds exactly; stretched segments search the stretched clock)."""
        elapsed = now - self._phase_start_s
        lo, hi = 0, self._phase_steps
        while lo < hi:
            mid = (lo + hi) // 2
            if self._segment_time_at(mid) >= elapsed:
                hi = mid
            else:
                lo = mid + 1
        return lo

    def on_preempt_end(self, now: float) -> tuple[str, float] | None:
        """Settle a truncated decode segment at its preemption boundary:
        charge exactly the steps that ran (closed form over [0, n_done) —
        the first half of the split whose two parts sum to the unpreempted
        `decode_cost` to 1e-9), advance every member's KV position, move
        the victims to the suspended set, and start the next phase (which
        admits the waiting arrival the preemption made room for)."""
        assert self._preempt_steps is not None and self.in_decode
        n_done = self._preempt_steps
        t_done, e_done = self.sim.decode_cost(
            self._phase_base, n_done, batch=len(self._phase_members),
            freq_scale=self._phase_scale)
        t_done, e_done = self._stretched(t_done, e_done)
        self._charge(self._phase_members, t_done, e_done, kind="decode",
                     start_s=self._phase_start_s, scale=self._phase_scale)
        if self.telemetry is not None:
            self.telemetry.on_preempt_split(
                self, self._phase_base, n_done, self._phase_steps,
                len(self._phase_members), self._phase_scale)
        for m in self._phase_members:
            m.generated += n_done
        # n_done < n_steps = min remaining, so nobody can have completed
        assert all(m.remaining > 0 for m in self._phase_members)
        for victim in self._preempt_victims:
            self.active.remove(victim)
            victim.preemptions += 1
            self.suspended.append(victim)
            self.n_preemptions += 1
        self._preempt_steps = None
        self._preempt_victims = []
        self._phase_members = []
        self._phase_steps = 0
        self._phase_kind = None
        self._phase_end_s = None
        if self._crash_pending:   # crash arrived while the settle was due
            self._complete_crash(now)
            return None
        return self._phase_event(self._start_phase(now))

    # --- faults: crash, recovery, migration, waste ---------------------
    def begin_crash(self, now: float) -> tuple[str, float] | None:
        """The node fails at `now`, quantized to the next exact charge
        boundary so the dying node's last settlement stays a closed-form
        charge:

          * off-phase — immediate (nothing in flight; state goes FAILED
            right here and the caller rescues `suspended`/`waiting`);
          * mid-decode — the in-flight token finishes: returns a
            (EventKind.CRASH_END, settle_s) event for the truncated-segment
            boundary
            (the same binary search preemption uses), invalidating the
            scheduled segment end via the phase epoch;
          * mid-prefill, with a preemption already pending, or with the
            decode at its natural boundary anyway — the crash lands at
            the already-scheduled settle (`on_phase_end`/`on_preempt_end`
            complete it), so no new event is needed.

        Callers detect the immediate case via `self.failed`."""
        if self._pstate == FAILED or self._crash_pending:
            return None
        self._crash_pending = True
        if not self.busy:
            self._complete_crash(now)
            return None
        if self._phase_kind == "decode" and not self.preempt_pending:
            lo = self._boundary_at(now)
            if lo < self._phase_steps:
                self._crash_steps = lo
                self._phase_epoch += 1     # stale segment-end event dies
                self._phase_end_s = (self._phase_start_s
                                     + self._segment_time_at(lo))
                return (_CRASH, self._phase_end_s)
        return None

    def on_crash_settle(self, now: float) -> None:
        """Settle the truncated decode segment at the crash boundary —
        the donor's half of the cross-node split contract: charged via
        the same closed-form split as a preemption (audited through the
        same `on_preempt_split` hook) — then complete the crash."""
        assert self._crash_steps is not None and self.in_decode
        n_done = self._crash_steps
        t_done, e_done = self.sim.decode_cost(
            self._phase_base, n_done, batch=len(self._phase_members),
            freq_scale=self._phase_scale)
        t_done, e_done = self._stretched(t_done, e_done)
        self._charge(self._phase_members, t_done, e_done, kind="decode",
                     start_s=self._phase_start_s, scale=self._phase_scale)
        if self.telemetry is not None:
            self.telemetry.on_preempt_split(
                self, self._phase_base, n_done, self._phase_steps,
                len(self._phase_members), self._phase_scale)
        for m in self._phase_members:
            m.generated += n_done
        assert all(m.remaining > 0 for m in self._phase_members)
        self._crash_steps = None
        self._complete_crash(now)

    def _complete_crash(self, now: float) -> None:
        """The quantized crash instant: every active member joins the
        suspended set (KV position and accrued energy intact — they are
        the refugees the sim loop migrates or abandons), all phase state
        clears, every stale heap event for this node dies with the epoch
        bump, and the node draws 0 W until its recovery event."""
        for m in self.active:
            self.suspended.append(m)
        self.active = []
        self._phase_members = []
        self._phase_steps = 0
        self._phase_kind = None
        self._phase_end_s = None
        self._preempt_steps = None
        self._preempt_victims = []
        self._clear_chunk_state()
        self._restore_member = None
        self._restore_charge = 0.0
        self._cache_invalidate(now)
        self._phase_epoch += 1
        self._crash_pending = False
        self._set_state(FAILED, now)
        self.n_crashes += 1

    def recover(self, now: float) -> tuple[str, float] | None:
        """The recovery event: FAILED → IDLE, serving whatever queued
        (the sim drains waiting/suspended at crash time, so normally
        nothing — the node simply rejoins the eligible set)."""
        assert self._pstate == FAILED, f"recover from {self._pstate}"
        self._set_state(IDLE, now)
        self.n_recoveries += 1
        return self._phase_event(self._start_phase(now))

    def book_waste(self, e_j: float) -> None:
        """Move `e_j` joules of lost work from the busy bucket to the
        wasted bucket (a *move*, not a new charge: total energy is
        unchanged and the fleet invariant 'attributed energy of completed
        requests == Σ busy' stays exact)."""
        self.busy_energy_j -= e_j
        self.wasted_energy_j += e_j
        if self.telemetry is not None:
            self.telemetry.on_waste(self, e_j)

    def book_shipping(self, ship_s: float, ship_j: float) -> None:
        """Meter an inbound KV shipment (the recipient pulls over its
        interconnect: bytes/ici_bw seconds at j_per_byte_ici, billed by
        the sim loop).  Background NIC DMA — concurrent with serving, so
        the seconds stay outside the horizon partition."""
        self.shipping_s += ship_s
        self.shipping_energy_j += ship_j

    def receive_migrant(self, member: _InFlight, now: float
                        ) -> tuple[str, float] | None:
        """A shipped refugee lands (its KV just finished transferring):
        it joins the suspended set and resumes for free at the next phase
        start with a spare slot — exactly the preemption resume path, now
        crossing nodes.  Mirrors `enqueue`'s power handling: a gated
        recipient wakes on demand."""
        assert self._pstate != FAILED, "migrant shipped to a failed node"
        self.suspended.append(member)
        member.migrations += 1
        self.n_migrations_in += 1
        if self._pstate == GATED:
            return (_WAKE, self.begin_wake(now))
        if self._pstate in (WAKING, GATING) or self.busy:
            return None
        return self._phase_event(self._start_phase(now))
