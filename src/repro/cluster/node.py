"""Per-node serving state machine with continuous batching.

A ClusterNode hosts one model replica on one hardware Node and serves the
requests a routing policy sends it.  Service is phase-granular:

  * prefill phase — up to max_batch waiting requests are admitted together
    and their (padded) prompts processed in one batched pass;
  * decode segment — the active batch decodes until the *next completion
    boundary* (the smallest remaining τout among members), after which
    finished requests leave and new waiting requests may join via a joiner
    prefill.  This is iteration-level continuous batching coarsened to
    completion boundaries, which keeps the event count O(requests) instead
    of O(tokens).

Time and energy per phase delegate to repro.energy.simulator
(AnalyticLLMSimulator.prefill_cost / decode_cost) on the node's hardware
(repro.energy.hardware.Node), so an uncontended node reproduces the
per-request simulator's PhaseBreakdown exactly — the energy-conservation
invariant tested in tests/test_cluster.py.

decode_cost is the exact closed-form integral (additive across segment
splits, so completion-boundary segmentation conserves energy by
construction) and both phase costs are memoized inside the simulator per
(context, steps, batch) — workloads with repeated query shapes never
re-integrate a decode segment, which is what keeps million-request
cluster sweeps tractable.
"""

from __future__ import annotations

import dataclasses
from collections import deque

from repro.core.energy_model import LLMProfile
from repro.energy.hardware import Node, SWING_NODE
from repro.energy.simulator import AnalyticLLMSimulator
from repro.models.common import ModelConfig

from repro.cluster.trace import TracedRequest


@dataclasses.dataclass
class _InFlight:
    req: TracedRequest
    start_s: float              # first service (prefill start)
    generated: int = 0          # decode tokens produced so far
    energy_j: float = 0.0       # attributed share of phase energy

    @property
    def remaining(self) -> int:
        return self.req.tau_out - self.generated

    @property
    def context(self) -> int:
        return self.req.tau_in + self.generated


@dataclasses.dataclass(frozen=True)
class Completion:
    req: TracedRequest
    start_s: float
    finish_s: float
    energy_j: float             # attributed accelerator+host joules
    isolated_runtime_s: float   # batch-1 uncontended service time (slowdown SLO)


class ClusterNode:
    """One model replica on one hardware node, with a waiting queue and a
    continuously-batched active set.  Driven by repro.cluster.sim."""

    def __init__(
        self,
        node_id: int,
        model_cfg: ModelConfig,
        profile: LLMProfile,
        hardware: Node = SWING_NODE,
        *,
        max_batch: int = 8,
        kv_cache: bool = True,
        decode_chunk: int = 256,   # legacy reference-loop chunk (decode_cost
                                   # itself is closed-form and chunk-free)
    ):
        self.node_id = node_id
        self.model_cfg = model_cfg
        self.profile = profile
        self.max_batch = max_batch
        self.sim = AnalyticLLMSimulator(
            model_cfg, hardware, batch=1, kv_cache=kv_cache,
            noise_sigma=0.0, decode_chunk=decode_chunk)
        self.hardware = self.sim.node  # n_accel resolved to fit the weights

        self.waiting: deque[TracedRequest] = deque()
        self.active: list[_InFlight] = []
        self._phase_end_s: float | None = None
        self._phase_members: list[_InFlight] = []
        self._phase_steps: int = 0

        # aggregate accounting
        self.busy_s = 0.0
        self.busy_energy_j = 0.0
        self.n_served = 0

    # ------------------------------------------------------------------
    @property
    def model_name(self) -> str:
        return self.profile.name

    @property
    def busy(self) -> bool:
        return self._phase_end_s is not None

    def load(self) -> int:
        """Queue depth + in-flight count (the least-loaded policy signal)."""
        return len(self.waiting) + len(self.active)

    @property
    def idle_power_w(self) -> float:
        a, h = self.hardware.accel, self.hardware.host
        return a.idle_w * self.hardware.n_accel + h.idle_w

    # ------------------------------------------------------------------
    def enqueue(self, req: TracedRequest, now: float) -> float | None:
        """Accept a routed request.  Returns the end time of a newly started
        phase if the node was idle, else None (the request waits)."""
        self.waiting.append(req)
        if not self.busy:
            return self._start_phase(now)
        return None

    def _charge(self, members: list[_InFlight], t: float, e_accel: float) -> None:
        e_total = e_accel + self.sim.host_power_w * t
        self.busy_s += t
        self.busy_energy_j += e_total
        share = e_total / len(members)
        for m in members:
            m.energy_j += share

    def _start_phase(self, now: float) -> float | None:
        """Pick the next phase; returns its end time (None if going idle)."""
        slots = self.max_batch - len(self.active)
        if slots > 0 and self.waiting:
            # (joiner) prefill for as many waiting requests as fit
            joiners = [self.waiting.popleft()
                       for _ in range(min(slots, len(self.waiting)))]
            members = [_InFlight(r, start_s=now) for r in joiners]
            t, e = self.sim.prefill_cost(max(r.tau_in for r in joiners),
                                         batch=len(joiners))
            self._charge(members, t, e)
            self.active.extend(members)
            self._phase_members = members
            self._phase_steps = 0
            self._phase_end_s = now + t
            return self._phase_end_s
        if self.active:
            # decode to the next completion boundary (padded batch: every
            # step attends up to the longest member context); closed-form
            # and memoized on (base, n_steps, batch), so bursts of
            # identical requests price each segment shape exactly once
            n_steps = min(m.remaining for m in self.active)
            base = max(m.context for m in self.active)
            t, e = self.sim.decode_cost(base, n_steps, batch=len(self.active))
            self._charge(self.active, t, e)
            self._phase_members = list(self.active)
            self._phase_steps = n_steps
            self._phase_end_s = now + t
            return self._phase_end_s
        self._phase_end_s = None
        return None

    def on_phase_end(self, now: float) -> tuple[list[Completion], float | None]:
        """Advance past the finished phase.  Returns (completions, next
        phase end time or None if the node went idle)."""
        assert self._phase_end_s is not None
        done: list[Completion] = []
        for m in self._phase_members:
            m.generated += self._phase_steps
        # τout == 0 requests complete straight after their prefill, so this
        # check runs after every phase, not only decode segments
        finished = [m for m in self.active if m.remaining <= 0]
        if finished:
            self.active = [m for m in self.active if m.remaining > 0]
            for m in finished:
                self.n_served += 1
                done.append(Completion(
                    req=m.req,
                    start_s=m.start_s,
                    finish_s=now,
                    energy_j=m.energy_j,
                    isolated_runtime_s=self.sim.simulate(
                        m.req.tau_in, m.req.tau_out).runtime_s,
                ))
        self._phase_members = []
        self._phase_steps = 0
        self._phase_end_s = None
        return done, self._start_phase(now)
