"""Launch layer: production meshes, sharding rules, step builders, dry-run."""

from repro.launch.mesh import make_production_mesh, make_test_mesh, mesh_chips  # noqa: F401
from repro.launch.steps import (  # noqa: F401
    build_prefill_step,
    build_serve_step,
    build_train_step,
)
