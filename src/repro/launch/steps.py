"""Step builders: the three jittable entry points the launcher, dry-run and
examples all share.

train_step: CE loss + gradient accumulation over microbatches (lax.scan)
+ optimizer update.  prefill_step / serve_step: the serving pair.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import get_api
from repro.models.common import ModelConfig
from repro.optim import get_optimizer


def build_train_step(cfg: ModelConfig, *, lr: float = 1e-4,
                     param_pspecs=None) -> tuple[Callable, object]:
    """Returns (train_step(params, opt_state, batch) -> (loss, params,
    opt_state), optimizer).

    param_pspecs (optional): PartitionSpec tree matching params — the
    gradient accumulator is constrained to it so grads stay FSDP-sharded
    through the microbatch scan instead of being all-reduced replicated
    (measured: the dominant all-reduce traffic in 671B training)."""
    api = get_api(cfg)
    opt = get_optimizer(cfg.optimizer)

    def loss_fn(p, mb):
        loss, _ = api.train_loss(cfg, p, mb)
        return loss

    accum_dtype = jnp.dtype(cfg.grad_accum_dtype)

    def constrain_grads(g):
        if param_pspecs is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g, param_pspecs)

    def train_step(params, opt_state, batch):
        B = batch["tokens"].shape[0]
        mb_size = cfg.microbatch or B
        if mb_size >= B:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = constrain_grads(grads)
        else:
            n = B // mb_size
            mbs = jax.tree.map(
                lambda x: x.reshape((n, mb_size) + x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            zeros = constrain_grads(zeros)

            def body(carry, mb):
                acc, loss_acc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                g = constrain_grads(g)
                acc = jax.tree.map(lambda a, gg: a + gg.astype(accum_dtype), acc, g)
                acc = constrain_grads(acc)
                return (acc, loss_acc + loss), None

            (grads, loss_sum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss_sum / n
        params, opt_state = opt.update(grads, opt_state, params, lr)
        return loss, params, opt_state

    return train_step, opt


def build_prefill_step(cfg: ModelConfig, *, cache_len: int,
                       long_context: bool = False) -> Callable:
    api = get_api(cfg)

    def prefill_step(params, inputs):
        return api.prefill(cfg, params, inputs, cache_len=cache_len,
                           long_context=long_context)

    return prefill_step


def build_serve_step(cfg: ModelConfig) -> Callable:
    """ONE new token against the cache — the decode dry-run target."""
    api = get_api(cfg)

    def serve_step(params, cache, inputs):
        return api.decode_step(cfg, params, cache, inputs)

    return serve_step
