"""Training driver: real execution on the local device(s).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b-reduced \
        --steps 200 --batch 8 --seq 128

Runs the same build_train_step the dry-run lowers, on synthetic LM batches,
and reports loss curve + step timing.  Used by examples/train_small.py to
train a ~100M-param model for a few hundred steps on CPU.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.workloads import lm_train_batches
from repro.launch.steps import build_train_step
from repro.models import get_api


def train(arch, *, steps: int, batch: int, seq: int, lr: float = 3e-4,
          seed: int = 0, log_every: int = 10,
          ckpt_dir: str | None = None, ckpt_every: int = 100) -> list[float]:
    from repro import checkpoint as ckptlib

    cfg = arch if not isinstance(arch, str) else get_config(arch)
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(seed))
    n_params = api.count_params(cfg)
    print(f"arch={cfg.name} family={cfg.family} params={n_params/1e6:.1f}M "
          f"devices={jax.device_count()}")

    step_fn, opt = build_train_step(cfg, lr=lr)
    opt_state = opt.init(params)
    start = 0
    if ckpt_dir is not None:
        latest = ckptlib.latest_step(ckpt_dir)
        if latest is not None:
            tree, start, _ = ckptlib.load_checkpoint(
                ckptlib.step_path(ckpt_dir, latest))
            params, opt_state = tree["params"], tree["opt_state"]
            print(f"resumed from step {start}")
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    losses: list[float] = []
    t0 = time.time()
    for i, b in enumerate(lm_train_batches(steps, batch, seq, cfg.vocab_size,
                                           seed=seed + start)):
        loss, params, opt_state = jit_step(params, opt_state, b)
        losses.append(float(loss))
        step_no = start + i + 1
        if i % log_every == 0 or i == steps - 1:
            dt = time.time() - t0
            print(f"step {step_no:4d} loss {losses[-1]:.4f} "
                  f"({dt/(i+1):.3f}s/step)", flush=True)
        if ckpt_dir is not None and step_no % ckpt_every == 0:
            ckptlib.save_checkpoint(
                ckptlib.step_path(ckpt_dir, step_no),
                {"params": params, "opt_state": opt_state}, step=step_no,
                metadata={"arch": cfg.name})
    return losses


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-1.7b-reduced")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    args = p.parse_args(argv)
    losses = train(args.arch, steps=args.steps, batch=args.batch,
                   seq=args.seq, lr=args.lr)
    improved = losses[-1] < losses[0]
    print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f} improved={improved}")
    return 0 if improved else 1


if __name__ == "__main__":
    raise SystemExit(main())
