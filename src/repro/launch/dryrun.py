import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Everything below runs against 512 placeholder host devices so the
# production mesh (16x16 single-pod / 2x16x16 multi-pod) can be built.
# Tests may shrink the device count (and mesh) via REPRO_DRYRUN_DEVICES /
# --mesh-shape BEFORE jax initializes devices.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"])

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from functools import partial  # noqa: E402
from pathlib import Path       # noqa: E402

import jax           # noqa: E402

from repro import shard                                  # noqa: E402
from repro.analysis.hlo import HLOModule, float_normalization_bytes  # noqa: E402
from repro.analysis.roofline import roofline_terms       # noqa: E402
from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config, token_specs  # noqa: E402
from repro.configs.shapes import InputShape              # noqa: E402
from repro.energy.costs import pass_costs                # noqa: E402
from repro.launch import sharding as shardrules          # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_chips  # noqa: E402
from repro.launch.steps import build_prefill_step, build_serve_step, build_train_step  # noqa: E402
from repro.models import active_params, get_api          # noqa: E402
from repro.models.common import ModelConfig              # noqa: E402


# ---------------------------------------------------------------------------
# Analytic per-step quantities for the roofline table
# ---------------------------------------------------------------------------

_OPT_BYTES_PER_PARAM = {"adamw": 26.0, "adafactor": 9.0, "sgd": 14.0}


def step_model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    n_act = active_params(cfg)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_act * B * S
    if shape.kind == "prefill":
        return 2.0 * n_act * B * S
    return 2.0 * n_act * B          # decode: one token per sequence


def step_hbm_bytes(cfg: ModelConfig, shape: InputShape) -> float:
    B, S = shape.global_batch, shape.seq_len
    api = get_api(cfg)
    if shape.kind == "train":
        fwd = pass_costs(cfg, S, S, B, decode=False).hbm_bytes
        opt = api.count_params(cfg) * _OPT_BYTES_PER_PARAM[cfg.optimizer]
        # fwd + bwd (~2x fwd traffic) + remat recompute (~1x) + optimizer
        return fwd * 4.0 + opt
    if shape.kind == "prefill":
        return pass_costs(cfg, S, S, B, decode=False).hbm_bytes
    return pass_costs(cfg, 1, S, B, decode=True).hbm_bytes


# ---------------------------------------------------------------------------
# One dry-run
# ---------------------------------------------------------------------------


def lower_one(cfg: ModelConfig, shape: InputShape, mesh, rules: dict):
    """Lower + compile one (config x shape) on a mesh.  Returns (compiled,
    seconds_to_lower, seconds_to_compile)."""
    api = get_api(cfg)
    with mesh, shard.use_rules(rules, shardrules.mesh_axis_sizes(mesh)):
        pshapes = api.param_shapes(cfg)
        defs = api.param_defs(cfg)
        if shape.kind == "train":
            # FSDP: params + optimizer state sharded over data as well
            pspecs = shardrules.fsdp_specs(defs, rules, mesh)
        else:
            pspecs = api.param_specs(cfg, rules)
        params_sds = shardrules.with_sharding(pshapes, pspecs, mesh)
        tspecs = token_specs(cfg, shape)
        inputs_sds = shardrules.with_sharding(
            tspecs, shardrules.input_pspecs(tspecs, rules), mesh)

        t0 = time.time()
        if shape.kind == "train":
            step, opt = build_train_step(cfg, param_pspecs=pspecs)
            opt_shapes = jax.eval_shape(opt.init, pshapes)
            opt_specs = shardrules.opt_state_pspecs(
                cfg.optimizer, defs, rules, param_spec_tree=pspecs)
            opt_sds = shardrules.with_sharding(opt_shapes, opt_specs, mesh)
            from jax.sharding import PartitionSpec as P
            out_sh = (jax.sharding.NamedSharding(mesh, P()),
                      shardrules.to_named(
                          jax.tree.map(lambda s: s, pspecs,
                                       is_leaf=lambda x: isinstance(x, P)), mesh),
                      shardrules.to_named(opt_specs, mesh))
            lowered = jax.jit(step, donate_argnums=(0, 1),
                              out_shardings=out_sh).lower(
                params_sds, opt_sds, inputs_sds)
        elif shape.kind == "prefill":
            step = build_prefill_step(cfg, cache_len=shape.seq_len,
                                      long_context=shape.long_context)
            logits_struct, cache_struct = jax.eval_shape(
                step, params_sds, inputs_sds)
            from jax.sharding import PartitionSpec as P
            out_sh = (
                shardrules.named_legal(
                    logits_struct, shard.resolve(("batch", "vocab"), rules), mesh),
                shardrules.named_legal(
                    cache_struct, shardrules.cache_pspecs(cache_struct, rules), mesh))
            lowered = jax.jit(step, out_shardings=out_sh).lower(
                params_sds, inputs_sds)
        else:
            step = build_serve_step(cfg)
            cache_struct = jax.eval_shape(partial(
                api.init_cache, cfg, shape.global_batch, shape.seq_len,
                long_context=shape.long_context))
            cache_specs = shardrules.cache_pspecs(cache_struct, rules)
            cache_sds = shardrules.with_sharding(cache_struct, cache_specs, mesh)
            logits_struct, _ = jax.eval_shape(
                step, params_sds, cache_sds, inputs_sds)
            out_sh = (
                shardrules.named_legal(
                    logits_struct, shard.resolve(("batch", "vocab"), rules), mesh),
                shardrules.named_legal(cache_struct, cache_specs, mesh))
            lowered = jax.jit(step, donate_argnums=(1,),
                              out_shardings=out_sh).lower(
                params_sds, cache_sds, inputs_sds)
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return compiled, t_lower, t_compile


def run_one(arch: str, shape_name: str, *, multi_pod: bool, out_dir: Path,
            rules_extra: dict | None = None, force: bool = False,
            mesh=None, tag: str = "", cfg_overrides: dict | None = None) -> dict:
    mesh_name = ("multipod" if multi_pod else "pod") + (f"-{tag}" if tag else "")
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = INPUT_SHAPES[shape_name]
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chips(mesh)
    rules = shardrules.build_rules(cfg, shape, multi_pod=multi_pod,
                                   extra=rules_extra)

    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "rules": {k: list(v) if isinstance(v, tuple) else v
                  for k, v in rules.items()},
        "status": "error",
    }
    try:
        compiled, t_lower, t_compile = lower_one(cfg, shape, mesh, rules)
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo_mod = HLOModule(compiled.as_text())
        totals = hlo_mod.entry_totals()
        upcast = float_normalization_bytes(hlo_mod)
        terms = roofline_terms(
            arch=arch, shape=shape_name, mesh_name=mesh_name, chips=chips,
            hlo_totals=totals,
            hbm_bytes_global=step_hbm_bytes(cfg, shape),
            model_flops=step_model_flops(cfg, shape),
        )
        record.update({
            "status": "ok",
            "t_lower_s": t_lower,
            "t_compile_s": t_compile,
            "memory_analysis": {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "alias_bytes": int(mem.alias_size_in_bytes),
                "code_bytes": int(mem.generated_code_size_in_bytes),
                "peak_bytes_per_device": int(
                    mem.argument_size_in_bytes + mem.temp_size_in_bytes
                    + mem.output_size_in_bytes - mem.alias_size_in_bytes),
                # XLA:CPU upcasts every bf16 stack to f32 at entry (no such
                # buffers exist on the TPU target) — subtract for the
                # deployment-relevant number:
                "cpu_float_normalization_bytes": int(upcast),
                "peak_bytes_per_device_tpu": int(max(
                    mem.argument_size_in_bytes + mem.output_size_in_bytes
                    - mem.alias_size_in_bytes,
                    mem.argument_size_in_bytes + mem.temp_size_in_bytes
                    + mem.output_size_in_bytes - mem.alias_size_in_bytes
                    - upcast)),
            },
            "cost_analysis": {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))},
            "hlo": {
                "flops_per_device": totals.flops,
                "collective_bytes_per_device": dict(totals.collective_bytes),
                "collective_counts": dict(totals.collective_count),
            },
            "roofline": terms.to_dict(),
        })
    except Exception as e:  # noqa: BLE001 — campaign must survive one failure
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc(limit=8)

    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(record, indent=2))
    return record


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv=None):
    p = argparse.ArgumentParser(description="multi-pod dry-run campaign")
    p.add_argument("--arch", action="append", default=None,
                   help="arch id (repeatable); default: all assigned")
    p.add_argument("--shape", action="append", default=None,
                   choices=list(INPUT_SHAPES), help="input shape (repeatable)")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--out", default="results/dryrun")
    p.add_argument("--force", action="store_true")
    p.add_argument("--tag", default="", help="suffix for perf-experiment runs")
    p.add_argument("--rule", action="append", default=[],
                   help="logical-axis override, e.g. kv_seq=model or batch=-")
    p.add_argument("--cfg", action="append", default=[],
                   help="config override, e.g. cache_dtype=float8_e4m3fn or "
                        "microbatch=16 (ints auto-parsed)")
    args = p.parse_args(argv)

    cfg_overrides = {}
    for c in args.cfg:
        k, v = c.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            if v in ("true", "True", "false", "False"):
                v = v.lower() == "true"
        cfg_overrides[k] = v

    archs = args.arch or list(ASSIGNED_ARCHS)
    shapes = args.shape or list(INPUT_SHAPES)
    rules_extra = {}
    for r in args.rule:
        k, v = r.split("=", 1)
        if v in ("-", "none", "None"):
            rules_extra[k] = None
        elif "," in v:
            rules_extra[k] = tuple(v.split(","))
        else:
            rules_extra[k] = v

    out_dir = Path(args.out)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            t0 = time.time()
            rec = run_one(arch, shape_name, multi_pod=args.multi_pod,
                          out_dir=out_dir, rules_extra=rules_extra or None,
                          force=args.force, mesh=mesh, tag=args.tag,
                          cfg_overrides=cfg_overrides or None)
            dt = time.time() - t0
            if rec["status"] == "ok":
                r = rec["roofline"]
                mb = rec["memory_analysis"]["peak_bytes_per_device_tpu"] / 1e9
                print(f"OK   {arch:24s} {shape_name:12s} {rec['mesh']:9s} "
                      f"mem/dev={mb:6.2f}GB dom={r['dominant']:10s} "
                      f"step={r['step_s']*1e3:9.3f}ms  ({dt:.0f}s)", flush=True)
            else:
                failures += 1
                print(f"FAIL {arch:24s} {shape_name:12s} {rec['mesh']:9s} "
                      f"{rec['error']}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
