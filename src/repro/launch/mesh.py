"""Production mesh definitions (functions, not module constants — importing
this module never touches jax device state)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading pod=2 axis
    (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (requires >= prod(shape) host devices)."""
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
