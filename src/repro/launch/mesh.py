"""Production mesh definitions (functions, not module constants — importing
this module never touches jax device state)."""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; older jax only accepts
    # (shape, axes) and treats every axis as Auto already.
    axis_type = getattr(getattr(jax.sharding, "AxisType", None), "Auto", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading pod=2 axis
    (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (requires >= prod(shape) host devices)."""
    return _make_mesh(shape, axes)


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
