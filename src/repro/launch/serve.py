"""Serving driver: the paper's system end-to-end.

    PYTHONPATH=src python -m repro.launch.serve --fleet llama2-7b,llama2-13b \
        --queries 64 --zeta 0.5

1. Characterize each hosted (reduced) model by REAL execution on this host
   (wall-clock metering, KV cache disabled — the paper's measurement mode).
2. Fit the per-model e_K / r_K workload models (Eq. 6/7).
3. Route an Alpaca-like workload with the offline scheduler at the given
   zeta and serve every batch through the real engines (KV cache ON — the
   production path), reporting measured energy/runtime per model.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import TABLE1, get_config
from repro.core.characterize import (
    CampaignSettings,
    fit_profile_from_trials,
    run_campaign,
)
from repro.data import alpaca_like_workload, token_batches
from repro.data.workloads import WorkloadSpec
from repro.energy.meter import WallClockMeter
from repro.models import get_api
from repro.serving import EnergyAwareRouter, InferenceEngine


def build_engine(arch: str, *, kv_cache: bool, seed: int = 0) -> InferenceEngine:
    cfg = get_config(arch)
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(seed))
    return InferenceEngine(cfg, params, kv_cache=kv_cache,
                           meter=WallClockMeter(), bucket=16)


def characterize_fleet(archs: list[str], *, batch: int = 2,
                       max_tokens: int = 64) -> list:
    """Real-execution campaign (reduced models, CPU) -> fitted profiles."""
    settings = CampaignSettings(
        vary_input_range=(8, max_tokens), vary_output_range=(8, max_tokens),
        grid_range=(8, max_tokens), max_trials=3, min_trials=2,
        ci_tolerance_s=0.5)
    profiles = []
    for arch in archs:
        base = arch.replace("-reduced", "")
        a_k = TABLE1.get(base, {"a_k": get_config(base).accuracy_ak})["a_k"]
        engine = build_engine(arch, kv_cache=False)
        rng = np.random.default_rng(0)

        warmed: set = set()

        def measure(tin, tout, engine=engine, rng=rng, warmed=warmed):
            toks = rng.integers(1, engine.cfg.vocab_size,
                                (batch, tin)).astype(np.int32)
            if (tin, tout) not in warmed:   # exclude jit compiles from the
                warmed.add((tin, tout))     # measured energy (paper §3:
                engine.generate({"tokens": toks}, tout)  # no warm-start bias)
            _, stats = engine.generate({"tokens": toks}, tout)
            return stats.energy_j, stats.runtime_s

        trials = run_campaign(arch, measure, settings)
        prof = fit_profile_from_trials(arch, a_k, trials)
        print(f"{arch}: energy R2={prof.energy.r_squared:.3f} "
              f"runtime R2={prof.runtime.r_squared:.3f}")
        profiles.append(prof)
    return profiles


def serve(archs: list[str], *, n_queries: int, zeta: float,
          batch_size: int = 4) -> dict:
    profiles = characterize_fleet(archs)
    router = EnergyAwareRouter(profiles, zeta=zeta)

    spec = WorkloadSpec(n_queries=n_queries, max_in=48, max_out=32,
                        in_log_mean=2.8, out_log_mean=2.5)
    queries = alpaca_like_workload(spec)
    from repro.serving.requests import Request
    reqs = [Request(i, np.zeros(q[0], np.int32), q[1])
            for i, q in enumerate(queries)]
    plan = router.route(reqs)

    engines = {a: build_engine(a, kv_cache=True) for a in archs}
    totals: dict = {}
    for arch, rs in plan.per_model.items():
        if not rs:
            continue
        eng = engines[arch]
        e_j = t_s = 0.0
        n_tok = 0
        qs = [(r.tau_in, r.max_new_tokens) for r in rs]
        for b in token_batches(qs, batch_size, eng.cfg.vocab_size):
            max_new = int(b["tau_out"].max())
            _, stats = eng.generate({"tokens": b["tokens"]}, max_new)
            e_j += stats.energy_j
            t_s += stats.runtime_s
            n_tok += int(b["lengths"].sum()) + max_new * batch_size
        totals[arch] = {"queries": len(rs), "energy_j": e_j,
                        "runtime_s": t_s, "tokens": n_tok}
        print(f"{arch}: {len(rs)} queries, {e_j:.1f} J, {t_s:.1f}s measured")
    return {"plan": plan, "totals": totals}


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--fleet", default="llama2-7b-reduced,llama2-70b-reduced")
    p.add_argument("--queries", type=int, default=24)
    p.add_argument("--zeta", type=float, default=0.5)
    args = p.parse_args(argv)
    out = serve(args.fleet.split(","), n_queries=args.queries, zeta=args.zeta)
    total_e = sum(t["energy_j"] for t in out["totals"].values())
    print(f"TOTAL measured energy: {total_e:.1f} J "
          f"(objective={out['plan'].assignment.objective:.3f})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
