"""Sharding rule resolution for the launch layer: batch/cache/optimizer
specs per (config x input shape x mesh), built on the logical-axis rules in
repro.shard.

The rules table is the §Perf lever: dryrun.py accepts overrides like
--rule kv_seq=model to move the KV cache onto the flash-decode layout
without touching model code.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import shard
from repro.configs.shapes import InputShape
from repro.models import cache as cachelib
from repro.models.common import ModelConfig, ParamDef, _flatten_defs, _set_path


def config_rule_overrides(cfg: ModelConfig) -> dict:
    """Per-config logical-axis overrides (e.g. DeepSeek-V3 shards its 256
    experts over data x model)."""
    ov: dict = {}
    if cfg.family == "moe":
        axes = tuple(cfg.expert_shard_axes)
        ov["expert"] = axes if len(axes) > 1 else axes[0]
        if len(axes) > 1:
            ov["capacity"] = None   # capacity dim can't reuse the data axis
    return ov


def shape_rule_overrides(shape: InputShape) -> dict:
    """Per-input-shape layout policy.

    train    — sequence-parallel activations ("seq": model): the per-layer
               hidden states saved for backward shard 16x further, which is
               what fits 67B/95-layer training in 16 GB/chip.
    decode   — fully sequence-parallel attention: cache S-sharded over
               model (flash-decode), attention heads replicated, weights
               row-parallel ("embed_w": model) so per-token all-reduces are
               tiny instead of per-layer cache all-gathers.
    long_500k— batch=1: cache sequence takes the data axis too.
    """
    if shape.kind == "train":
        return {"seq": "model"}
    if shape.kind == "decode":
        ov = {"embed_w": "model", "heads": None, "kv_heads": None}
        if shape.name == "long_500k":
            ov.update({"batch": None, "kv_seq": "data", "capacity": None})
        return ov
    return {}


def build_rules(cfg: ModelConfig, shape: InputShape, *, multi_pod: bool,
                extra: dict | None = None) -> dict:
    rules = shard.make_rules(multi_pod=multi_pod,
                             overrides=config_rule_overrides(cfg))
    rules.update(shape_rule_overrides(shape))
    if extra:
        rules.update(extra)
    return rules


# ---------------------------------------------------------------------------
# Input / cache / optimizer specs
# ---------------------------------------------------------------------------

_INPUT_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "token": ("batch",),
    "patches": ("batch", None, None),
    "frames": ("batch", "frames", None),
}


def input_pspecs(specs: dict, rules: dict) -> dict:
    return {k: shard.resolve(_INPUT_AXES[k], rules) for k in specs}


_CACHE_AXES = {
    cachelib.KVCache: {
        "k": ("layers", "batch", "kv_seq", "kv_heads", None),
        "v": ("layers", "batch", "kv_seq", "kv_heads", None),
        "pos": (),
    },
    cachelib.WindowKVCache: {
        "k": ("layers", "batch", "kv_seq", "kv_heads", None),
        "v": ("layers", "batch", "kv_seq", "kv_heads", None),
        "pos": (),
    },
    cachelib.MLACache: {
        "c_kv": ("layers", "batch", "kv_seq", None),
        "k_rope": ("layers", "batch", "kv_seq", None),
        "pos": (),
    },
    cachelib.SSMCache: {
        "conv": ("layers", "batch", None, "mlp"),
        "state": ("layers", "batch", "ssm_heads", None, None),
        "pos": (),
    },
    cachelib.HybridCache: {
        "lru": ("layers", "batch", "lru"),
        "conv": ("layers", "batch", None, "lru"),
        "k": ("layers", "batch", "kv_seq", "kv_heads", None),
        "v": ("layers", "batch", "kv_seq", "kv_heads", None),
        "pos": (),
    },
    cachelib.EncDecCache: {
        "self_k": ("layers", "batch", "kv_seq", "kv_heads", None),
        "self_v": ("layers", "batch", "kv_seq", "kv_heads", None),
        "cross_k": ("layers", "batch", "frames", "kv_heads", None),
        "cross_v": ("layers", "batch", "frames", "kv_heads", None),
        "pos": (),
    },
}


def cache_pspecs(cache_struct, rules: dict):
    """Same-structure pytree of PartitionSpecs for a cache object
    (works on real caches or eval_shape structs)."""
    axes_map = _CACHE_AXES[type(cache_struct)]
    kw = {name: shard.resolve(axes, rules) for name, axes in axes_map.items()}
    return type(cache_struct)(**kw)


def opt_state_pspecs(opt_name: str, param_defs: dict, rules: dict, *,
                     param_spec_tree: dict | None = None, mesh=None) -> dict:
    """Optimizer-state PartitionSpecs mirroring the (possibly FSDP'd)
    parameter layout."""
    flat = _flatten_defs(param_defs)

    def leaf_entries(path: str, d: ParamDef) -> list:
        if param_spec_tree is not None:
            node = param_spec_tree
            for k in path.split("/"):
                node = node[k]
            spec = node
        else:
            spec = shard.resolve(d.axes, rules)
            if mesh is not None:
                spec = legalize_spec(d.shape, spec, mesh)
        return list(spec) + [None] * (len(d.shape) - len(spec))

    def trim(entries: list) -> P:
        entries = list(entries)
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    if opt_name in ("adamw", "sgd"):
        m: dict = {}
        for path, d in flat:
            _set_path(m, path, trim(leaf_entries(path, d)))
        import copy
        if opt_name == "sgd":
            return {"m": m}
        return {"m": m, "v": copy.deepcopy(m), "step": P()}
    if opt_name == "adafactor":
        f: dict = {}
        for path, d in flat:
            e = leaf_entries(path, d)
            if len(d.shape) >= 2:
                _set_path(f, path, {"vr": trim(e[:-1]),
                                    "vc": trim(e[:-2] + e[-1:])})
            else:
                _set_path(f, path, {"v": trim(e)})
        return {"f": f, "step": P()}
    raise KeyError(opt_name)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def legalize_spec(shape: tuple, spec: P, mesh) -> P:
    """Input-sharding legalization (see repro.shard.legalize_spec)."""
    return shard.legalize_spec(shape, spec, mesh_axis_sizes(mesh))


def fsdp_specs(param_defs: dict, rules: dict, mesh, *,
               fsdp_axes: tuple = ("data",)) -> dict:
    """ZeRO/FSDP parameter layout: after resolving the tensor-parallel spec,
    additionally shard each parameter over the data axis on its largest
    free dividing dim.  Weights are then all-gathered per layer inside the
    scan (the FSDP exchange), which is what lets 67B-671B training states
    fit 16 GB/chip."""
    sizes = mesh_axis_sizes(mesh)
    f = 1
    for a in fsdp_axes:
        f *= sizes[a]
    fsdp_entry = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]

    out: dict = {}
    for path, d in _flatten_defs(param_defs):
        spec = shard.legalize_spec(d.shape, shard.resolve(d.axes, rules), sizes)
        entries = list(spec) + [None] * (len(d.shape) - len(spec))
        used = set()
        for e in entries:
            if e is not None:
                used.update(e if isinstance(e, tuple) else (e,))
        if not any(a in used for a in fsdp_axes):
            cands = sorted(
                (j for j in range(len(entries))
                 if entries[j] is None and d.shape[j] % f == 0 and d.shape[j] >= f),
                key=lambda j: -d.shape[j])
            if cands:
                entries[cands[0]] = fsdp_entry
        while entries and entries[-1] is None:
            entries.pop()
        _set_path(out, path, P(*entries))
    return out


def named_legal(struct_tree, spec_tree, mesh):
    """(shapes, specs) -> legalized NamedSharding pytree (for out_shardings)."""
    return jax.tree.map(
        lambda st, sp: NamedSharding(mesh, legalize_spec(st.shape, sp, mesh)),
        struct_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, (P, jax.ShapeDtypeStruct)))


def to_named(tree, mesh):
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree, is_leaf=lambda x: isinstance(x, P))


def with_sharding(struct_tree, spec_tree, mesh):
    """Attach (legalized) NamedShardings to a ShapeDtypeStruct pytree."""
    return jax.tree.map(
        lambda st, sp: jax.ShapeDtypeStruct(
            st.shape, st.dtype,
            sharding=NamedSharding(mesh, legalize_spec(st.shape, sp, mesh))),
        struct_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, P) or isinstance(x, jax.ShapeDtypeStruct))
