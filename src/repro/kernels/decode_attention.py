"""Flash-decode GQA attention Pallas kernel — the serving hot spot.

One new query token per sequence attends over a long KV cache.  TPU-native
design (not a CUDA port): the cache is streamed HBM->VMEM in S-blocks while
the (tiny) query block and the online-softmax state live in VMEM scratch;
the MXU sees [G, D] x [D, BS] and [G, BS] x [BS, D] matmuls per block, with
G (query heads per KV head) padded to the 8-sublane tile and D a multiple
of 128 lanes.

Grid: (B, Hkv, S/BS).  The S dimension is innermost/sequential ("arbitrary"
semantics): scratch m/l/acc carries the running max / normalizer / value
accumulator across S-blocks; the output is written on the last block.

Masking: positions > pos contribute nothing (NEG_INF before softmax), so
one kernel serves both the growing-prefix case and full ring buffers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_s: int, scale: float):
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                     # [G, D]
    k = k_ref[0, :, 0, :]               # [BS, D]
    v = v_ref[0, :, 0, :]               # [BS, D]
    pos = pos_ref[0]

    s = jax.lax.dot_general(
        q.astype(jnp.float32), k.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale      # [G, BS]

    k_pos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_pos <= pos, s, NEG_INF)

    m_prev = m_ref[...]                 # [G, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)              # [G, BS]

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # [G, D]
    m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


def flash_decode_gqa(q: jax.Array, k: jax.Array, v: jax.Array, pos,
                     *, block_s: int = 512, interpret: bool = False) -> jax.Array:
    """q [B,Hq,D]; k,v [B,S,Hkv,D]; pos scalar int32 (mask: index <= pos).
    Returns [B,Hq,D] in q.dtype."""
    B, Hq, D = q.shape
    _, S, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    scale = 1.0 / (D ** 0.5)

    # pad G to the 8-sublane tile so [G, D] blocks are MXU/VPU friendly
    Gp = max(8, ((G + 7) // 8) * 8)
    qg = q.reshape(B, Hkv, G, D)
    if Gp != G:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, Gp - G), (0, 0)))
    block_s = min(block_s, S)
    assert S % block_s == 0, (S, block_s)
    n_s = S // block_s

    grid = (B, Hkv, n_s)
    out = pl.pallas_call(
        functools.partial(_kernel, block_s=block_s, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                    # pos
            pl.BlockSpec((1, 1, Gp, D), lambda b, h, s: (b, h, 0, 0)),  # q
            pl.BlockSpec((1, block_s, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, block_s, 1, D), lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Gp, D), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, Gp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Gp, 1), jnp.float32),    # running max
            pltpu.VMEM((Gp, 1), jnp.float32),    # running normalizer
            pltpu.VMEM((Gp, D), jnp.float32),    # value accumulator
        ],
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32).reshape(1), qg, k, v)
    return out[:, :, :G, :].reshape(B, Hq, D)
