"""Jitted public wrappers for the Pallas kernels.

On TPU these lower to the real kernels; on CPU (this container) callers
pass interpret=True (tests) or use the pure-jnp paths in repro.models.
`use_kernels(cfg)` is the engine-level switch.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.decode_attention import flash_decode_gqa
from repro.kernels.rglru_scan import rglru_scan_pallas
from repro.kernels.ssd_scan import ssd_scan


@partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q, k, v, pos, *, block_s: int = 512,
                     interpret: bool = False):
    """Flash-decode GQA: q [B,Hq,D]; k,v [B,S,Hkv,D]; pos scalar."""
    return flash_decode_gqa(q, k, v, pos, block_s=block_s, interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(xdt, dA, B, C, *, chunk: int = 128, interpret: bool = False):
    """Mamba-2 SSD chunk scan.  Returns (y, final_state)."""
    return ssd_scan(xdt, dA, B, C, chunk=chunk, interpret=interpret)


@partial(jax.jit, static_argnames=("block_s", "block_w", "interpret"))
def rglru(a, b, *, block_s: int = 256, block_w: int = 512,
          interpret: bool = False):
    """RG-LRU recurrence h_t = a_t h_{t-1} + b_t."""
    return rglru_scan_pallas(a, b, block_s=block_s, block_w=block_w,
                             interpret=interpret)
