"""Mamba-2 SSD chunk-scan Pallas kernel (state-space duality, TPU-native).

Per (batch, head) the sequence is processed in chunks of C steps.  The
chunk-local quadratic term runs on the MXU ([C,N]x[N,C] scores masked by
the decay triangle, then [C,C]x[C,P]), while the O(PN) recurrent state is
carried across chunks in VMEM scratch — HBM sees each input exactly once.
This is the SSD insight mapped to the TPU memory hierarchy: quadratic
*within* a VMEM-resident tile, linear *across* tiles.

Grid: (B, H, n_chunks), chunk dim innermost/sequential.

y[t] = C_t . S_t,  S_t = exp(dA_t) S_{t-1} + B_t (x) xdt_t
     = intra-chunk causal term + C_t . (decay-to-t) S_{chunk_start}
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(xdt_ref, dA_ref, b_ref, c_ref, y_ref, fin_ref, state_ref, *,
            chunk: int):
    c_idx = pl.program_id(2)
    n_c = pl.num_programs(2)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = xdt_ref[0, 0, 0].astype(jnp.float32)        # [C, P]
    dA = dA_ref[0, 0, 0, :, 0].astype(jnp.float32)  # [C]
    Bm = b_ref[0, 0, 0].astype(jnp.float32)         # [C, N]
    Cm = c_ref[0, 0, 0].astype(jnp.float32)         # [C, N]

    cs = jnp.cumsum(dA)                        # [C] inclusive cumulative dA
    # pairwise decay L[i, j] = exp(cs_i - cs_j) for i >= j else 0
    seg = cs[:, None] - cs[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(row >= col, jnp.exp(seg), 0.0)

    # intra-chunk: y_diag = (L * (C B^T)) x
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [C, C]
    y = jax.lax.dot_general(L * scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # [C, P]

    # inter-chunk: contribution of the carried state
    decay_in = jnp.exp(cs)[:, None]            # decay from chunk start to t
    y += decay_in * jax.lax.dot_general(
        Cm, state_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)    # [C, N] x [N <- state [P,N]]^T -> [C, P]

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    # state update: S_new = exp(sum dA) S + sum_t exp(cs_last - cs_t) x_t (x) B_t
    total = cs[chunk - 1]
    w = jnp.exp(total - cs)[:, None]           # [C, 1]
    state_ref[...] = (jnp.exp(total) * state_ref[...]
                      + jax.lax.dot_general(x * w, Bm, (((0,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32))

    @pl.when(c_idx == n_c - 1)
    def _finish():
        fin_ref[0, 0] = state_ref[...]   # fin block is [1, 1, P, N]


def ssd_scan(xdt: jax.Array, dA: jax.Array, B: jax.Array, C: jax.Array, *,
             chunk: int = 128, interpret: bool = False):
    """xdt [b,s,h,p]; dA [b,s,h]; B, C [b,s,h,n].
    Returns (y [b,s,h,p] in xdt.dtype, final_state [b,h,p,n] f32)."""
    b, s, h, p = xdt.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    # lay out as [b, h, nc, C, *] so blocks are contiguous per grid cell
    xr = xdt.transpose(0, 2, 1, 3).reshape(b, h, nc, chunk, p)
    dAr = dA.transpose(0, 2, 1).reshape(b, h, nc, chunk, 1)
    Br = B.transpose(0, 2, 1, 3).reshape(b, h, nc, chunk, n)
    Cr = C.transpose(0, 2, 1, 3).reshape(b, h, nc, chunk, n)

    grid = (b, h, nc)
    y, fin = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p), lambda i, j, c: (i, j, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, 1), lambda i, j, c: (i, j, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, n), lambda i, j, c: (i, j, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, n), lambda i, j, c: (i, j, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p), lambda i, j, c: (i, j, c, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda i, j, c: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, chunk, p), xdt.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xr, dAr, Br, Cr)
    y = y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    return y, fin
