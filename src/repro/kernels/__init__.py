"""Pallas TPU kernels for the perf-critical compute layers, each validated
in interpret mode against the pure-jnp oracles in repro.kernels.ref:

  * decode_attention — flash-decode GQA (the serving hot spot the paper
    measures; online softmax over streamed KV blocks)
  * ssd_scan         — Mamba-2 SSD chunk scan (quadratic-in-VMEM,
    linear-across-chunks)
  * rglru_scan       — RG-LRU linear recurrence (doubling scan per block)
"""

from repro.kernels import ops  # noqa: F401
