"""Pallas TPU kernels for the perf-critical compute layers, each validated
in interpret mode against the pure-jnp oracles in repro.kernels.ref:

  * decode_attention — flash-decode GQA (the serving hot spot the paper
    measures; online softmax over streamed KV blocks)
  * ssd_scan         — Mamba-2 SSD chunk scan (quadratic-in-VMEM,
    linear-across-chunks)
  * rglru_scan       — RG-LRU linear recurrence (doubling scan per block)
  * cost_batch       — jit (x64) + Pallas batch cost kernels: the analytic
                       energy surface (prefill roofline + exact closed-form
                       decode integral) over million-query arrays in one
                       on-device call, ≤1e-9 vs the numpy closed form
"""

from repro.kernels import cost_batch, ops  # noqa: F401
