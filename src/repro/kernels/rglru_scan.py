"""RG-LRU linear-recurrence Pallas kernel (RecurrentGemma's temporal core).

h_t = a_t * h_{t-1} + b_t over the sequence, per channel.  The TPU-native
shape: channels are tiled over the lane dimension (grid axis w, parallel);
the sequence is processed in blocks (grid axis s, sequential) with the
carried state h in VMEM scratch; within a block a log2(C)-step Blelloch-
style doubling scan turns the elementwise recurrence into VPU-friendly
whole-block operations instead of a C-step scalar loop.

a/b are precomputed by the surrounding jnp code (they involve matmuls that
belong on the MXU outside this kernel); the kernel is the memory-bound
recurrence itself, reading each input exactly once from HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h_ref, state_ref, *, block_s: int):
    s_idx = pl.program_id(2)   # sequence blocks: innermost, sequential

    @pl.when(s_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    a = a_ref[0].astype(jnp.float32)     # [C, W]
    b = b_ref[0].astype(jnp.float32)     # [C, W]

    # inclusive doubling scan of the affine composition (a, b):
    # (a2, b2) o (a1, b1) = (a1*a2, a2*b1 + b2) applied along C
    n = 1
    while n < block_s:
        a_shift = jnp.concatenate([jnp.ones((n, a.shape[1]), jnp.float32),
                                   a[:-n]], axis=0)
        b_shift = jnp.concatenate([jnp.zeros((n, b.shape[1]), jnp.float32),
                                   b[:-n]], axis=0)
        b = a * b_shift + b
        a = a * a_shift
        n *= 2

    # fold in the carried state: h_t = a_{1..t} * h0 + b_{1..t}
    h = a * state_ref[...][None].reshape(1, -1) + b
    h_ref[0] = h.astype(h_ref.dtype)
    state_ref[...] = h[-1]


def rglru_scan_pallas(a: jax.Array, b: jax.Array, *, block_s: int = 256,
                      block_w: int = 512, interpret: bool = False) -> jax.Array:
    """a, b [B, S, W] f32 -> h [B, S, W] f32 with h_t = a_t h_{t-1} + b_t."""
    B, S, W = a.shape
    block_s = min(block_s, S)
    block_w = min(block_w, W)
    assert S % block_s == 0 and W % block_w == 0, (S, W, block_s, block_w)
    # w (channel blocks) is the parallel middle axis; s must be innermost so
    # the VMEM state scratch carries across sequence blocks per (batch, w).
    grid = (B, W // block_w, S // block_s)

    return pl.pallas_call(
        functools.partial(_kernel, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_w), lambda i, w, s: (i, s, w)),
            pl.BlockSpec((1, block_s, block_w), lambda i, w, s: (i, s, w)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_w), lambda i, w, s: (i, s, w)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
    )(a, b)
