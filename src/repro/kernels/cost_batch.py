"""jit/Pallas batch cost kernels — the analytic energy surface on device.

``simulate_batch(sim, tau_in, tau_out)`` evaluates what
``AnalyticLLMSimulator.simulate`` computes — one prefill roofline pass
plus the EXACT closed-form decode integral (piecewise-quadratic power
sums per roofline branch, ``repro.energy.simulator``'s algorithm) — over
whole arrays of (τin, τout) in a single ``jax.jit`` call, and
``cost_matrices(sims, ...)`` stacks k per-node evaluations into the m×k
energy/runtime matrices the scheduler consumes.  Million-query × k-node
cost surfaces are therefore produced on-device with no Python loop over
queries; agreement with the numpy closed form is gated at ≤1e-9 relative
(tests/test_cost_kernels.py and the perf-suite ``jit_cost_kernel`` gate).

All array math runs under ``jax.experimental.enable_x64`` — the decode
power sums reach count³ ≈ 1e18 at τout ~ 10⁶, far beyond float32 — scoped
to these calls so the rest of the repo keeps jax's default f32 semantics.

``pass_costs_pallas`` is the Pallas variant of the elementwise pass-cost
surface, tiled (8, 128) over the query axis.  It pays on TPU, where the
fused elementwise pipeline stays in VMEM and f32 is native; on CPU it
runs in interpret mode for validation only — use the jit path there.

Static model/hardware structure (family branches, window clamps, MoE
breakpoints, roofline capacities) is resolved at trace time from the
hashable ``ModelConfig``/``Node`` dataclasses; compiled callables are
cached per (cfg, node, kv_cache) so repeated sweeps pay tracing once.
"""

from __future__ import annotations

from typing import Callable, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.energy import costs as costs_lib
from repro.energy.hardware import Node
from repro.models import active_params, get_api
from repro.models.common import ModelConfig


# ---------------------------------------------------------------------------
# Elementwise pass-cost surface (jnp mirror of costs.pass_costs_batch)
# ---------------------------------------------------------------------------


def pass_surface(cfg: ModelConfig, new_tokens, context, batch, *,
                 include_weights: bool = True, decode: bool = False):
    """(flops, hbm_bytes) of a forward pass, as jnp expressions over
    broadcastable arrays.  Family/window/MoE structure is static (resolved
    from cfg at trace time); the formulas mirror
    ``repro.energy.costs.pass_costs_batch`` term for term."""
    nt, ctx, bt = jnp.broadcast_arrays(new_tokens, context, batch)
    b = 2 if cfg.param_dtype == "bfloat16" else 4
    n_active = float(active_params(cfg))   # python floats: exact weak-typed
    tokens = bt * nt                       # constants in f32 and f64 alike

    flops = 2.0 * n_active * tokens
    # attention
    if cfg.family == "ssm":
        H, P, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
        flops = flops + cfg.n_layers * bt * nt * (2 * H * P * N * 4)
    else:
        heads = cfg.n_heads
        hd = cfg.head_dim_
        if cfg.use_mla:
            hd = cfg.qk_nope_dim + cfg.qk_rope_dim
        if cfg.family == "hybrid":
            n_attn = cfg.n_layers // max(1, len(cfg.block_pattern))
            c = jnp.minimum(ctx, cfg.local_window) if cfg.local_window else ctx
            flops = flops + n_attn * bt * 4 * heads * hd * nt * c
        else:
            n_layers = cfg.dec_layers if cfg.family == "encdec" else cfg.n_layers
            c = jnp.minimum(ctx, cfg.window) if cfg.window else ctx
            flops = flops + n_layers * bt * 4 * heads * hd * nt * c
            if cfg.family == "encdec":
                flops = flops + (cfg.dec_layers * bt * 4 * heads * hd
                                 * nt * cfg.n_frames)
    # MoE router overhead
    if cfg.family == "moe":
        nm = cfg.n_layers - cfg.n_dense_layers
        flops = flops + nm * bt * nt * (2 * cfg.d_model * cfg.n_experts
                                        + 32 * cfg.n_experts)

    bytes_ = jnp.zeros_like(tokens)
    if include_weights:
        api = get_api(cfg)
        if cfg.family != "moe":
            bytes_ = bytes_ + float(api.count_params(cfg) * b)
        else:
            total = api.count_params(cfg)
            de = cfg.d_expert or cfg.d_ff
            nm = cfg.n_layers - cfg.n_dense_layers
            per_expert = 3 * cfg.d_model * de
            routed = nm * cfg.n_experts * per_expert
            hit = jnp.minimum(float(cfg.n_experts), tokens * cfg.top_k)
            bytes_ = bytes_ + (float(total - routed)
                               + hit * float(nm * per_expert)) * b
    bytes_ = bytes_ + tokens * float(cfg.n_layers * cfg.d_model * 12 * b)
    kvb = costs_lib.kv_bytes_per_token(cfg)
    bytes_ = bytes_ + tokens * kvb
    if decode:
        if cfg.family == "hybrid":
            c = jnp.minimum(ctx, cfg.local_window) if cfg.local_window else ctx
        elif cfg.window:
            c = jnp.minimum(ctx, cfg.window)
        else:
            c = ctx
        extra = bt * c * kvb
        if cfg.family == "ssm":
            ssm_state_bytes = (cfg.n_layers * cfg.ssm_nheads * cfg.ssm_headdim
                               * cfg.ssm_state * 4)
            extra = extra + bt * float(2 * ssm_state_bytes)
        bytes_ = bytes_ + extra
    return flops, bytes_


# ---------------------------------------------------------------------------
# Closed-form decode integral (jnp mirror of _decode_closed_form)
# ---------------------------------------------------------------------------


def _interp_quadratic(y0, y1, y2, h):
    c0 = y0
    c1 = (-3.0 * y0 + 4.0 * y1 - y2) / (2.0 * h)
    c2 = (y0 - 2.0 * y1 + y2) / (2.0 * h * h)
    return c0, c1, c2


def _poly_sum(c, u0, count):
    """Σ_{j=0}^{count-1} p(u0+j), exact power-sum form (needs float64)."""
    c0, c1, c2 = c
    s1 = count * (count - 1.0) / 2.0
    s2 = (count - 1.0) * count * (2.0 * count - 1.0) / 6.0
    return (c0 * count
            + c1 * (count * u0 + s1)
            + c2 * (count * u0 * u0 + 2.0 * u0 * s1 + s2))


def _quad_roots_sorted(qc, u0, uhi):
    """Roots of c2 u² + c1 u + c0 strictly inside (u0, uhi), as two values
    (invalid → +inf, which the edge clamp maps to an empty split) —
    branchless mirror of simulator._quad_roots_in."""
    c0, c1, c2 = qc
    lin = c2 == 0.0
    c1_safe = jnp.where(c1 != 0.0, c1, 1.0)
    r_lin = jnp.where(c1 != 0.0, -c0 / c1_safe, jnp.inf)
    disc = c1 * c1 - 4.0 * c2 * c0
    sq = jnp.sqrt(jnp.maximum(disc, 0.0))
    q = jnp.where(c1 != 0.0, -0.5 * (c1 + jnp.sign(c1_safe) * sq), 0.5 * sq)
    c2_safe = jnp.where(lin, 1.0, c2)
    ra = q / c2_safe
    rb = jnp.where(q != 0.0, c0 / jnp.where(q != 0.0, q, 1.0), ra)
    r_dbl = -c1 / (2.0 * c2_safe)
    q1 = jnp.where(disc > 0.0, ra, jnp.where(disc == 0.0, r_dbl, jnp.inf))
    q2 = jnp.where(disc > 0.0, rb, jnp.inf)
    r1 = jnp.where(lin, r_lin, q1)
    r2 = jnp.where(lin, jnp.inf, q2)
    valid1 = (r1 > u0) & (r1 < uhi)
    valid2 = (r2 > u0) & (r2 < uhi)
    r1 = jnp.where(valid1, r1, jnp.inf)
    r2 = jnp.where(valid2, r2, jnp.inf)
    return jnp.minimum(r1, r2), jnp.maximum(r1, r2)


def _decode_phase(cfg: ModelConfig, node: Node, ctx0, n, batch, *,
                  kv_cache: bool):
    """(seconds, accelerator joules) of the decode phase, vectorized —
    the exact piecewise-quadratic power-sum integral of
    ``AnalyticLLMSimulator._decode_closed_form`` in jnp."""
    a = node.accel
    fcap = node.n_accel * a.peak_flops * a.flops_efficiency
    bcap = node.n_accel * a.hbm_bw * a.bw_efficiency
    reprefix = not kv_cache

    n_eff = jnp.maximum(n, 1.0)
    base = ctx0 + 0.5                  # grid: L_t = base + t
    lo = base
    hi = base + (n_eff - 1.0)

    def step_costs(L):
        if reprefix:   # paper mode: re-run the full L-token prefix per step
            return pass_surface(cfg, L, L, batch, decode=False)
        return pass_surface(cfg, jnp.ones_like(L), L, batch, decode=True)

    # static breakpoint structure (≤ 2: attention-window clamp, MoE
    # expert-saturation in re-prefix mode); values may be traced via batch
    bps = []
    w = costs_lib.attention_window(cfg)
    if np.isfinite(w):
        bps.append(w * jnp.ones_like(base))
    if reprefix and cfg.family == "moe" and cfg.top_k:
        bps.append(cfg.n_experts / (batch * cfg.top_k) * jnp.ones_like(base))
    if len(bps) == 2:
        bps = [jnp.minimum(bps[0], bps[1]), jnp.maximum(bps[0], bps[1])]

    # segment coordinates and the step-index boundaries (grid points with
    # L ≤ seg.hi belong to the segment, exactly as the numpy loop assigns)
    edges_s = [lo] + [jnp.clip(b, lo, hi) for b in bps] + [hi]
    t_bounds = [jnp.zeros_like(base)]
    run = jnp.zeros_like(base)
    for b in bps:
        raw = jnp.clip(jnp.floor(b - base) + 1.0, 0.0, n_eff)
        te = jnp.where(b <= lo, 0.0, jnp.where(b >= hi, n_eff, raw))
        run = jnp.maximum(run, te)
        t_bounds.append(run)
    t_bounds.append(n_eff)

    t_sum = jnp.zeros_like(base)
    flops_sum = jnp.zeros_like(base)
    bytes_sum = jnp.zeros_like(base)
    for s in range(len(edges_s) - 1):
        s0, s1 = edges_s[s], edges_s[s + 1]
        t0, t1 = t_bounds[s], t_bounds[s + 1]
        count = jnp.maximum(t1 - t0, 0.0)
        live = count > 0.0
        h = (s1 - s0) / 2.0
        hs = jnp.where(h > 0.0, h, 1.0)   # degenerate segments have count 0
        y0f, y0b = step_costs(s0)
        y1f, y1b = step_costs(s0 + hs)
        y2f, y2b = step_costs(s0 + 2.0 * hs)
        cf = _interp_quadratic(y0f, y1f, y2f, hs)
        cb = _interp_quadratic(y0b, y1b, y2b, hs)
        u0 = (base + t0) - s0
        flops_sum = flops_sum + jnp.where(live, _poly_sum(cf, u0, count), 0.0)
        bytes_sum = bytes_sum + jnp.where(live, _poly_sum(cb, u0, count), 0.0)

        # roofline branch: q(u) = flops(u)/fcap − bytes(u)/bcap; split the
        # step range at the quadratic's roots, then pick the branch per
        # sub-range from the same three probes the numpy path uses
        qc = tuple(f / fcap - bb / bcap for f, bb in zip(cf, cb))
        uhi = u0 + (count - 1.0)
        r1, r2 = _quad_roots_sorted(qc, u0, uhi)
        e1 = jnp.where(jnp.isfinite(r1),
                       jnp.clip(jnp.ceil(r1 - u0), 0.0, count), 0.0)
        e2 = jnp.where(jnp.isfinite(r2),
                       jnp.clip(jnp.ceil(r2 - u0), 0.0, count), 0.0)
        elo = jnp.minimum(e1, e2)
        ehi = jnp.maximum(e1, e2)

        def q_at(j):
            u = u0 + j
            return qc[0] + qc[1] * u + qc[2] * u * u

        for j0, j1 in ((jnp.zeros_like(count), elo), (elo, ehi), (ehi, count)):
            cnt = jnp.maximum(j1 - j0, 0.0)
            sub = live & (cnt > 0.0)
            probes = (q_at(j0), q_at(jnp.floor((j0 + j1 - 1.0) / 2.0)),
                      q_at(j1 - 1.0))
            use_f = ((probes[0] >= 0.0) & (probes[1] >= 0.0)
                     & (probes[2] >= 0.0))
            use_b = ((probes[0] <= 0.0) & (probes[1] <= 0.0)
                     & (probes[2] <= 0.0))
            tf = _poly_sum(cf, u0 + j0, cnt) / fcap
            tb = _poly_sum(cb, u0 + j0, cnt) / bcap
            # mixed probes cannot occur for a true root-split quadratic;
            # max() is the conservative fp-edge-case fallback
            val = jnp.where(use_f, tf,
                            jnp.where(use_b, tb, jnp.maximum(tf, tb)))
            t_sum = t_sum + jnp.where(sub, val, 0.0)

    t_dec = t_sum + n_eff * node.dispatch_overhead_s
    e_dec = (a.idle_w * node.n_accel * t_dec
             + a.j_per_flop * flops_sum
             + a.j_per_byte_hbm * bytes_sum)
    empty = n <= 0.0
    return (jnp.where(empty, 0.0, t_dec), jnp.where(empty, 0.0, e_dec))


# ---------------------------------------------------------------------------
# Compiled per-(model, node, mode) simulate kernels
# ---------------------------------------------------------------------------

_SIM_CACHE: dict[tuple, Callable] = {}


def _compiled_simulate(cfg: ModelConfig, node: Node, kv_cache: bool,
                       host_power_w: float) -> Callable:
    key = (cfg, node, kv_cache, host_power_w)
    fn = _SIM_CACHE.get(key)
    if fn is not None:
        return fn
    a = node.accel
    fcap = node.n_accel * a.peak_flops * a.flops_efficiency
    bcap = node.n_accel * a.hbm_bw * a.bw_efficiency

    @jax.jit
    def run(tin, tout, batch):
        pf, pb = pass_surface(cfg, tin, tin, batch, decode=False)
        t_pre = (jnp.maximum(pf / fcap, pb / bcap)
                 + node.dispatch_overhead_s)
        e_pre = (a.idle_w * node.n_accel * t_pre
                 + a.j_per_flop * pf + a.j_per_byte_hbm * pb)
        t_dec, e_dec = _decode_phase(cfg, node, tin, tout, batch,
                                     kv_cache=kv_cache)
        runtime = t_pre + t_dec
        energy = e_pre + e_dec + host_power_w * runtime
        return energy, runtime

    _SIM_CACHE[key] = run
    return run


def simulate_batch(sim, tau_in, tau_out, *, batch=None
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Noise-free (energy_j, runtime_s) per query for an
    ``AnalyticLLMSimulator``, computed on-device in one jit call — the
    batched equivalent of ``[sim.simulate(a, b) for a, b in zip(...)]``.
    ≤1e-9 relative against the numpy closed form (gated)."""
    B = float(sim.batch if batch is None else batch)
    with enable_x64():
        fn = _compiled_simulate(sim.cfg, sim.node, sim.kv_cache,
                                sim.host_power_w)
        tin = jnp.asarray(np.asarray(tau_in, dtype=np.float64))
        tout = jnp.asarray(np.asarray(tau_out, dtype=np.float64))
        e, r = fn(tin, tout, jnp.asarray(B, dtype=jnp.float64))
        return np.asarray(e), np.asarray(r)


def cost_matrices(sims: Sequence, tau_in, tau_out, *, per_query: bool = False
                  ) -> tuple[np.ndarray, np.ndarray]:
    """m×k energy/runtime matrices over k simulators (one per fleet node),
    each column one on-device jit call.  ``per_query=True`` divides by each
    simulator's batch (the scheduler's batch-normalized convention)."""
    cols_e, cols_r = [], []
    for sim in sims:
        e, r = simulate_batch(sim, tau_in, tau_out)
        if per_query:
            e, r = e / sim.batch, r / sim.batch
        cols_e.append(e)
        cols_r.append(r)
    return np.stack(cols_e, axis=1), np.stack(cols_r, axis=1)


# ---------------------------------------------------------------------------
# Pallas variant of the elementwise pass-cost surface
# ---------------------------------------------------------------------------

_LANES = 128
_SUBLANES = 8
_BLOCK = _LANES * _SUBLANES


def pass_costs_pallas(cfg: ModelConfig, new_tokens, context, batch, *,
                      include_weights: bool = True, decode: bool = False,
                      interpret: bool | None = None
                      ) -> tuple[np.ndarray, np.ndarray]:
    """(flops, hbm_bytes) arrays via a Pallas elementwise kernel, tiled
    (8, 128) over the query axis.  Worth it on TPU (fused pipeline in
    VMEM, f32 native); on CPU this runs in interpret mode for validation
    only — the jit path (`pass_surface` under x64) is the production one.
    f32 accumulation: validate at ~1e-6 relative, not the 1e-9 x64 gate."""
    from jax.experimental import pallas as pl

    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"

    nt = np.asarray(new_tokens, dtype=np.float32).ravel()
    ctx = np.asarray(context, dtype=np.float32).ravel()
    bt = np.broadcast_to(np.asarray(batch, dtype=np.float32), nt.shape).copy()
    m = nt.shape[0]
    pad = (-m) % _BLOCK
    if pad:
        nt = np.concatenate([nt, np.ones(pad, np.float32)])
        ctx = np.concatenate([ctx, np.ones(pad, np.float32)])
        bt = np.concatenate([bt, np.ones(pad, np.float32)])
    rows = nt.shape[0] // _LANES
    shape2d = (rows, _LANES)

    def kernel(nt_ref, ctx_ref, bt_ref, f_ref, b_ref):
        f, b = pass_surface(cfg, nt_ref[...], ctx_ref[...], bt_ref[...],
                            include_weights=include_weights, decode=decode)
        f_ref[...] = f
        b_ref[...] = b

    spec = pl.BlockSpec((_SUBLANES, _LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        kernel,
        grid=(rows // _SUBLANES,),
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct(shape2d, jnp.float32)] * 2,
        interpret=interpret,
    )(nt.reshape(shape2d), ctx.reshape(shape2d), bt.reshape(shape2d))
    flops = np.asarray(out[0]).ravel()[:m]
    bytes_ = np.asarray(out[1]).ravel()[:m]
    return flops, bytes_
