"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Kept dependency-free of the model modules so kernel tests stand alone.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         pos) -> jax.Array:
    """Flash-decode oracle.  q [B,Hq,D]; k,v [B,S,Hkv,D]; entries with
    index > pos masked.  Returns [B,Hq,D] in q.dtype."""
    B, Hq, D = q.shape
    _, S, Hkv, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k,
                   preferred_element_type=jnp.float32) / (D ** 0.5)
    valid = jnp.arange(S) <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", w, v.astype(jnp.float32))
    return o.reshape(B, Hq, D).astype(q.dtype)


def ssd_scan_ref(xdt: jax.Array, dA: jax.Array, B: jax.Array, C: jax.Array,
                 h0: jax.Array | None = None):
    """Sequential SSD oracle.

    xdt [b,s,h,p] (x*dt), dA [b,s,h] (dt*A, negative), B,C [b,s,h,n].
    Returns (y [b,s,h,p] f32, final_state [b,h,p,n] f32).
    State recurrence: S_t = exp(dA_t)*S_{t-1} + B_t (x) xdt_t; y_t = C_t . S_t.
    """
    b, s, h, p = xdt.shape
    n = B.shape[-1]
    state0 = jnp.zeros((b, h, p, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(state, inp):
        x_t, dA_t, B_t, C_t = inp
        decay = jnp.exp(dA_t.astype(jnp.float32))[:, :, None, None]
        upd = jnp.einsum("bhp,bhn->bhpn", x_t.astype(jnp.float32),
                         B_t.astype(jnp.float32))
        state = state * decay + upd
        y = jnp.einsum("bhpn,bhn->bhp", state, C_t.astype(jnp.float32))
        return state, y

    xs = (xdt.transpose(1, 0, 2, 3), dA.transpose(1, 0, 2),
          B.transpose(1, 0, 2, 3), C.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3), final


def rglru_scan_ref(a: jax.Array, b: jax.Array,
                   h0: jax.Array | None = None) -> jax.Array:
    """Linear-recurrence oracle: h_t = a_t*h_{t-1} + b_t, h_0 given.
    a, b [B,S,W] f32.  Returns h [B,S,W] f32."""
    B, S, W = a.shape
    state0 = jnp.zeros((B, W), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        a_t, b_t = inp
        h = a_t * h + b_t
        return h, h

    _, hs = jax.lax.scan(step, state0,
                         (a.transpose(1, 0, 2), b.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)
