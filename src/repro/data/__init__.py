"""Data pipeline: synthetic workloads and token streams."""

from repro.data.workloads import (  # noqa: F401
    WorkloadSpec,
    alpaca_like_workload,
    arrival_times,
    grid_workload,
    timestamped_workload,
    token_batches,
)
