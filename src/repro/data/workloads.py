"""Workload generation.

The paper's case study uses 500 queries from the Alpaca dataset (52,002
instruction/GPT-4-answer pairs).  Alpaca is not shippable in this offline
container, so `alpaca_like_workload` draws (τin, τout) from log-normal
distributions fit to Alpaca's published token-length statistics
(instruction+input: median ≈ 21 tokens, long tail to ~500; output:
median ≈ 65, long tail to ~1000), truncated to the paper's measured range.

`token_batches` turns a workload into padded token/label arrays for the
training and serving paths (synthetic ids — the substrate is length-
driven, exactly like the paper's standardized prompts).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np

Query = tuple[int, int]


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    n_queries: int = 500
    in_log_mean: float = 3.4      # exp(3.4) ~ 30 tokens
    in_log_sigma: float = 0.9
    out_log_mean: float = 4.2     # exp(4.2) ~ 67 tokens
    out_log_sigma: float = 0.9
    min_tokens: int = 8
    max_in: int = 2048
    max_out: int = 4096
    seed: int = 0


def alpaca_like_workload(spec: WorkloadSpec = WorkloadSpec()) -> list[Query]:
    rng = np.random.default_rng(spec.seed)
    tin = np.exp(rng.normal(spec.in_log_mean, spec.in_log_sigma, spec.n_queries))
    tout = np.exp(rng.normal(spec.out_log_mean, spec.out_log_sigma, spec.n_queries))
    tin = np.clip(tin, spec.min_tokens, spec.max_in).astype(int)
    tout = np.clip(tout, spec.min_tokens, spec.max_out).astype(int)
    return [(int(a), int(b)) for a, b in zip(tin, tout)]


def arrival_times(
    n: int,
    rate_qps: float,
    *,
    pattern: str = "poisson",
    burstiness: float = 4.0,
    diurnal_amplitude: float = 0.8,
    diurnal_period_s: float = 600.0,
    onoff_on_s: float = 30.0,
    onoff_off_s: float = 120.0,
    seed: int = 0,
) -> np.ndarray:
    """Timestamps (seconds, ascending, starting near 0) for n requests.

    pattern="poisson"  — exponential interarrivals at rate_qps.
    pattern="bursty"   — Gamma interarrivals with squared CV = burstiness
                         (shape 1/burstiness), same mean rate; models the
                         clustered arrivals of real serving traffic.
    pattern="diurnal"  — nonhomogeneous Poisson via thinning with
                         rate(t) = rate_qps·(1 + A·sin(2πt/period)); the
                         mean rate over a full period is rate_qps.
    pattern="onoff"    — square-wave traffic: Poisson bursts during
                         onoff_on_s-second windows separated by
                         onoff_off_s seconds of silence (mean rate over a
                         full period is rate_qps).  The adversarial input
                         for node power-gating: long idle gaps that invite
                         gating, followed by fronts that force wakes.
    """
    if rate_qps <= 0:
        raise ValueError(f"rate_qps must be > 0, got {rate_qps}")
    rng = np.random.default_rng(seed)
    if pattern == "poisson":
        gaps = rng.exponential(1.0 / rate_qps, n)
        return np.cumsum(gaps)
    if pattern == "bursty":
        shape = 1.0 / burstiness
        gaps = rng.gamma(shape, burstiness / rate_qps, n)
        return np.cumsum(gaps)
    if pattern == "diurnal":
        a = min(max(diurnal_amplitude, 0.0), 1.0)
        peak = rate_qps * (1.0 + a)
        out = np.empty(n, dtype=np.float64)
        t, i = 0.0, 0
        while i < n:
            t += rng.exponential(1.0 / peak)
            lam = rate_qps * (1.0 + a * np.sin(2.0 * np.pi * t / diurnal_period_s))
            if rng.random() * peak < lam:
                out[i] = t
                i += 1
        return out
    if pattern == "onoff":
        on = float(onoff_on_s)
        off = float(onoff_off_s)
        if on <= 0 or off < 0:
            raise ValueError("need onoff_on_s > 0 and onoff_off_s >= 0")
        # draw a homogeneous Poisson stream in on-window time, then map
        # on-time to wall time by inserting the off windows
        lam = rate_qps * (on + off) / on
        tau = np.cumsum(rng.exponential(1.0 / lam, n))
        return tau + np.floor(tau / on) * off
    raise ValueError(f"unknown arrival pattern: {pattern!r}")


def fault_trace(
    n_nodes: int,
    horizon_s: float,
    *,
    mttf_s: float | None = None,
    mttr_s: float = 60.0,
    straggle_mttf_s: float | None = None,
    straggle_mttr_s: float = 30.0,
    slowdown_range: tuple[float, float] = (1.5, 3.0),
    seed: int = 0,
    domains: Sequence[Sequence[int]] | None = None,
) -> list[tuple[float, int, str, float]]:
    """Seeded fault-event stream for a fleet of `n_nodes` nodes: the
    failure-side counterpart of `arrival_times`.

    Two independent alternating-renewal processes, both with exponential
    holding times (the classic MTTF/MTTR availability model):

      * crash/recovery — up for Exp(mttf_s), down for Exp(mttr_s):
        emits ("crash", 1.0) then ("recover", 1.0) pairs;
      * straggle/normal — healthy for Exp(straggle_mttf_s), degraded for
        Exp(straggle_mttr_s) at a slowdown factor drawn uniformly from
        `slowdown_range`: emits ("slow", σ) then ("normal", 1.0) pairs.

    `domains` switches crash/recovery to *correlated* mode: it must be a
    partition of range(n_nodes) (each index in exactly one group); each
    group runs ONE crash/recover renewal whose events are emitted
    simultaneously for every member — the blast-radius model for racks
    and PDU legs.  Straggling stays per-node (a slow NIC is not a rack
    event).  `domains=None` and the one-node-per-domain partition
    [(0,), (1,), ...] draw the identical RNG stream and return the
    identical event list — independent faults are the degenerate
    topology, pinned in tests.

    Passing None for a process's MTTF disables it.  Events are returned
    as (time_s, node_index, kind, value) tuples sorted by time (ties
    break by node index then emission order), truncated to `horizon_s`.
    The same seed always replays the identical stream — fault traces are
    first-class replayable inputs, like arrival traces.
    """
    if n_nodes <= 0:
        raise ValueError(f"n_nodes must be >= 1, got {n_nodes}")
    if horizon_s <= 0:
        raise ValueError(f"horizon_s must be > 0, got {horizon_s}")
    if mttf_s is not None and (mttf_s <= 0 or mttr_s <= 0):
        raise ValueError("mttf_s and mttr_s must be > 0")
    if straggle_mttf_s is not None and (straggle_mttf_s <= 0
                                        or straggle_mttr_s <= 0):
        raise ValueError("straggle_mttf_s and straggle_mttr_s must be > 0")
    lo, hi = slowdown_range
    if not (1.0 <= lo <= hi):
        raise ValueError("slowdown_range must satisfy 1 <= lo <= hi")
    if domains is None:
        groups: list[tuple[int, ...]] = [(i,) for i in range(n_nodes)]
    else:
        groups = [tuple(g) for g in domains]
        flat = [n for g in groups for n in g]
        if sorted(flat) != list(range(n_nodes)):
            raise ValueError(
                "domains must partition range(n_nodes): every node index "
                "in exactly one domain")
    rng = np.random.default_rng(seed)
    events: list[tuple[float, int, str, float]] = []

    def alternating(members: tuple[int, ...], up_s: float, down_s: float,
                    down_kind: str, up_kind: str, draw_value) -> None:
        t = float(rng.exponential(up_s))
        while t < horizon_s:
            value = draw_value()
            for node in members:
                events.append((t, node, down_kind, value))
            t += float(rng.exponential(down_s))
            if t >= horizon_s:
                break
            for node in members:
                events.append((t, node, up_kind, 1.0))
            t += float(rng.exponential(up_s))

    for members in groups:
        if mttf_s is not None:
            alternating(members, mttf_s, mttr_s, "crash", "recover",
                        lambda: 1.0)
        if straggle_mttf_s is not None:
            for node in members:
                alternating((node,), straggle_mttf_s, straggle_mttr_s,
                            "slow", "normal",
                            lambda: float(rng.uniform(lo, hi)))
    events.sort(key=lambda ev: (ev[0], ev[1]))
    return events


def session_workload(
    n_sessions: int,
    *,
    turns: int = 4,
    think_s: float = 20.0,
    rate_qps: float = 0.2,
    pattern: str = "poisson",
    spec: WorkloadSpec = WorkloadSpec(),
    seed: int = 0,
    **arrival_kw,
) -> list[tuple[float, Query, tuple[int, int, int]]]:
    """Seeded multi-turn conversational sessions: the prefix-sharing
    counterpart of `timestamped_workload` (and the third replayable
    input class after arrival and fault traces).

    Each of the `n_sessions` sessions opens at a time drawn from the
    usual arrival processes (`pattern` + `rate_qps` over session starts,
    so sessions compose with Poisson/bursty/diurnal/onoff shaping) and
    runs `turns` turns.  Turn 0 is an ordinary Alpaca-like query.  Every
    later turn re-submits the full previous context — prompt plus the
    model's answer — as a *shared prefix* and appends a fresh
    Alpaca-like user input:

        τin(k) = prefix(k) + fresh(k),
        prefix(k) = min(τin(k−1) + τout(k−1), max_in − fresh(k)),

    (the min truncates histories that outgrow the model's `max_in`
    context window — the truncated tail is still reported as shared so
    prefix < τin always holds and a KV prefix cache can price the hit).
    Think-time gaps between a session's turns are Exp(`think_s`).

    Returns time-sorted (arrival_s, (τin, τout), (session_id, turn,
    prefix_tokens)) triples; ties break by (session, turn).  The same
    seed always replays the identical stream — session traces are
    first-class replayable inputs, like arrival and fault traces.
    """
    if n_sessions <= 0:
        raise ValueError(f"n_sessions must be >= 1, got {n_sessions}")
    if turns < 1:
        raise ValueError(f"turns must be >= 1, got {turns}")
    if think_s <= 0:
        raise ValueError(f"think_s must be > 0, got {think_s}")
    starts = arrival_times(n_sessions, rate_qps, pattern=pattern,
                           seed=seed + 1, **arrival_kw)
    rng = np.random.default_rng(seed)
    items: list[tuple[float, Query, tuple[int, int, int]]] = []
    for sid in range(n_sessions):
        fresh = np.exp(rng.normal(spec.in_log_mean, spec.in_log_sigma, turns))
        fresh = np.clip(fresh, spec.min_tokens, spec.max_in).astype(int)
        touts = np.exp(rng.normal(spec.out_log_mean, spec.out_log_sigma,
                                  turns))
        touts = np.clip(touts, spec.min_tokens, spec.max_out).astype(int)
        gaps = rng.exponential(think_s, turns)   # gaps[0] unused: fixed draw
        t = float(starts[sid])
        prefix = 0
        for k in range(turns):
            if k > 0:
                t += float(gaps[k])
                prefix = min(prefix, spec.max_in - int(fresh[k]))
                prefix = max(prefix, 0)
            tau_in = prefix + int(fresh[k])
            tau_out = int(touts[k])
            items.append((t, (tau_in, tau_out), (sid, k, prefix)))
            prefix = tau_in + tau_out
    items.sort(key=lambda it: (it[0], it[2][0], it[2][1]))
    return items


def timestamped_workload(
    spec: WorkloadSpec = WorkloadSpec(),
    *,
    rate_qps: float = 1.0,
    pattern: str = "poisson",
    seed: int | None = None,
    **arrival_kw,
) -> list[tuple[float, Query]]:
    """Alpaca-like queries with streaming arrival timestamps:
    [(arrival_s, (τin, τout)), ...] sorted by time — the online-serving
    counterpart of `alpaca_like_workload` (consumed by repro.cluster)."""
    seed = spec.seed if seed is None else seed
    queries = alpaca_like_workload(dataclasses.replace(spec, seed=seed))
    times = arrival_times(len(queries), rate_qps, pattern=pattern,
                          seed=seed + 1, **arrival_kw)
    return [(float(t), q) for t, q in zip(times, queries)]


def grid_workload(lo: int = 8, hi: int = 2048) -> list[Query]:
    """Power-of-two grid, the paper's §6.1 ANOVA campaign."""
    levels = []
    v = lo
    while v <= hi:
        levels.append(v)
        v *= 2
    return [(a, b) for a in levels for b in levels]


def token_batches(
    queries: Sequence[Query],
    batch_size: int,
    vocab_size: int,
    *,
    pad_to: int | None = None,
    seed: int = 0,
) -> Iterator[dict]:
    """Yield padded batches {"tokens": [B, S], "lengths": [B], "tau_out": [B]}.

    Token ids are synthetic (uniform); lengths drive cost, as in the paper's
    standardized prompts.  S = pad_to or the max τin in the batch, rounded
    up to a multiple of 8.
    """
    rng = np.random.default_rng(seed)
    for i in range(0, len(queries), batch_size):
        chunk = queries[i : i + batch_size]
        if len(chunk) < batch_size:  # repeat-pad the final partial batch
            chunk = list(chunk) + [chunk[-1]] * (batch_size - len(chunk))
        lens = np.array([q[0] for q in chunk], dtype=np.int32)
        touts = np.array([q[1] for q in chunk], dtype=np.int32)
        S = int(pad_to or max(8, int(np.ceil(lens.max() / 8)) * 8))
        toks = rng.integers(1, vocab_size, size=(batch_size, S), dtype=np.int64)
        mask = np.arange(S)[None, :] < lens[:, None]
        toks = np.where(mask, toks, 0)
        yield {
            "tokens": toks.astype(np.int32),
            "lengths": lens,
            "tau_out": touts,
        }


def lm_train_batches(
    n_steps: int, batch_size: int, seq_len: int, vocab_size: int, *,
    seed: int = 0, kind: str = "markov", noise: float = 0.15
) -> Iterator[dict]:
    """Synthetic LM training batches with next-token labels.

    kind="markov": a noisy deterministic chain (next = 3*cur+7 mod V with
    prob 1-noise, else uniform) — learnable structure, so training loss
    visibly falls below ln(V).  kind="uniform": i.i.d. tokens (loss floor
    is exactly ln(V); useful for cost benchmarking only)."""
    rng = np.random.default_rng(seed)
    for _ in range(n_steps):
        if kind == "uniform":
            toks = rng.integers(1, vocab_size,
                                size=(batch_size, seq_len + 1), dtype=np.int64)
        else:
            toks = np.empty((batch_size, seq_len + 1), np.int64)
            toks[:, 0] = rng.integers(1, vocab_size, batch_size)
            for t in range(seq_len):
                nxt = (3 * toks[:, t] + 7) % vocab_size
                flip = rng.random(batch_size) < noise
                nxt[flip] = rng.integers(1, vocab_size, int(flip.sum()))
                toks[:, t + 1] = nxt
        yield {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
