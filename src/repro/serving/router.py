"""Energy-aware request routing — the paper's scheduler applied to serving.

The offline scheduler (repro.core.scheduler) partitions a known workload;
the EnergyAwareRouter wraps it for the serving path: given a batch of
Requests with known/estimated output lengths (the paper assumes offline
knowledge, citing Zheng et al. for online estimation), it assigns each to
a hosted model and groups them into per-model batches.

OnlineRouter is the streaming counterpart: it routes one request at a
time through any repro.cluster policy (zeta_online by default) over live
per-model load counters — the adapter that lets the serving engine use the
cluster simulator's policies against real traffic.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.energy_model import LLMProfile, normalized_costs
from repro.core.scheduler import Assignment, schedule, schedule_capacitated
from repro.serving.requests import Request


@dataclasses.dataclass
class RoutingPlan:
    assignment: Assignment
    per_model: dict[str, list[Request]]


class EnergyAwareRouter:
    def __init__(self, profiles: Sequence[LLMProfile], *, zeta: float = 0.5,
                 gamma: Sequence[float] | None = None):
        self.profiles = list(profiles)
        self.zeta = zeta
        self.gamma = gamma

    def route(self, requests: Sequence[Request],
              tau_out_estimates: Sequence[int] | None = None) -> RoutingPlan:
        if tau_out_estimates is None:
            tau_out_estimates = [r.max_new_tokens for r in requests]
        queries = [(r.tau_in, int(t)) for r, t in zip(requests, tau_out_estimates)]
        if self.gamma is not None:
            asg = schedule_capacitated(self.profiles, queries, self.zeta, self.gamma)
        else:
            asg = schedule(self.profiles, queries, self.zeta)
        per_model: dict[str, list[Request]] = {p.name: [] for p in self.profiles}
        for req, k in zip(requests, asg.assignee):
            name = self.profiles[int(k)].name
            req.model = name
            per_model[name].append(req)
        return RoutingPlan(assignment=asg, per_model=per_model)

    def predicted_costs(self, requests: Sequence[Request]) -> np.ndarray:
        queries = [(r.tau_in, r.max_new_tokens) for r in requests]
        return normalized_costs(self.profiles, queries).energy


# ---------------------------------------------------------------------------
# Online (streaming) adapter over the cluster policies
# ---------------------------------------------------------------------------


class _ModelView:
    """The minimal node surface a cluster policy reads: identity, profile,
    a live load signal (outstanding requests on this model), and the
    power/wake signals — constant here, since a live router's models are
    always-on (power_rank 0, no pending wake energy)."""

    power_rank = 0
    pending_wake_j = 0.0

    def __init__(self, node_id: int, profile: LLMProfile):
        self.node_id = node_id
        self.profile = profile
        self.outstanding = 0

    def load(self) -> int:
        return self.outstanding


class OnlineRouter:
    """Route requests one at a time as they arrive (no batching window).

    Wraps a repro.cluster RoutingPolicy over per-model load views; the
    caller reports completions so load-aware policies see live queue
    depths.  Offline-information policies (the oracle) need a full trace
    and are rejected here — they belong in the cluster simulator.
    """

    def __init__(self, profiles: Sequence[LLMProfile], *,
                 policy=None, zeta: float = 0.5):
        from repro.cluster.policies import OfflineOraclePolicy, ZetaOnlinePolicy
        from repro.cluster.trace import ArrivalTrace

        if isinstance(policy, OfflineOraclePolicy):
            raise ValueError("the offline oracle needs the full trace — "
                             "use repro.cluster.simulate_cluster")
        self.views = [_ModelView(i, p) for i, p in enumerate(profiles)]
        self.policy = policy or ZetaOnlinePolicy()
        self.policy.attach(self.views, ArrivalTrace("live", ()), zeta)
        self._clock = 0
        self._view_of: dict[int, int] = {}  # request_id -> view index

    def route_one(self, request: Request,
                  tau_out_estimate: int | None = None) -> str:
        """Assign one request to a hosted model; returns the model name."""
        from repro.cluster.trace import TracedRequest

        tau_out = int(tau_out_estimate if tau_out_estimate is not None
                      else request.max_new_tokens)
        traced = TracedRequest(request.request_id, float(self._clock),
                               request.tau_in, tau_out)
        self._clock += 1
        nid = self.policy.select(traced, self.views, float(self._clock))
        view = self.views[nid]
        view.outstanding += 1
        self._view_of[request.request_id] = nid
        request.model = view.profile.name
        return view.profile.name

    def complete(self, request: Request) -> None:
        """Report a finished request so load signals stay accurate."""
        nid = self._view_of.pop(request.request_id, None)
        if nid is not None and self.views[nid].outstanding > 0:
            self.views[nid].outstanding -= 1
