"""Energy-aware request routing — the paper's scheduler applied to serving.

The offline scheduler (repro.core.scheduler) partitions a known workload;
the Router wraps it for the serving path: given a batch of Requests with
known/estimated output lengths (the paper assumes offline knowledge,
citing Zheng et al. for online estimation), it assigns each to a hosted
model and groups them into per-model batches.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.energy_model import LLMProfile, normalized_costs
from repro.core.scheduler import Assignment, schedule, schedule_capacitated
from repro.serving.requests import Request


@dataclasses.dataclass
class RoutingPlan:
    assignment: Assignment
    per_model: dict[str, list[Request]]


class EnergyAwareRouter:
    def __init__(self, profiles: Sequence[LLMProfile], *, zeta: float = 0.5,
                 gamma: Sequence[float] | None = None):
        self.profiles = list(profiles)
        self.zeta = zeta
        self.gamma = gamma

    def route(self, requests: Sequence[Request],
              tau_out_estimates: Sequence[int] | None = None) -> RoutingPlan:
        if tau_out_estimates is None:
            tau_out_estimates = [r.max_new_tokens for r in requests]
        queries = [(r.tau_in, int(t)) for r, t in zip(requests, tau_out_estimates)]
        if self.gamma is not None:
            asg = schedule_capacitated(self.profiles, queries, self.zeta, self.gamma)
        else:
            asg = schedule(self.profiles, queries, self.zeta)
        per_model: dict[str, list[Request]] = {p.name: [] for p in self.profiles}
        for req, k in zip(requests, asg.assignee):
            name = self.profiles[int(k)].name
            req.model = name
            per_model[name].append(req)
        return RoutingPlan(assignment=asg, per_model=per_model)

    def predicted_costs(self, requests: Sequence[Request]) -> np.ndarray:
        queries = [(r.tau_in, r.max_new_tokens) for r in requests]
        return normalized_costs(self.profiles, queries).energy
