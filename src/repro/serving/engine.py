"""Batched inference engine: prefill + decode with explicit KV-cache control.

Two modes, both first-class because the paper *measures* with KV caching
disabled (§3, §5.1) while production serving uses it:

  * kv_cache=True  — prefill once, then one jitted decode_step per token
    (cache donated, so the update is in-place on device).
  * kv_cache=False — the paper's measurement mode: every generated token
    re-runs the full forward pass over the exact growing sequence
    (runtime superlinear in τout — the source of the τin·τout interaction
    term in Eq. 6/7).  Greedy decoding in this mode is bit-identical to
    the cached mode (verified by test_greedy_modes_agree).

An optional meter (repro.energy.meter.EnergyMeter) wraps each phase and
returns joules; GenStats feeds the characterization campaign directly.
"""

from __future__ import annotations

import dataclasses
import math
import time
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_api
from repro.models.common import ModelConfig
from repro.serving.sampler import Sampler


@dataclasses.dataclass
class GenStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    prefill_energy_j: float = 0.0
    decode_energy_j: float = 0.0
    tau_in: int = 0
    tau_out: int = 0

    @property
    def runtime_s(self) -> float:
        return self.prefill_s + self.decode_s

    @property
    def energy_j(self) -> float:
        return self.prefill_energy_j + self.decode_energy_j

    @property
    def tokens_per_s(self) -> float:
        return self.tau_out / self.decode_s if self.decode_s > 0 else float("inf")


class _NullMeter:
    """Measures wall time only; energy reported as 0."""

    def measure(self, fn):
        t0 = time.perf_counter()
        out = fn()
        out = jax.block_until_ready(out)
        return out, time.perf_counter() - t0, 0.0


class InferenceEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        *,
        kv_cache: bool = True,
        sampler: Sampler = Sampler(),
        bucket: int = 32,
        long_context: bool = False,
        meter: Any = None,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.params = params
        self.api = get_api(cfg)
        self.kv_cache = kv_cache
        self.sampler = sampler
        self.bucket = bucket
        self.long_context = long_context
        self.meter = meter or _NullMeter()
        self.key = jax.random.PRNGKey(seed)

        self._prefill = jax.jit(
            partial(self.api.prefill, cfg),
            static_argnames=("cache_len", "long_context"))

        def _decode(params, cache, token, key):
            logits, cache = self.api.decode_step(cfg, params, cache,
                                                 {"token": token})
            nxt = self.sampler(logits, key)
            return nxt, cache

        self._decode = jax.jit(_decode, donate_argnums=(1,))

    # ------------------------------------------------------------------
    def _pad_len(self, n: int) -> int:
        return max(self.bucket, int(math.ceil(n / self.bucket)) * self.bucket)

    def _extra_inputs(self, batch: dict) -> dict:
        return {k: v for k, v in batch.items()
                if k in ("patches", "frames")}

    # ------------------------------------------------------------------
    def generate(self, batch: dict, max_new_tokens: int) -> tuple[np.ndarray, GenStats]:
        """batch: {"tokens": [B, S0] int32, (+"patches"/"frames")}.
        Returns (generated [B, max_new_tokens] int32, stats)."""
        if self.kv_cache:
            return self._generate_cached(batch, max_new_tokens)
        return self._generate_uncached(batch, max_new_tokens)

    def _generate_cached(self, batch, max_new):
        tokens = jnp.asarray(batch["tokens"], jnp.int32)
        B, S0 = tokens.shape
        extra = self._extra_inputs(batch)
        span = S0 + max_new + (self.cfg.n_patches if self.cfg.family == "vlm" else 0)
        cache_len = self._pad_len(span)

        inputs = {"tokens": tokens, **extra}
        (logits, cache), t_prefill, e_prefill = self.meter.measure(
            lambda: self._prefill(self.params, inputs, cache_len=cache_len,
                                  long_context=self.long_context))

        stats = GenStats(prefill_s=t_prefill, prefill_energy_j=e_prefill,
                         tau_in=S0, tau_out=max_new)
        out = np.zeros((B, max_new), np.int32)
        self.key, k0 = jax.random.split(self.key)
        token = self.sampler(logits, k0)

        t0 = time.perf_counter()
        e_total = 0.0
        for t in range(max_new):
            out[:, t] = np.asarray(token)
            self.key, kt = jax.random.split(self.key)
            (token, cache), dt, de = self.meter.measure(
                lambda tok=token, kk=kt, c=cache: self._decode(self.params, c, tok, kk))
            e_total += de
        stats.decode_s = time.perf_counter() - t0
        stats.decode_energy_j = e_total
        return out, stats

    def _generate_uncached(self, batch, max_new):
        tokens = np.asarray(batch["tokens"], np.int32)
        B, S0 = tokens.shape
        extra = self._extra_inputs(batch)
        buf = np.zeros((B, S0 + max_new), np.int32)
        buf[:, :S0] = tokens

        stats = GenStats(tau_in=S0, tau_out=max_new)
        out = np.zeros((B, max_new), np.int32)
        e_total = 0.0
        t_start = time.perf_counter()
        first_step_s = None
        for t in range(max_new):
            L = S0 + t
            window = np.asarray(buf[:, :L], np.int32)
            inputs = {"tokens": jnp.asarray(window), **extra}
            # full re-forward over the exact prefix — the paper's mode
            (logits, _cache), dt, de = self.meter.measure(
                lambda i=inputs, lp=L: self._prefill(self.params, i, cache_len=lp,
                                                     long_context=self.long_context))
            e_total += de
            if first_step_s is None:
                first_step_s = dt
            self.key, kt = jax.random.split(self.key)
            token = np.asarray(self.sampler(logits, kt))
            out[:, t] = token
            buf[:, L] = token
        total = time.perf_counter() - t_start
        # attribute the first full-prefix pass as "prefill", rest as decode
        stats.prefill_s = first_step_s or 0.0
        stats.decode_s = total - stats.prefill_s
        stats.prefill_energy_j = 0.0
        stats.decode_energy_j = e_total
        return out, stats


def measure_fn(engine_factory: Callable[[], InferenceEngine], batch_size: int,
               vocab_size: int, *, seed: int = 0):
    """Adapter: (tau_in, tau_out) -> (energy_j, runtime_s), the callback the
    characterization campaign (repro.core.characterize) consumes.  Runs a
    real generation of the requested shape on the engine."""
    engine = engine_factory()
    rng = np.random.default_rng(seed)

    def measure(tau_in: int, tau_out: int) -> tuple[float, float]:
        toks = rng.integers(1, vocab_size, size=(batch_size, tau_in), dtype=np.int64)
        batch = {"tokens": toks.astype(np.int32)}
        if engine.cfg.family == "vlm":
            from repro.models.vlm import VISION_DIM
            batch["patches"] = np.zeros((batch_size, engine.cfg.n_patches, VISION_DIM), np.float32)
        if engine.cfg.family == "encdec":
            batch["frames"] = np.zeros((batch_size, engine.cfg.n_frames, engine.cfg.d_model), np.float32)
        _, stats = engine.generate(batch, tau_out)
        return stats.energy_j, stats.runtime_s

    return measure
