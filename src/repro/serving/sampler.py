"""Token samplers for the decode loop."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Sampler:
    temperature: float = 0.0     # 0 => greedy
    top_k: int = 0               # 0 => no truncation

    def __call__(self, logits: jax.Array, key: jax.Array) -> jax.Array:
        """logits [B, V] -> token ids [B] int32."""
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        scaled = logits / self.temperature
        if self.top_k:
            kth = jax.lax.top_k(scaled, self.top_k)[0][..., -1:]
            scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
        return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
