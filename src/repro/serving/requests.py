"""Request/response objects for the serving path."""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Request:
    request_id: int
    tokens: np.ndarray          # [tau_in] int32 prompt
    max_new_tokens: int
    model: str | None = None    # filled by the router

    @property
    def tau_in(self) -> int:
        return int(len(self.tokens))


@dataclasses.dataclass
class Response:
    request_id: int
    model: str
    tokens: np.ndarray          # generated ids
    prefill_s: float
    decode_s: float
    energy_j: float             # metered (real or modeled)

    @property
    def tau_out(self) -> int:
        return int(len(self.tokens))

    @property
    def runtime_s(self) -> float:
        return self.prefill_s + self.decode_s
