"""Serving substrate: engine, router, request objects, samplers."""

from repro.serving.engine import GenStats, InferenceEngine, measure_fn  # noqa: F401
from repro.serving.requests import Request, Response  # noqa: F401
from repro.serving.router import EnergyAwareRouter, OnlineRouter, RoutingPlan  # noqa: F401
from repro.serving.sampler import Sampler  # noqa: F401
