"""Dry-run artifact analysis: HLO parsing and roofline terms."""

from repro.analysis.hlo import HLOModule, Totals, analyze_hlo_text  # noqa: F401
from repro.analysis.roofline import RooflineTerms, roofline_terms  # noqa: F401
