"""Three-term roofline analysis from the compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HBM_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

HLO_FLOPs and collective_bytes come from the trip-count-correct HLO parser
(repro.analysis.hlo); HBM bytes come from the analytic cost model (XLA's
"bytes accessed" does not survive fusion/loop accounting meaningfully on
this backend — see DESIGN.md).  MODEL_FLOPS = 6·N_active·D (train) or
2·N_active·D (forward-only); the ratio MODEL_FLOPS / HLO_FLOPs measures
how much compiled compute is "useful".
"""

from __future__ import annotations

import dataclasses

from repro.analysis.hlo import Totals
from repro.energy.hardware import AcceleratorSpec, TPU_V5E


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    # global quantities
    hlo_flops: float            # parser per-device FLOPs x chips
    hbm_bytes: float            # analytic model, global
    collective_bytes: float     # parser per-device x chips
    model_flops: float          # 6·N·D or 2·N·D
    # terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else float("nan")

    def to_dict(self) -> dict:
        return {
            **dataclasses.asdict(self),
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "step_s": self.step_s,
        }


def roofline_terms(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    hlo_totals: Totals,
    hbm_bytes_global: float,
    model_flops: float,
    accel: AcceleratorSpec = TPU_V5E,
    ici_links: int = 4,          # v5e: 4 ICI links per chip (2D torus)
) -> RooflineTerms:
    hlo_flops_global = hlo_totals.flops * chips
    coll_global = hlo_totals.total_collective_bytes * chips
    compute_s = hlo_flops_global / (chips * accel.peak_flops)
    memory_s = hbm_bytes_global / (chips * accel.hbm_bw)
    collective_s = coll_global / (chips * accel.ici_bw * ici_links)
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=hlo_flops_global, hbm_bytes=hbm_bytes_global,
        collective_bytes=coll_global, model_flops=model_flops,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
    )
