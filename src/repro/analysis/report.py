"""Render the roofline table (EXPERIMENTS.md §Roofline) from the dry-run
JSON records.

    PYTHONPATH=src python -m repro.analysis.report [--mesh pod] [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dir_: Path, mesh: str) -> list[dict]:
    recs = []
    for f in sorted(dir_.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        if r.get("status") == "ok":
            recs.append(r)
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 9))
    return recs


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.0f}us"


def markdown_table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | compute | memory | collective | dominant | "
           "MODEL/HLO flops | mem/dev (TPU) | note |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in recs:
        t = r["roofline"]
        mem = r["memory_analysis"]
        gb = mem["peak_bytes_per_device_tpu"] / 1e9
        note = "FITS" if gb <= 16.0 else f"OVER ({gb:.0f}GB)"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"**{t['dominant']}** | {t['useful_flops_ratio']:.2f} | "
            f"{gb:.2f}GB | {note} |")
    return "\n".join(lines)


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--mesh", default="pod")
    p.add_argument("--dir", default="results/dryrun")
    args = p.parse_args(argv)
    recs = load(Path(args.dir), args.mesh)
    print(markdown_table(recs))
    doms: dict = {}
    fits = 0
    for r in recs:
        doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
        fits += r["memory_analysis"]["peak_bytes_per_device_tpu"] / 1e9 <= 16.0
    print(f"\n{len(recs)} records | dominant: {doms} | fit 16GB/chip: "
          f"{fits}/{len(recs)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
