"""Post-SPMD HLO text analysis: per-device FLOPs and collective bytes with
while-loop trip-count multiplication.

XLA's `compiled.cost_analysis()` counts each while body ONCE, so for
scan-over-layers models it underestimates by ~n_layers (verified
empirically on this backend).  This parser rebuilds the computation call
graph from `compiled.as_text()`:

  * dot ops        -> FLOPs = 2 * |result| * |contracted dims|
  * collectives    -> bytes = sum of operand buffer sizes, by opcode
  * fusion/call    -> callee totals, once per call site
  * while          -> (body + cond) totals x trip count, where the trip
                      count is recovered from the loop-bound constant
                      compared in the condition computation (the pattern
                      lax.scan emits)

All numbers are per-device (shapes in post-SPMD HLO are already
partitioned); multiply by chip count for cluster totals.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes appearing in shape_str (handles
    tuples by summation)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> tuple[list[int], str]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return [], ""
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return dims, m.group(1)


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    collective_bytes: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    collective_count: dict = dataclasses.field(default_factory=lambda: defaultdict(float))

    def add(self, other: "Totals", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] += v * mult
        for k, v in other.collective_count.items():
            self.collective_count[k] += v * mult

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))


@dataclasses.dataclass
class _Op:
    name: str
    shape_str: str
    opcode: str
    line: str


class HLOModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[_Op]] = {}
        self.shapes: dict[str, str] = {}   # op name -> shape string (global)
        self._parse(text)
        self._totals_cache: dict[str, Totals] = {}

    # ------------------------------------------------------------------
    def _parse(self, text: str) -> None:
        current: list[_Op] | None = None
        for raw in text.splitlines():
            line = raw.rstrip()
            hdr = _COMP_HDR_RE.match(line.strip())
            if hdr and line.rstrip().endswith("{"):
                name = hdr.group(1)
                current = []
                self.computations[name] = current
                # parameters declared in the header
                for pm in re.finditer(r"([\w.\-]+):\s*([a-z0-9]+\[[\d,]*\](?:\{[\d,]*\})?)",
                                      hdr.group(2)):
                    self.shapes[pm.group(1)] = pm.group(2)
                continue
            if current is None:
                continue
            if line.strip() == "}":
                current = None
                continue
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rest = m.group(1), m.group(2)
            # rest: "<shape> <opcode>(operands), attrs"
            om = re.match(r"((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[\d,:TSE()]*\})?))\s+([\w\-]+)",
                          rest)
            if not om:
                continue
            shape_str, opcode = om.group(1), om.group(2)
            self.shapes[name] = shape_str
            current.append(_Op(name=name, shape_str=shape_str, opcode=opcode, line=line))

    # ------------------------------------------------------------------
    def _operand_names(self, op: _Op) -> list[str]:
        # operands inside the first (...) after opcode
        idx = op.line.find(op.opcode + "(")
        if idx < 0:
            return []
        seg = op.line[idx + len(op.opcode) + 1:]
        depth = 1
        out = []
        buf = ""
        for ch in seg:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    buf += " "
                    break
            buf += ch
        return _OPERANDS_RE.findall(buf)

    def _trip_count(self, cond_name: str) -> float:
        """Loop bound from the condition computation (lax.scan pattern:
        compare(iter, constant(N)), direction=LT)."""
        ops = self.computations.get(cond_name, [])
        best = 1.0
        for op in ops:
            if op.opcode == "compare" or "compare(" in op.line:
                for c in _CONST_RE.findall(op.line):
                    best = max(best, float(c))
        if best == 1.0:  # fall back: any constant in the computation
            for op in ops:
                for c in _CONST_RE.findall(op.line):
                    best = max(best, float(c))
        return best

    def _dot_flops(self, op: _Op) -> float:
        result_dims, _ = _shape_dims(op.shape_str)
        out = 1.0
        for d in result_dims:
            out *= d
        # contraction size from lhs operand shape + lhs_contracting_dims
        operands = self._operand_names(op)
        contr = 1.0
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
        if m and operands:
            lhs_shape = self.shapes.get(operands[0], "")
            lhs_dims, _ = _shape_dims(lhs_shape)
            for ds in m.group(1).split(","):
                if ds and int(ds) < len(lhs_dims):
                    contr *= lhs_dims[int(ds)]
        return 2.0 * out * contr

    # ------------------------------------------------------------------
    def computation_totals(self, name: str) -> Totals:
        if name in self._totals_cache:
            return self._totals_cache[name]
        t = Totals()
        self._totals_cache[name] = t   # break cycles defensively
        for op in self.computations.get(name, []):
            if op.opcode == "dot":
                t.flops += self._dot_flops(op)
            elif op.opcode in COLLECTIVE_OPS or op.opcode.rstrip("-start") in COLLECTIVE_OPS:
                base = op.opcode.replace("-start", "")
                if base in COLLECTIVE_OPS:
                    b = sum(_shape_bytes(self.shapes.get(o, ""))
                            for o in self._operand_names(op))
                    if b == 0:
                        b = _shape_bytes(op.shape_str)
                    t.collective_bytes[base] += b
                    t.collective_count[base] += 1
            elif op.opcode == "fusion":
                m = _CALLS_RE.search(op.line)
                if m:
                    t.add(self.computation_totals(m.group(1)))
            elif op.opcode == "call":
                m = re.search(r"to_apply=%?([\w.\-]+)", op.line)
                if m:
                    t.add(self.computation_totals(m.group(1)))
            elif op.opcode == "while":
                m = _WHILE_RE.search(op.line)
                if m:
                    cond, body = m.group(1), m.group(2)
                    trips = self._trip_count(cond)
                    t.add(self.computation_totals(body), trips)
                    t.add(self.computation_totals(cond), trips)
            elif op.opcode == "conditional":
                for m in re.finditer(r"(?:true_computation|false_computation|branch_computations=\{[^}]*)=%?([\w.\-]+)", op.line):
                    t.add(self.computation_totals(m.group(1)))
        self._totals_cache[name] = t
        return t

    def entry_totals(self) -> Totals:
        # the ENTRY computation is the one not called by anyone — find by
        # name conventions first, else pick the largest
        for cand in self.computations:
            if cand.startswith("main"):
                return self.computation_totals(cand)
        # fallback: computation with most ops
        name = max(self.computations, key=lambda k: len(self.computations[k]))
        return self.computation_totals(name)


def analyze_hlo_text(text: str) -> Totals:
    return HLOModule(text).entry_totals()


def float_normalization_bytes(text_or_module) -> int:
    """Bytes of XLA:CPU's float-normalization upcasts: the CPU backend has
    no native bf16 compute, so it inserts entry-level f32 copies of every
    bf16 parameter (weights, caches).  These buffers do NOT exist on the
    TPU target — subtract them to get the TPU-relevant peak memory.

    Heuristic: entry-computation `convert`/`wrapped_convert` fusions with
    f32 results > 1 MiB (only the normalization pass produces whole-stack
    entry-level converts at that scale in these graphs)."""
    mod = (text_or_module if isinstance(text_or_module, HLOModule)
           else HLOModule(text_or_module))
    entry_name = None
    for cand in mod.computations:
        if cand.startswith("main"):
            entry_name = cand
            break
    if entry_name is None:
        entry_name = max(mod.computations, key=lambda k: len(mod.computations[k]))
    total = 0
    for op in mod.computations[entry_name]:
        if not op.shape_str.startswith("f32"):
            continue
        is_upcast = (op.opcode == "convert"
                     or (op.opcode == "fusion" and "wrapped_convert" in op.line))
        if is_upcast:
            b = _shape_bytes(op.shape_str)
            if b > (1 << 20):
                total += b
    return total
