"""Parametric ζ-sweep engine: warm-start incremental scheduling and
streaming Pareto-frontier tracing (the paper's §6 energy–runtime trade-off
study, made cheap enough for periodic online re-planning).

Three cooperating layers:

  * ``IncrementalScheduler`` — holds one capacitated scheduling problem
    (profiles × workload × ζ × capacities) across edits.  The raw
    energy/runtime/accuracy matrices are built once per query and grown
    in-place; ``reschedule(added=, removed=, capacity_deltas=, zeta=)``
    re-normalizes, rebuilds the ζ objective with one saxpy, and repairs
    the previous assignment via ``scheduler._repair_assignment`` instead
    of re-solving — O(delta) chain moves for small edits, against O(m)
    for a cold solve.

  * ``pareto_frontier`` — the streaming ζ sweep.  Normalized cost
    matrices are computed once for the whole sweep; each capacitated ζ
    point warm-starts from its neighbour's assignment.  For the
    unconstrained (coverage-only) objective it can instead return the
    EXACT frontier breakpoints — see below — so the whole frontier is
    described by O(#breakpoints) assignments rather than a grid.

  * ``frontier_breakpoints`` — per query, the Eq. 2 objective of model v
    is the line f_v(ζ) = ζ·(ê_v + â_v) − â_v; the argmin over v follows
    the lower envelope of k lines, so the assignment changes only at
    envelope crossings.  The union of those crossings over the workload
    is the exact, finite set of ζ where the optimal unconstrained
    assignment changes.

Exactness contract
------------------
Everything this module returns is exact — never "approximately equal":

  * ``IncrementalScheduler.reschedule`` terminates only when the repaired
    assignment satisfies the residual-graph optimality conditions of
    ``scheduler.capacitated_optimality_certificate`` (pass ``check=True``
    to assert the certificate on every solve).  Its objective matches a
    cold ``schedule_capacitated`` solve on the identical workload within
    the same ≤1e-12-relative equivalence class the chains-vs-flow tests
    use (permuted exact optima over duplicate queries may differ in the
    last ulp of the pairwise sum; the assignments themselves are both
    LP-optimal).
  * ``frontier_breakpoints`` returns the exact crossing ζ values (joint
    minimality of the crossing lines is verified against the full
    envelope), not a grid refinement.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from repro.core import scheduler
from repro.core.energy_model import (
    LLMProfile,
    NormalizedCosts,
    Query,
    normalized_costs,
    objective_matrix,
)
from repro.core.scheduler import Assignment


class IncrementalScheduler:
    """One capacitated Eq. 2 problem, solved warm across edits.

    Queries get stable integer ids in insertion order (``next_id`` before
    an add is the id of the first added query); ``removed=`` takes those
    ids.  Capacities come from ``gamma`` (re-materialized over the current
    workload size every solve, so shares track m) or a fixed integer
    ``caps`` vector; ``capacity_deltas`` accumulates signed per-model
    shifts on top of either."""

    def __init__(
        self,
        profiles: Sequence[LLMProfile],
        queries: Sequence[Query],
        zeta: float,
        gamma: Sequence[float] | None = None,
        *,
        caps: Sequence[int] | None = None,
        costs: NormalizedCosts | None = None,
        check: bool = False,
    ):
        self.profiles = list(profiles)
        self.model_names = tuple(p.name for p in self.profiles)
        self.k = len(self.profiles)
        if self.k < 1:
            raise ValueError("need at least one profile")
        if not 0.0 <= zeta <= 1.0:
            raise ValueError(f"zeta must be in [0, 1], got {zeta}")
        self.zeta = float(zeta)
        if (gamma is None) == (caps is None):
            raise ValueError("pass exactly one of gamma= or caps=")
        self.gamma = None if gamma is None else tuple(float(g) for g in gamma)
        self._caps_base = (None if caps is None
                           else np.asarray(caps, dtype=np.int64).copy())
        self._cap_deltas = np.zeros(self.k, dtype=np.int64)
        self.check = check

        # cached repair bookkeeping: the lazy _ArcHeaps (and the row-aligned
        # objective buffer they index) survive across reschedules while
        # (ζ, e_max, a_max) are unchanged — a delta repair then skips the
        # O(mk) heap rebuild.  Invalidated on ζ moves, normalization-maxima
        # shifts, and buffer reallocation (_grow/_compact re-home rows).
        self._arcs = None
        self._arcs_key: tuple[float, float, float] | None = None
        self._arcs_rows = 0          # _C_buf rows filled under _arcs_key
        self._C_buf: np.ndarray | None = None
        self.arc_reuse_count = 0     # observability for tests/benchmarks
        self.arc_rebuild_count = 0

        # row-parallel buffers (grown by doubling, compacted when dead rows
        # dominate, so a long stream of reschedules over a sliding window
        # stays O(window) in memory and per-solve cost, not O(arrivals))
        self._next_id = 0                      # external ids handed out
        self._m_total = 0                      # rows in use
        self._queries: list[Query] = []        # by row
        self._row_of: dict[int, int] = {}      # external id -> row
        cap0 = max(64, 2 * len(queries))
        self._E = np.empty((cap0, self.k))
        self._A = np.empty((cap0, self.k))
        self._Rt = np.empty((cap0, self.k))
        self._ids = np.empty(cap0, dtype=np.int64)
        self._alive = np.zeros(cap0, dtype=bool)
        self._assignee = np.empty(cap0, dtype=np.int64)  # -1 = never solved
        self._assignment: Assignment | None = None
        if costs is not None:
            if (costs.model_names != self.model_names
                    or len(costs.queries) != len(queries)):
                raise ValueError("costs= does not match profiles/queries")
            self._append(queries, rows=(costs.energy, costs.accuracy,
                                        costs.runtime))
            self._solve()
        else:
            self.reschedule(added=queries)

    # ------------------------------------------------------------------
    @property
    def next_id(self) -> int:
        """Id the next added query will receive (insertion counter)."""
        return self._next_id

    @property
    def m_active(self) -> int:
        return int(self._alive[:self._m_total].sum())

    @property
    def assignment(self) -> Assignment:
        if self._assignment is None:
            raise RuntimeError("no solve yet")
        return self._assignment

    def _active_rows(self) -> np.ndarray:
        return np.nonzero(self._alive[:self._m_total])[0]

    @property
    def active_ids(self) -> np.ndarray:
        """External ids of live queries, in id (= insertion) order."""
        return self._ids[self._active_rows()]

    def active_queries(self) -> list[Query]:
        """Current workload in id order — the cold-solve-equivalent input."""
        return [self._queries[r] for r in self._active_rows()]

    def _live_row(self, query_id: int) -> int:
        row = self._row_of.get(query_id)
        if row is None or not self._alive[row]:
            raise KeyError(f"query id {query_id} is not live")
        return row

    def bin_of(self, query_id: int) -> int:
        """Current model index of a live query."""
        return int(self._assignee[self._live_row(query_id)])

    def model_of(self, query_id: int) -> str:
        return self.model_names[self.bin_of(query_id)]

    # ------------------------------------------------------------------
    def _invalidate_arcs(self) -> None:
        self._arcs = None
        self._arcs_key = None
        self._arcs_rows = 0
        self._C_buf = None

    def _grow(self, n_new: int) -> None:
        need = self._m_total + n_new
        cap = self._E.shape[0]
        if need <= cap:
            return
        self._invalidate_arcs()   # reallocation re-homes the rows arcs index
        new_cap = max(need, 2 * cap)
        m = self._m_total
        for name in ("_E", "_A", "_Rt"):
            old = getattr(self, name)
            buf = np.empty((new_cap, self.k))
            buf[:m] = old[:m]
            setattr(self, name, buf)
        for name, dtype in (("_ids", np.int64), ("_assignee", np.int64)):
            old = getattr(self, name)
            buf = np.empty(new_cap, dtype=dtype)
            buf[:m] = old[:m]
            setattr(self, name, buf)
        alive = np.zeros(new_cap, dtype=bool)
        alive[:m] = self._alive[:m]
        self._alive = alive

    def _compact(self) -> None:
        """Drop dead rows (triggered when they dominate, so a sliding-
        window stream stays O(window), not O(total arrivals)).  Also the
        bound on stale heap entries: compaction rebuilds the arcs cache."""
        self._invalidate_arcs()
        keep = self._active_rows()
        n = len(keep)
        for name in ("_E", "_A", "_Rt", "_ids", "_assignee"):
            buf = getattr(self, name)
            buf[:n] = buf[keep]
        self._alive[:n] = True
        self._alive[n:self._m_total] = False
        self._queries = [self._queries[r] for r in keep]
        self._m_total = n
        self._row_of = {int(q): r for r, q in enumerate(self._ids[:n])}

    def _append(self, queries: Sequence[Query],
                rows: tuple[np.ndarray, np.ndarray, np.ndarray] | None = None
                ) -> None:
        n = len(queries)
        if n == 0:
            return
        self._grow(n)
        lo, hi = self._m_total, self._m_total + n
        if rows is None:
            tin = np.array([q[0] for q in queries], dtype=np.float64)
            tout = np.array([q[1] for q in queries], dtype=np.float64)
            # same elementwise model evaluations normalized_costs performs,
            # so a cold solve over the identical workload sees bit-identical
            # raw matrices
            self._E[lo:hi] = np.stack([p.energy(tin, tout)
                                       for p in self.profiles], axis=1)
            self._Rt[lo:hi] = np.stack([p.runtime(tin, tout)
                                        for p in self.profiles], axis=1)
            self._A[lo:hi] = np.stack([p.accuracy(tin, tout)
                                       for p in self.profiles], axis=1)
        else:
            e, a, r = rows
            self._E[lo:hi], self._A[lo:hi], self._Rt[lo:hi] = e, a, r
        self._queries.extend((int(a), int(b)) for a, b in queries)
        self._alive[lo:hi] = True
        self._assignee[lo:hi] = -1
        ids = np.arange(self._next_id, self._next_id + n, dtype=np.int64)
        self._ids[lo:hi] = ids
        self._row_of.update((int(q), lo + i) for i, q in enumerate(ids))
        self._next_id += n
        self._m_total = hi

    def _caps_for(self, m: int) -> np.ndarray:
        if self.gamma is not None:
            caps = scheduler._capacities_from_gamma(self.gamma, m)
        else:
            caps = self._caps_base.copy()
        caps = np.maximum(caps + self._cap_deltas, 0)
        if int(caps.sum()) < m:
            raise RuntimeError(
                f"infeasible capacities {caps.tolist()} for {m} queries")
        return caps

    def _objective_rows(self, rows: np.ndarray, e_max: float,
                        a_max: float) -> np.ndarray:
        """Eq. 2 objective rows under the given normalization maxima —
        elementwise-identical to ``objective_matrix(normalized_costs(...))``
        on the same rows (same divisions, same saxpy)."""
        E, A = self._E[rows], self._A[rows]
        e_hat = E / e_max if e_max > 0 else E
        a_hat = A / a_max if a_max > 0 else A
        return self.zeta * e_hat - (1.0 - self.zeta) * a_hat

    def _solve(self) -> Assignment:
        act = self._active_rows()
        m = len(act)
        if m == 0:
            raise ValueError("empty workload")
        E, A, Rt = self._E[act], self._A[act], self._Rt[act]
        # the same normalization arithmetic normalized_costs applies (its
        # "divide by the largest known value" rule over the active rows)
        e_max = float(E.max())
        a_max = float(A.max())
        costs = NormalizedCosts(
            model_names=self.model_names,
            queries=tuple(self._queries[r] for r in act),
            energy=E, accuracy=A, runtime=Rt,
            energy_hat=E / e_max if e_max > 0 else E,
            accuracy_hat=A / a_max if a_max > 0 else A,
        )
        caps = self._caps_for(m)
        key = (self.zeta, e_max, a_max)

        if self._arcs is not None and key == self._arcs_key:
            # same ζ and normalization maxima: every cached regret
            # (C[i,v] − C[i,u]) is still exact for surviving rows, so the
            # heaps extend instead of rebuilding — removed rows were
            # retired to −1 (skipped lazily), added rows get their
            # objective row appended and an argmin warm seed pushed.
            self.arc_reuse_count += 1
            lo, hi = self._arcs_rows, self._m_total
            if hi > lo:
                self._C_buf[lo:hi] = self._objective_rows(
                    np.arange(lo, hi), e_max, a_max)
                self._arcs_rows = hi
            fresh_rows = act[self._assignee[act] < 0]
            for r in fresh_rows:
                j = int(self._C_buf[r].argmin())
                self._assignee[r] = j
                self._arcs.push(int(r), j)
            C = self._C_buf[act]
            scheduler._repair_live(
                caps, self._assignee, self._arcs,
                tol=1e-12 * max(1.0, float(np.abs(C).max())),
                n_rows=self._m_total)
            assignee = self._assignee[act].copy()
        else:
            # ζ or a normalization maximum moved (or buffers were
            # re-homed): every objective entry changed — rebuild the
            # row-aligned buffer and heaps, then warm-repair as before.
            self.arc_rebuild_count += 1
            C_act = objective_matrix(costs, self.zeta)
            cap_rows = self._E.shape[0]
            if self._C_buf is None or self._C_buf.shape[0] != cap_rows:
                self._C_buf = np.empty((cap_rows, self.k))
            self._C_buf[act] = C_act
            fresh_rows = act[self._assignee[act] < 0]
            if len(fresh_rows):  # new queries start at their argmin
                self._assignee[fresh_rows] = (
                    self._C_buf[fresh_rows].argmin(axis=1))
            self._arcs = scheduler._ArcHeaps(
                self._C_buf, self._assignee, self.k, n_rows=self._m_total)
            self._arcs_key = key
            self._arcs_rows = self._m_total
            C = self._C_buf[act]
            scheduler._repair_live(
                caps, self._assignee, self._arcs,
                tol=1e-12 * max(1.0, float(np.abs(C).max())),
                n_rows=self._m_total)
            assignee = self._assignee[act].copy()
        if self.check and not scheduler.capacitated_optimality_certificate(
                C, assignee, caps):
            raise RuntimeError("optimality certificate failed after repair")
        self._assignment = scheduler._evaluate(costs, assignee, self.zeta, C=C)
        return self._assignment

    # ------------------------------------------------------------------
    def reschedule(
        self,
        added: Sequence[Query] = (),
        removed: Iterable[int] = (),
        capacity_deltas: Sequence[int] | None = None,
        *,
        zeta: float | None = None,
    ) -> Assignment:
        """Apply a workload/capacity/ζ delta and re-solve warm.

        ``added`` queries get ids ``next_id, next_id+1, ...``; ``removed``
        are existing live ids; ``capacity_deltas`` shifts per-model caps
        (accumulating across calls); ``zeta`` moves the objective.
        Returns the exact Assignment over the updated workload (active
        queries in id order)."""
        if zeta is not None:
            if not 0.0 <= zeta <= 1.0:
                raise ValueError(f"zeta must be in [0, 1], got {zeta}")
            self.zeta = float(zeta)
        if capacity_deltas is not None:
            d = np.asarray(capacity_deltas, dtype=np.int64)
            if d.shape != (self.k,):
                raise ValueError(f"capacity_deltas must have shape ({self.k},)")
            self._cap_deltas += d
        for rid in removed:
            row = self._live_row(int(rid))
            self._alive[row] = False
            self._assignee[row] = -1   # retire: cached heaps skip −1 lazily
        if self._m_total > 256 and self.m_active < self._m_total // 2:
            self._compact()
        self._append(list(added))
        return self._solve()


# ---------------------------------------------------------------------------
# Streaming ζ sweep / Pareto frontier
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParetoFrontier:
    """A traced energy–runtime–accuracy frontier.

    ``zetas[i]`` is where ``assignments[i]`` was evaluated.  In breakpoint
    mode, ``breakpoints`` are the exact ζ where the unconstrained argmin
    assignment changes and ``zetas`` are the segment midpoints (one
    representative per constant-assignment piece); in grid mode
    ``breakpoints`` is None."""

    zetas: tuple[float, ...]
    assignments: tuple[Assignment, ...]
    breakpoints: tuple[float, ...] | None = None

    def energies(self) -> np.ndarray:
        return np.array([a.total_energy_j for a in self.assignments])

    def runtimes(self) -> np.ndarray:
        return np.array([a.total_runtime_s for a in self.assignments])

    def accuracies(self) -> np.ndarray:
        return np.array([a.mean_accuracy_ak for a in self.assignments])

    def objectives(self) -> np.ndarray:
        return np.array([a.objective for a in self.assignments])


def frontier_breakpoints(costs: NormalizedCosts, *,
                         tol: float = 1e-12) -> np.ndarray:
    """Exact ζ ∈ (0, 1) where the unconstrained argmin assignment changes.

    Per query, model v's objective is the line f_v(ζ) = ζ·(ê_v+â_v) − â_v;
    candidates are pairwise crossings, kept iff the crossing pair is
    jointly minimal over all k lines there (i.e. the crossing lies on the
    lower envelope, where the argmin actually switches)."""
    S = costs.energy_hat + costs.accuracy_hat     # line slopes
    A = costs.accuracy_hat                        # line intercepts are -A
    m, k = S.shape
    scale = max(1.0, float(np.abs(S).max()), float(np.abs(A).max()))
    out: list[np.ndarray] = []
    for u in range(k):
        for v in range(u + 1, k):
            ds = S[:, u] - S[:, v]
            ok = np.abs(ds) > tol * scale         # parallel lines never cross
            z = np.where(ok, (A[:, u] - A[:, v]) / np.where(ok, ds, 1.0), -1.0)
            inside = ok & (z > tol) & (z < 1.0 - tol)
            if not inside.any():
                continue
            zi = z[inside]
            F = zi[:, None] * S[inside] - A[inside]
            on_envelope = F[:, u] <= F.min(axis=1) + 1e-9 * scale
            if on_envelope.any():
                out.append(zi[on_envelope])
    if not out:
        return np.empty(0)
    z = np.unique(np.concatenate(out))
    keep = [float(z[0])]
    for val in z[1:]:                             # merge fp-duplicate crossings
        if val - keep[-1] > tol:
            keep.append(float(val))
    return np.array(keep)


def pareto_frontier(
    profiles: Sequence[LLMProfile],
    queries: Sequence[Query],
    zetas: Sequence[float] | None = None,
    *,
    gamma: Sequence[float] | None = None,
    caps: Sequence[int] | None = None,
    costs: NormalizedCosts | None = None,
    breakpoints: bool = False,
    check: bool = False,
) -> ParetoFrontier:
    """Trace the Eq. 2 energy–runtime–accuracy frontier over ζ.

    The normalized cost matrices are built ONCE for the whole sweep; each
    ζ objective is one saxpy over them.  Modes:

      * ``breakpoints=True`` (unconstrained only): exact frontier — the ζ
        where the argmin assignment changes, plus one assignment per
        constant segment (evaluated at the segment midpoint, with pure
        argmin semantics: ``schedule(..., enforce_nonempty=False)``).
      * grid (default): one assignment per requested ζ.  Capacitated
        solves warm-start from the adjacent ζ's assignment through
        ``IncrementalScheduler``; unconstrained solves are the vectorized
        argmin of ``scheduler.schedule``.
    """
    if costs is None:
        costs = normalized_costs(profiles, queries)
    constrained = gamma is not None or caps is not None
    if breakpoints:
        if constrained:
            raise ValueError("exact breakpoints apply to the unconstrained "
                             "argmin; use a ζ grid for capacitated sweeps")
        bps = frontier_breakpoints(costs)
        edges = np.concatenate([[0.0], bps, [1.0]])
        mids = (edges[:-1] + edges[1:]) / 2.0
        asgs = []
        for z in mids:
            C = objective_matrix(costs, float(z))
            asgs.append(scheduler._evaluate(costs, C.argmin(axis=1),
                                            float(z), C=C))
        return ParetoFrontier(tuple(float(z) for z in mids), tuple(asgs),
                              tuple(float(b) for b in bps))

    if zetas is None:
        raise ValueError("grid mode needs zetas= (or pass breakpoints=True)")
    zs = [float(z) for z in zetas]
    order = np.argsort(zs, kind="stable")
    asg_by_pos: dict[int, Assignment] = {}
    if not constrained:
        for pos in order:
            asg_by_pos[pos] = scheduler.schedule(profiles, queries, zs[pos],
                                                 costs=costs)
    else:
        inc: IncrementalScheduler | None = None
        for pos in order:
            if inc is None:
                inc = IncrementalScheduler(profiles, queries, zs[pos],
                                           gamma, caps=caps, costs=costs,
                                           check=check)
                asg_by_pos[pos] = inc.assignment
            else:
                asg_by_pos[pos] = inc.reschedule(zeta=zs[pos])
    return ParetoFrontier(tuple(zs), tuple(asg_by_pos[i]
                                           for i in range(len(zs))), None)
