"""Workload-based energy / runtime / accuracy models (paper §4 and §6.2).

The paper's per-LLM models:

    e_K(τin, τout) = α0·τin + α1·τout + α2·τin·τout        (Eq. 6)
    r_K(τin, τout) = β0·τin + β1·τout + β2·τin·τout        (Eq. 7)
    a_K(τin, τout) = A_K·τin + A_K·τout                    (Eq. 1)

fit by OLS per model (Table 3), plus the normalized counterparts
ê_K, â_K ∈ [0, 1] used by the scheduler objective (Eq. 2).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Mapping, Sequence

import numpy as np

from repro.core import stats


Query = tuple[int, int]  # (tau_in, tau_out) — the paper's q = (τin, τout)


@dataclasses.dataclass(frozen=True)
class BilinearModel:
    """c0·τin + c1·τout + c2·τin·τout with fit diagnostics."""

    coeffs: tuple[float, float, float]
    r_squared: float = float("nan")
    f_statistic: float = float("nan")
    f_pvalue: float = float("nan")

    def __call__(self, tau_in, tau_out):
        c0, c1, c2 = self.coeffs
        tau_in = np.asarray(tau_in, dtype=np.float64)
        tau_out = np.asarray(tau_out, dtype=np.float64)
        return c0 * tau_in + c1 * tau_out + c2 * tau_in * tau_out

    @staticmethod
    def fit(
        tau_in: Sequence[float], tau_out: Sequence[float], y: Sequence[float]
    ) -> "BilinearModel":
        X = stats.bilinear_design(np.asarray(tau_in), np.asarray(tau_out))
        res = stats.ols(X, np.asarray(y, dtype=np.float64))
        return BilinearModel(
            coeffs=(float(res.params[0]), float(res.params[1]), float(res.params[2])),
            r_squared=res.r_squared,
            f_statistic=res.f_statistic,
            f_pvalue=res.f_pvalue,
        )

    def to_dict(self) -> dict:
        return {
            "coeffs": list(self.coeffs),
            "r_squared": self.r_squared,
            "f_statistic": self.f_statistic,
            "f_pvalue": self.f_pvalue,
        }

    @staticmethod
    def from_dict(d: Mapping) -> "BilinearModel":
        return BilinearModel(
            coeffs=tuple(d["coeffs"]),
            r_squared=d.get("r_squared", float("nan")),
            f_statistic=d.get("f_statistic", float("nan")),
            f_pvalue=d.get("f_pvalue", float("nan")),
        )


@dataclasses.dataclass(frozen=True)
class AccuracyModel:
    """a_K(τin, τout) = A_K·(τin + τout), A_K = leaderboard average (Eq. 1)."""

    a_k: float  # A_K in percent, e.g. 50.97 for Llama-2 7B

    def __call__(self, tau_in, tau_out):
        tau_in = np.asarray(tau_in, dtype=np.float64)
        tau_out = np.asarray(tau_out, dtype=np.float64)
        return self.a_k * tau_in + self.a_k * tau_out


@dataclasses.dataclass(frozen=True)
class LLMProfile:
    """Everything the scheduler needs to know about one hosted model K."""

    name: str
    energy: BilinearModel       # e_K, joules
    runtime: BilinearModel      # r_K, seconds
    accuracy: AccuracyModel     # a_K

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "energy": self.energy.to_dict(),
            "runtime": self.runtime.to_dict(),
            "a_k": self.accuracy.a_k,
        }

    @staticmethod
    def from_dict(d: Mapping) -> "LLMProfile":
        return LLMProfile(
            name=d["name"],
            energy=BilinearModel.from_dict(d["energy"]),
            runtime=BilinearModel.from_dict(d["runtime"]),
            accuracy=AccuracyModel(a_k=float(d["a_k"])),
        )


def fit_profile(
    name: str,
    a_k: float,
    tau_in: Sequence[float],
    tau_out: Sequence[float],
    energy_j: Sequence[float],
    runtime_s: Sequence[float],
) -> LLMProfile:
    """Fit e_K and r_K from a characterization campaign (paper §6.2)."""
    return LLMProfile(
        name=name,
        energy=BilinearModel.fit(tau_in, tau_out, energy_j),
        runtime=BilinearModel.fit(tau_in, tau_out, runtime_s),
        accuracy=AccuracyModel(a_k=a_k),
    )


# ---------------------------------------------------------------------------
# Normalization (the ê_K / â_K of Eq. 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NormalizedCosts:
    """Pre-computed ê_K(q) and â_K(q) for a workload × model-set.

    The paper: "we dynamically normalize our energy and accuracy measures
    across all the queries ... by dividing by the largest known value of
    energy and accuracy prior to optimization."
    """

    model_names: tuple[str, ...]
    queries: tuple[Query, ...]
    energy: np.ndarray          # (m, K) raw joules
    accuracy: np.ndarray        # (m, K) raw a_K values
    runtime: np.ndarray         # (m, K) raw seconds
    energy_hat: np.ndarray      # (m, K) in [0, 1]
    accuracy_hat: np.ndarray    # (m, K) in [0, 1]


def normalized_costs(
    profiles: Sequence[LLMProfile], queries: Sequence[Query]
) -> NormalizedCosts:
    tin = np.array([q[0] for q in queries], dtype=np.float64)
    tout = np.array([q[1] for q in queries], dtype=np.float64)
    energy = np.stack([p.energy(tin, tout) for p in profiles], axis=1)
    runtime = np.stack([p.runtime(tin, tout) for p in profiles], axis=1)
    acc = np.stack([p.accuracy(tin, tout) for p in profiles], axis=1)

    e_max = float(energy.max())
    a_max = float(acc.max())
    e_hat = energy / e_max if e_max > 0 else energy
    a_hat = acc / a_max if a_max > 0 else acc
    return NormalizedCosts(
        model_names=tuple(p.name for p in profiles),
        queries=tuple((int(a), int(b)) for a, b in queries),
        energy=energy,
        runtime=runtime,
        accuracy=acc,
        energy_hat=e_hat,
        accuracy_hat=a_hat,
    )


def objective_matrix(costs: NormalizedCosts, zeta: float) -> np.ndarray:
    """Per-(query, model) cost of Eq. 2: ζ·ê_K(q) − (1−ζ)·â_K(q)."""
    if not 0.0 <= zeta <= 1.0:
        raise ValueError(f"zeta must be in [0, 1], got {zeta}")
    return zeta * costs.energy_hat - (1.0 - zeta) * costs.accuracy_hat


# ---------------------------------------------------------------------------
# (De)serialization of a fitted fleet
# ---------------------------------------------------------------------------


def save_profiles(profiles: Sequence[LLMProfile], path: str) -> None:
    with open(path, "w") as f:
        json.dump([p.to_dict() for p in profiles], f, indent=2)


def load_profiles(path: str) -> list[LLMProfile]:
    with open(path) as f:
        return [LLMProfile.from_dict(d) for d in json.load(f)]
