"""Core contribution of the paper: workload-based energy/runtime models,
the statistics pipeline behind them, and the offline energy-optimal
scheduler."""

from repro.core.energy_model import (  # noqa: F401
    AccuracyModel,
    BilinearModel,
    LLMProfile,
    NormalizedCosts,
    Query,
    fit_profile,
    load_profiles,
    normalized_costs,
    objective_matrix,
    save_profiles,
)
from repro.core.scheduler import (  # noqa: F401
    Assignment,
    capacitated_optimality_certificate,
    schedule,
    schedule_capacitated,
    schedule_random,
    schedule_round_robin,
    schedule_single_model,
    zeta_sweep,
)
from repro.core.sweep import (  # noqa: F401
    IncrementalScheduler,
    ParetoFrontier,
    frontier_breakpoints,
    pareto_frontier,
)
