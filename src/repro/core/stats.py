"""Statistical machinery for the paper's modeling pipeline.

Implements, dependency-free (numpy only):

  * ordinary least squares with the summary statistics the paper reports
    (R^2, overall F-statistic, p-value) — Table 3 of the paper,
  * two-way ANOVA with interaction — Table 2 of the paper,
  * the F-distribution survival function via the regularized incomplete
    beta function (Lentz continued fraction), since scipy/statsmodels are
    not available in this environment,
  * Student-t critical values for the paper's §5.1.3 confidence-interval
    stopping criterion.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Special functions
# ---------------------------------------------------------------------------

_BETACF_MAX_ITER = 300
_BETACF_EPS = 3.0e-12
_BETACF_FPMIN = 1.0e-300


def _betacf(a: float, b: float, x: float) -> float:
    """Continued fraction for the incomplete beta function (Lentz)."""
    qab = a + b
    qap = a + 1.0
    qam = a - 1.0
    c = 1.0
    d = 1.0 - qab * x / qap
    if abs(d) < _BETACF_FPMIN:
        d = _BETACF_FPMIN
    d = 1.0 / d
    h = d
    for m in range(1, _BETACF_MAX_ITER + 1):
        m2 = 2 * m
        aa = m * (b - m) * x / ((qam + m2) * (a + m2))
        d = 1.0 + aa * d
        if abs(d) < _BETACF_FPMIN:
            d = _BETACF_FPMIN
        c = 1.0 + aa / c
        if abs(c) < _BETACF_FPMIN:
            c = _BETACF_FPMIN
        d = 1.0 / d
        h *= d * c
        aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2))
        d = 1.0 + aa * d
        if abs(d) < _BETACF_FPMIN:
            d = _BETACF_FPMIN
        c = 1.0 + aa / c
        if abs(c) < _BETACF_FPMIN:
            c = _BETACF_FPMIN
        d = 1.0 / d
        delta = d * c
        h *= delta
        if abs(delta - 1.0) < _BETACF_EPS:
            break
    return h


def betainc_reg(a: float, b: float, x: float) -> float:
    """Regularized incomplete beta function I_x(a, b)."""
    if x <= 0.0:
        return 0.0
    if x >= 1.0:
        return 1.0
    ln_front = (
        math.lgamma(a + b)
        - math.lgamma(a)
        - math.lgamma(b)
        + a * math.log(x)
        + b * math.log1p(-x)
    )
    front = math.exp(ln_front)
    if x < (a + 1.0) / (a + b + 2.0):
        return front * _betacf(a, b, x) / a
    return 1.0 - front * _betacf(b, a, 1.0 - x) / b


def f_sf(f_stat: float, dfn: float, dfd: float) -> float:
    """Survival function (p-value) of the F(dfn, dfd) distribution."""
    if not np.isfinite(f_stat):
        return 0.0
    if f_stat <= 0.0:
        return 1.0
    x = dfd / (dfd + dfn * f_stat)
    return betainc_reg(dfd / 2.0, dfn / 2.0, x)


def t_sf(t_stat: float, df: float) -> float:
    """Two-sided not — one-sided survival function of Student-t."""
    if not np.isfinite(t_stat):
        return 0.0
    x = df / (df + t_stat * t_stat)
    p = 0.5 * betainc_reg(df / 2.0, 0.5, x)
    return p if t_stat >= 0 else 1.0 - p


# 97.5% one-sided Student-t critical values, df = 1..30 (then ~normal).
_T975 = [
    12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228,
    2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086,
    2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
]


def t_critical_975(df: int) -> float:
    """t_{0.975, df} for the paper's 95% CI stopping rule."""
    if df < 1:
        return float("inf")
    if df <= 30:
        return _T975[df - 1]
    return 1.96


# ---------------------------------------------------------------------------
# Ordinary least squares (Table 3 of the paper)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OLSResult:
    """Fit summary mirroring what the paper reports per model."""

    params: np.ndarray          # (p,) coefficients
    bse: np.ndarray             # (p,) standard errors
    tvalues: np.ndarray         # (p,) per-coefficient t statistics
    pvalues: np.ndarray         # (p,) per-coefficient two-sided p-values
    r_squared: float            # uncentered when no intercept (statsmodels convention)
    f_statistic: float          # overall regression F
    f_pvalue: float
    df_model: int
    df_resid: int
    resid: np.ndarray

    def predict(self, X: np.ndarray) -> np.ndarray:
        return np.asarray(X, dtype=np.float64) @ self.params


def ols(X: np.ndarray, y: np.ndarray, *, has_intercept: bool = False) -> OLSResult:
    """OLS with summary statistics.

    The paper's e_K / r_K models (Eqs. 6–7) have NO intercept, so by default
    R^2 is the uncentered version — identical to what statsmodels' OLS
    reports for a model without a constant column, which is what the paper
    used (statsmodels v0.14.2).
    """
    X = np.asarray(X, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64).reshape(-1)
    n, p = X.shape
    if n <= p:
        raise ValueError(f"need more observations ({n}) than regressors ({p})")

    params, _, rank, _ = np.linalg.lstsq(X, y, rcond=None)
    if rank < p:
        raise ValueError("design matrix is rank deficient")
    fitted = X @ params
    resid = y - fitted
    ssr = float(resid @ resid)

    if has_intercept:
        sst = float(np.sum((y - y.mean()) ** 2))
        df_model = p - 1
    else:
        sst = float(y @ y)
        df_model = p
    df_resid = n - p
    r2 = 1.0 - ssr / sst if sst > 0 else 0.0

    sigma2 = ssr / df_resid if df_resid > 0 else np.nan
    xtx_inv = np.linalg.inv(X.T @ X)
    bse = np.sqrt(np.maximum(np.diag(xtx_inv) * sigma2, 0.0))
    with np.errstate(divide="ignore", invalid="ignore"):
        tvals = np.where(bse > 0, params / bse, np.inf)
    pvals = np.array([2.0 * t_sf(abs(t), df_resid) for t in tvals])

    if r2 >= 1.0:
        f_stat = float("inf")
    else:
        f_stat = (r2 / df_model) / ((1.0 - r2) / df_resid)
    f_p = f_sf(f_stat, df_model, df_resid)

    return OLSResult(
        params=params, bse=bse, tvalues=tvals, pvalues=pvals,
        r_squared=r2, f_statistic=f_stat, f_pvalue=f_p,
        df_model=df_model, df_resid=df_resid, resid=resid,
    )


def bilinear_design(tau_in: np.ndarray, tau_out: np.ndarray) -> np.ndarray:
    """Design matrix [τin, τout, τin·τout] of the paper's Eqs. 6–7."""
    tau_in = np.asarray(tau_in, dtype=np.float64).reshape(-1)
    tau_out = np.asarray(tau_out, dtype=np.float64).reshape(-1)
    return np.stack([tau_in, tau_out, tau_in * tau_out], axis=1)


# ---------------------------------------------------------------------------
# Two-way ANOVA with interaction (Table 2 of the paper)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AnovaRow:
    source: str
    sum_sq: float
    df: int
    f_statistic: float
    p_value: float


@dataclasses.dataclass(frozen=True)
class AnovaResult:
    factor_a: AnovaRow
    factor_b: AnovaRow
    interaction: AnovaRow
    residual_sum_sq: float
    residual_df: int

    def rows(self) -> list[AnovaRow]:
        return [self.factor_a, self.factor_b, self.interaction]


def anova_two_way(
    a_levels: Sequence,
    b_levels: Sequence,
    y: Sequence[float],
    *,
    a_name: str = "Input Tokens",
    b_name: str = "Output Tokens",
) -> AnovaResult:
    """Two-way ANOVA with interaction, via sequential (Type-I) sums of
    squares computed by nested OLS projections.  Handles unbalanced cells,
    which the paper's randomized-trial campaign produces.
    """
    a = np.asarray(a_levels)
    b = np.asarray(b_levels)
    y = np.asarray(y, dtype=np.float64).reshape(-1)
    if not (len(a) == len(b) == len(y)):
        raise ValueError("a_levels, b_levels and y must be the same length")
    n = len(y)

    ua, ia = np.unique(a, return_inverse=True)
    ub, ib = np.unique(b, return_inverse=True)
    na, nb = len(ua), len(ub)
    if na < 2 or nb < 2:
        raise ValueError("each factor needs at least 2 levels")

    def dummies(idx: np.ndarray, k: int) -> np.ndarray:
        # treatment coding, drop first level
        d = np.zeros((n, k - 1))
        for j in range(1, k):
            d[idx == j, j - 1] = 1.0
        return d

    one = np.ones((n, 1))
    da = dummies(ia, na)
    db = dummies(ib, nb)
    # interaction dummies
    dab = np.einsum("ni,nj->nij", da, db).reshape(n, -1)

    def rss(X: np.ndarray) -> tuple[float, int]:
        beta, _, rank, _ = np.linalg.lstsq(X, y, rcond=None)
        r = y - X @ beta
        return float(r @ r), int(rank)

    rss0, rk0 = rss(one)
    rss_a, rk_a = rss(np.hstack([one, da]))
    rss_ab, rk_ab = rss(np.hstack([one, da, db]))
    rss_full, rk_full = rss(np.hstack([one, da, db, dab]))

    ss_a, df_a = rss0 - rss_a, rk_a - rk0
    ss_b, df_b = rss_a - rss_ab, rk_ab - rk_a
    ss_i, df_i = rss_ab - rss_full, rk_full - rk_ab
    df_resid = n - rk_full
    if df_resid <= 0:
        raise ValueError("no residual degrees of freedom — need replicates")
    ms_e = rss_full / df_resid

    def row(name: str, ss: float, df: int) -> AnovaRow:
        f = (ss / df) / ms_e if df > 0 and ms_e > 0 else float("nan")
        p = f_sf(f, df, df_resid) if df > 0 else float("nan")
        return AnovaRow(source=name, sum_sq=ss, df=df, f_statistic=f, p_value=p)

    return AnovaResult(
        factor_a=row(a_name, ss_a, df_a),
        factor_b=row(b_name, ss_b, df_b),
        interaction=row("Interaction", ss_i, df_i),
        residual_sum_sq=rss_full,
        residual_df=df_resid,
    )


# ---------------------------------------------------------------------------
# Confidence-interval stopping rule (paper §5.1.3)
# ---------------------------------------------------------------------------


def ci_halfwidth_95(samples: Sequence[float]) -> float:
    """Half-width of the 95% CI of the mean of `samples`."""
    x = np.asarray(samples, dtype=np.float64)
    n = len(x)
    if n < 2:
        return float("inf")
    s = x.std(ddof=1)
    return t_critical_975(n - 1) * s / math.sqrt(n)


def ci_halfwidth_95_batch(samples: np.ndarray) -> np.ndarray:
    """Row-wise `ci_halfwidth_95` over a (conditions, trials) matrix —
    the vectorized form the batched characterization campaign uses to
    check the §5.1.3 stopping rule for a whole grid per call."""
    x = np.asarray(samples, dtype=np.float64)
    if x.ndim != 2:
        raise ValueError(f"need a (conditions, trials) matrix, got {x.shape}")
    n = x.shape[1]
    if n < 2:
        return np.full(x.shape[0], np.inf)
    s = x.std(axis=1, ddof=1)
    return t_critical_975(n - 1) * s / math.sqrt(n)


def should_stop_trials_batch(
    runtimes: np.ndarray, *, tolerance_s: float = 0.5, max_trials: int = 25
) -> np.ndarray:
    """Vectorized §5.1.3 stopping rule over a (conditions, trials) matrix
    (every row has the same trial count, as in round-based batched
    campaigns).  Returns a boolean mask of conditions that may stop."""
    x = np.asarray(runtimes, dtype=np.float64)
    if x.shape[1] >= max_trials:
        return np.ones(x.shape[0], dtype=bool)
    return ci_halfwidth_95_batch(x) <= tolerance_s


def should_stop_trials(
    runtimes: Sequence[float], *, tolerance_s: float = 0.5, max_trials: int = 25
) -> bool:
    """Paper §5.1.3: stop when the runtime CI half-width is within 0.5 s at
    95% confidence, or when 25 trials have been run."""
    if len(runtimes) >= max_trials:
        return True
    return ci_halfwidth_95(runtimes) <= tolerance_s
