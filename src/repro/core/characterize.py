"""Characterization campaign driver (paper §5.1).

Runs the paper's three experimental conditions against any measurement
backend (`measure(tau_in, tau_out) -> (energy_j, runtime_s)`):

  * vary-input:  τin ∈ {8 … 2048} powers of two, τout = 32      (§5.1.1)
  * vary-output: τout ∈ {8 … 4096} powers of two, τin = 32      (§5.1.2)
  * grid:        τin, τout ∈ {8 … 2048} powers of two           (§6.1, ANOVA)

with randomized trial order and the CI stopping criterion of §5.1.3
(95% CI half-width ≤ 0.5 s, at most 25 trials).
"""

from __future__ import annotations

import dataclasses
import random
from typing import Callable, Iterable, Sequence

import numpy as np

from repro.core import stats
from repro.core.energy_model import LLMProfile, fit_profile

MeasureFn = Callable[[int, int], tuple[float, float]]  # -> (energy_j, runtime_s)
# arrays of (tau_in, tau_out) -> (energy_j[], runtime_s[])
MeasureBatchFn = Callable[[np.ndarray, np.ndarray], tuple[np.ndarray, np.ndarray]]


@dataclasses.dataclass(frozen=True)
class Trial:
    model: str
    condition: str          # "vary_input" | "vary_output" | "grid"
    tau_in: int
    tau_out: int
    trial_index: int
    energy_j: float
    runtime_s: float


@dataclasses.dataclass(frozen=True)
class CampaignSettings:
    vary_input_range: tuple[int, int] = (8, 2048)    # §5.1.1
    vary_input_fixed_out: int = 32
    vary_output_range: tuple[int, int] = (8, 4096)   # §5.1.2
    vary_output_fixed_in: int = 32
    grid_range: tuple[int, int] = (8, 2048)          # §6.1
    ci_tolerance_s: float = 0.5                      # §5.1.3 (i)
    max_trials: int = 25                             # §5.1.3 (ii)
    min_trials: int = 2
    seed: int = 0


def _pow2_levels(lo: int, hi: int) -> list[int]:
    out = []
    v = lo
    while v <= hi:
        out.append(v)
        v *= 2
    return out


def _conditions(settings: CampaignSettings) -> list[tuple[str, int, int]]:
    conds: list[tuple[str, int, int]] = []
    for tin in _pow2_levels(*settings.vary_input_range):
        conds.append(("vary_input", tin, settings.vary_input_fixed_out))
    for tout in _pow2_levels(*settings.vary_output_range):
        conds.append(("vary_output", settings.vary_output_fixed_in, tout))
    for tin in _pow2_levels(*settings.grid_range):
        for tout in _pow2_levels(*settings.grid_range):
            conds.append(("grid", tin, tout))
    return conds


def run_campaign(
    model_name: str,
    measure: MeasureFn | None,
    settings: CampaignSettings = CampaignSettings(),
    *,
    measure_batch: MeasureBatchFn | None = None,
) -> list[Trial]:
    """Run the full §5.1 campaign for one model; returns all trials.

    With `measure` (scalar backend) trials run one (τin, τout, trial) at a
    time.  With `measure_batch` (e.g. `AnalyticLLMSimulator.measure_batch`)
    the campaign runs round-based: every still-active condition gets its
    next trial from ONE vectorized call per round, and the §5.1.3 stopping
    rule is checked for the whole grid at once
    (`stats.should_stop_trials_batch`) — the same adaptive-trial semantics,
    orders of magnitude fewer backend calls."""
    rng = random.Random(settings.seed)
    conds = _conditions(settings)
    rng.shuffle(conds)  # §5.1.3 randomized order

    if measure_batch is not None:
        return _run_campaign_batched(model_name, measure_batch, conds, settings)
    if measure is None:
        raise ValueError("need a measure or measure_batch backend")

    trials: list[Trial] = []
    for condition, tin, tout in conds:
        runtimes: list[float] = []
        while True:
            energy, runtime = measure(tin, tout)
            trials.append(
                Trial(
                    model=model_name,
                    condition=condition,
                    tau_in=tin,
                    tau_out=tout,
                    trial_index=len(runtimes),
                    energy_j=float(energy),
                    runtime_s=float(runtime),
                )
            )
            runtimes.append(float(runtime))
            if len(runtimes) >= settings.min_trials and stats.should_stop_trials(
                runtimes,
                tolerance_s=settings.ci_tolerance_s,
                max_trials=settings.max_trials,
            ):
                break
    return trials


def _run_campaign_batched(
    model_name: str,
    measure_batch: MeasureBatchFn,
    conds: list[tuple[str, int, int]],
    settings: CampaignSettings,
) -> list[Trial]:
    """Round-based campaign: one `measure_batch` call per trial round.

    Every active condition has the same trial count within a round, so the
    stopping rule vectorizes over the whole (conditions, trials) matrix."""
    trials: list[Trial] = []
    active = list(range(len(conds)))
    runtime_hist: list[list[float]] = [[] for _ in conds]
    round_no = 0
    while active:
        tin = np.array([conds[c][1] for c in active], dtype=np.int64)
        tout = np.array([conds[c][2] for c in active], dtype=np.int64)
        energy, runtime = measure_batch(tin, tout)
        for c, e, r in zip(active, energy, runtime):
            condition, ti, to = conds[c]
            trials.append(
                Trial(
                    model=model_name,
                    condition=condition,
                    tau_in=ti,
                    tau_out=to,
                    trial_index=round_no,
                    energy_j=float(e),
                    runtime_s=float(r),
                )
            )
            runtime_hist[c].append(float(r))
        round_no += 1
        if round_no >= settings.min_trials:
            mat = np.array([runtime_hist[c] for c in active], dtype=np.float64)
            stop = stats.should_stop_trials_batch(
                mat,
                tolerance_s=settings.ci_tolerance_s,
                max_trials=settings.max_trials,
            )
            active = [c for c, s in zip(active, stop) if not s]
    return trials


def trials_to_arrays(
    trials: Iterable[Trial], *, conditions: Sequence[str] | None = None
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """(tau_in, tau_out, energy, runtime) arrays, optionally filtered."""
    sel = [
        t for t in trials if conditions is None or t.condition in conditions
    ]
    tin = np.array([t.tau_in for t in sel], dtype=np.float64)
    tout = np.array([t.tau_out for t in sel], dtype=np.float64)
    e = np.array([t.energy_j for t in sel], dtype=np.float64)
    r = np.array([t.runtime_s for t in sel], dtype=np.float64)
    return tin, tout, e, r


def fit_profile_from_trials(
    model_name: str, a_k: float, trials: Iterable[Trial]
) -> LLMProfile:
    """Fit the paper's Eq. 6/7 models from the grid condition (as §6.1/6.2:
    'grid search … to eliminate the bias of holding the input or output size
    constant')."""
    tin, tout, e, r = trials_to_arrays(trials, conditions=("grid",))
    if len(tin) == 0:  # fall back to all conditions
        tin, tout, e, r = trials_to_arrays(trials)
    return fit_profile(model_name, a_k, tin, tout, e, r)


def anova_from_trials(trials: Iterable[Trial]) -> dict[str, stats.AnovaResult]:
    """Two-way ANOVA on the grid data (paper Table 2), for energy & runtime.

    Aggregates across models as the paper does ('data aggregated across all
    models in Table 1').
    """
    sel = [t for t in trials if t.condition == "grid"]
    tin = [t.tau_in for t in sel]
    tout = [t.tau_out for t in sel]
    e = [t.energy_j for t in sel]
    r = [t.runtime_s for t in sel]
    return {
        "energy": stats.anova_two_way(tin, tout, e),
        "runtime": stats.anova_two_way(tin, tout, r),
    }


def trials_to_csv(trials: Iterable[Trial], path: str) -> None:
    with open(path, "w") as f:
        f.write("model,condition,tau_in,tau_out,trial_index,energy_j,runtime_s\n")
        for t in trials:
            f.write(
                f"{t.model},{t.condition},{t.tau_in},{t.tau_out},"
                f"{t.trial_index},{t.energy_j:.6f},{t.runtime_s:.6f}\n"
            )
