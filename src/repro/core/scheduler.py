"""Offline energy-optimal workload scheduling (paper §4, §6.3).

The paper encodes Eq. 2 as an ILP in PuLP.  The evaluated problem has a
transportation structure (each query assigned to exactly one model; per-model
share constraints), for which exact combinatorial algorithms exist:

  * ``schedule()`` — per-query argmin over the cost matrix.  This is the
    exact optimum of Eq. 2 subject only to coverage/disjointness (Eqs. 4–5);
    the strict-share constraint (Eq. 3: every model gets >0 queries) is
    repaired with minimum-regret swaps, which preserves optimality among
    feasible solutions when m >> K (argument: the repair chooses the global
    minimum extra cost over all ways to give a starved model one query).

  * ``schedule_capacitated()`` — γ-constrained variant (the paper's data
    center partition γ_K).  Two exact solvers:

      - method="chains" (default): successive shortest reassignment chains
        on the K-bin aggregated residual graph.  Start from the
        unconstrained argmin; while some model is over its cap, move one
        query along the cheapest surplus→deficit chain (arc (u,v) costs
        the minimum regret C[i,v] − C[i,u] over queries i currently on u,
        maintained in per-arc heaps; Floyd–Warshall over the K ≪ m bins
        finds the chain).  This is the successive-shortest-path min-cost
        flow algorithm run on the contracted network, so it terminates at
        an exact optimum — in O(surplus · (K³ + K log m)) instead of the
        per-query Dijkstra augmentations of the full flow network.

      - method="flow": the original ``_MinCostFlow`` (successive shortest
        augmenting paths with Johnson potentials on the full m-node
        network), kept as the reference oracle the fast path is asserted
        against.

    ``capacitated_optimality_certificate`` checks any assignment for
    residual negative cycles/chains — an O(Km + K³) exact LP-optimality
    certificate used by the perf suite at sizes where the oracle is too
    slow to run.

    ``schedule_capacitated(..., warm_start=prior_assignee)`` repairs an
    existing assignment instead of solving from scratch:
    negative-cycle/negative-chain canceling on the same K-bin residual
    graph (``_repair_assignment``), terminating exactly when the
    optimality certificate holds.  With a near-optimal prior (the previous
    ζ of a sweep, or a workload that changed by a few queries) the repair
    does O(delta) chain moves instead of O(m) — the substrate of
    ``repro.core.sweep``'s incremental re-planner.

Baselines from the paper's Figure 3: single-model, round-robin, random.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Sequence

import numpy as np

from repro.core.energy_model import (
    LLMProfile,
    NormalizedCosts,
    Query,
    normalized_costs,
    objective_matrix,
)


@dataclasses.dataclass(frozen=True)
class Assignment:
    """A disjoint partition of the workload Q into {Q_K} (Eqs. 4–5)."""

    model_names: tuple[str, ...]
    assignee: np.ndarray        # (m,) int — model index per query
    objective: float            # Eq. 2 value
    total_energy_j: float
    total_runtime_s: float
    total_accuracy: float       # Σ a_K(q) over assignment (paper's accuracy metric)
    mean_accuracy_ak: float     # workload-weighted mean A_K (plotted in Fig. 3c)

    def counts(self) -> np.ndarray:
        return np.bincount(self.assignee, minlength=len(self.model_names))


def _evaluate(
    costs: NormalizedCosts, assignee: np.ndarray, zeta: float,
    *, C: np.ndarray | None = None,
) -> Assignment:
    """Score an assignment.  Callers that already hold the ζ objective
    matrix pass it via `C` to avoid recomputing it (once per ζ in
    `zeta_sweep`)."""
    if C is None:
        C = objective_matrix(costs, zeta)
    m = len(assignee)
    rows = np.arange(m)
    obj = C[rows, assignee].sum()
    tin = np.array([q[0] for q in costs.queries], dtype=np.float64)
    tout = np.array([q[1] for q in costs.queries], dtype=np.float64)
    tok = tin + tout
    a_k_per_query = costs.accuracy[rows, assignee] / np.maximum(tok, 1.0)
    return Assignment(
        model_names=costs.model_names,
        assignee=assignee.copy(),
        objective=float(obj),
        total_energy_j=float(costs.energy[rows, assignee].sum()),
        total_runtime_s=float(costs.runtime[rows, assignee].sum()),
        total_accuracy=float(costs.accuracy[rows, assignee].sum()),
        mean_accuracy_ak=float(a_k_per_query.mean()),
    )


# ---------------------------------------------------------------------------
# Exact unconstrained (coverage-only) scheduler
# ---------------------------------------------------------------------------


def schedule(
    profiles: Sequence[LLMProfile],
    queries: Sequence[Query],
    zeta: float,
    *,
    enforce_nonempty: bool = True,
    costs: NormalizedCosts | None = None,
) -> Assignment:
    """Optimal partition for Eq. 2 (argmin per query + Eq. 3 repair)."""
    if costs is None:
        costs = normalized_costs(profiles, queries)
    C = objective_matrix(costs, zeta)
    m, k = C.shape
    assignee = C.argmin(axis=1)

    if enforce_nonempty and m >= k:
        counts = np.bincount(assignee, minlength=k)
        starved = np.nonzero(counts == 0)[0]
        if len(starved):
            # exact joint repair: assign one query to each starved model,
            # donors keep >= 1 — a small min-cost flow over the regrets
            # (greedy per-starved-model repair is not optimal when several
            # models are starved at once)
            n_s = len(starved)
            mcf = _MinCostFlow(1 + n_s + m + k + 1)
            src = 0
            snk = 1 + n_s + m + k
            base = C[np.arange(m), assignee]
            shift = float(np.max(C)) + 1.0  # make arc costs non-negative
            for si, s in enumerate(starved):
                mcf.add_edge(src, 1 + si, 1, 0.0)
                for i in range(m):
                    regret = float(C[i, s] - base[i])
                    mcf.add_edge(1 + si, 1 + n_s + i, 1, regret + shift)
            for i in range(m):
                mcf.add_edge(1 + n_s + i, 1 + n_s + m + int(assignee[i]), 1, 0.0)
            for j in range(k):
                cap = max(0, int(counts[j]) - 1)
                mcf.add_edge(1 + n_s + m + j, snk, cap, 0.0)
            flow, _ = mcf.min_cost_flow(src, snk, n_s)
            if flow == n_s:
                for si, s in enumerate(starved):
                    for e in mcf.graph[1 + si]:
                        v, cap, _, _ = e
                        if 1 + n_s <= v < 1 + n_s + m and cap == 0:
                            assignee[v - 1 - n_s] = s
                            break
    return _evaluate(costs, assignee, zeta, C=C)


def schedule_with_liveness(
    profiles: Sequence[LLMProfile],
    queries: Sequence[Query],
    zeta: float,
    live: np.ndarray,
    *,
    costs: NormalizedCosts | None = None,
) -> Assignment:
    """Failure-aware Eq. 2 optimum: per-query argmin restricted to *live*
    model columns.

    `live` is an (m, k) matrix: either a boolean mask — live[i, j] ==
    False means model j cannot serve query i on the realized fault trace
    (every hosting node permanently down from the query's arrival; see
    ``FaultTrace.down_forever_from``) — or integer *capacity counts*
    (surviving replicas, or surviving fault domains under correlated
    failures: the domain-masked form), where a column is masked exactly
    when its count is 0.  The unconstrained Eq. 2 separates per query,
    so masking columns keeps the solve an exact argmin — this is the
    offline bound replayed against the *same* fault trace the online
    policies faced, so the offline→online gap stays a true bound under
    failures.  A query with no live column falls back to the full row
    (the online fleet would abandon it; pricing it at its best model
    keeps the bound conservative)."""
    if costs is None:
        costs = normalized_costs(profiles, queries)
    C = objective_matrix(costs, zeta)
    if live.shape != C.shape:
        raise ValueError(f"live mask shape {live.shape} != {C.shape}")
    if live.dtype != np.bool_:
        if not np.issubdtype(live.dtype, np.integer):
            raise ValueError(
                f"live must be boolean or integer counts, got {live.dtype}")
        if (live < 0).any():
            raise ValueError("live counts must be >= 0")
        live = live > 0
    masked = np.where(live, C, np.inf)
    dead_rows = ~live.any(axis=1)
    if dead_rows.any():
        masked[dead_rows] = C[dead_rows]
    assignee = masked.argmin(axis=1)
    return _evaluate(costs, assignee, zeta, C=C)


def cached_costs(
    profiles: Sequence[LLMProfile],
    queries: Sequence[Query],
    cached: Sequence[int] | np.ndarray,
) -> NormalizedCosts:
    """Cost matrices conditioned on a realized KV prefix-cache hit
    sequence: query i's energy and runtime under every model are
    discounted by the profile-predicted cost of a prefill-only pass over
    its `cached[i]` warm tokens — the same prefix-difference contract the
    node charges (prefill(τin) − prefill(cached)), expressed through the
    fitted profiles so the offline replay prices cached prefills the way
    the online fleet did.  cached[i] == 0 leaves row i exactly unchanged;
    discounts never drive a cost below zero.  Accuracy is untouched (the
    cache changes where tokens come from, not what the model answers),
    and ê is re-normalized over the discounted matrix."""
    cached = np.asarray(cached, dtype=np.int64)
    if cached.shape != (len(queries),):
        raise ValueError(
            f"cached must have one entry per query: shape {cached.shape} "
            f"for {len(queries)} queries")
    if (cached < 0).any():
        raise ValueError("cached token counts must be >= 0")
    tin = np.array([q[0] for q in queries], dtype=np.int64)
    if (cached >= tin).any():
        raise ValueError("cached token counts must be < tau_in (a suffix "
                         "always remains to prefill)")
    base = normalized_costs(profiles, queries)
    if not cached.any():
        return base
    warm = cached > 0
    tin_c = cached.astype(np.float64)
    tout_c = np.zeros_like(tin_c)
    e_disc = np.stack([p.energy(tin_c, tout_c) for p in profiles], axis=1)
    r_disc = np.stack([p.runtime(tin_c, tout_c) for p in profiles], axis=1)
    e_disc[~warm] = 0.0
    r_disc[~warm] = 0.0
    energy = np.maximum(base.energy - e_disc, 0.0)
    runtime = np.maximum(base.runtime - r_disc, 0.0)
    e_max = float(energy.max())
    a_max = float(base.accuracy.max())
    return NormalizedCosts(
        model_names=base.model_names,
        queries=base.queries,
        energy=energy,
        runtime=runtime,
        accuracy=base.accuracy,
        energy_hat=energy / e_max if e_max > 0 else energy,
        accuracy_hat=(base.accuracy / a_max if a_max > 0
                      else base.accuracy),
    )


def schedule_with_cache(
    profiles: Sequence[LLMProfile],
    queries: Sequence[Query],
    zeta: float,
    cached: Sequence[int] | np.ndarray,
    *,
    costs: NormalizedCosts | None = None,
) -> Assignment:
    """Cache-aware Eq. 2 optimum: per-query argmin over the cost columns
    conditioned on the realized hit sequence (`cached_costs`).  The
    oracle bound stays valid because the *online* assignment is scored
    under the same discounted matrix (policies.objective_of_assignment
    with cached=): the row-wise argmin is ≤ any realized column choice
    by construction, whatever node the session-affinity router picked."""
    if costs is None:
        costs = cached_costs(profiles, queries, cached)
    C = objective_matrix(costs, zeta)
    assignee = C.argmin(axis=1)
    return _evaluate(costs, assignee, zeta, C=C)


# ---------------------------------------------------------------------------
# Capacity-constrained (γ partition) scheduler
# ---------------------------------------------------------------------------


def _capacities_from_gamma(gamma: Sequence[float], m: int) -> np.ndarray:
    g = np.asarray(gamma, dtype=np.float64)
    if abs(g.sum() - 1.0) > 1e-6:
        raise ValueError(f"gamma must sum to 1, got {g.sum()}")
    caps = np.floor(g * m).astype(int)
    # distribute the remainder to largest fractional parts
    rem = m - caps.sum()
    frac = g * m - np.floor(g * m)
    for j in np.argsort(-frac)[:rem]:
        caps[j] += 1
    return caps


class _MinCostFlow:
    """Successive shortest augmenting paths with Johnson potentials."""

    def __init__(self, n: int):
        self.n = n
        self.graph: list[list[list]] = [[] for _ in range(n)]  # [to, cap, cost, rev_idx]

    def add_edge(self, u: int, v: int, cap: int, cost: float) -> None:
        self.graph[u].append([v, cap, cost, len(self.graph[v])])
        self.graph[v].append([u, 0, -cost, len(self.graph[u]) - 1])

    def min_cost_flow(self, s: int, t: int, maxf: int) -> tuple[int, float]:
        n = self.n
        prevv = [0] * n
        preve = [0] * n
        INF = float("inf")
        flow, cost = 0, 0.0
        h = [0.0] * n  # potentials (all edge costs are >= 0 after row shift)
        while flow < maxf:
            dist = [INF] * n
            dist[s] = 0.0
            pq = [(0.0, s)]
            while pq:
                d, u = heapq.heappop(pq)
                if d > dist[u] + 1e-12:
                    continue
                for ei, e in enumerate(self.graph[u]):
                    v, cap, c, _ = e
                    if cap <= 0:
                        continue
                    nd = d + c + h[u] - h[v]
                    if nd < dist[v] - 1e-12:
                        dist[v] = nd
                        prevv[v] = u
                        preve[v] = ei
                        heapq.heappush(pq, (nd, v))
            if dist[t] == INF:
                break
            for i in range(n):
                if dist[i] < INF:
                    h[i] += dist[i]
            # bottleneck along path
            d = maxf - flow
            v = t
            while v != s:
                d = min(d, self.graph[prevv[v]][preve[v]][1])
                v = prevv[v]
            v = t
            while v != s:
                e = self.graph[prevv[v]][preve[v]]
                e[1] -= d
                self.graph[v][e[3]][1] += d
                cost += e[2] * d
                v = prevv[v]
            flow += d
        return flow, cost


def _solve_capacitated_flow(C: np.ndarray, caps: np.ndarray) -> np.ndarray:
    """Reference oracle: exact min-cost flow on the full m-node network."""
    m, k = C.shape
    # Row-shift so all arc costs are non-negative (doesn't change argmin
    # structure: every query is assigned exactly once).
    shift = C.min(axis=1, keepdims=True)
    Cs = C - shift

    # nodes: 0 = source, 1..m = queries, m+1..m+k = models, m+k+1 = sink
    mcf = _MinCostFlow(m + k + 2)
    src, snk = 0, m + k + 1
    for i in range(m):
        mcf.add_edge(src, 1 + i, 1, 0.0)
        for j in range(k):
            mcf.add_edge(1 + i, 1 + m + j, 1, float(Cs[i, j]))
    for j in range(k):
        mcf.add_edge(1 + m + j, snk, int(caps[j]), 0.0)

    flow, _ = mcf.min_cost_flow(src, snk, m)
    if flow < m:
        raise RuntimeError(f"infeasible: routed {flow}/{m} queries")

    assignee = np.full(m, -1, dtype=int)
    for i in range(m):
        for e in mcf.graph[1 + i]:
            v, cap, _, _ = e
            if m + 1 <= v <= m + k and cap == 0:  # saturated forward arc
                assignee[i] = v - m - 1
                break
    assert (assignee >= 0).all()
    return assignee


class _ArcHeaps:
    """Lazy per-arc regret heaps over an assignment (the chains solver's
    and the warm-start repair's shared bookkeeping).

    ``heaps[u][v]`` holds (C[i,v] − C[i,u], i) for queries i assigned to u
    at push time; entries go stale when i moves (or is retired to bin −1)
    and are skipped lazily against the live ``assignee`` array, which is
    shared by reference with the caller."""

    def __init__(self, C: np.ndarray, assignee: np.ndarray, k: int,
                 n_rows: int | None = None):
        """`n_rows` bounds the initial scan (rows beyond it are treated as
        unassigned — callers holding capacity-sized buffers pass the used
        height; later `push` calls may register any row of C)."""
        self.C = C
        self.assignee = assignee
        self.k = k
        self.heaps: list[list[list]] = [[[] for _ in range(k)]
                                        for _ in range(k)]
        scan = assignee if n_rows is None else assignee[:n_rows]
        for u in range(k):
            idx = np.nonzero(scan == u)[0]
            if not len(idx):
                continue
            base = C[idx, u]
            for v in range(k):
                if v == u:
                    continue
                h = list(zip((C[idx, v] - base).tolist(), idx.tolist()))
                heapq.heapify(h)
                self.heaps[u][v] = h

    def arc_min(self, u: int, v: int):
        """(cost, query) of the current cheapest u→v reassignment."""
        h = self.heaps[u][v]
        a = self.assignee
        while h and a[h[0][1]] != u:
            heapq.heappop(h)
        return h[0] if h else None

    def push(self, i: int, v: int) -> None:
        """Register query i as newly assigned to bin v."""
        ci = self.C[i]
        bv = ci[v]
        for w in range(self.k):
            if w != v:
                heapq.heappush(self.heaps[v][w], (float(ci[w] - bv), i))

    def residual(self, counts: np.ndarray) -> list[list[float]]:
        """Current cheapest-regret matrix R (inf where no query to move)."""
        k = self.k
        INF = float("inf")
        R = [[INF] * k for _ in range(k)]
        for u in range(k):
            if counts[u] == 0:
                continue
            for v in range(k):
                if v != u:
                    top = self.arc_min(u, v)
                    if top is not None:
                        R[u][v] = top[0]
        return R


def _cheapest_chain(R: list[list[float]], k: int,
                    sources, targets) -> tuple[float, list[int]] | None:
    """Cheapest residual chain from any source bin to any target bin.

    Edge-count-bounded Bellman–Ford DP (≤ k−1 arcs) with per-level parent
    pointers: unlike Floyd–Warshall next-hop reconstruction, it cannot
    loop when fp rounding of tied path sums creates ~1e-19-weight residual
    cycles (degenerate workloads with many duplicate queries do this).
    Any cycle a pathological instance still smuggles into the parent chain
    is spliced out — the removed cycle weight is fp noise by the no-
    negative-cycle invariant, so the cost is unchanged up to ulps."""
    INF = float("inf")
    src = set(int(s) for s in sources)
    tgt = [int(t) for t in targets]
    if not src or not tgt:
        return None
    prev = [0.0 if v in src else INF for v in range(k)]
    pars: list[list[int]] = []
    best: tuple[float, int, int] | None = None   # (cost, n_edges, dest)
    for _ in range(1, k):
        cur = [INF] * k
        par = [-1] * k
        for u in range(k):
            pu = prev[u]
            if pu == INF:
                continue
            Ru = R[u]
            for v in range(k):
                w = Ru[v]
                if w < INF and pu + w < cur[v]:
                    cur[v] = pu + w
                    par[v] = u
        pars.append(par)
        for d in tgt:
            if cur[d] < INF and (best is None or cur[d] < best[0]):
                best = (cur[d], len(pars), d)
        prev = cur
    if best is None:
        return None
    cost, e, v = best
    path = [v]
    for level in range(e - 1, -1, -1):
        v = pars[level][v]
        path.append(v)
    path.reverse()
    while len(set(path)) != len(path):   # splice out fp-tie cycles
        seen: dict[int, int] = {}
        for i, b in enumerate(path):
            if b in seen:
                path = path[:seen[b]] + path[i:]
                break
            seen[b] = i
    return cost, path


def _solve_capacitated_chains(C: np.ndarray, caps: np.ndarray) -> np.ndarray:
    """Exact fast path exploiting k ≪ m: successive shortest reassignment
    chains on the k-bin aggregated residual graph.

    Starts from the unconstrained argmin (an ε=0-optimal pseudoflow for the
    transportation LP) and, while any bin exceeds its cap, moves one query
    along the cheapest chain from a surplus bin to a deficit bin.  Each
    chain is a shortest path in the residual graph, so reduced-cost
    optimality is preserved at every step (the classical correctness
    argument for successive-shortest-path min-cost flow with excesses) and
    the terminal feasible assignment is an exact optimum.
    """
    m, k = C.shape
    if int(caps.sum()) < m:
        raise RuntimeError(f"infeasible: capacities {caps.tolist()} < {m} queries")
    assignee = C.argmin(axis=1).astype(np.int64)
    counts = np.bincount(assignee, minlength=k)
    surplus = counts - caps
    n_moves = int(surplus[surplus > 0].sum())
    if n_moves == 0:
        return assignee

    arcs = _ArcHeaps(C, assignee, k)
    for _ in range(n_moves):
        R = arcs.residual(counts)
        found = _cheapest_chain(
            R, k,
            sources=[s for s in range(k) if counts[s] > caps[s]],
            targets=[d for d in range(k) if counts[d] < caps[d]])
        if found is None:
            raise RuntimeError("no augmenting chain — infeasible capacities")
        _, path = found
        # gather the chain's moves from the pre-move state, then apply
        moves = []
        for u, v in zip(path, path[1:]):
            top = arcs.arc_min(u, v)
            assert top is not None, "arc vanished mid-chain"
            moves.append((u, v, top[1]))
        for u, v, i in moves:
            assignee[i] = v
            counts[u] -= 1
            counts[v] += 1
            arcs.push(i, v)
    return assignee


def capacitated_optimality_certificate(
    C: np.ndarray, assignee: np.ndarray, caps: np.ndarray, *,
    tol: float | None = None,
) -> bool:
    """Exact LP-optimality check for a capacitated assignment.

    A feasible assignment is optimal iff the k-bin residual graph (arc
    (u,v) = cheapest regret of moving one query from u to v) has no
    negative cycle and no negative chain into a bin with spare capacity.
    O(km + k³) — usable at sizes where re-solving with the flow oracle is
    intractable."""
    m, k = C.shape
    counts = np.bincount(assignee, minlength=k)
    if (counts > caps).any():
        return False
    if tol is None:
        tol = 1e-9 * max(1.0, float(np.abs(C).max()))
    base = C[np.arange(m), assignee]
    R = np.full((k, k), np.inf)
    for u in range(k):
        mask = assignee == u
        if mask.any():
            R[u] = (C[mask] - base[mask, None]).min(axis=0)
    np.fill_diagonal(R, np.inf)
    dist = R.copy()
    np.fill_diagonal(dist, 0.0)
    for w in range(k):
        dist = np.minimum(dist, dist[:, [w]] + dist[[w], :])
    if (np.diag(dist) < -tol).any():          # improving cycle
        return False
    slack = np.nonzero(counts < caps)[0]
    if len(slack) and (dist[:, slack] < -tol).any():   # improving chain
        return False
    return True


def _find_negative_cycle(R: list[list[float]], k: int,
                         tol: float) -> list[int] | None:
    """Bellman–Ford negative-cycle detection on the k-bin residual graph.
    Returns the cycle as a bin sequence [b0, ..., bl] whose arcs are the
    consecutive pairs plus the closing (bl, b0), or None."""
    INF = float("inf")
    dist = [0.0] * k          # virtual source at distance 0 to every bin
    pred = [-1] * k
    x = -1
    for _ in range(k):
        x = -1
        for u in range(k):
            du = dist[u]
            Ru = R[u]
            for v in range(k):
                w = Ru[v]
                if w < INF and du + w < dist[v] - tol:
                    dist[v] = du + w
                    pred[v] = u
                    x = v
        if x < 0:
            return None
    for _ in range(k):        # walk into the cycle x is reachable from
        x = pred[x]
    cyc = [x]
    v = pred[x]
    while v != x:
        cyc.append(v)
        v = pred[v]
    cyc.reverse()             # arcs: (cyc[i], cyc[i+1]) and (cyc[-1], cyc[0])
    return cyc


def _repair_assignment(C: np.ndarray, caps: np.ndarray, assignee: np.ndarray,
                       *, tol: float | None = None) -> np.ndarray:
    """Exact repair of an arbitrary warm-start assignment to the optimum of
    the capacitated transportation LP.

    Restores feasibility (cheapest surplus→deficit chains) and optimality
    (negative-cycle / negative-chain canceling, Klein's algorithm on the
    k-bin aggregated residual graph), terminating exactly when
    ``capacitated_optimality_certificate`` holds.  Arc minima come from
    the same lazy ``_ArcHeaps`` the cold chains solver uses — O(k log m)
    per move after an O(mk) build — so a near-optimal warm start costs
    O(delta) chain moves, and even a far-from-optimal one (e.g. the
    normalizers shifted under a workload edit, re-ranking whole duplicate
    groups) stays a constant factor of the cold solve.  Termination is
    guaranteed: every cancellation strictly decreases the objective by
    more than ``tol`` at fixed counts, and every feasibility move strictly
    decreases total surplus."""
    m, k = C.shape
    if int(caps.sum()) < m:
        raise RuntimeError(f"infeasible: capacities {caps.tolist()} < {m} queries")
    assignee = np.asarray(assignee, dtype=np.int64).copy()
    if assignee.shape != (m,) or ((assignee < 0) | (assignee >= k)).any():
        raise ValueError("warm_start must be an (m,) array of bin indices")
    if tol is None:
        tol = 1e-12 * max(1.0, float(np.abs(C).max()))
    arcs = _ArcHeaps(C, assignee, k)
    _repair_live(caps, assignee, arcs, tol=tol, n_rows=m)
    return assignee


def _repair_live(caps: np.ndarray, assignee: np.ndarray, arcs: _ArcHeaps,
                 *, tol: float, n_rows: int) -> None:
    """The repair inner loop, in place over row-aligned buffers.

    `assignee` may be taller than the live workload and may hold −1
    sentinels (retired rows — skipped by the lazy heaps and excluded from
    counts); only rows < `n_rows` are scanned.  `arcs` must index the same
    (C, assignee) pair — passing a prebuilt instance is what lets
    ``sweep.IncrementalScheduler`` reuse its heaps across same-ζ delta
    repairs instead of rebuilding them O(mk) per call.  Terminates exactly
    when the ``capacitated_optimality_certificate`` conditions hold on the
    live rows (same argument as ``_repair_assignment``)."""
    k = len(caps)
    live = assignee[:n_rows]
    counts = np.bincount(live[live >= 0], minlength=k).astype(np.int64)
    m_live = int(counts.sum())
    if int(caps.sum()) < m_live:
        raise RuntimeError(
            f"infeasible: capacities {caps.tolist()} < {m_live} queries")

    def apply_moves(path: list[int], cyclic: bool) -> None:
        pairs = list(zip(path, path[1:]))
        if cyclic:
            pairs.append((path[-1], path[0]))
        # gather every move from the pre-move state, then apply (a query
        # entering bin v mid-chain must not be re-moved by the (v, w) arc)
        moves = []
        for u, v in pairs:
            top = arcs.arc_min(u, v)
            assert top is not None, "stale residual arc"
            moves.append((u, v, top[1]))
        for u, v, i in moves:
            assert assignee[i] == u, "stale residual arc"
            assignee[i] = v
            counts[u] -= 1
            counts[v] += 1
            arcs.push(i, v)

    max_iter = 64 * (m_live + k * k) + 1024   # bug guard, not an algorithmic bound
    for _ in range(max_iter):
        R = arcs.residual(counts)
        cyc = _find_negative_cycle(R, k, tol)
        if cyc is not None:
            apply_moves(cyc, cyclic=True)
            continue
        surplus = np.nonzero(counts > caps)[0]
        deficit = [d for d in range(k) if counts[d] < caps[d]]
        if len(surplus):
            found = _cheapest_chain(R, k, sources=surplus, targets=deficit)
            if found is None:
                raise RuntimeError("no augmenting chain — infeasible capacities")
            apply_moves(found[1], cyclic=False)
            continue
        found = _cheapest_chain(R, k, sources=range(k), targets=deficit)
        if found is None or found[0] >= -tol:
            return               # certificate conditions hold — exact optimum
        apply_moves(found[1], cyclic=False)
    raise RuntimeError("warm-start repair did not converge (pathological C?)")


def schedule_capacitated(
    profiles: Sequence[LLMProfile],
    queries: Sequence[Query],
    zeta: float,
    gamma: Sequence[float] | None = None,
    *,
    costs: NormalizedCosts | None = None,
    method: str = "chains",
    caps: Sequence[int] | None = None,
    warm_start: np.ndarray | None = None,
) -> Assignment:
    """Exact optimum of Eq. 2 with |Q_K| ≤ γ_K·|Q| capacities.

    method="chains" (default) is the fast aggregated successive-shortest-
    path solver; method="flow" is the full min-cost-flow reference oracle.
    Both are exact — the perf suite and tests assert their objectives
    coincide.

    Capacities come from `gamma` (shares of m, the paper's γ_K) or an
    explicit integer `caps` vector — exactly one of the two.  With
    `warm_start=` (a prior (m,) assignee array, chains method only) the
    solution is repaired from the prior via `_repair_assignment` instead
    of re-solved; the result is still exact."""
    if costs is None:
        costs = normalized_costs(profiles, queries)
    C = objective_matrix(costs, zeta)
    m, k = C.shape
    if (gamma is None) == (caps is None):
        raise ValueError("pass exactly one of gamma= or caps=")
    if caps is None:
        caps_arr = _capacities_from_gamma(gamma, m)
    else:
        caps_arr = np.asarray(caps, dtype=np.int64)
        if caps_arr.shape != (k,) or (caps_arr < 0).any():
            raise ValueError(f"caps must be a non-negative ({k},) vector")
        if int(caps_arr.sum()) < m:
            raise ValueError(f"infeasible caps: sum {caps_arr.sum()} < {m}")
    if warm_start is not None:
        if method != "chains":
            raise ValueError("warm_start= requires method='chains'")
        assignee = _repair_assignment(C, caps_arr, warm_start)
    elif method == "chains":
        assignee = _solve_capacitated_chains(C, caps_arr)
    elif method == "flow":
        assignee = _solve_capacitated_flow(C, caps_arr)
    else:
        raise ValueError(f"unknown method {method!r}; use 'chains' or 'flow'")
    return _evaluate(costs, assignee, zeta, C=C)


# ---------------------------------------------------------------------------
# Replica-split capacities (multi-replica models over several nodes)
# ---------------------------------------------------------------------------


def replica_capacities(
    caps: Sequence[int], replica_counts: Sequence[int],
) -> tuple[np.ndarray, np.ndarray]:
    """Split per-model capacities into balanced per-replica capacities.

    Model K's bin (capacity caps[K]) is mapped onto its replica_counts[K]
    replicas: each gets ⌊caps[K]/R⌋ queries, the remainder going one each
    to the first replicas — totals are preserved exactly, so the
    replica-level transportation problem has the same model-level optimum
    as the unsplit one (replica columns are duplicates).  Returns
    (caps_rep (R_total,), model_of_replica (R_total,)) with replicas
    flattened model-major in registry order."""
    caps = np.asarray(caps, dtype=np.int64)
    rc = np.asarray(replica_counts, dtype=np.int64)
    if caps.shape != rc.shape:
        raise ValueError("caps and replica_counts must align per model")
    if (rc < 1).any():
        raise ValueError("every model needs at least one replica")
    if (caps < 0).any():
        raise ValueError("capacities must be non-negative")
    model_of = np.repeat(np.arange(len(caps)), rc)
    caps_rep = np.empty(int(rc.sum()), dtype=np.int64)
    pos = 0
    for c, r in zip(caps.tolist(), rc.tolist()):
        base, extra = divmod(c, r)
        caps_rep[pos:pos + r] = base
        caps_rep[pos:pos + extra] += 1
        pos += r
    return caps_rep, model_of


@dataclasses.dataclass(frozen=True)
class ReplicaAssignment:
    """A model-level Assignment plus the replica placement realizing it."""

    assignment: Assignment      # model-level view (objective, totals)
    replica_of: np.ndarray      # (m,) int — global replica index per query
    model_of_replica: np.ndarray  # (R,) int — model index of each replica
    replica_caps: np.ndarray    # (R,) int — per-replica capacity

    def replica_counts(self) -> np.ndarray:
        return np.bincount(self.replica_of,
                           minlength=len(self.model_of_replica))


def schedule_replicated(
    profiles: Sequence[LLMProfile],
    queries: Sequence[Query],
    zeta: float,
    replica_counts: Sequence[int],
    *,
    gamma: Sequence[float] | None = None,
    caps: Sequence[int] | None = None,
    costs: NormalizedCosts | None = None,
) -> ReplicaAssignment:
    """Replica-aware Eq. 2 optimum: each model's bin split over its
    replicas as balanced γ-shares, solved exactly on the expanded
    (duplicate-column) cost matrix with the chains solver.

    Capacity source, in precedence order: explicit integer `caps` per
    model; `gamma` shares of m (the paper's γ_K); or — the default — the
    realized counts of the *unconstrained* optimum (`schedule` with
    coverage/disjointness only), in which case the model-level objective
    is bit-identical to the unconstrained one (the argmin is feasible for
    its own counts) and only the placement across replicas is solved.
    That default is what keeps a replica-aware oracle a true lower bound
    on every online policy's objective.

    Exactness without an expanded solve: replicas of one model are
    duplicate columns of the cost matrix, so *any* caps-respecting
    placement of the model-level optimum is a replica-level optimum.  The
    model-level problem is solved once (schedule / schedule_capacitated —
    both exact), then each model's queries are dealt over its replicas
    round-robin in O(m); the resulting per-replica counts are the
    balanced split of the realized count, componentwise ≤ the balanced
    capacity split, so the caps always hold."""
    if costs is None:
        costs = normalized_costs(profiles, queries)
    m = len(costs.queries)
    k = len(costs.model_names)
    if len(replica_counts) != k:
        raise ValueError("replica_counts must have one entry per model")
    if gamma is not None and caps is not None:
        raise ValueError("pass at most one of gamma= or caps=")
    if caps is not None:
        caps_model = np.asarray(caps, dtype=np.int64)
        if caps_model.shape != (k,) or (caps_model < 0).any():
            raise ValueError(f"caps must be a non-negative ({k},) vector")
        if int(caps_model.sum()) < m:
            raise ValueError(f"infeasible caps: sum {caps_model.sum()} < {m}")
        base = schedule_capacitated(profiles, queries, zeta,
                                    caps=caps_model, costs=costs)
    elif gamma is not None:
        caps_model = _capacities_from_gamma(gamma, m)
        base = schedule_capacitated(profiles, queries, zeta, gamma,
                                    costs=costs)
    else:
        base = schedule(profiles, queries, zeta,
                        enforce_nonempty=False, costs=costs)
        caps_model = base.counts()
    caps_rep, model_of = replica_capacities(caps_model, replica_counts)
    rc = np.asarray(replica_counts, dtype=np.int64)
    rep_start = np.concatenate([[0], np.cumsum(rc)])
    rep_assignee = np.empty(m, dtype=np.int64)
    for j in range(k):
        idx = np.nonzero(base.assignee == j)[0]
        rep_assignee[idx] = rep_start[j] + np.arange(len(idx)) % rc[j]
    return ReplicaAssignment(
        assignment=base,
        replica_of=rep_assignee,
        model_of_replica=model_of,
        replica_caps=caps_rep,
    )


# ---------------------------------------------------------------------------
# Baselines (paper Fig. 3 constant lines)
# ---------------------------------------------------------------------------


def schedule_single_model(
    profiles: Sequence[LLMProfile],
    queries: Sequence[Query],
    model_index: int,
    *,
    zeta: float = 0.5,
    costs: NormalizedCosts | None = None,
) -> Assignment:
    if costs is None:
        costs = normalized_costs(profiles, queries)
    assignee = np.full(len(queries), model_index, dtype=int)
    return _evaluate(costs, assignee, zeta)


def schedule_round_robin(
    profiles: Sequence[LLMProfile],
    queries: Sequence[Query],
    *,
    zeta: float = 0.5,
    costs: NormalizedCosts | None = None,
) -> Assignment:
    if costs is None:
        costs = normalized_costs(profiles, queries)
    assignee = np.arange(len(queries)) % len(profiles)
    return _evaluate(costs, assignee, zeta)


def schedule_random(
    profiles: Sequence[LLMProfile],
    queries: Sequence[Query],
    *,
    zeta: float = 0.5,
    seed: int = 0,
    costs: NormalizedCosts | None = None,
) -> Assignment:
    if costs is None:
        costs = normalized_costs(profiles, queries)
    rng = np.random.default_rng(seed)
    assignee = rng.integers(0, len(profiles), size=len(queries))
    return _evaluate(costs, assignee, zeta)


def zeta_sweep(
    profiles: Sequence[LLMProfile],
    queries: Sequence[Query],
    zetas: Sequence[float],
    *,
    gamma: Sequence[float] | None = None,
) -> list[Assignment]:
    """The paper's Figure 3 sweep: one Assignment per ζ value.

    Cold solve per ζ (kept as the simple reference); the streaming engine
    with warm-start reuse across adjacent ζ and exact frontier breakpoints
    is ``repro.core.sweep.pareto_frontier``."""
    costs = normalized_costs(profiles, queries)
    out = []
    for z in zetas:
        if gamma is None:
            out.append(schedule(profiles, queries, z, costs=costs))
        else:
            out.append(schedule_capacitated(profiles, queries, z, gamma, costs=costs))
    return out
