"""qwen3-1.7b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B family].

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    long_context_window=8192,
    microbatch=32,
    param_dtype="bfloat16",
    source="hf:Qwen/Qwen3-8B (scaled per assignment)",
    accuracy_ak=62.0,
    n_params_note="~1.7B",
)
