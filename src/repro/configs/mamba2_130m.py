"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

24L d_model=768 (attention-free) vocab=50280, ssm_state=128.
d_inner = 2*768 = 1536, headdim 64 -> 24 SSD heads, 1 group.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,                   # attention-free
    n_kv_heads=0,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    ssm_chunk=256,
    conv_kernel=4,
    microbatch=0,
    param_dtype="bfloat16",
    source="arXiv:2405.21060",
    accuracy_ak=35.0,
    n_params_note="~130M",
)
