"""internvl2-2b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.  The InternViT
vision tower is stubbed; input_specs() provides patch embeddings
[B, 256, 1024] consumed through the MLP projector.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    rope_theta=1e6,             # InternLM2
    n_patches=256,
    long_context_window=8192,   # sliding-window variant for long_500k
    microbatch=32,
    param_dtype="bfloat16",
    source="arXiv:2404.16821",
    accuracy_ak=55.0,
    n_params_note="~2.2B incl. stubbed ViT",
)
