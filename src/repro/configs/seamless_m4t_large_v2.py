"""seamless-m4t-large-v2 [audio] — enc-dec, multimodal [arXiv:2308.11596].

24L d_model=1024 16H (kv=16, MHA) d_ff=8192 vocab=256206.  Interpreted as
24 encoder + 24 decoder layers (the NLLB-style text backbone of M4T-large);
the speech frontend is stubbed — input_specs() provides frame embeddings
[B, n_frames, d_model].
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=48,                 # 24 enc + 24 dec
    enc_layers=24,
    dec_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    n_frames=4096,
    long_context_window=8192,
    microbatch=32,
    param_dtype="bfloat16",
    source="arXiv:2308.11596",
    accuracy_ak=52.0,
    n_params_note="~2.3B backbone",
)
