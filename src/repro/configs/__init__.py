"""Config registry: 10 assigned architectures (+ the paper's 7-model zoo)
selectable by --arch id, plus reduced smoke variants and input shapes."""

from __future__ import annotations

import importlib

from repro.configs.paper_zoo import (  # noqa: F401
    CASE_STUDY_GAMMA,
    CASE_STUDY_MODELS,
    PAPER_ZOO,
    TABLE1,
)
from repro.configs.reduced import reduce_config  # noqa: F401
from repro.configs.shapes import (  # noqa: F401
    INPUT_SHAPES,
    InputShape,
    long_context_note,
    token_specs,
)
from repro.models.common import ModelConfig

# arch id -> module (one file per assigned architecture)
_ASSIGNED_MODULES = {
    "internvl2-2b": "internvl2_2b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "mamba2-130m": "mamba2_130m",
    "qwen2.5-14b": "qwen2_5_14b",
    "deepseek-67b": "deepseek_67b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "llama3.2-3b": "llama3_2_3b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "qwen3-1.7b": "qwen3_1_7b",
}

ASSIGNED_ARCHS = tuple(_ASSIGNED_MODULES)


def get_config(arch: str) -> ModelConfig:
    """Resolve an --arch id (assigned archs, paper zoo, or '<id>-reduced')."""
    if arch.endswith("-reduced"):
        return reduce_config(get_config(arch[: -len("-reduced")]))
    if arch in _ASSIGNED_MODULES:
        mod = importlib.import_module(f"repro.configs.{_ASSIGNED_MODULES[arch]}")
        return mod.CONFIG
    if arch in PAPER_ZOO:
        return PAPER_ZOO[arch]
    raise KeyError(
        f"unknown arch {arch!r}; assigned={sorted(_ASSIGNED_MODULES)}, "
        f"paper zoo={sorted(PAPER_ZOO)}")


def list_archs() -> list[str]:
    return sorted(_ASSIGNED_MODULES) + sorted(PAPER_ZOO)
