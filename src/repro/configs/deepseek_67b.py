"""deepseek-67b [dense] — llama-arch [arXiv:2401.02954].

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102400,
    rope_theta=10000.0,
    long_context_window=8192,
    microbatch=32,
    param_dtype="bfloat16",
    source="arXiv:2401.02954",
    accuracy_ak=66.0,
    n_params_note="~67B",
)
