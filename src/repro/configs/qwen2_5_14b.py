"""qwen2.5-14b [dense] — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B family].

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    long_context_window=8192,
    microbatch=32,
    param_dtype="bfloat16",
    source="hf:Qwen/Qwen2.5-0.5B (scaled per assignment)",
    accuracy_ak=63.0,
    n_params_note="~14B",
)
