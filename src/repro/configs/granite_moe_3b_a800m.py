"""granite-moe-3b-a800m [moe] — 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base family].

32L d_model=1536 24H (GQA kv=8) d_ff=512 (expert width) vocab=49155,
MoE 40e top-8.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,                    # expert FFN width per the assignment
    vocab_size=49155,
    n_experts=40,
    top_k=8,
    capacity_factor=1.25,
    rope_theta=10000.0,
    long_context_window=8192,
    microbatch=32,
    param_dtype="bfloat16",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    accuracy_ak=48.0,
    n_params_note="~3B total, ~800M active",
)
