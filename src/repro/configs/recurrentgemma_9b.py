"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, (rec,rec,attn)
pattern [arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000; local window 2048.
38 = 12 x (rec, rec, attn) + 2 tail recurrent layers.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rec", "rec", "attn"),
    lru_width=4096,
    local_window=2048,
    conv_kernel=4,
    attn_logit_softcap=0.0,
    microbatch=32,
    param_dtype="bfloat16",
    source="arXiv:2402.19427",
    accuracy_ak=60.0,
    n_params_note="~9B",
)
