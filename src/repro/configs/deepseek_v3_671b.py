"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437].

61L d_model=7168 128H d_ff=2048 (expert width) vocab=129280, MoE 256e
top-8.  MLA dims per the V3 report: q LoRA 1536, kv LoRA 512, nope 128,
rope 64, v 128; first 3 layers dense (d_ff 18432).  Adafactor for train
(AdamW state cannot fit 256 chips x 16 GB for 671B params).
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=2048,                   # routed-expert width
    vocab_size=129280,
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    n_dense_layers=3,
    dense_d_ff=18432,
    capacity_factor=1.25,
    expert_shard_axes=("data", "model"),  # 256 experts over 256 chips
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    # Absorbed-matmul decode is integral to MLA (V3 report §2.1): the
    # latent cache only works if W_UK/W_UV are absorbed at decode.  The
    # expand-vs-absorb comparison is kept as an ablation lever in §Perf.
    mla_absorb=True,
    mtp=True,
    rope_theta=10000.0,
    long_context_window=8192,
    microbatch=32,
    grad_accum_dtype="bfloat16",
    optimizer="adafactor",
    param_dtype="bfloat16",
    source="arXiv:2412.19437",
    accuracy_ak=75.0,
    n_params_note="671B total, ~37B active",
)
