"""The four assigned input shapes and per-arch input_specs().

`input_specs(cfg, shape)` returns (kind, specs) where kind is
"train" | "prefill" | "decode" and specs is a dict of ShapeDtypeStructs
(no allocation — this is the dry-run contract).  Decode shapes lower
serve_step: ONE token against a cache of seq_len (window-bounded for the
long_500k sliding-window / recurrent modes).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.vlm import VISION_DIM


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode
    long_context: bool = False


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode", long_context=True),
}


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def token_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Model inputs (tokens / frontier-stub embeddings) for one step kind.
    The decode cache spec is produced separately via eval_shape on
    init_cache (see repro.launch.dryrun)."""
    B, S = shape.global_batch, shape.seq_len
    emb_dtype = jnp.dtype(cfg.param_dtype)

    if shape.kind == "train":
        if cfg.family == "vlm":
            s_txt = S - cfg.n_patches
            return {
                "patches": jax.ShapeDtypeStruct((B, cfg.n_patches, VISION_DIM), emb_dtype),
                "tokens": _i32(B, s_txt),
                "labels": _i32(B, s_txt),
            }
        if cfg.family == "encdec":
            return {
                "frames": jax.ShapeDtypeStruct((B, cfg.n_frames, cfg.d_model), emb_dtype),
                "tokens": _i32(B, S),
                "labels": _i32(B, S),
            }
        return {"tokens": _i32(B, S), "labels": _i32(B, S)}

    if shape.kind == "prefill":
        if cfg.family == "vlm":
            s_txt = S - cfg.n_patches
            return {
                "patches": jax.ShapeDtypeStruct((B, cfg.n_patches, VISION_DIM), emb_dtype),
                "tokens": _i32(B, s_txt),
            }
        if cfg.family == "encdec":
            return {
                "frames": jax.ShapeDtypeStruct((B, cfg.n_frames, cfg.d_model), emb_dtype),
                "tokens": _i32(B, S),
            }
        return {"tokens": _i32(B, S)}

    # decode: one token; the cache is a separate argument
    return {"token": _i32(B)}


def long_context_note(cfg: ModelConfig) -> str:
    """How each family runs the 524288-token decode (DESIGN.md §5)."""
    if cfg.family == "ssm":
        return "native (constant-size SSD state)"
    if cfg.family == "hybrid":
        return "native (RG-LRU state + local attention window)"
    return f"sliding_window({cfg.long_context_window})"
