"""Reduced same-family variants for CPU smoke tests and the real-execution
characterization campaign: <=2 layers, d_model<=512, <=4 experts, float32.

Each reduced config preserves the *family-defining structure* (GQA ratios,
MoE routing, MLA latents, SSD state, the (rec,rec,attn) pattern, enc-dec
split) so the smoke test exercises the same code paths as the full config.
"""

from __future__ import annotations

from repro.models.common import ModelConfig


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    kw: dict = dict(
        name=cfg.name + "-reduced",
        n_layers=2,
        d_model=256,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=512,
        param_dtype="float32",
        microbatch=0,
        remat=False,
        window=min(cfg.window, 64) if cfg.window else 0,
        long_context_window=64,
        n_frames=32,
    )
    if cfg.family in ("dense", "vlm", "encdec", "moe", "hybrid"):
        kw.update(n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)), head_dim=32)
    if cfg.family == "vlm":
        kw.update(n_patches=8)
    if cfg.family == "encdec":
        kw.update(enc_layers=2, dec_layers=2, n_layers=4, n_kv_heads=4)
    if cfg.family == "moe":
        kw.update(
            n_experts=4, top_k=2, d_expert=0, d_ff=128,
            n_dense_layers=1 if cfg.n_dense_layers else 0,
            dense_d_ff=256 if cfg.dense_d_ff else 0,
            expert_shard_axes=("model",),
            n_shared_experts=min(cfg.n_shared_experts, 1),
        )
        if cfg.use_mla:
            kw.update(q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=32,
                      qk_rope_dim=16, v_head_dim=32)
    if cfg.family == "ssm":
        kw.update(ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_ngroups=1,
                  ssm_chunk=16)
    if cfg.family == "hybrid":
        # 1 unit of (rec, rec, attn) + 2 tail rec layers = 5 layers
        kw.update(n_layers=5, lru_width=128, local_window=32, head_dim=64)
    return cfg.replace(**kw)
