"""The paper's own 7-model fleet (Table 1) with leaderboard accuracy A_K.

These configs feed the analytic energy simulator (full scale) and the CPU
characterization campaign (reduced scale).  Falcon's parallel-block detail
is approximated by the standard sequential residual block — the energy
model only needs parameter/FLOP/byte counts, which match.

| LLM (params)   | vRAM (GB) | # A100s | A_K (%) |
|----------------|-----------|---------|---------|
| Falcon 7B      | 14.48     | 1       | 44.17   |
| Falcon 40B     | 83.66     | 3       | 58.07   |
| Llama-2 7B     | 13.48     | 1       | 50.97   |
| Llama-2 13B    | 26.03     | 1       | 55.69   |
| Llama-2 70B    | 137.98    | 4       | 64.52   |
| Mistral 7B     | 15.00     | 1       | 60.97   |
| Mixtral 8x7B   | 93.37     | 3       | 68.47   |
"""

from repro.models.common import ModelConfig

# paper Table 1 metadata keyed by config name
TABLE1 = {
    "falcon-7b": {"vram_gb": 14.48, "n_a100": 1, "a_k": 44.17},
    "falcon-40b": {"vram_gb": 83.66, "n_a100": 3, "a_k": 58.07},
    "llama2-7b": {"vram_gb": 13.48, "n_a100": 1, "a_k": 50.97},
    "llama2-13b": {"vram_gb": 26.03, "n_a100": 1, "a_k": 55.69},
    "llama2-70b": {"vram_gb": 137.98, "n_a100": 4, "a_k": 64.52},
    "mistral-7b": {"vram_gb": 15.00, "n_a100": 1, "a_k": 60.97},
    "mixtral-8x7b": {"vram_gb": 93.37, "n_a100": 3, "a_k": 68.47},
}

# Falcon's MLP is 2 matrices of width 4d (8d^2 params); our SwiGLU block has
# 3 matrices (3*d*d_ff), so d_ff = 8d/3 keeps the parameter count (and hence
# weight traffic / FLOPs per token) faithful to the real model.
FALCON_7B = ModelConfig(
    name="falcon-7b", family="dense", n_layers=32, d_model=4544,
    n_heads=71, n_kv_heads=1, head_dim=64, d_ff=12096, vocab_size=65024,
    rope_theta=10000.0, param_dtype="bfloat16", accuracy_ak=44.17,
    source="tiiuae/falcon-7b", n_params_note="7B (MQA)")

FALCON_40B = ModelConfig(
    name="falcon-40b", family="dense", n_layers=60, d_model=8192,
    n_heads=128, n_kv_heads=8, head_dim=64, d_ff=21824, vocab_size=65024,
    rope_theta=10000.0, param_dtype="bfloat16", accuracy_ak=58.07,
    source="tiiuae/falcon-40b", n_params_note="40B (GQA)")

LLAMA2_7B = ModelConfig(
    name="llama2-7b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=32, head_dim=128, d_ff=11008, vocab_size=32000,
    rope_theta=10000.0, param_dtype="bfloat16", accuracy_ak=50.97,
    source="meta-llama/Llama-2-7b", n_params_note="7B (MHA)")

LLAMA2_13B = ModelConfig(
    name="llama2-13b", family="dense", n_layers=40, d_model=5120,
    n_heads=40, n_kv_heads=40, head_dim=128, d_ff=13824, vocab_size=32000,
    rope_theta=10000.0, param_dtype="bfloat16", accuracy_ak=55.69,
    source="meta-llama/Llama-2-13b", n_params_note="13B (MHA)")

LLAMA2_70B = ModelConfig(
    name="llama2-70b", family="dense", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=28672, vocab_size=32000,
    rope_theta=10000.0, param_dtype="bfloat16", accuracy_ak=64.52,
    source="meta-llama/Llama-2-70b", n_params_note="70B (GQA)")

MISTRAL_7B = ModelConfig(
    name="mistral-7b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab_size=32000,
    window=4096, rope_theta=10000.0, param_dtype="bfloat16",
    accuracy_ak=60.97, source="mistralai/Mistral-7B-v0.1",
    n_params_note="7B (SWA 4096)")

MIXTRAL_8X7B = ModelConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab_size=32000,
    n_experts=8, top_k=2, capacity_factor=1.25, rope_theta=10000.0,
    param_dtype="bfloat16", accuracy_ak=68.47,
    source="mistralai/Mixtral-8x7B-v0.1", n_params_note="47B total, 13B active")

PAPER_ZOO = {
    c.name: c for c in [
        FALCON_7B, FALCON_40B, LLAMA2_7B, LLAMA2_13B, LLAMA2_70B,
        MISTRAL_7B, MIXTRAL_8X7B,
    ]
}

# the three-model case study of §6.3
CASE_STUDY_MODELS = ("llama2-7b", "llama2-13b", "llama2-70b")
CASE_STUDY_GAMMA = (0.05, 0.2, 0.75)
