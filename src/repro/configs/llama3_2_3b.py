"""llama3.2-3b [dense] — small llama3 [hf:meta-llama/Llama-3.2-1B family].

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500000.0,
    long_context_window=8192,
    microbatch=32,
    param_dtype="bfloat16",
    source="hf:meta-llama/Llama-3.2-1B (scaled per assignment)",
    accuracy_ak=58.0,
    n_params_note="~3.2B",
)
