"""Checkpointing: save/restore parameter + optimizer-state pytrees.

Tensor data is written as raw .npy files inside a directory, with a JSON
manifest for the tree structure and dtypes (bf16 stored as uint16 views —
npy has no bfloat16).  Atomic via write-to-tmp + rename.  Restore places
arrays with jax.device_put against an optional sharding tree, so a
checkpoint written on one topology can be reloaded onto another (the specs
are re-resolved, not stored).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> list[tuple[str, Any]]:
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out.extend(_flatten(tree[k], f"{prefix}/{k}" if prefix else str(k)))
        return out
    return [(prefix, tree)]


def _unflatten(items: dict[str, Any]) -> dict:
    root: dict = {}
    for path, v in items.items():
        keys = path.split("/")
        node = root
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = v
    return root


def save_checkpoint(path: str | Path, tree: Any, *, step: int = 0,
                    metadata: dict | None = None) -> None:
    """Write `tree` (nested dict of arrays) to `path` atomically."""
    path = Path(path)
    tmp = Path(tempfile.mkdtemp(dir=path.parent if path.parent.exists() else None,
                                prefix=path.name + ".tmp"))
    manifest: dict = {"step": step, "metadata": metadata or {}, "tensors": {}}
    try:
        for i, (name, leaf) in enumerate(_flatten(tree)):
            arr = np.asarray(leaf)
            dtype = str(arr.dtype)
            if arr.dtype == jnp.bfloat16:
                arr = arr.view(np.uint16)
            elif "float8" in dtype:
                arr = arr.view(np.uint8)
            fname = f"t{i:05d}.npy"
            np.save(tmp / fname, arr)
            manifest["tensors"][name] = {"file": fname, "dtype": dtype}
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if path.exists():
            shutil.rmtree(path)
        os.replace(tmp, path)
    finally:
        if tmp.exists() and tmp != path:
            shutil.rmtree(tmp, ignore_errors=True)


def load_checkpoint(path: str | Path, *, shardings: Any | None = None
                    ) -> tuple[dict, int, dict]:
    """Returns (tree, step, metadata).  With `shardings` (same-structure
    pytree of jax Shardings), each array is device_put onto its sharding."""
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    shard_map = dict(_flatten(shardings)) if shardings is not None else {}
    items: dict[str, Any] = {}
    for name, info in manifest["tensors"].items():
        arr = np.load(path / info["file"])
        dtype = info["dtype"]
        if dtype == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        elif "float8" in dtype:
            arr = arr.view(jnp.dtype(dtype))
        sh = shard_map.get(name)
        items[name] = (jax.device_put(arr, sh) if sh is not None
                       else jnp.asarray(arr))
    return _unflatten(items), int(manifest["step"]), manifest["metadata"]


def latest_step(ckpt_dir: str | Path) -> int | None:
    """Highest step among `step_NNNNN` children of ckpt_dir."""
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.glob("step_*")
             if p.name.split("_")[1].isdigit()]
    return max(steps) if steps else None


def step_path(ckpt_dir: str | Path, step: int) -> Path:
    return Path(ckpt_dir) / f"step_{step:08d}"
