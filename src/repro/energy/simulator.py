"""Per-request analytic energy/runtime simulator (the NVML/uProf stand-in).

Integrates the structural cost model (repro.energy.costs) over a request's
lifetime on a Node using roofline timing:

    t_pass = max(flops / (n·peak·eff), bytes / (n·bw·eff)) + dispatch
    E_pass = idle_w·n·t_pass + e_flop·flops + e_byte·bytes + host

With kv_cache=False (the paper's measurement mode) each generated token
re-runs the full prefix — runtime/energy pick up τin·τout and τout²
terms, which is what makes the paper's interaction-term OLS non-vacuous.

Multiplicative log-normal noise gives trial-to-trial variance so the
§5.1.3 CI stopping rule operates as in the paper.

The decode phase is integrated in EXACT closed form: the per-step cost is
piecewise-polynomial in the context length L (repro.energy.costs.
decode_step_polys), so Σ over steps reduces to power sums per roofline
branch — O(#segments) instead of O(τout) Python-loop passes, and exact
where the old midpoint-chunk loop was approximate.  The loop survives as
`decode_cost_chunked` (chunk=1 is the exact per-step reference the closed
form is tested against).  Phase costs are memoized per
(context, steps, batch, frequency) so cluster simulations never
re-integrate a repeated decode segment, and `measure_batch` vectorizes
whole characterization grids per call (noise-stream-compatible with
sequential `measure`).

Per-phase DVFS: `prefill_cost`/`decode_cost` take `freq_scale=` — the
phase is priced at `node.accel.at_frequency(s)` (scaled peak_flops /
hbm_bw / dyn_w, fixed idle_w; FLOP/byte counts are frequency-invariant,
so the same piecewise-polynomial closed forms apply at any operating
point, with the roofline crossover re-solved under the scaled caps).
`best_prefill_frequency`/`best_decode_frequency` pick the energy-minimal
operating point analytically: one O(#segments) closed-form evaluation per
allowed scale, argmin over `accel.dvfs_scales` of phase energy plus any
time-proportional draw the caller charges per busy second (`extra_w`,
e.g. the host serving power).  No per-step simulation anywhere.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.energy import costs as costs_lib
from repro.energy.hardware import Node, SWING_NODE, min_accelerators
from repro.models import get_api
from repro.models.common import ModelConfig

_MEMO_MAX_ENTRIES = 1 << 17   # per-cache LRU bound

# Process-wide phase-cost memo store, keyed by the *physics token* — the
# exact set of inputs prefill_cost/decode_cost depend on besides their
# arguments: (model config, accelerator spec, accelerator count, dispatch
# overhead, kv-cache mode), all frozen dataclasses and hence value-hashable.
# Cluster campaigns rebuild pristine fleets per run (fresh_nodes /
# compare_policies), which used to reset every per-instance memo; two
# simulators with equal tokens compute bit-identical values, so sharing
# the (prefill, decode) dicts across instances only changes *when* a value
# is computed, never what it is.
_SHARED_MEMOS: dict[tuple, tuple[dict, dict]] = {}


def _shared_memos(token: tuple) -> tuple[dict, dict]:
    memos = _SHARED_MEMOS.get(token)
    if memos is None:
        memos = _SHARED_MEMOS[token] = ({}, {})
    return memos


def _lru_get(memo: dict, key):
    """Hit = move-to-end (dicts preserve insertion order, so the front is
    always the least-recently-used entry)."""
    out = memo.pop(key, None)
    if out is not None:
        memo[key] = out
    return out


def _lru_put(memo: dict, key, val, limit: int) -> None:
    """Insert, evicting the least-recently-used entry at the bound —
    wholesale clearing used to drop the hot keys mid-campaign."""
    if len(memo) >= limit:
        memo.pop(next(iter(memo)))
    memo[key] = val


@dataclasses.dataclass(frozen=True)
class PhaseBreakdown:
    prefill_s: float
    decode_s: float
    prefill_j: float
    decode_j: float
    host_j: float

    @property
    def runtime_s(self) -> float:
        return self.prefill_s + self.decode_s

    @property
    def energy_j(self) -> float:
        return self.prefill_j + self.decode_j + self.host_j


def _poly_sum(coeffs: tuple[float, float, float], u0: float, count: int) -> float:
    """Σ_{j=0}^{count-1} p(u0 + j) for p(u) = c0 + c1·u + c2·u² (exact
    power-sum form — the closed-form decode integral's workhorse)."""
    c0, c1, c2 = coeffs
    s1 = count * (count - 1) / 2.0                    # Σ j
    s2 = (count - 1) * count * (2 * count - 1) / 6.0  # Σ j²
    return (c0 * count
            + c1 * (count * u0 + s1)
            + c2 * (count * u0 * u0 + 2.0 * u0 * s1 + s2))


def _quad_roots_in(c2: float, c1: float, c0: float,
                   lo: float, hi: float) -> list[float]:
    """Real roots of c2·u² + c1·u + c0 strictly inside (lo, hi)."""
    roots: list[float] = []
    if c2 == 0.0:
        if c1 != 0.0:
            roots = [-c0 / c1]
    else:
        disc = c1 * c1 - 4.0 * c2 * c0
        if disc > 0.0:
            sq = math.sqrt(disc)
            q = -0.5 * (c1 + math.copysign(sq, c1)) if c1 != 0.0 else sq * 0.5
            r1 = q / c2
            r2 = c0 / q if q != 0.0 else r1
            roots = [r1, r2]
        elif disc == 0.0:
            roots = [-c1 / (2.0 * c2)]
    out = sorted({r for r in roots if lo < r < hi})
    return out


class AnalyticLLMSimulator:
    """measure(tau_in, tau_out) -> (energy_j, runtime_s) — plug-compatible
    with the characterization campaign."""

    def __init__(
        self,
        cfg: ModelConfig,
        node: Node = SWING_NODE,
        *,
        batch: int = 32,               # the paper fixes batch 32
        kv_cache: bool = False,        # the paper disables the KV cache
        noise_sigma: float = 0.015,
        seed: int = 0,
        decode_chunk: int = 256,       # chunk size of the legacy reference loop
        shared_memos: bool = True,     # join the process-wide phase-cost store
    ):
        self.cfg = cfg
        self.batch = batch
        self.kv_cache = kv_cache
        self.noise_sigma = noise_sigma
        self.rng = np.random.default_rng(seed)
        self.decode_chunk = decode_chunk

        api = get_api(cfg)
        pbytes = api.count_params(cfg) * (2 if cfg.param_dtype == "bfloat16" else 4)
        n = min_accelerators(pbytes, node.accel)
        self.node = node.with_accelerators(n)

        # phase-cost memos: repeated (context, steps, batch, freq) segments
        # are common in cluster sims (identical queries, completion-boundary
        # batching) and must not re-integrate.  LRU-bounded (move-to-end on
        # hit, evict-oldest on insert) so long campaigns keep hot keys.
        # Shared process-wide across simulators with the same physics token
        # so fresh fleets start warm (see _SHARED_MEMOS); pass
        # shared_memos=False for a private cache (tests that reason about
        # eviction, or a caller that shrinks _memo_max_entries and must not
        # thrash the global store).
        if shared_memos:
            self._prefill_memo, self._decode_memo = _shared_memos(
                (cfg, self.node.accel, self.node.n_accel,
                 self.node.dispatch_overhead_s, kv_cache))
        else:
            self._prefill_memo = {}
            self._decode_memo = {}
        self._memo_max_entries = _MEMO_MAX_ENTRIES
        # per-operating-point accelerator specs (freq_scale -> spec)
        self._accel_at: dict[float, object] = {1.0: self.node.accel}

    # ------------------------------------------------------------------
    def _accel(self, scale: float):
        spec = self._accel_at.get(scale)
        if spec is None:
            spec = self.node.accel.at_frequency(scale)
            self._accel_at[scale] = spec
        return spec

    def _pass_time_energy(self, pc: costs_lib.PassCosts,
                          scale: float = 1.0) -> tuple[float, float]:
        a = self._accel(scale)
        n = self.node.n_accel
        t_c = pc.flops / (n * a.peak_flops * a.flops_efficiency)
        t_m = pc.hbm_bytes / (n * a.hbm_bw * a.bw_efficiency)
        t = max(t_c, t_m) + self.node.dispatch_overhead_s
        e = (a.idle_w * n * t
             + a.j_per_flop * pc.flops
             + a.j_per_byte_hbm * pc.hbm_bytes)
        return t, e

    def _pass_time_energy_batch(
        self, pc: costs_lib.PassCostsBatch
    ) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized roofline timing/energy over arrays of pass costs."""
        a = self.node.accel
        n = self.node.n_accel
        t_c = pc.flops / (n * a.peak_flops * a.flops_efficiency)
        t_m = pc.hbm_bytes / (n * a.hbm_bw * a.bw_efficiency)
        t = np.maximum(t_c, t_m) + self.node.dispatch_overhead_s
        e = (a.idle_w * n * t
             + a.j_per_flop * pc.flops
             + a.j_per_byte_hbm * pc.hbm_bytes)
        return t, e

    # --- phase-level costs (the cluster simulator delegates to these) ----

    @property
    def host_power_w(self) -> float:
        """Host-side draw while serving (paper's EPYC uProf term)."""
        h = self.node.host
        return h.idle_w / 4.0 + h.active_w_per_core * h.serving_cores

    def prefill_cost(self, tau_in: int, batch: int | None = None,
                     *, freq_scale: float = 1.0) -> tuple[float, float]:
        """(seconds, accelerator joules) of one prefill pass over the prompt,
        priced at core-clock scale `freq_scale` (per-phase DVFS)."""
        B = self.batch if batch is None else batch
        key = (tau_in, B, freq_scale)
        out = _lru_get(self._prefill_memo, key)
        if out is None:
            pc = costs_lib.pass_costs(self.cfg, tau_in, tau_in, B, decode=False)
            out = self._pass_time_energy(pc, freq_scale)
            _lru_put(self._prefill_memo, key, out, self._memo_max_entries)
        return out

    def prefill_cost_batch(self, tau_in, batch: int | None = None
                           ) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized prefill_cost over an array of prompt lengths."""
        B = self.batch if batch is None else batch
        tin = np.asarray(tau_in, dtype=np.float64)
        pc = costs_lib.pass_costs_batch(self.cfg, tin, tin, B, decode=False)
        return self._pass_time_energy_batch(pc)

    # --- decode: exact closed-form integration ------------------------

    def decode_cost(self, ctx0: float, n_steps: int,
                    batch: int | None = None,
                    *, freq_scale: float = 1.0) -> tuple[float, float]:
        """(seconds, accelerator joules) of `n_steps` decode steps starting
        at absolute context length `ctx0` (= τin + tokens already generated),
        priced at core-clock scale `freq_scale` (per-phase DVFS).

        Exact: step t attends context L_t = ctx0 + t + ½ (the convention
        the per-step reference loop uses); the per-step cost is piecewise
        polynomial in L_t, so the phase total is evaluated in closed form
        via power sums per roofline branch (the compute/memory crossover is
        re-solved under the frequency-scaled caps).  Exactness makes the
        integral additive — decode_cost(c, a) + decode_cost(c+a, b) ==
        decode_cost(c, a+b) — which is what lets the cluster simulator's
        segment-split decode conserve energy against simulate()."""
        B = self.batch if batch is None else batch
        if n_steps <= 0:
            return 0.0, 0.0
        key = (ctx0, n_steps, B, freq_scale)
        out = _lru_get(self._decode_memo, key)
        if out is None:
            out = self._decode_closed_form(ctx0, n_steps, B, freq_scale)
            _lru_put(self._decode_memo, key, out, self._memo_max_entries)
        return out

    def _step_pass(self, L: float, B: float) -> costs_lib.PassCosts:
        if self.kv_cache:
            return costs_lib.pass_costs(self.cfg, 1, L, B, decode=True)
        # paper mode: re-run the full prefix for every generated token
        return costs_lib.pass_costs(self.cfg, L, L, B, decode=False)

    def _decode_closed_form(self, ctx0: float, n_steps: int, B: float,
                            scale: float = 1.0) -> tuple[float, float]:
        a = self._accel(scale)
        n = self.node.n_accel
        fcap = n * a.peak_flops * a.flops_efficiency
        bcap = n * a.hbm_bw * a.bw_efficiency

        base = ctx0 + 0.5                      # grid: L_t = base + t
        if n_steps <= 4:                       # tiny phases: sum directly
            t_dec = e_dec = 0.0
            for t in range(n_steps):
                t1, e1 = self._pass_time_energy(self._step_pass(base + t, B),
                                                scale)
                t_dec += t1
                e_dec += e1
            return t_dec, e_dec

        segs = costs_lib.decode_step_polys(
            self.cfg, B, base, base + (n_steps - 1),
            reprefix=not self.kv_cache)

        t_sum = 0.0          # Σ max(t_c, t_m), dispatch added at the end
        flops_sum = 0.0
        bytes_sum = 0.0
        t_begin = 0
        for si, seg in enumerate(segs):
            if si == len(segs) - 1:
                t_end = n_steps
            else:  # grid points with L ≤ seg.hi belong to this piece
                t_end = min(n_steps, int(math.floor(seg.hi - base)) + 1)
            t_end = max(t_end, t_begin)
            count = t_end - t_begin
            if count == 0:
                continue
            u0 = (base + t_begin) - seg.lo
            flops_sum += _poly_sum(seg.flops, u0, count)
            bytes_sum += _poly_sum(seg.hbm_bytes, u0, count)

            # roofline branch: q(u) = flops(u)/fcap − bytes(u)/bcap
            qc = tuple(f / fcap - b / bcap
                       for f, b in zip(seg.flops, seg.hbm_bytes))
            uhi = u0 + (count - 1)
            splits = _quad_roots_in(qc[2], qc[1], qc[0], u0, uhi)
            # sub-ranges in relative index j, split where q crosses zero
            edges = [0] + [min(count, max(0, int(math.ceil(r - u0))))
                           for r in splits] + [count]
            edges = sorted(set(edges))

            def q_at(j: int) -> float:
                u = u0 + j
                return qc[0] + qc[1] * u + qc[2] * u * u

            for j0, j1 in zip(edges, edges[1:]):
                if j1 <= j0:
                    continue
                probes = (q_at(j0), q_at((j0 + j1 - 1) // 2), q_at(j1 - 1))
                if all(p >= 0.0 for p in probes):
                    t_sum += _poly_sum(seg.flops, u0 + j0, j1 - j0) / fcap
                elif all(p <= 0.0 for p in probes):
                    t_sum += _poly_sum(seg.hbm_bytes, u0 + j0, j1 - j0) / bcap
                else:  # crossover landed inside despite the split: sum directly
                    for j in range(j0, j1):
                        u = u0 + j
                        fv = seg.flops[0] + seg.flops[1] * u + seg.flops[2] * u * u
                        bv = (seg.hbm_bytes[0] + seg.hbm_bytes[1] * u
                              + seg.hbm_bytes[2] * u * u)
                        t_sum += max(fv / fcap, bv / bcap)
            t_begin = t_end

        t_dec = t_sum + n_steps * self.node.dispatch_overhead_s
        e_dec = (a.idle_w * n * t_dec
                 + a.j_per_flop * flops_sum
                 + a.j_per_byte_hbm * bytes_sum)
        return t_dec, e_dec

    def decode_cost_chunked(self, ctx0: float, n_steps: int,
                            batch: int | None = None, *,
                            chunk: int | None = None,
                            freq_scale: float = 1.0) -> tuple[float, float]:
        """The legacy midpoint-chunk integration loop, kept as the reference
        the closed form is validated against: chunk=1 evaluates every step
        at its true context L = ctx0 + t + ½ (exact; what `decode_cost`
        reproduces), larger chunks approximate runs of steps by their
        midpoint (the pre-closed-form default)."""
        B = self.batch if batch is None else batch
        t_dec = 0.0
        e_dec = 0.0
        step = self.decode_chunk if chunk is None else chunk
        for t0 in range(0, n_steps, step):
            c = min(step, n_steps - t0)
            L = ctx0 + t0 + c / 2.0
            t1, e1 = self._pass_time_energy(self._step_pass(L, B), freq_scale)
            t_dec += t1 * c
            e_dec += e1 * c
        return t_dec, e_dec

    # --- per-phase DVFS governor --------------------------------------

    def _best_frequency(self, cost_at, extra_w: float
                        ) -> tuple[float, float, float]:
        """argmin over the accelerator's operating points of
        phase_energy + extra_w · phase_time, each candidate priced by one
        closed-form evaluation.  Ties break toward the higher clock (same
        energy, less latency).  Returns (scale, seconds, accel joules)."""
        best = None
        for s in self.node.accel.dvfs_scales:
            t, e = cost_at(s)
            tot = e + extra_w * t
            if best is None or tot < best[0] - 1e-12 * max(1.0, abs(best[0])):
                best = (tot, s, t, e)
            elif abs(tot - best[0]) <= 1e-12 * max(1.0, abs(best[0])) \
                    and s > best[1]:
                best = (tot, s, t, e)
        return best[1], best[2], best[3]

    def best_prefill_frequency(self, tau_in: int, batch: int | None = None,
                               *, extra_w: float = 0.0
                               ) -> tuple[float, float, float]:
        """Energy-minimal operating point for one prefill pass:
        (freq_scale, seconds, accelerator joules).  `extra_w` is any
        time-proportional power the caller charges per busy second (host
        serving draw) — it belongs in the argmin, else the governor
        underclocks into latency that costs more than it saves."""
        return self._best_frequency(
            lambda s: self.prefill_cost(tau_in, batch, freq_scale=s), extra_w)

    def best_decode_frequency(self, ctx0: float, n_steps: int,
                              batch: int | None = None,
                              *, extra_w: float = 0.0
                              ) -> tuple[float, float, float]:
        """Energy-minimal operating point for a decode segment:
        (freq_scale, seconds, accelerator joules)."""
        return self._best_frequency(
            lambda s: self.decode_cost(ctx0, n_steps, batch, freq_scale=s),
            extra_w)

    # ------------------------------------------------------------------

    def simulate(self, tau_in: int, tau_out: int) -> PhaseBreakdown:
        t_pre, e_pre = self.prefill_cost(tau_in)
        t_dec, e_dec = self.decode_cost(tau_in, tau_out)
        e_host = self.host_power_w * (t_pre + t_dec)
        return PhaseBreakdown(t_pre, t_dec, e_pre, e_dec, e_host)

    def measure(self, tau_in: int, tau_out: int) -> tuple[float, float]:
        pb = self.simulate(tau_in, tau_out)
        # np.exp (not math.exp) so the noise factors are bit-identical to
        # measure_batch's vectorized np.exp on the same generator stream
        noise = float(np.exp(self.rng.normal(0.0, self.noise_sigma)))
        noise2 = float(np.exp(self.rng.normal(0.0, self.noise_sigma)))
        return pb.energy_j * noise, pb.runtime_s * noise2

    def measure_batch(self, tau_in, tau_out) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized `measure` over arrays of (τin, τout): phase costs are
        computed once per unique pair (closed form + memo), and the noise
        draws consume the generator stream in the same order as the
        equivalent sequence of `measure` calls on the same pairs — one
        batched call is bit-identical to that call sequence.  (A batched
        *campaign* still differs from a sequential one: the round-based
        driver interleaves conditions, so the same draws land on
        different trials.)"""
        tin = np.atleast_1d(np.asarray(tau_in, dtype=np.int64))
        tout = np.atleast_1d(np.asarray(tau_out, dtype=np.int64))
        tin, tout = np.broadcast_arrays(tin, tout)
        pairs = np.stack([tin.ravel(), tout.ravel()], axis=1)
        uniq, inv = np.unique(pairs, axis=0, return_inverse=True)
        e_u = np.empty(len(uniq))
        r_u = np.empty(len(uniq))
        for i, (a, b) in enumerate(uniq):
            pb = self.simulate(int(a), int(b))
            e_u[i] = pb.energy_j
            r_u[i] = pb.runtime_s
        energy = e_u[inv]
        runtime = r_u[inv]
        draws = self.rng.normal(0.0, self.noise_sigma, size=2 * len(pairs))
        return (energy * np.exp(draws[0::2]),
                runtime * np.exp(draws[1::2]))

    # per-query (batch-normalized) versions used by the scheduler case study
    def measure_per_query(self, tau_in: int, tau_out: int) -> tuple[float, float]:
        e, r = self.measure(tau_in, tau_out)
        return e / self.batch, r / self.batch
