"""Per-request analytic energy/runtime simulator (the NVML/uProf stand-in).

Integrates the structural cost model (repro.energy.costs) over a request's
lifetime on a Node using roofline timing:

    t_pass = max(flops / (n·peak·eff), bytes / (n·bw·eff)) + dispatch
    E_pass = idle_w·n·t_pass + e_flop·flops + e_byte·bytes + host

With kv_cache=False (the paper's measurement mode) each generated token
re-runs the full prefix — runtime/energy pick up τin·τout and τout²
terms, which is what makes the paper's interaction-term OLS non-vacuous.

Multiplicative log-normal noise gives trial-to-trial variance so the
§5.1.3 CI stopping rule operates as in the paper.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.energy import costs as costs_lib
from repro.energy.hardware import Node, SWING_NODE, min_accelerators
from repro.models import get_api
from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class PhaseBreakdown:
    prefill_s: float
    decode_s: float
    prefill_j: float
    decode_j: float
    host_j: float

    @property
    def runtime_s(self) -> float:
        return self.prefill_s + self.decode_s

    @property
    def energy_j(self) -> float:
        return self.prefill_j + self.decode_j + self.host_j


class AnalyticLLMSimulator:
    """measure(tau_in, tau_out) -> (energy_j, runtime_s) — plug-compatible
    with the characterization campaign."""

    def __init__(
        self,
        cfg: ModelConfig,
        node: Node = SWING_NODE,
        *,
        batch: int = 32,               # the paper fixes batch 32
        kv_cache: bool = False,        # the paper disables the KV cache
        noise_sigma: float = 0.015,
        seed: int = 0,
        decode_chunk: int = 256,       # integrate decode in chunks for speed
    ):
        self.cfg = cfg
        self.batch = batch
        self.kv_cache = kv_cache
        self.noise_sigma = noise_sigma
        self.rng = np.random.default_rng(seed)
        self.decode_chunk = decode_chunk

        api = get_api(cfg)
        pbytes = api.count_params(cfg) * (2 if cfg.param_dtype == "bfloat16" else 4)
        n = min_accelerators(pbytes, node.accel)
        self.node = node.with_accelerators(n)

    # ------------------------------------------------------------------
    def _pass_time_energy(self, pc: costs_lib.PassCosts) -> tuple[float, float]:
        a = self.node.accel
        n = self.node.n_accel
        t_c = pc.flops / (n * a.peak_flops * a.flops_efficiency)
        t_m = pc.hbm_bytes / (n * a.hbm_bw * a.bw_efficiency)
        t = max(t_c, t_m) + self.node.dispatch_overhead_s
        e = (a.idle_w * n * t
             + a.j_per_flop * pc.flops
             + a.j_per_byte_hbm * pc.hbm_bytes)
        return t, e

    # --- phase-level costs (the cluster simulator delegates to these) ----

    @property
    def host_power_w(self) -> float:
        """Host-side draw while serving (paper's EPYC uProf term)."""
        h = self.node.host
        return h.idle_w / 4.0 + h.active_w_per_core * h.serving_cores

    def prefill_cost(self, tau_in: int, batch: int | None = None
                     ) -> tuple[float, float]:
        """(seconds, accelerator joules) of one prefill pass over the prompt."""
        B = self.batch if batch is None else batch
        pc = costs_lib.pass_costs(self.cfg, tau_in, tau_in, B)
        return self._pass_time_energy(pc)

    def decode_cost(self, ctx0: float, n_steps: int,
                    batch: int | None = None) -> tuple[float, float]:
        """(seconds, accelerator joules) of `n_steps` decode steps starting
        at absolute context length `ctx0` (= τin + tokens already generated).

        Integrated in self.decode_chunk chunks with midpoint context — calling
        this once with (tau_in, tau_out) reproduces simulate()'s decode phase
        exactly, which is what makes the cluster simulator's per-request
        energy conserve against the per-request simulator."""
        B = self.batch if batch is None else batch
        cfg = self.cfg
        t_dec = 0.0
        e_dec = 0.0
        step = self.decode_chunk
        for t0 in range(0, n_steps, step):
            n = min(step, n_steps - t0)
            L = ctx0 + t0 + n / 2.0
            if self.kv_cache:
                # one single-token pass per output token, growing context
                pc = costs_lib.pass_costs(cfg, 1, L, B)
            else:
                # paper mode: re-run the full prefix for every generated token
                pc = costs_lib.pass_costs(cfg, L, L, B)
            t1, e1 = self._pass_time_energy(pc)
            t_dec += t1 * n
            e_dec += e1 * n
        return t_dec, e_dec

    def simulate(self, tau_in: int, tau_out: int) -> PhaseBreakdown:
        t_pre, e_pre = self.prefill_cost(tau_in)
        t_dec, e_dec = self.decode_cost(tau_in, tau_out)
        e_host = self.host_power_w * (t_pre + t_dec)
        return PhaseBreakdown(t_pre, t_dec, e_pre, e_dec, e_host)

    def measure(self, tau_in: int, tau_out: int) -> tuple[float, float]:
        pb = self.simulate(tau_in, tau_out)
        noise = math.exp(self.rng.normal(0.0, self.noise_sigma))
        noise2 = math.exp(self.rng.normal(0.0, self.noise_sigma))
        return pb.energy_j * noise, pb.runtime_s * noise2

    # per-query (batch-normalized) versions used by the scheduler case study
    def measure_per_query(self, tau_in: int, tau_out: int) -> tuple[float, float]:
        e, r = self.measure(tau_in, tau_out)
        return e / self.batch, r / self.batch
