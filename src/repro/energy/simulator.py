"""Per-request analytic energy/runtime simulator (the NVML/uProf stand-in).

Integrates the structural cost model (repro.energy.costs) over a request's
lifetime on a Node using roofline timing:

    t_pass = max(flops / (n·peak·eff), bytes / (n·bw·eff)) + dispatch
    E_pass = idle_w·n·t_pass + e_flop·flops + e_byte·bytes + host

With kv_cache=False (the paper's measurement mode) each generated token
re-runs the full prefix — runtime/energy pick up τin·τout and τout²
terms, which is what makes the paper's interaction-term OLS non-vacuous.

Multiplicative log-normal noise gives trial-to-trial variance so the
§5.1.3 CI stopping rule operates as in the paper.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.energy import costs as costs_lib
from repro.energy.hardware import Node, SWING_NODE, min_accelerators
from repro.models import get_api
from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class PhaseBreakdown:
    prefill_s: float
    decode_s: float
    prefill_j: float
    decode_j: float
    host_j: float

    @property
    def runtime_s(self) -> float:
        return self.prefill_s + self.decode_s

    @property
    def energy_j(self) -> float:
        return self.prefill_j + self.decode_j + self.host_j


class AnalyticLLMSimulator:
    """measure(tau_in, tau_out) -> (energy_j, runtime_s) — plug-compatible
    with the characterization campaign."""

    def __init__(
        self,
        cfg: ModelConfig,
        node: Node = SWING_NODE,
        *,
        batch: int = 32,               # the paper fixes batch 32
        kv_cache: bool = False,        # the paper disables the KV cache
        noise_sigma: float = 0.015,
        seed: int = 0,
        decode_chunk: int = 256,       # integrate decode in chunks for speed
    ):
        self.cfg = cfg
        self.batch = batch
        self.kv_cache = kv_cache
        self.noise_sigma = noise_sigma
        self.rng = np.random.default_rng(seed)
        self.decode_chunk = decode_chunk

        api = get_api(cfg)
        pbytes = api.count_params(cfg) * (2 if cfg.param_dtype == "bfloat16" else 4)
        n = min_accelerators(pbytes, node.accel)
        self.node = node.with_accelerators(n)

    # ------------------------------------------------------------------
    def _pass_time_energy(self, pc: costs_lib.PassCosts) -> tuple[float, float]:
        a = self.node.accel
        n = self.node.n_accel
        t_c = pc.flops / (n * a.peak_flops * a.flops_efficiency)
        t_m = pc.hbm_bytes / (n * a.hbm_bw * a.bw_efficiency)
        t = max(t_c, t_m) + self.node.dispatch_overhead_s
        e = (a.idle_w * n * t
             + a.j_per_flop * pc.flops
             + a.j_per_byte_hbm * pc.hbm_bytes)
        return t, e

    def simulate(self, tau_in: int, tau_out: int) -> PhaseBreakdown:
        cfg, B = self.cfg, self.batch
        # prefill over the prompt
        pc = costs_lib.pass_costs(cfg, tau_in, tau_in, B)
        t_pre, e_pre = self._pass_time_energy(pc)

        t_dec = 0.0
        e_dec = 0.0
        if self.kv_cache:
            # one single-token pass per output token, growing context
            step = self.decode_chunk
            for t0 in range(0, tau_out, step):
                n_steps = min(step, tau_out - t0)
                ctx = tau_in + t0 + n_steps / 2.0
                pc = costs_lib.pass_costs(cfg, 1, ctx, B)
                t1, e1 = self._pass_time_energy(pc)
                t_dec += t1 * n_steps
                e_dec += e1 * n_steps
        else:
            # paper mode: re-run the full prefix for every generated token
            step = self.decode_chunk
            for t0 in range(0, tau_out, step):
                n_steps = min(step, tau_out - t0)
                L = tau_in + t0 + n_steps / 2.0
                pc = costs_lib.pass_costs(cfg, L, L, B)
                t1, e1 = self._pass_time_energy(pc)
                t_dec += t1 * n_steps
                e_dec += e1 * n_steps

        # host-side energy over the whole request (paper's EPYC uProf term)
        h = self.node.host
        host_w = h.idle_w / 4.0 + h.active_w_per_core * h.serving_cores
        e_host = host_w * (t_pre + t_dec)
        return PhaseBreakdown(t_pre, t_dec, e_pre, e_dec, e_host)

    def measure(self, tau_in: int, tau_out: int) -> tuple[float, float]:
        pb = self.simulate(tau_in, tau_out)
        noise = math.exp(self.rng.normal(0.0, self.noise_sigma))
        noise2 = math.exp(self.rng.normal(0.0, self.noise_sigma))
        return pb.energy_j * noise, pb.runtime_s * noise2

    # per-query (batch-normalized) versions used by the scheduler case study
    def measure_per_query(self, tau_in: int, tau_out: int) -> tuple[float, float]:
        e, r = self.measure(tau_in, tau_out)
        return e / self.batch, r / self.batch
