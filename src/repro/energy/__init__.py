"""Energy substrate: hardware specs, meters, and the analytic simulator."""

from repro.energy.costs import (  # noqa: F401
    PassCosts,
    PassCostsBatch,
    decode_step_polys,
    kv_bytes_per_token,
    pass_costs,
    pass_costs_batch,
)
from repro.energy.hardware import (  # noqa: F401
    A100_40GB,
    EPYC_7742,
    GENERIC_HOST,
    Node,
    SWING_NODE,
    TPU_NODE,
    TPU_V5E,
    min_accelerators,
)
from repro.energy.meter import ModeledMeter, WallClockMeter  # noqa: F401
from repro.energy.simulator import AnalyticLLMSimulator, PhaseBreakdown  # noqa: F401
