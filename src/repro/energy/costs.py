"""Analytic per-pass FLOP/byte cost model for every architecture family.

`pass_costs(cfg, new_tokens, context, batch)` returns the FLOPs and HBM
bytes of one forward pass that processes `new_tokens` positions per
sequence against `context` total attended positions.  This is the
structural cost surface the energy simulator integrates over a request —
deliberately richer than the paper's bilinear e_K (quadratic attention
terms, MoE router overhead, constant-state SSM), so fitting Eq. 6/7 against
it is a real test of the paper's model form.
"""

from __future__ import annotations

import dataclasses

from repro.models import active_params, get_api
from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class PassCosts:
    flops: float
    hbm_bytes: float

    def __add__(self, other: "PassCosts") -> "PassCosts":
        return PassCosts(self.flops + other.flops, self.hbm_bytes + other.hbm_bytes)


def _dtype_bytes(cfg: ModelConfig) -> int:
    return 2 if cfg.param_dtype == "bfloat16" else 4


def jnp_dtype_bytes(name: str) -> int:
    import numpy as np
    import jax.numpy as jnp
    return jnp.dtype(name).itemsize


def kv_bytes_per_token(cfg: ModelConfig) -> float:
    """Cache bytes written per token per layer-stack (all layers)."""
    b = jnp_dtype_bytes(cfg.cache_dtype) if cfg.cache_dtype else _dtype_bytes(cfg)
    if cfg.family == "ssm":
        return 0.0  # constant-size state, no per-token growth
    if cfg.use_mla:
        return cfg.n_layers * (cfg.kv_lora_rank + cfg.qk_rope_dim) * b
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // max(1, len(cfg.block_pattern))
        return n_attn * 2 * cfg.n_kv_heads * cfg.head_dim_ * b
    n_layers = cfg.dec_layers if cfg.family == "encdec" else cfg.n_layers
    return n_layers * 2 * cfg.n_kv_heads * cfg.head_dim_ * b


def _attention_flops(cfg: ModelConfig, new_tokens: float, context: float,
                     batch: float) -> float:
    """Score + weighted-value FLOPs for all attention layers."""
    if cfg.family == "ssm":
        # SSD: intra-chunk quadratic within chunk + state updates, ~linear
        H, P, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
        per_tok = 2 * H * P * N * 4  # B·x outer product, C·h, decay, gather
        return cfg.n_layers * batch * new_tokens * per_tok
    heads = cfg.n_heads
    hd = cfg.head_dim_
    if cfg.use_mla:
        hd = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // max(1, len(cfg.block_pattern))
        ctx = min(context, cfg.local_window or context)
        return n_attn * batch * 4 * heads * hd * new_tokens * ctx
    n_layers = cfg.dec_layers if cfg.family == "encdec" else cfg.n_layers
    ctx = context
    if cfg.window:
        ctx = min(context, cfg.window)
    flops = n_layers * batch * 4 * heads * hd * new_tokens * ctx
    if cfg.family == "encdec":
        # cross attention into n_frames memory
        flops += cfg.dec_layers * batch * 4 * heads * hd * new_tokens * cfg.n_frames
    return flops


def router_overhead_flops(cfg: ModelConfig, new_tokens: float, batch: float) -> float:
    """MoE routing: logits + top-k + dispatch bookkeeping (the 'added
    runtime and energy overhead' of §5.2)."""
    if cfg.family != "moe":
        return 0.0
    nm = cfg.n_layers - cfg.n_dense_layers
    return nm * batch * new_tokens * (2 * cfg.d_model * cfg.n_experts
                                      + 32 * cfg.n_experts)


def pass_costs(cfg: ModelConfig, new_tokens: float, context: float,
               batch: float, *, include_weights: bool = True) -> PassCosts:
    """One forward pass: `new_tokens` positions/sequence, `context` attended."""
    b = _dtype_bytes(cfg)
    n_active = active_params(cfg)
    tokens = batch * new_tokens

    flops = 2.0 * n_active * tokens
    flops += _attention_flops(cfg, new_tokens, context, batch)
    flops += router_overhead_flops(cfg, new_tokens, batch)

    bytes_ = 0.0
    if include_weights:
        api = get_api(cfg)
        bytes_ += api.count_params(cfg) * b if cfg.family != "moe" else _moe_weight_bytes(cfg, tokens, b)
    # activations: ~12 d_model reads/writes per token per layer
    bytes_ += cfg.n_layers * tokens * cfg.d_model * 12 * b
    # cache traffic: write new tokens, read full context per new token (decode)
    kvb = kv_bytes_per_token(cfg)
    bytes_ += tokens * kvb
    if new_tokens <= 2:  # decode-like pass: read the whole cache
        ctx = context
        if cfg.family == "hybrid":
            ctx = min(context, cfg.local_window or context)
        elif cfg.window:
            ctx = min(context, cfg.window)
        bytes_ += batch * ctx * kvb
        if cfg.family == "ssm":
            ssm_state_bytes = (cfg.n_layers * cfg.ssm_nheads * cfg.ssm_headdim
                               * cfg.ssm_state * 4)
            bytes_ += batch * 2 * ssm_state_bytes
    return PassCosts(flops=flops, hbm_bytes=bytes_)


def _moe_weight_bytes(cfg: ModelConfig, tokens: float, b: int) -> float:
    """MoE weight traffic: non-expert weights once + experts actually hit.
    With many tokens every expert is touched; with few (decode), only
    ~tokens*top_k experts stream in."""
    api = get_api(cfg)
    total = api.count_params(cfg)
    de = cfg.d_expert or cfg.d_ff
    nm = cfg.n_layers - cfg.n_dense_layers
    per_expert = 3 * cfg.d_model * de
    routed = nm * cfg.n_experts * per_expert
    base = total - routed
    hit = min(float(cfg.n_experts), tokens * cfg.top_k)
    return (base + nm * hit * per_expert) * b
