"""Analytic per-pass FLOP/byte cost model for every architecture family.

`pass_costs(cfg, new_tokens, context, batch)` returns the FLOPs and HBM
bytes of one forward pass that processes `new_tokens` positions per
sequence against `context` total attended positions.  This is the
structural cost surface the energy simulator integrates over a request —
deliberately richer than the paper's bilinear e_K (quadratic attention
terms, MoE router overhead, constant-state SSM), so fitting Eq. 6/7 against
it is a real test of the paper's model form.

Two fast entry points back the vectorized engine:

  * `pass_costs_batch` — the same surface evaluated over numpy arrays of
    (new_tokens, context, batch) in one shot (used by
    `AnalyticLLMSimulator.measure_batch` and the perf suite);
  * `decode_step_polys` — the per-decode-step cost as an explicit
    piecewise polynomial in the absolute context length L.  Within a
    piece the surface is a polynomial of degree ≤ 2 (attention is
    new_tokens·context, everything else is affine), with breakpoints only
    at the attention-window clamp and the MoE expert-saturation point, so
    Σ_L over a decode phase has an exact power-sum closed form — this is
    what replaces the midpoint-chunk loop in
    `AnalyticLLMSimulator.decode_cost`.

Decode-vs-prefill is an explicit `decode` kwarg (threaded from
`prefill_cost`/`decode_cost`): the old `new_tokens <= 2` heuristic
misclassified genuine τin ≤ 2 prefills as decode-like passes and charged
them a full-cache read.  `decode=None` keeps the heuristic for legacy
direct callers.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.models import active_params, get_api
from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class PassCosts:
    flops: float
    hbm_bytes: float

    def __add__(self, other: "PassCosts") -> "PassCosts":
        return PassCosts(self.flops + other.flops, self.hbm_bytes + other.hbm_bytes)


@dataclasses.dataclass(frozen=True)
class PassCostsBatch:
    """Elementwise FLOPs/bytes for a batch of passes (numpy arrays)."""

    flops: np.ndarray
    hbm_bytes: np.ndarray


def _dtype_bytes(cfg: ModelConfig) -> int:
    return 2 if cfg.param_dtype == "bfloat16" else 4


# dtype-name -> itemsize, resolved once per dtype (kv_bytes_per_token is on
# the hot path; re-importing jax.numpy per call was measurable).
_DTYPE_ITEMSIZE: dict[str, int] = {}


def jnp_dtype_bytes(name: str) -> int:
    b = _DTYPE_ITEMSIZE.get(name)
    if b is None:
        import jax.numpy as jnp

        b = int(jnp.dtype(name).itemsize)
        _DTYPE_ITEMSIZE[name] = b
    return b


def kv_bytes_per_token(cfg: ModelConfig) -> float:
    """Cache bytes written per token per layer-stack (all layers)."""
    b = jnp_dtype_bytes(cfg.cache_dtype) if cfg.cache_dtype else _dtype_bytes(cfg)
    if cfg.family == "ssm":
        return 0.0  # constant-size state, no per-token growth
    if cfg.use_mla:
        return cfg.n_layers * (cfg.kv_lora_rank + cfg.qk_rope_dim) * b
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // max(1, len(cfg.block_pattern))
        return n_attn * 2 * cfg.n_kv_heads * cfg.head_dim_ * b
    n_layers = cfg.dec_layers if cfg.family == "encdec" else cfg.n_layers
    return n_layers * 2 * cfg.n_kv_heads * cfg.head_dim_ * b


def attention_window(cfg: ModelConfig) -> float:
    """The context clamp applied to attention reads/FLOPs (inf = unclamped)."""
    if cfg.family == "hybrid":
        return float(cfg.local_window) if cfg.local_window else float("inf")
    return float(cfg.window) if cfg.window else float("inf")


def _attention_flops(cfg: ModelConfig, new_tokens, context, batch):
    """Score + weighted-value FLOPs for all attention layers.  Array-generic:
    every operand may be a scalar or a broadcastable numpy array (the one
    implementation serves both `pass_costs` and `pass_costs_batch`)."""
    if cfg.family == "ssm":
        # SSD: intra-chunk quadratic within chunk + state updates, ~linear
        H, P, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
        per_tok = 2 * H * P * N * 4  # B·x outer product, C·h, decay, gather
        return cfg.n_layers * batch * new_tokens * per_tok
    heads = cfg.n_heads
    hd = cfg.head_dim_
    if cfg.use_mla:
        hd = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // max(1, len(cfg.block_pattern))
        ctx = np.minimum(context, cfg.local_window) if cfg.local_window else context
        return n_attn * batch * 4 * heads * hd * new_tokens * ctx
    n_layers = cfg.dec_layers if cfg.family == "encdec" else cfg.n_layers
    ctx = np.minimum(context, cfg.window) if cfg.window else context
    flops = n_layers * batch * 4 * heads * hd * new_tokens * ctx
    if cfg.family == "encdec":
        # cross attention into n_frames memory
        flops = flops + (cfg.dec_layers * batch * 4 * heads * hd
                         * new_tokens * cfg.n_frames)
    return flops


def router_overhead_flops(cfg: ModelConfig, new_tokens, batch):
    """MoE routing: logits + top-k + dispatch bookkeeping (the 'added
    runtime and energy overhead' of §5.2).  Array-generic."""
    if cfg.family != "moe":
        return 0.0
    nm = cfg.n_layers - cfg.n_dense_layers
    return nm * batch * new_tokens * (2 * cfg.d_model * cfg.n_experts
                                      + 32 * cfg.n_experts)


def _decode_cache_read_bytes(cfg: ModelConfig, context, batch, kvb: float):
    """HBM bytes of an incremental decode step's cache read (the whole
    attended context, window-clamped) plus SSM state traffic.  Array-generic."""
    if cfg.family == "hybrid":
        ctx = np.minimum(context, cfg.local_window) if cfg.local_window else context
    elif cfg.window:
        ctx = np.minimum(context, cfg.window)
    else:
        ctx = context
    bytes_ = batch * ctx * kvb
    if cfg.family == "ssm":
        ssm_state_bytes = (cfg.n_layers * cfg.ssm_nheads * cfg.ssm_headdim
                           * cfg.ssm_state * 4)
        bytes_ = bytes_ + batch * 2 * ssm_state_bytes
    return bytes_


def pass_costs(cfg: ModelConfig, new_tokens: float, context: float,
               batch: float, *, include_weights: bool = True,
               decode: bool | None = None) -> PassCosts:
    """One forward pass: `new_tokens` positions/sequence, `context` attended.

    `decode=True` charges the full-cache read of an incremental decode
    step; `decode=False` is a prefill-style pass (no existing cache).
    `decode=None` falls back to the legacy `new_tokens <= 2` heuristic for
    direct callers that predate the explicit flag.
    """
    if decode is None:
        decode = new_tokens <= 2
    b = _dtype_bytes(cfg)
    n_active = active_params(cfg)
    tokens = batch * new_tokens

    flops = 2.0 * n_active * tokens
    flops += _attention_flops(cfg, new_tokens, context, batch)
    flops += router_overhead_flops(cfg, new_tokens, batch)

    bytes_ = 0.0
    if include_weights:
        api = get_api(cfg)
        bytes_ += api.count_params(cfg) * b if cfg.family != "moe" else _moe_weight_bytes(cfg, tokens, b)
    # activations: ~12 d_model reads/writes per token per layer
    bytes_ += cfg.n_layers * tokens * cfg.d_model * 12 * b
    # cache traffic: write new tokens, read full context per new token (decode)
    kvb = kv_bytes_per_token(cfg)
    bytes_ += tokens * kvb
    if decode:  # incremental decode pass: read the whole cache
        bytes_ += _decode_cache_read_bytes(cfg, context, batch, kvb)
    return PassCosts(flops=float(flops), hbm_bytes=float(bytes_))


def pass_costs_batch(cfg: ModelConfig, new_tokens, context, batch, *,
                     include_weights: bool = True,
                     decode: bool = False) -> PassCostsBatch:
    """Vectorized `pass_costs` over broadcastable arrays of
    (new_tokens, context, batch).  `decode` applies to the whole batch
    (mixed prefill/decode batches are two calls).  Shares the array-generic
    term helpers with the scalar path, so the two can never drift."""
    nt = np.asarray(new_tokens, dtype=np.float64)
    ctx_in = np.asarray(context, dtype=np.float64)
    bt = np.asarray(batch, dtype=np.float64)
    nt, ctx_in, bt = np.broadcast_arrays(nt, ctx_in, bt)

    b = _dtype_bytes(cfg)
    n_active = active_params(cfg)
    tokens = bt * nt

    flops = 2.0 * n_active * tokens
    flops = flops + _attention_flops(cfg, nt, ctx_in, bt)
    flops = flops + router_overhead_flops(cfg, nt, bt)

    bytes_ = np.zeros_like(tokens)
    if include_weights:
        api = get_api(cfg)
        if cfg.family != "moe":
            bytes_ = bytes_ + api.count_params(cfg) * b
        else:
            bytes_ = bytes_ + _moe_weight_bytes(cfg, tokens, b)
    bytes_ = bytes_ + cfg.n_layers * tokens * cfg.d_model * 12 * b
    kvb = kv_bytes_per_token(cfg)
    bytes_ = bytes_ + tokens * kvb
    if decode:
        bytes_ = bytes_ + _decode_cache_read_bytes(cfg, ctx_in, bt, kvb)
    return PassCostsBatch(flops=flops, hbm_bytes=bytes_)


def _moe_weight_bytes(cfg: ModelConfig, tokens, b: int):
    """MoE weight traffic: non-expert weights once + experts actually hit.
    With many tokens every expert is touched; with few (decode), only
    ~tokens*top_k experts stream in.  Array-generic."""
    api = get_api(cfg)
    total = api.count_params(cfg)
    de = cfg.d_expert or cfg.d_ff
    nm = cfg.n_layers - cfg.n_dense_layers
    per_expert = 3 * cfg.d_model * de
    routed = nm * cfg.n_experts * per_expert
    base = total - routed
    hit = np.minimum(float(cfg.n_experts), tokens * cfg.top_k)
    return (base + nm * hit * per_expert) * b


# ---------------------------------------------------------------------------
# Closed-form decode integration support
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StepPolySegment:
    """Per-decode-step cost on L ∈ [lo, hi] as exact degree-≤2 polynomials
    in u = L − lo: poly(u) = c0 + c1·u + c2·u²."""

    lo: float
    hi: float
    flops: tuple[float, float, float]
    hbm_bytes: tuple[float, float, float]


def _interp_quadratic(y0: float, y1: float, y2: float,
                      h: float) -> tuple[float, float, float]:
    """Coefficients in u of the unique degree-≤2 polynomial through
    (0, y0), (h, y1), (2h, y2)."""
    c0 = y0
    c1 = (-3.0 * y0 + 4.0 * y1 - y2) / (2.0 * h)
    c2 = (y0 - 2.0 * y1 + y2) / (2.0 * h * h)
    return c0, c1, c2


def decode_step_breakpoints(cfg: ModelConfig, batch: float, *,
                            reprefix: bool) -> list[float]:
    """Context lengths where the per-step decode cost changes polynomial
    piece: the attention-window clamp, and (re-prefix mode only) the MoE
    expert-saturation point tokens·top_k = n_experts."""
    bps: list[float] = []
    w = attention_window(cfg)
    if np.isfinite(w):
        bps.append(w)
    if reprefix and cfg.family == "moe" and cfg.top_k and batch > 0:
        bps.append(cfg.n_experts / (batch * cfg.top_k))
    return sorted(set(bps))


def decode_step_polys(cfg: ModelConfig, batch: float, lo: float, hi: float, *,
                      reprefix: bool,
                      include_weights: bool = True) -> list[StepPolySegment]:
    """Exact piecewise-polynomial form of the per-step decode cost over
    L ∈ [lo, hi].

    reprefix=False (KV cache on): one single-token pass attending L context.
    reprefix=True (the paper's no-cache mode): the full L-token prefix is
    re-run for each generated token — a prefill-style pass of L new tokens.

    The cost surface is continuous and polynomial (degree ≤ 2 in L) between
    breakpoints, so interpolating through 3 points of each piece recovers
    it exactly; keeping this derived from `pass_costs` itself (rather than
    re-deriving coefficients per family) means the closed form can never
    drift from the reference surface.
    """
    if hi < lo:
        raise ValueError(f"need hi >= lo, got [{lo}, {hi}]")

    def step(L: float) -> PassCosts:
        if reprefix:
            return pass_costs(cfg, L, L, batch,
                              include_weights=include_weights, decode=False)
        return pass_costs(cfg, 1.0, L, batch,
                          include_weights=include_weights, decode=True)

    if hi == lo:  # degenerate single-point range
        pc = step(lo)
        return [StepPolySegment(lo, hi, (pc.flops, 0.0, 0.0),
                                (pc.hbm_bytes, 0.0, 0.0))]

    bounds = [lo] + [b for b in decode_step_breakpoints(cfg, batch,
                                                        reprefix=reprefix)
                     if lo < b < hi] + [hi]
    segs: list[StepPolySegment] = []
    for s0, s1 in zip(bounds, bounds[1:]):
        h = (s1 - s0) / 2.0
        p0, p1, p2 = step(s0), step(s0 + h), step(s1)
        segs.append(StepPolySegment(
            lo=s0, hi=s1,
            flops=_interp_quadratic(p0.flops, p1.flops, p2.flops, h),
            hbm_bytes=_interp_quadratic(p0.hbm_bytes, p1.hbm_bytes,
                                        p2.hbm_bytes, h),
        ))
    return segs
