"""Energy meters — the PyJoules/uProf adaptation layer (paper §3.2).

`WallClockMeter` measures real wall time of JAX computations on this host
and converts to joules with the host power model (the AMD-uProf method:
power-per-active-core x time).  `ModeledMeter` instead charges an analytic
roofline energy for a declared cost, for use where wall time on CPU is not
representative of the target accelerator.

Both expose  measure(fn) -> (result, seconds, joules)  — the engine's
metering contract.
"""

from __future__ import annotations

import time

import jax

from repro.energy.hardware import GENERIC_HOST, HostSpec, Node


class WallClockMeter:
    """E = P·t with P from the host spec (cores actively serving)."""

    def __init__(self, host: HostSpec = GENERIC_HOST):
        self.host = host
        self.total_s = 0.0
        self.total_j = 0.0

    @property
    def power_w(self) -> float:
        return self.host.idle_w / 4.0 + self.host.active_w_per_core * self.host.serving_cores

    def measure(self, fn):
        t0 = time.perf_counter()
        out = fn()
        out = jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        joules = self.power_w * dt
        self.total_s += dt
        self.total_j += joules
        return out, dt, joules


class ModeledMeter:
    """Wall time measured; energy charged from a per-call cost estimate
    produced by `cost_fn() -> (flops, bytes)` against a Node power model."""

    def __init__(self, node: Node, cost_fn):
        self.node = node
        self.cost_fn = cost_fn
        self.total_s = 0.0
        self.total_j = 0.0

    def measure(self, fn):
        t0 = time.perf_counter()
        out = fn()
        out = jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        flops, bytes_ = self.cost_fn()
        a = self.node.accel
        joules = (a.idle_w * self.node.n_accel * dt
                  + a.j_per_flop * flops + a.j_per_byte_hbm * bytes_)
        self.total_s += dt
        self.total_j += joules
        return out, dt, joules
